"""AOT path tests: lowering produces loadable HLO text + accurate manifest.

Keeps shapes tiny — full-size artifacts are built by `make artifacts`, and the
Rust runtime integration test executes them for numeric agreement.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels import ref
from compile.pack import pack_hrpb, pad_to_bucket


def test_to_hlo_text_contains_entry():
    lowered, _ = aot.lower_dense_mm(8, 8, 8)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text


def test_hrpb_lowering_embeds_gather_and_dots():
    lowered, args = aot.lower_hrpb_spmm(nb=8, mp=2, k=64, n=16)
    text = aot.to_hlo_text(lowered)
    assert "gather" in text        # B-row gather survived lowering
    assert "dot" in text           # brick MMAs
    assert "scatter" in text or "reduce" in text or "add" in text


def test_manifest_matches_written_files(tmp_path):
    out = str(tmp_path / "arts")
    man = aot.build_all(out, quick=True)
    with open(os.path.join(out, "manifest.json")) as fh:
        disk = json.load(fh)
    assert disk == man
    for e in man["artifacts"]:
        p = os.path.join(out, e["file"])
        assert os.path.exists(p) and os.path.getsize(p) > 0
        for a in e["args"]:
            assert a["dtype"] in ("float32", "int32")


def test_lowered_hrpb_executes_correctly():
    """Round-trip inside python: compile the lowered module and compare to the
    dense oracle — the same check the Rust side repeats through PJRT."""
    nb, mp, k, n = 16, 3, 96, 8
    m = mp * 16
    rng = np.random.default_rng(2)
    a = np.where(rng.random((m, k)) < 0.1,
                 rng.standard_normal((m, k)), 0.0).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    blocks, cols, pids, mp_got = pack_hrpb(a)
    assert mp_got == mp
    blocks, cols, pids = pad_to_bucket(blocks, cols, pids, nb)

    lowered, _ = aot.lower_hrpb_spmm(nb, mp, k, n)
    compiled = lowered.compile()
    (got,) = compiled(jnp.asarray(blocks), jnp.asarray(cols),
                      jnp.asarray(pids), jnp.asarray(b))
    want = ref.spmm_dense(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
