"""L1 correctness: Pallas brick-MMA kernel vs pure-jnp oracle.

hypothesis sweeps block counts, tile shapes, widths, densities and value
regimes; every property asserts allclose against einsum ground truth.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.hrpb_spmm import (
    BRICK_K,
    brick_mma,
    brick_mma_jnp,
    tf32_round,
)

jax.config.update("jax_enable_x64", False)


def _rand(shape, rng, density=1.0, scale=1.0):
    x = rng.standard_normal(shape).astype(np.float32) * scale
    if density < 1.0:
        mask = rng.random(shape) < density
        x = np.where(mask, x, 0.0).astype(np.float32)
    return x


@pytest.mark.parametrize("nb", [1, 3, 8])
@pytest.mark.parametrize("n", [8, 32, 128])
def test_brick_mma_matches_einsum_basic(nb, n):
    rng = np.random.default_rng(7 * nb + n)
    blocks = _rand((nb, 16, 16), rng)
    bsub = _rand((nb, 16, n), rng)
    got = brick_mma(jnp.asarray(blocks), jnp.asarray(bsub))
    want = brick_mma_jnp(jnp.asarray(blocks), jnp.asarray(bsub))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    nb=st.integers(1, 6),
    tk_bricks=st.integers(1, 8),
    n=st.sampled_from([8, 16, 32, 64]),
    density=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_brick_mma_property_shapes_density(nb, tk_bricks, n, density, seed):
    """Kernel == oracle over random shapes (TK any brick multiple), sparse
    blocks, arbitrary widths — the hypothesis sweep required by the spec."""
    tk = tk_bricks * BRICK_K
    rng = np.random.default_rng(seed)
    blocks = _rand((nb, 16, tk), rng, density=density)
    bsub = _rand((nb, tk, n), rng)
    got = brick_mma(jnp.asarray(blocks), jnp.asarray(bsub))
    want = brick_mma_jnp(jnp.asarray(blocks), jnp.asarray(bsub))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    scale=st.sampled_from([1e-20, 1e-6, 1.0, 1e6, 1e18]),
    seed=st.integers(0, 2**31 - 1),
)
def test_brick_mma_value_regimes(scale, seed):
    """Extreme magnitudes must not diverge from the oracle (no fast-math
    reassociation surprises in interpret mode)."""
    rng = np.random.default_rng(seed)
    blocks = _rand((2, 16, 16), rng, scale=scale)
    bsub = _rand((2, 16, 32), rng, scale=scale)
    got = np.asarray(brick_mma(jnp.asarray(blocks), jnp.asarray(bsub)))
    want = np.asarray(brick_mma_jnp(jnp.asarray(blocks), jnp.asarray(bsub)))
    # products are O(scale^2); allow rounding noise at that magnitude for
    # near-cancelling sums where relative error is meaningless
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * float(scale) ** 2 * 16)


def test_brick_mma_zero_blocks_give_zero():
    blocks = jnp.zeros((4, 16, 16), jnp.float32)
    bsub = jnp.ones((4, 16, 32), jnp.float32)
    out = brick_mma(blocks, bsub)
    assert float(jnp.abs(out).max()) == 0.0


def test_brick_mma_identity_blocks_copy_b():
    eye = jnp.tile(jnp.eye(16, dtype=jnp.float32)[None], (3, 1, 1))
    rng = np.random.default_rng(0)
    bsub = jnp.asarray(_rand((3, 16, 24), rng))
    out = brick_mma(eye, bsub)
    np.testing.assert_allclose(np.asarray(out), np.asarray(bsub), rtol=1e-6)


def test_brick_mma_rejects_mismatched_tk():
    blocks = jnp.zeros((1, 16, 16), jnp.float32)
    bsub = jnp.zeros((1, 12, 8), jnp.float32)
    with pytest.raises(AssertionError):
        brick_mma(blocks, bsub)


class TestTf32Round:
    def test_exact_small_ints_preserved(self):
        x = jnp.asarray([0.0, 1.0, -2.0, 1024.0], jnp.float32)
        np.testing.assert_array_equal(np.asarray(tf32_round(x)), np.asarray(x))

    def test_mantissa_truncated_to_10_bits(self):
        x = jnp.asarray([1.0 + 2.0**-12], jnp.float32)  # below TF32 ulp
        assert float(tf32_round(x)[0]) == 1.0

    def test_relative_error_bound(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal(1024).astype(np.float32))
        r = np.asarray(tf32_round(x))
        rel = np.abs(r - np.asarray(x)) / np.maximum(np.abs(np.asarray(x)), 1e-30)
        assert rel.max() <= 2.0**-10  # half-ulp of a 10-bit mantissa

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_idempotent(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(128).astype(np.float32) * 100)
        once = tf32_round(x)
        twice = tf32_round(once)
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
