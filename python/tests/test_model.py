"""L2 correctness: exported models vs dense ground truth, via the real packer."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import dense_mm, gcn_layer, hrpb_spmm
from compile.pack import TM, pack_hrpb, pad_to_bucket


def _rand_sparse(m, k, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    mask = rng.random((m, k)) < density
    return np.where(mask, a, 0.0).astype(np.float32)


def _run_model_vs_dense(m, k, n, density, seed, pad=0):
    a = _rand_sparse(m, k, density, seed)
    rng = np.random.default_rng(seed + 1)
    b = rng.standard_normal((k, n)).astype(np.float32)
    blocks, cols, pids, mp = pack_hrpb(a)
    if pad:
        blocks, cols, pids = pad_to_bucket(blocks, cols, pids,
                                           blocks.shape[0] + pad)
    (c,) = hrpb_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                     jnp.asarray(pids), jnp.asarray(b), num_panels=mp)
    want = ref.spmm_dense(jnp.asarray(a), jnp.asarray(b))
    got = np.asarray(c)[:m]
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(4, 160),
    n=st.sampled_from([8, 32, 64]),
    density=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_hrpb_spmm_matches_dense(m, k, n, density, seed):
    _run_model_vs_dense(m, k, n, density, seed)


def test_hrpb_spmm_bucket_padding_is_inert():
    _run_model_vs_dense(48, 96, 32, 0.2, 11, pad=17)


def test_hrpb_spmm_matches_ref_path():
    a = _rand_sparse(64, 128, 0.15, 5)
    b = np.random.default_rng(6).standard_normal((128, 32)).astype(np.float32)
    blocks, cols, pids, mp = pack_hrpb(a)
    (c,) = hrpb_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                     jnp.asarray(pids), jnp.asarray(b), num_panels=mp)
    want = ref.hrpb_spmm_ref(jnp.asarray(blocks), jnp.asarray(cols),
                             jnp.asarray(pids), jnp.asarray(b), mp)
    np.testing.assert_allclose(np.asarray(c), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gcn_layer_matches_dense_ref():
    nodes, fin, fout = 48, 24, 16
    a = _rand_sparse(nodes, nodes, 0.1, 3)
    rng = np.random.default_rng(4)
    x = rng.standard_normal((nodes, fin)).astype(np.float32)
    w = rng.standard_normal((fin, fout)).astype(np.float32)
    blocks, cols, pids, mp = pack_hrpb(a)
    (h,) = gcn_layer(jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(pids),
                     jnp.asarray(x), jnp.asarray(w), num_panels=mp)
    want = ref.gcn_layer_ref(jnp.asarray(a), jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(h)[:nodes], np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_dense_mm_model():
    rng = np.random.default_rng(9)
    a = rng.standard_normal((32, 48)).astype(np.float32)
    b = rng.standard_normal((48, 16)).astype(np.float32)
    (c,) = dense_mm(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=1e-5, atol=1e-5)


def test_coo_second_opinion():
    """numpy COO oracle agrees with the jax dense oracle (oracle sanity)."""
    a = _rand_sparse(40, 60, 0.1, 8)
    rows, cols = np.nonzero(a)
    vals = a[rows, cols]
    b = np.random.default_rng(10).standard_normal((60, 8)).astype(np.float32)
    got = ref.spmm_coo(rows, cols, vals, 40, b)
    want = np.asarray(ref.spmm_dense(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
