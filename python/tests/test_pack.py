"""Packing contract tests: compile/pack.py (the host-side HRPB dense-brick
packer feeding PJRT) — round-trip, compaction, pattern encoding, alpha."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.pack import (
    BRICK_K,
    BRICK_M,
    TK,
    TM,
    alpha_density,
    brick_patterns,
    pack_hrpb,
    pad_to_bucket,
)


def _rand_sparse(m, k, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    mask = rng.random((m, k)) < density
    return np.where(mask, a, 0.0).astype(np.float32)


def _unpack(blocks, active_cols, panel_ids, m, k):
    """Reverse the packer: scatter block values back to a dense matrix."""
    out = np.zeros(((m + TM - 1) // TM * TM, k), dtype=np.float32)
    for blk, cols, pid in zip(blocks, active_cols, panel_ids):
        for j, c in enumerate(cols):
            col_vals = blk[:, j]
            if np.any(col_vals != 0.0):
                out[pid * TM : (pid + 1) * TM, c] += col_vals
    return out[:m]


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 200),
    density=st.floats(0.0, 0.4),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(m, k, density, seed):
    a = _rand_sparse(m, k, density, seed)
    blocks, cols, pids, np_ = pack_hrpb(a)
    assert np_ == (m + TM - 1) // TM
    got = _unpack(blocks, cols, pids, m, k)
    np.testing.assert_array_equal(got, a)


def test_compaction_only_active_columns_occupy_slots():
    a = np.zeros((16, 64), np.float32)
    a[3, 10] = 1.0
    a[5, 50] = 2.0
    blocks, cols, pids, _ = pack_hrpb(a)
    assert blocks.shape[0] == 1  # 2 active cols -> one block
    assert set(cols[0][:2].tolist()) == {10, 50}
    # slots beyond the active columns are zero-padded
    assert np.all(blocks[0][:, 2:] == 0.0)


def test_empty_panel_produces_no_block():
    a = np.zeros((48, 32), np.float32)
    a[0, 0] = 1.0  # only panel 0 active
    blocks, cols, pids, np_ = pack_hrpb(a)
    assert np_ == 3
    assert set(pids.tolist()) == {0}


def test_all_zero_matrix_yields_single_inert_block():
    a = np.zeros((16, 16), np.float32)
    blocks, cols, pids, np_ = pack_hrpb(a)
    assert blocks.shape[0] == 1 and np.all(blocks == 0)


def test_pad_to_bucket_appends_inert_blocks():
    a = _rand_sparse(32, 64, 0.1, 1)
    blocks, cols, pids, _ = pack_hrpb(a)
    nb0 = blocks.shape[0]
    b2, c2, p2 = pad_to_bucket(blocks, cols, pids, nb0 + 5)
    assert b2.shape[0] == nb0 + 5
    assert np.all(b2[nb0:] == 0.0) and np.all(p2[nb0:] == 0)
    with pytest.raises(ValueError):
        pad_to_bucket(blocks, cols, pids, nb0 - 1)


def test_brick_pattern_bit_positions():
    blk = np.zeros((1, TM, TK), np.float32)
    blk[0, 0, 0] = 1.0        # brick (0,0), bit 0
    blk[0, 1, 2] = 1.0        # brick (0,0), bit 1*4+2 = 6
    blk[0, 0, 5] = 1.0        # brick (0,1), bit 0*4+(5-4) = 1
    pats = brick_patterns(blk)
    assert pats[0, 0, 0] == (1 << 0) | (1 << 6)
    assert pats[0, 0, 1] == (1 << 1)
    assert pats[0, 0, 2] == 0


def test_alpha_density_full_and_single():
    full = np.ones((1, TM, TK), np.float32)
    assert alpha_density(full) == 1.0
    one = np.zeros((1, TM, TK), np.float32)
    one[0, 0, 0] = 1.0
    assert alpha_density(one) == pytest.approx(1.0 / (BRICK_M * BRICK_K))


@settings(max_examples=20, deadline=None)
@given(density=st.floats(0.02, 0.9), seed=st.integers(0, 2**31 - 1))
def test_alpha_at_least_column_floor(density, seed):
    """Every active brick column has >= 1 nonzero, so alpha >= 1/16 on any
    packed matrix (the paper's section 6.4 lower bound)."""
    a = _rand_sparse(64, 128, density, seed)
    if not np.any(a):
        return
    blocks, _, _, _ = pack_hrpb(a)
    assert alpha_density(blocks) >= 1.0 / BRICK_M - 1e-9
