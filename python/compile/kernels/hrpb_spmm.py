"""L1 Pallas kernel: the cuTeSpMM brick-MMA hot spot, adapted GPU -> TPU.

The paper's Algorithm 1 (per thread block): stage one packed HRPB block of the
sparse A and the gathered rows of dense B in shared memory, then loop over the
TK/brick_k brick columns issuing 16x4 @ 4x8 tensor-core MMAs, accumulating C
in registers.

TPU adaptation (DESIGN.md section "Hardware-Adaptation"): the per-lane
pattern-popcount decode has no MXU equivalent, so decode happens at pack time
(see compile/pack.py) and the kernel consumes zero-filled [TM, TK] blocks.
The HBM<->shared-memory schedule becomes a BlockSpec HBM<->VMEM schedule: the
grid iterates over packed blocks; each program stages one A block and its
gathered [TK, N] B panel in VMEM and walks brick columns feeding MXU-shaped
dots, with the C tile VMEM-resident — a faithful mirror of Algorithm 1's
loop structure (lines 14-41).

interpret=True is mandatory on this CPU-only image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BRICK_M = 16
BRICK_K = 4
BRICK_N = 8


def _brick_mma_kernel(a_ref, b_ref, o_ref, *, brick_k: int):
    """One grid step == one packed HRPB block (paper: one thread-block step).

    a_ref: [TM, TK] zero-filled sparse block (VMEM; paper's SM_A)
    b_ref: [TK, N] gathered dense rows      (VMEM; paper's SM_B)
    o_ref: [TM, N] output tile              (VMEM; paper's c_frag)
    """
    tm, tk = a_ref.shape
    n = b_ref.shape[1]
    acc = jnp.zeros((tm, n), dtype=jnp.float32)
    # Paper Algorithm 1 line 25: loop over the TK/brick_k brick columns. Each
    # iteration is one MXU-shaped contraction ([TM, brick_k] @ [brick_k, N]),
    # the TPU image of the WMMA 16x4x8 issue. The loop is fully unrolled at
    # trace time exactly as the CUDA kernel unrolls it (TK, brick_k static).
    for i in range(tk // brick_k):
        a_brick = a_ref[:, i * brick_k : (i + 1) * brick_k]
        b_brick = b_ref[i * brick_k : (i + 1) * brick_k, :]
        acc += jnp.dot(a_brick, b_brick, preferred_element_type=jnp.float32)
    o_ref[...] = acc


def brick_mma(blocks: jax.Array, bsub: jax.Array, *, brick_k: int = BRICK_K,
              interpret: bool = True) -> jax.Array:
    """Batched brick MMA over all packed blocks.

    blocks: f32[NB, TM, TK]   bsub: f32[NB, TK, N]  ->  f32[NB, TM, N]

    Grid = (NB,): program b stages block b + its B panel in VMEM. VMEM
    footprint per program (TM=16, TK=16, N=128): 1 KiB + 8 KiB + 8 KiB, far
    below TPU VMEM, leaving headroom for the pipeline's double buffering.
    """
    nb, tm, tk = blocks.shape
    _, tk2, n = bsub.shape
    assert tk == tk2, f"block TK {tk} != B panel TK {tk2}"
    assert tk % brick_k == 0, f"TK {tk} not a multiple of brick_k {brick_k}"
    kernel = functools.partial(_brick_mma_kernel, brick_k=brick_k)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((None, tm, tk), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, tk, n), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, tm, n), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, tm, n), jnp.float32),
        interpret=interpret,
    )(blocks, bsub)


def brick_mma_jnp(blocks: jax.Array, bsub: jax.Array) -> jax.Array:
    """Pure-jnp equivalent of `brick_mma` (einsum over the batch); used as the
    in-graph fallback and by the test oracle."""
    return jnp.einsum(
        "bmk,bkn->bmn", blocks, bsub, preferred_element_type=jnp.float32
    )


def tf32_round(x: jax.Array) -> jax.Array:
    """Round f32 to TF32 precision (10-bit mantissa, round-to-nearest-even on
    the 13 dropped bits) — the input rounding the A100 tensor core applies.
    Used by tests to bound the numeric gap the paper's TF32 path would add."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    # round-half-to-even at bit 13
    lsb = (bits >> 13) & 1
    rounded = bits + 0xFFF + lsb
    masked = rounded & jnp.uint32(0xFFFFE000)
    return jax.lax.bitcast_convert_type(masked, jnp.float32)
