"""L1: Pallas kernels for the SpMM hot spot + pure-jnp oracles."""

from .hrpb_spmm import brick_mma, brick_mma_jnp  # noqa: F401
from . import ref  # noqa: F401
