"""Pure-jnp correctness oracles for the L1 kernel and L2 model.

Everything here is deliberately naive and obviously-correct; pytest compares
the Pallas kernel and the exported models against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def spmm_dense(a_dense: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with A materialized dense — the ground truth."""
    return jnp.dot(a_dense, b, preferred_element_type=jnp.float32)


def spmm_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
             m: int, b: np.ndarray) -> np.ndarray:
    """COO SpMM in numpy (no jax): independent second opinion for tests."""
    c = np.zeros((m, b.shape[1]), dtype=np.float64)
    for r, k, v in zip(rows, cols, vals):
        c[r] += v * b[k].astype(np.float64)
    return c.astype(np.float32)


def hrpb_spmm_ref(blocks, active_cols, panel_ids, b, num_panels: int):
    """Reference HRPB SpMM: gather + einsum + segment-sum, no Pallas.

    Shapes per the pack contract in compile/pack.py. Returns f32[num_panels*TM, N].
    """
    tm = blocks.shape[1]
    n = b.shape[1]
    bsub = b[active_cols]  # [NB, TK, N] gather
    parts = jnp.einsum("bmk,bkn->bmn", blocks, bsub,
                       preferred_element_type=jnp.float32)
    c = jax.ops.segment_sum(parts, panel_ids, num_segments=num_panels)
    return c.reshape(num_panels * tm, n)


def gcn_layer_ref(a_dense, x, w):
    """One GCN propagation layer: relu(A @ (X @ W)) with dense A."""
    return jax.nn.relu(jnp.dot(a_dense, jnp.dot(x, w)))
