"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT lowering.

Nothing in this package runs on the request path; `aot.py` is invoked once by
`make artifacts` and emits HLO text artifacts that the Rust runtime loads via
PJRT.
"""
