"""HRPB dense-brick packing (host side, numpy).

This is the *PJRT feeding* form of the paper's HRPB structure: the paper's GPU
kernel decodes 64-bit brick patterns into registers on the fly (Algorithm 1,
lines 33-38); a TPU/MXU has no per-lane ballot/popcount, so the decode happens
at pack time and the kernel consumes zero-filled dense blocks. The compaction
step — only columns with at least one nonzero inside a row panel occupy block
slots — is identical to the paper's, so the operation count fed to the MMA
unit matches the paper's active-brick count.

Pack layout (the contract shared with `rust/src/hrpb/decode.rs`):

  blocks      f32[NB, TM, TK]  zero-filled values, block b holds rows of row
                               panel `panel_ids[b]` restricted to the block's
                               active columns
  active_cols i32[NB, TK]      original column ids of each block slot
                               (padding slots -> 0 with zero values)
  panel_ids   i32[NB]          owning row panel of each block
  B           f32[K, N]        dense operand

  C[p*TM + r, :] = sum over blocks b with panel_ids[b] == p of
                     blocks[b] @ B[active_cols[b], :]

Padding blocks (to reach a shape bucket's NB) are all-zero with
panel_ids = 0, so they contribute nothing.
"""

from __future__ import annotations

import numpy as np

TM = 16
TK = 16
BRICK_M = 16
BRICK_K = 4
BRICK_N = 8


def pack_hrpb(a_dense: np.ndarray, tm: int = TM, tk: int = TK):
    """Pack a dense 2-D array into HRPB dense-brick form.

    Returns (blocks, active_cols, panel_ids, num_panels). Rows are padded to a
    multiple of `tm`; empty panels produce no blocks.
    """
    m, k = a_dense.shape
    num_panels = (m + tm - 1) // tm
    blocks = []
    cols_out = []
    pids = []
    for p in range(num_panels):
        r0, r1 = p * tm, min((p + 1) * tm, m)
        panel = np.zeros((tm, k), dtype=np.float32)
        panel[: r1 - r0] = a_dense[r0:r1]
        active = np.nonzero(np.any(panel != 0.0, axis=0))[0]
        if active.size == 0:
            continue
        nblk = (active.size + tk - 1) // tk
        for b in range(nblk):
            sl = active[b * tk : (b + 1) * tk]
            blk = np.zeros((tm, tk), dtype=np.float32)
            cols = np.zeros((tk,), dtype=np.int32)
            blk[:, : sl.size] = panel[:, sl]
            cols[: sl.size] = sl
            blocks.append(blk)
            cols_out.append(cols)
            pids.append(p)
    if not blocks:  # fully-zero matrix: one padding block keeps shapes valid
        blocks = [np.zeros((tm, tk), dtype=np.float32)]
        cols_out = [np.zeros((tk,), dtype=np.int32)]
        pids = [0]
    return (
        np.stack(blocks).astype(np.float32),
        np.stack(cols_out).astype(np.int32),
        np.asarray(pids, dtype=np.int32),
        num_panels,
    )


def pad_to_bucket(blocks, active_cols, panel_ids, nb: int):
    """Pad the packed arrays out to a shape bucket's NB with inert blocks."""
    cur = blocks.shape[0]
    if cur > nb:
        raise ValueError(f"packed NB={cur} exceeds bucket NB={nb}")
    if cur == nb:
        return blocks, active_cols, panel_ids
    pad = nb - cur
    blocks = np.concatenate([blocks, np.zeros((pad,) + blocks.shape[1:], np.float32)])
    active_cols = np.concatenate(
        [active_cols, np.zeros((pad, active_cols.shape[1]), np.int32)]
    )
    panel_ids = np.concatenate([panel_ids, np.zeros((pad,), np.int32)])
    return blocks, active_cols, panel_ids


def brick_patterns(blocks: np.ndarray) -> np.ndarray:
    """64-bit nonzero patterns of each (BRICK_M, BRICK_K) brick, row-major bit
    order — the paper's Figure 3(b) encoding. Used by tests to cross-check the
    Rust packer's pattern arithmetic."""
    nb, tm, tk = blocks.shape
    rows = tm // BRICK_M
    cols = tk // BRICK_K
    out = np.zeros((nb, rows, cols), dtype=np.uint64)
    for b in range(nb):
        for i in range(rows):
            for j in range(cols):
                brick = blocks[b, i * BRICK_M : (i + 1) * BRICK_M, j * BRICK_K : (j + 1) * BRICK_K]
                bits = np.uint64(0)
                for r in range(BRICK_M):
                    for c in range(BRICK_K):
                        if brick[r, c] != 0.0:
                            bits |= np.uint64(1) << np.uint64(r * BRICK_K + c)
                out[b, i, j] = bits
    return out


def alpha_density(blocks: np.ndarray) -> float:
    """Average nonzero density of *active* bricks (the paper's alpha)."""
    pats = brick_patterns(blocks)
    counts = np.array([bin(int(p)).count("1") for p in pats.flatten()])
    active = counts[counts > 0]
    if active.size == 0:
        return 0.0
    return float(active.mean()) / (BRICK_M * BRICK_K)
