"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Each model is a pure function over fixed-shape arrays (one HLO artifact per
shape bucket — see aot.py and rust/src/runtime/bucket.rs, which must agree on
the bucket list and the argument order below).

Artifact ABI (all models):
  hrpb_spmm   (blocks f32[NB,TM,TK], active_cols i32[NB,TK],
               panel_ids i32[NB], B f32[K,N]) -> (C f32[MP*TM, N],)
  gcn_layer   (blocks, active_cols, panel_ids, X f32[K,F], W f32[F,N])
              -> (H f32[MP*TM, N],)
  dense_mm    (A f32[M,K], B f32[K,N]) -> (C f32[M,N],)

Outputs are 1-tuples because aot.py lowers with return_tuple=True (the xla
crate unwraps with to_tuple1 — see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.hrpb_spmm import brick_mma


def hrpb_spmm(blocks, active_cols, panel_ids, b, *, num_panels: int,
              interpret: bool = True):
    """HRPB SpMM: gather B rows per block, Pallas brick MMA, segment-sum
    partials into row panels. C is produced panel-major and reshaped.

    Padding blocks (all-zero, panel 0) contribute exact zeros, so a bucketed
    artifact computes the same C as an exact-shape one.
    """
    tm = blocks.shape[1]
    n = b.shape[1]
    bsub = b[active_cols]  # XLA gather: [NB, TK, N]
    parts = brick_mma(blocks, bsub, interpret=interpret)  # [NB, TM, N]
    c = jax.ops.segment_sum(parts, panel_ids, num_segments=num_panels)
    return (c.reshape(num_panels * tm, n),)


def gcn_layer(blocks, active_cols, panel_ids, x, w, *, num_panels: int,
              interpret: bool = True):
    """One GCN layer: H = relu(A_hat @ (X @ W)) with A_hat in HRPB form.

    The dense feature transform X@W stays in the same artifact so XLA fuses
    the whole layer; the sparse propagation reuses the hrpb_spmm path.
    """
    xw = jnp.dot(x, w, preferred_element_type=jnp.float32)
    (c,) = hrpb_spmm(blocks, active_cols, panel_ids, xw,
                     num_panels=num_panels, interpret=interpret)
    return (jax.nn.relu(c),)


def dense_mm(a, b):
    """Dense matmul artifact — used by the runtime self-check and as the
    dense baseline the examples validate against."""
    return (jnp.dot(a, b, preferred_element_type=jnp.float32),)


def model_fns():
    """Name -> (fn, needs_num_panels) registry used by aot.py."""
    return {
        "hrpb_spmm": (hrpb_spmm, True),
        "gcn_layer": (gcn_layer, True),
        "dense_mm": (dense_mm, False),
    }
