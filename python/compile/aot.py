"""AOT lowering: JAX models -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Run from python/:  python -m compile.aot --out-dir ../artifacts

Shape buckets here MUST agree with rust/src/runtime/bucket.rs. Each artifact
is named  <model>__nb<NB>_mp<MP>_k<K>_n<N>.hlo.txt  and listed in
manifest.json together with its argument shapes so the Rust registry can
validate feeds without parsing HLO.
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import dense_mm, gcn_layer, hrpb_spmm

TM = 16
TK = 16

# (NB, MP, K, N) buckets for hrpb_spmm. Chosen to cover the example workloads
# (quickstart / gnn_layer / end_to_end) with modest CPU compile time; larger
# corpora use the native Rust engine instead of PJRT.
SPMM_BUCKETS = [
    (256, 32, 512, 32),
    (256, 32, 512, 128),
    (1024, 128, 2048, 32),
    (1024, 128, 2048, 128),
    (4096, 192, 4096, 32),
    (4096, 192, 4096, 128),
]

# (NB, MP, K, F, N) buckets for gcn_layer (K = #nodes, F = in features,
# N = out features). cora-scale: 2708 nodes -> MP=170 panels, F 1433 -> 1440.
GCN_BUCKETS = [
    (2048, 176, 2816, 1440, 32),
    (2048, 176, 2816, 64, 32),
]

# (M, K, N) buckets for the dense reference matmul.
DENSE_BUCKETS = [
    (256, 256, 128),
    (2816, 1440, 64),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_hrpb_spmm(nb, mp, k, n):
    fn = partial(hrpb_spmm, num_panels=mp, interpret=True)
    args = (
        _spec((nb, TM, TK), jnp.float32),
        _spec((nb, TK), jnp.int32),
        _spec((nb,), jnp.int32),
        _spec((k, n), jnp.float32),
    )
    return jax.jit(fn).lower(*args), args


def lower_gcn_layer(nb, mp, k, f, n):
    fn = partial(gcn_layer, num_panels=mp, interpret=True)
    args = (
        _spec((nb, TM, TK), jnp.float32),
        _spec((nb, TK), jnp.int32),
        _spec((nb,), jnp.int32),
        _spec((k, f), jnp.float32),
        _spec((f, n), jnp.float32),
    )
    return jax.jit(fn).lower(*args), args


def lower_dense_mm(m, k, n):
    args = (_spec((m, k), jnp.float32), _spec((k, n), jnp.float32))
    return jax.jit(dense_mm).lower(*args), args


def _arg_manifest(args):
    return [{"shape": list(a.shape), "dtype": a.dtype.name} for a in args]


def build_all(out_dir: str, quick: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    spmm_buckets = SPMM_BUCKETS[:2] if quick else SPMM_BUCKETS
    gcn_buckets = [] if quick else GCN_BUCKETS
    dense_buckets = DENSE_BUCKETS[:1] if quick else DENSE_BUCKETS

    for nb, mp, k, n in spmm_buckets:
        name = f"hrpb_spmm__nb{nb}_mp{mp}_k{k}_n{n}"
        lowered, args = lower_hrpb_spmm(nb, mp, k, n)
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entries.append({
            "name": name, "model": "hrpb_spmm", "file": name + ".hlo.txt",
            "nb": nb, "mp": mp, "k": k, "n": n, "tm": TM, "tk": TK,
            "args": _arg_manifest(args),
            "out_shape": [mp * TM, n],
        })
        print(f"  wrote {name}")

    for nb, mp, k, f, n in gcn_buckets:
        name = f"gcn_layer__nb{nb}_mp{mp}_k{k}_f{f}_n{n}"
        lowered, args = lower_gcn_layer(nb, mp, k, f, n)
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entries.append({
            "name": name, "model": "gcn_layer", "file": name + ".hlo.txt",
            "nb": nb, "mp": mp, "k": k, "f": f, "n": n, "tm": TM, "tk": TK,
            "args": _arg_manifest(args),
            "out_shape": [mp * TM, n],
        })
        print(f"  wrote {name}")

    for m, k, n in dense_buckets:
        name = f"dense_mm__m{m}_k{k}_n{n}"
        lowered, args = lower_dense_mm(m, k, n)
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entries.append({
            "name": name, "model": "dense_mm", "file": name + ".hlo.txt",
            "m": m, "k": k, "n": n,
            "args": _arg_manifest(args),
            "out_shape": [m, n],
        })
        print(f"  wrote {name}")

    manifest = {"tm": TM, "tk": TK, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"manifest: {len(entries)} artifacts -> {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small bucket subset (CI / tests)")
    args = ap.parse_args()
    build_all(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
