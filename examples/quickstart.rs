//! Quickstart: build a sparse matrix, pack it into HRPB, run SpMM on the
//! native engine, verify against the dense oracle, and print the paper's
//! synergy/OI diagnostics.
//!
//! ```
//! cargo run --release --example quickstart
//! ```

use cutespmm::formats::{Coo, Dense};
use cutespmm::gpumodel::{algos, Machine, MatrixProfile};
use cutespmm::spmm::Algo;
use cutespmm::util::rng::Rng;

fn main() {
    // 1. a small banded matrix (Emilia-like clustering at toy scale)
    let mut t = Vec::new();
    let mut rng = Rng::new(42);
    let n_rows = 24_576; // above the paper's 10k-row evaluation cutoff
    for r in 0..n_rows {
        for d in 0..12usize {
            let c = (r + d).min(n_rows - 1);
            if rng.chance(0.7) {
                t.push((r, c, rng.nz_value()));
            }
        }
    }
    let a = Coo::from_triplets(n_rows, n_rows, &t);
    println!("A: {}x{} nnz={} (density {:.4}%)", a.rows, a.cols, a.nnz(), 100.0 * a.density());

    // 2. preprocess: HRPB pack (done once, amortized over many SpMMs — §6.3)
    let engine = Algo::Hrpb.prepare(&a);
    let hrpb = cutespmm::hrpb::build_from_coo(&a);
    let stats = cutespmm::hrpb::stats::compute(&hrpb);
    println!(
        "HRPB: {} blocks, {} bricks, alpha={:.3} -> synergy {}",
        stats.num_blocks,
        stats.num_bricks,
        stats.alpha,
        cutespmm::synergy::Synergy::from_alpha(stats.alpha).name()
    );

    // 3. SpMM against a random dense B
    let b = Dense::random(a.cols, 128, &mut rng);
    let t0 = std::time::Instant::now();
    let c = engine.spmm(&b);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "C = A @ B: {}x{} in {:.3} ms ({:.2} GFLOP/s useful)",
        c.rows,
        c.cols,
        dt * 1e3,
        engine.flops(128) / dt / 1e9
    );

    // 4. verify against an independent engine (dense oracle is too big here)
    let want = Algo::Csr.prepare(&a).spmm(&b);
    let err = c.rel_fro_error(&want);
    println!("verification vs CSR engine: rel fro error = {err:.2e}");
    assert!(err < 1e-5);

    // 5. what the paper's analytical model says this matrix would do on GPUs
    let p = MatrixProfile::compute(&a);
    for m in [Machine::a100(), Machine::rtx4090()] {
        let cute = algos::predict(Algo::Hrpb, &p, 128, &m);
        let (best_algo, best) = algos::predict_best_sc(&p, 128, &m);
        println!(
            "[{}] modeled: cuTeSpMM {:.0} GFLOPs vs best-SC({}) {:.0} GFLOPs -> {:.2}x",
            m.name,
            cute.gflops,
            best_algo.name(),
            best.gflops,
            cute.gflops / best.gflops
        );
    }
    println!("quickstart OK");
}
