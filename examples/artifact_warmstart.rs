//! Artifact warm start: persist preprocessed HRPB artifacts, then simulate a
//! node restart and watch registration skip the rebuild.
//!
//! ```text
//! cargo run --release --example artifact_warmstart
//! ```
//!
//! §6.3 argues HRPB preprocessing amortizes over many SpMM invocations.
//! Without persistence, a restart of a node serving thousands of registered
//! matrices re-pays every build — a cold-start storm. This example runs the
//! same registration twice against one artifact directory: the first
//! coordinator builds (in parallel) and persists, the second warm-starts
//! from disk. Both serve bit-correct results.

use cutespmm::coordinator::{Config, Coordinator};
use cutespmm::formats::Dense;
use cutespmm::gen::{Family, MatrixSpec};
use cutespmm::util::rng::Rng;
use std::time::Instant;

fn zoo() -> Vec<MatrixSpec> {
    vec![
        MatrixSpec {
            name: "fem-band".into(),
            rows: 16_384,
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
            seed: 11,
        },
        MatrixSpec {
            name: "mesh2d".into(),
            rows: 16_384,
            family: Family::Mesh { dims: 2 },
            seed: 12,
        },
        MatrixSpec {
            name: "social-rmat".into(),
            rows: 8_192,
            family: Family::Rmat { edge_factor: 8, skew: 0.57 },
            seed: 13,
        },
    ]
}

fn run_generation(label: &str, dir: &std::path::Path) -> f64 {
    let coord = Coordinator::start(
        Config { workers: 2, artifact_dir: Some(dir.to_path_buf()), ..Default::default() },
        None,
    );
    let t0 = Instant::now();
    let mut ids = Vec::new();
    let mut matrices = Vec::new();
    for spec in zoo() {
        let coo = spec.generate();
        ids.push(coord.register(&spec.name, &coo));
        matrices.push(coo);
    }
    let reg_s = t0.elapsed().as_secs_f64();
    println!("[{label}] registered {} matrices in {:.2} ms", ids.len(), reg_s * 1e3);
    for (id, coo) in ids.iter().zip(&matrices) {
        let entry = coord.registry().get(*id).unwrap();
        println!(
            "[{label}]   {:<12} nnz={:<8} preprocess {:.2} ms",
            entry.name,
            entry.nnz,
            entry.preprocess_time.as_secs_f64() * 1e3
        );
        // one request per matrix proves the warm path serves correctly
        let b = Dense::random(coo.cols, 8, &mut Rng::new(99));
        let resp = coord.call(*id, b).expect("serve");
        assert_eq!(resp.c.rows, coo.rows);
    }
    println!("[{label}] {}", coord.metrics().report());
    coord.shutdown();
    reg_s
}

fn main() {
    let dir = std::env::temp_dir().join(format!("cutespmm_warmstart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let cold = run_generation("cold start", &dir);
    println!();
    let warm = run_generation("warm start", &dir);
    println!();
    println!(
        "registration: cold {:.2} ms -> warm {:.2} ms ({:.1}x faster; artifacts in {})",
        cold * 1e3,
        warm * 1e3,
        cold / warm.max(1e-12),
        dir.display()
    );
    let _ = std::fs::remove_dir_all(&dir);
}
