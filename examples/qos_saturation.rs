//! QoS saturation demo: flood the coordinator's bounded admission layer
//! with mixed-priority traffic over a cheap and an expensive matrix, and
//! watch it shed load with typed rejections instead of growing an unbounded
//! queue.
//!
//! ```text
//! cargo run --release --example qos_saturation
//! ```
//!
//! The deterministic three-policy comparison (unbounded vs reject-on-full
//! vs QoS) lives in `cutespmm experiment qos`; this driver exercises the
//! real threaded serving path.

use cutespmm::coordinator::{BatchPolicy, Config, Coordinator, EnginePolicy};
use cutespmm::formats::{Coo, Dense};
use cutespmm::qos::{Priority, QosConfig, RejectReason};
use cutespmm::util::rng::Rng;
use std::time::{Duration, Instant};

fn main() {
    let qos = QosConfig {
        queue_capacity: 32,
        watermark_s: 2e-3,
        default_deadline: Some(Duration::from_millis(250)),
    };
    println!(
        "qos: capacity={} watermark={:.1}ms default_deadline={}ms",
        qos.queue_capacity,
        qos.watermark_s * 1e3,
        qos.default_deadline.unwrap().as_millis()
    );
    let coord = Coordinator::start(
        Config {
            workers: 2,
            engine: EnginePolicy::Native,
            batch: BatchPolicy::default(),
            qos: Some(qos),
            ..Default::default()
        },
        None,
    );

    let mut rng = Rng::new(7);
    let cheap = Coo::random(512, 512, 0.02, &mut rng);
    let heavy = Coo::random(4096, 4096, 0.01, &mut rng);
    let cheap_id = coord.register("cheap", &cheap);
    let heavy_id = coord.register("heavy", &heavy);
    for id in [cheap_id, heavy_id] {
        let e = coord.registry().get(id).unwrap();
        println!(
            "registered {}: {}x{} nnz={} synergy={} predicted {:.2} us/col",
            e.name,
            e.rows,
            e.cols,
            e.nnz,
            e.synergy.name(),
            e.cost_s_per_col * 1e6
        );
    }

    println!("\nflooding: 400 requests, alternating matrices, every 4th high-priority ...");
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut shed = [0u64; RejectReason::COUNT];
    for i in 0..400usize {
        let (id, b_rows) = if i % 2 == 0 { (cheap_id, 512) } else { (heavy_id, 4096) };
        let b = Dense::random(b_rows, 8, &mut rng);
        let priority = if i % 4 == 0 { Priority::High } else { Priority::Normal };
        match coord.submit_qos(id, b, priority, None) {
            Ok(rx) => rxs.push(rx),
            Err((rejected, _b)) => shed[rejected.reason.index()] += 1,
        }
    }
    let (mut served, mut failed) = (0u64, 0u64);
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(_)) => served += 1,
            _ => failed += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("served={served} failed={failed} in {wall:.3}s ({:.0} req/s)", served as f64 / wall);
    for reason in RejectReason::all() {
        if shed[reason.index()] > 0 {
            println!("shed at admission ({}): {}", reason.name(), shed[reason.index()]);
        }
    }
    println!("{}", coord.metrics().report());
    coord.shutdown();
}
