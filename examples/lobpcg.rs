//! LOBPCG-style blocked eigensolve on a mesh Laplacian — the paper's §6.3
//! scientific-computing amortization case: ONE preprocessing, hundreds of
//! SpMM invocations.
//!
//! Simplified blocked power iteration with Rayleigh–Ritz-free orthonorm
//! (enough to exercise the SpMM-dominated loop structure of LOBPCG): find
//! the dominant eigenpairs of a 2-D Laplacian by repeated `V <- orth(A V)`.
//!
//! ```
//! cargo run --release --example lobpcg
//! ```

use cutespmm::formats::{Coo, Dense};
use cutespmm::gen::{Family, MatrixSpec};
use cutespmm::spmm::Algo;
use cutespmm::util::rng::Rng;
use cutespmm::util::timer::time_once;

/// Modified Gram-Schmidt orthonormalization of the columns of V.
fn orthonormalize(v: &mut Dense) {
    for j in 0..v.cols {
        // subtract projections on previous columns
        for k in 0..j {
            let mut dot = 0f64;
            for r in 0..v.rows {
                dot += (v[(r, j)] * v[(r, k)]) as f64;
            }
            for r in 0..v.rows {
                v[(r, j)] -= dot as f32 * v[(r, k)];
            }
        }
        let mut norm = 0f64;
        for r in 0..v.rows {
            norm += (v[(r, j)] * v[(r, j)]) as f64;
        }
        let norm = (norm.sqrt() as f32).max(1e-30);
        for r in 0..v.rows {
            v[(r, j)] /= norm;
        }
    }
}

/// Rayleigh quotients diag(Vᵀ A V) for converged eigenvalue estimates.
fn rayleigh(v: &Dense, av: &Dense) -> Vec<f64> {
    (0..v.cols)
        .map(|j| (0..v.rows).map(|r| (v[(r, j)] * av[(r, j)]) as f64).sum())
        .collect()
}

fn main() {
    // 2-D Laplacian (mesh) — SPD up to sign; dominant eigenvalues near 8
    let spec = MatrixSpec {
        name: "lap2d".into(),
        rows: 40_000,
        family: Family::Mesh { dims: 2 },
        seed: 11,
    };
    let lap: Coo = spec.generate();
    println!("A: {}x{} nnz={} (2-D Laplacian)", lap.rows, lap.cols, lap.nnz());

    // one-time preprocessing (the §6.3 overhead)
    let (engine, t_prep) = time_once(|| Algo::Hrpb.prepare(&lap));
    println!("HRPB preprocessing: {:.2} ms (paid once)", t_prep * 1e3);

    let block = 8; // eigenpair block size
    let iters = 150;
    let mut rng = Rng::new(5);
    let mut v = Dense::random(lap.rows, block, &mut rng);
    orthonormalize(&mut v);

    let t0 = std::time::Instant::now();
    let mut av = engine.spmm(&v);
    let mut total_spmm = 1usize;
    let mut eigs = Vec::new();
    for it in 0..iters {
        v = av;
        orthonormalize(&mut v);
        av = engine.spmm(&v);
        total_spmm += 1;
        if (it + 1) % 50 == 0 {
            eigs = rayleigh(&v, &av);
            println!(
                "iter {:>3}: leading Rayleigh quotients {:?}",
                it + 1,
                eigs.iter().take(4).map(|e| format!("{e:.4}")).collect::<Vec<_>>()
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let spmm_flops = engine.flops(block) * total_spmm as f64;
    println!(
        "{} SpMM invocations in {:.2} s ({:.2} GFLOP/s sustained on the SpMM path)",
        total_spmm,
        dt,
        spmm_flops / dt / 1e9
    );
    println!(
        "amortization: preprocessing / one-SpMM = {:.1}x, / whole solve = {:.4}x",
        t_prep / (dt / total_spmm as f64),
        t_prep / dt
    );
    // dominant eigenvalue of the 5-point Laplacian stencil approaches 8
    let lead = eigs.first().copied().unwrap_or(0.0).abs();
    assert!(lead > 4.0 && lead < 8.5, "unexpected dominant eigenvalue {lead}");
    println!("lobpcg OK (dominant |lambda| = {lead:.3})");
}
