//! Plan router: the synergy-driven planner end to end — rank engines per
//! matrix, serve a mixed model zoo under `EnginePolicy::Auto`, and show the
//! per-engine routing counters and observed-vs-predicted drift.
//!
//! ```text
//! cargo run --release --example plan_router [-- calibrate]
//! ```
//!
//! With `calibrate`, a micro-benchmark pass first rescales the analytical
//! model into this host's seconds, which arms the online feedback loop.

use cutespmm::coordinator::{Config, Coordinator, EnginePolicy};
use cutespmm::formats::Dense;
use cutespmm::gen::{Family, MatrixSpec};
use cutespmm::gpumodel::Machine;
use cutespmm::planner::Planner;
use cutespmm::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let planner = Arc::new(Planner::new(Machine::a100()));
    if std::env::args().any(|a| a == "calibrate") {
        println!("calibrating candidate engines on this host ...");
        let c = planner.calibrate(4096);
        for algo in cutespmm::planner::CANDIDATES {
            println!("  {:<10} model x {:.3e}", algo.name(), c.scale_for(algo));
        }
    }

    // a zoo spanning the synergy regimes: the planner should split it
    let zoo = vec![
        MatrixSpec {
            name: "fem-dense-band".into(),
            rows: 16_384,
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.0 },
            seed: 1,
        },
        MatrixSpec {
            name: "mesh2d".into(),
            rows: 16_384,
            family: Family::Mesh { dims: 2 },
            seed: 2,
        },
        MatrixSpec {
            name: "web-rmat".into(),
            rows: 8_192,
            family: Family::Rmat { edge_factor: 6, skew: 0.57 },
            seed: 3,
        },
        MatrixSpec {
            name: "chem-blockdiag".into(),
            rows: 8_192,
            family: Family::BlockDiag { unit: 24, unit_density: 0.3 },
            seed: 4,
        },
    ];

    let coord = Arc::new(Coordinator::start_with_planner(
        Config { workers: 4, engine: EnginePolicy::Auto, ..Default::default() },
        None,
        Some(planner.clone()),
    ));

    let mut ids = Vec::new();
    for spec in &zoo {
        let coo = spec.generate();
        let id = coord.register(&spec.name, &coo);
        let entry = coord.registry().get(id).unwrap();
        let plan = entry.plan.as_ref().expect("auto registration plans");
        println!(
            "{:<16} {:>7}x{:<7} nnz={:<8} alpha={:.3} {:<6} -> {:<8} ({})",
            entry.name,
            entry.rows,
            entry.cols,
            entry.nnz,
            plan.alpha,
            plan.synergy.name(),
            plan.engine.name(),
            plan.rationale
        );
        ids.push((id, coo.cols));
    }

    // mixed traffic: every matrix serves on its planned engine
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let coord = coord.clone();
            let ids = ids.clone();
            s.spawn(move || {
                let mut rng = Rng::new(100 + t);
                for i in 0..20 {
                    let (id, cols) = ids[(t as usize + i) % ids.len()];
                    let b = Dense::random(cols, 16, &mut rng);
                    let resp = coord.call(id, b).expect("request failed");
                    assert_eq!(resp.c.cols, 16);
                }
            });
        }
    });

    println!("\n{}", coord.metrics().report());
    println!("\nper-engine routing:");
    for lane in coord.metrics().engine_snapshot() {
        print!(
            "  {:<10} requests={:<4} batches={:<4} observed={:>8} us",
            lane.engine, lane.requests, lane.batches, lane.observed_us
        );
        if lane.predicted_us > 0 {
            println!("  predicted={:>8} us  drift={:.2}x", lane.predicted_us, lane.drift);
        } else {
            println!();
        }
    }
    let cache = planner.cache().stats();
    println!("\nplan cache: {} hits / {} misses", cache.hits, cache.misses);
    for d in planner.feedback().snapshot() {
        println!(
            "feedback {:<10} ratio={:.2} samples={} demoted={}",
            d.algo.name(),
            d.ratio,
            d.samples,
            d.demoted
        );
    }
    println!("plan_router OK");
}
