//! END-TO-END DRIVER — proves all layers compose (the validation run
//! recorded in EXPERIMENTS.md):
//!
//!   L1/L2 (build time): `make artifacts` lowered the Pallas brick kernel +
//!     JAX model to HLO text.
//!   Runtime: the Rust PJRT executor loads and runs those artifacts.
//!   L3: the coordinator serves batched SpMM traffic over both engines.
//!
//! The driver loads a small real workload (cora-scale GCN adjacency +
//! pubmed), serves batched requests through BOTH the native engine and the
//! PJRT artifact, cross-checks the numerics between them and against the
//! dense oracle, and reports latency/throughput for each path.
//!
//! ```
//! make artifacts && cargo run --release --example end_to_end
//! ```

use cutespmm::coordinator::{BatchPolicy, Config, Coordinator, EnginePolicy};
use cutespmm::formats::{Coo, Dense};
use cutespmm::gen::named;
use cutespmm::runtime;
use cutespmm::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

struct PathReport {
    engine: &'static str,
    requests: usize,
    wall_s: f64,
    p50_us: u64,
    p95_us: u64,
    served_gflop: f64,
}

fn drive(engine_policy: EnginePolicy, pjrt: Option<cutespmm::runtime::PjrtHandle>,
         matrices: &[(String, Coo)], requests_per_matrix: usize) -> (PathReport, Vec<Dense>) {
    let coord = Arc::new(Coordinator::start(
        Config {
            workers: 4,
            queue_capacity: 4096,
            batch: BatchPolicy {
                max_batch_cols: 128,
                max_batch_reqs: 8,
                max_delay: Duration::from_millis(1),
            },
            engine: engine_policy,
            qos: None,
            artifact_dir: None,
            ..Default::default()
        },
        pjrt,
    ));
    let ids: Vec<_> = matrices.iter().map(|(n, c)| coord.register(n, c)).collect();

    // deterministic request stream so both paths compute identical answers
    let t0 = std::time::Instant::now();
    let mut outputs = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (mi, (_, coo)) in matrices.iter().enumerate() {
            let coord = coord.clone();
            let id = ids[mi];
            let cols = coo.cols;
            handles.push(s.spawn(move || {
                let mut outs = Vec::new();
                let mut rxs = Vec::new();
                for i in 0..requests_per_matrix {
                    let mut rng = Rng::new((mi * 1000 + i) as u64);
                    let b = Dense::random(cols, 32, &mut rng);
                    rxs.push(coord.submit(id, b));
                }
                for rx in rxs {
                    let resp = rx.recv().unwrap().expect("request failed");
                    outs.push(resp.c);
                }
                outs
            }));
        }
        for h in handles {
            outputs.extend(h.join().unwrap());
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let m = coord.metrics();
    let report = PathReport {
        engine: match engine_policy {
            EnginePolicy::Native => "native",
            EnginePolicy::PreferPjrt => "pjrt",
            EnginePolicy::Auto => "auto",
        },
        requests: matrices.len() * requests_per_matrix,
        wall_s,
        p50_us: m.request_latency.percentile_us(50.0),
        p95_us: m.request_latency.percentile_us(95.0),
        served_gflop: m.flops() / 1e9,
    };
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    (report, outputs)
}

fn main() {
    // small real workloads: cora + pubmed citation graphs, scaled so the
    // AOT shape bucket stays in the (nb=1024, k=2048) class — the CPU
    // PJRT plugin interprets the Pallas kernel, so the largest bucket is
    // minutes-per-compile; the native engine serves full-size matrices.
    let matrices: Vec<(String, Coo)> = [("cora", 2usize), ("pubmed", 10)]
        .iter()
        .map(|&(n, scale)| {
            let spec = named::scaled(n, scale).unwrap();
            (spec.name.clone(), spec.generate())
        })
        .collect();
    for (n, c) in &matrices {
        println!("workload {n}: {}x{} nnz={}", c.rows, c.cols, c.nnz());
    }
    let reqs = 40;

    // path 1: native engine
    let (native, out_native) = drive(EnginePolicy::Native, None, &matrices, reqs);

    // path 2: PJRT artifacts (the full three-layer stack)
    let pjrt_available = runtime::artifacts_available();
    let (pjrt_report, out_pjrt) = if pjrt_available {
        let svc = runtime::PjrtService::start(runtime::default_artifacts_dir()).expect("pjrt");
        println!("PJRT platform: {}", svc.handle().platform().unwrap());
        let (r, o) = drive(EnginePolicy::PreferPjrt, Some(svc.handle()), &matrices, reqs);
        (Some(r), Some(o))
    } else {
        println!("! artifacts not built; run `make artifacts` for the PJRT path");
        (None, None)
    };

    // cross-check the two paths bit-for-shape
    if let Some(out_pjrt) = &out_pjrt {
        let mut max_err = 0.0f64;
        for (a, b) in out_native.iter().zip(out_pjrt) {
            max_err = max_err.max(a.rel_fro_error(b));
        }
        println!("native vs PJRT cross-check: max rel fro error = {max_err:.2e}");
        assert!(max_err < 1e-4, "engines disagree");
    }

    // oracle check on a sample
    {
        let (name, coo) = &matrices[0];
        let mut rng = Rng::new(0);
        let b = Dense::random(coo.cols, 32, &mut rng);
        let want = coo.to_dense().matmul(&b);
        assert!(out_native[0].rows == coo.rows, "{name} shape");
        let engine = cutespmm::spmm::Algo::Hrpb.prepare(coo);
        assert!(engine.spmm(&b).rel_fro_error(&want) < 1e-5);
    }

    println!("\n== end-to-end report ==");
    println!(
        "{:<8} {:>9} {:>10} {:>10} {:>10} {:>12}",
        "engine", "requests", "wall(s)", "p50(µs)", "p95(µs)", "GFLOP served"
    );
    for r in std::iter::once(&native).chain(pjrt_report.as_ref()) {
        println!(
            "{:<8} {:>9} {:>10.3} {:>10} {:>10} {:>12.2}",
            r.engine, r.requests, r.wall_s, r.p50_us, r.p95_us, r.served_gflop
        );
    }
    println!("end_to_end OK");
}
