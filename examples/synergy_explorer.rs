//! Synergy explorer: classify a generated corpus slice into the paper's
//! Table 1 classes, print Table 2-style counts, and show the Fig. 7
//! OI ↔ modeled-throughput correlation.
//!
//! ```
//! cargo run --release --example synergy_explorer [-- full]
//! ```

use cutespmm::bench::corpus_run;
use cutespmm::bench::render;
use cutespmm::gen::corpus::{specs, CorpusScale};
use cutespmm::spmm::Algo;
use cutespmm::util::stats;

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let scale = if full { CorpusScale::Full } else { CorpusScale::Quick };
    let all = specs(scale, 42);
    // explorer default: a fast slice of the quick corpus
    // stratified slice so every family/synergy regime is sampled
    let step = (all.len() / 40).max(1);
    let strided: Vec<_> = all.iter().cloned().step_by(step).collect();
    let slice: &[cutespmm::gen::MatrixSpec] = if full { &all } else { &strided };
    eprintln!("profiling {} matrices ...", slice.len());
    let records = corpus_run::run_specs(slice, &[128]);

    // Table 2-style counts
    let counts = corpus_run::synergy_counts(&records);
    let rows: Vec<Vec<String>> = counts
        .iter()
        .map(|&(s, c)| vec![s.name().to_string(), c.to_string()])
        .collect();
    println!("{}", render::table(&["Synergy", "# matrices"], &rows));

    // per-family alpha summary
    let mut fams: Vec<&str> = records.iter().map(|r| r.family).collect();
    fams.sort_unstable();
    fams.dedup();
    let mut frows = Vec::new();
    for fam in fams {
        let alphas: Vec<f64> =
            records.iter().filter(|r| r.family == fam).map(|r| r.alpha).collect();
        let bs = stats::box_stats(&alphas);
        frows.push(vec![
            fam.to_string(),
            alphas.len().to_string(),
            format!("{:.3}", bs.median),
            format!("{:.3}", bs.min),
            format!("{:.3}", bs.max),
        ]);
    }
    println!("{}", render::table(&["family", "count", "alpha p50", "min", "max"], &frows));

    // Fig. 7 correlation on this slice
    let (ois, gfs): (Vec<f64>, Vec<f64>) = records
        .iter()
        .filter_map(|r| r.get("A100", 128, Algo::Hrpb).map(|c| (512.0 * r.alpha, c.gflops)))
        .unzip();
    println!(
        "OI_shmem vs modeled cuTeSpMM GFLOPs (A100, N=128): pearson={:.3} spearman={:.3}",
        stats::pearson(&ois, &gfs),
        stats::spearman(&ois, &gfs)
    );
}
