//! GNN forward pass: a 2-layer GCN over a cora-like citation graph, with the
//! SpMM (Â · X) served by the coordinator — the paper's motivating workload.
//!
//! `H1 = ReLU(Â (X W0))`, `H2 = Â (H1 W1)`; Â is the degree-normalized
//! adjacency. Dense projections run locally; every sparse product goes
//! through the serving layer (PJRT artifact when available, native engine
//! otherwise).
//!
//! ```
//! cargo run --release --example gnn_layer [-- pjrt]
//! ```

use cutespmm::coordinator::{Config, Coordinator, EnginePolicy};
use cutespmm::formats::{Coo, Dense};
use cutespmm::runtime;
use cutespmm::util::rng::Rng;

/// Degree-normalized adjacency with self loops: D^{-1/2}(A + I)D^{-1/2}.
fn normalize(adj: &Coo) -> Coo {
    let mut with_loops = adj.clone();
    for i in 0..adj.rows {
        with_loops.push(i, i, 1.0);
    }
    with_loops.normalize();
    let deg = with_loops.row_counts();
    let inv_sqrt: Vec<f32> = deg.iter().map(|&d| 1.0 / (d.max(1) as f32).sqrt()).collect();
    let mut out = Coo::new(adj.rows, adj.cols);
    for i in 0..with_loops.nnz() {
        let (r, c) = (with_loops.row_idx[i] as usize, with_loops.col_idx[i] as usize);
        out.push(r, c, with_loops.values[i] * inv_sqrt[r] * inv_sqrt[c]);
    }
    out.normalize();
    out
}

fn relu(x: &mut Dense) {
    for v in &mut x.data {
        *v = v.max(0.0);
    }
}

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "pjrt");
    let mut rng = Rng::new(2708);

    // cora-scale graph: 2708 nodes, ~10k edges, 1433 features, 7 classes
    let nodes = 2708;
    let feats = 1433;
    let hidden = 32; // matches the n=32 AOT bucket so PJRT can serve layer 1
    let classes = 7;
    let spec = cutespmm::gen::named::by_name("cora").unwrap().spec;
    let adj = normalize(&spec.generate());
    println!("graph: {} nodes, {} normalized edges", nodes, adj.nnz());

    // serving layer
    let pjrt_svc = if use_pjrt && runtime::artifacts_available() {
        Some(runtime::PjrtService::start(runtime::default_artifacts_dir()).expect("pjrt"))
    } else {
        None
    };
    let engine =
        if pjrt_svc.is_some() { EnginePolicy::PreferPjrt } else { EnginePolicy::Native };
    let coord = Coordinator::start(
        Config { workers: 2, engine, ..Default::default() },
        pjrt_svc.as_ref().map(|s| s.handle()),
    );
    let gid = coord.register("cora-normalized", &adj);

    // parameters + features
    let x = Dense::random(nodes, feats, &mut rng);
    let w0 = Dense::random(feats, hidden, &mut rng);
    let w1 = Dense::random(hidden, classes, &mut rng);

    let t0 = std::time::Instant::now();
    // layer 1: XW0 locally (dense), Â(XW0) via the coordinator
    let xw0 = x.matmul(&w0);
    let resp1 = coord.call(gid, xw0).expect("layer-1 spmm");
    let mut h1 = resp1.c;
    relu(&mut h1);
    // layer 2
    let h1w1 = h1.matmul(&w1);
    let resp2 = coord.call(gid, h1w1).expect("layer-2 spmm");
    let logits = resp2.c;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "2-layer GCN forward in {:.2} ms (spmm engine: {}/{})",
        dt * 1e3,
        resp1.engine,
        resp2.engine
    );
    println!("logits: {}x{}", logits.rows, logits.cols);

    // verify against a local dense reference
    let dense_adj = adj.to_dense();
    let mut want_h1 = dense_adj.matmul(&x.matmul(&w0));
    relu(&mut want_h1);
    let want = dense_adj.matmul(&want_h1.matmul(&w1));
    let err = logits.rel_fro_error(&want);
    println!("verification vs dense reference: rel fro error = {err:.2e}");
    assert!(err < 1e-3, "GCN forward diverged: {err}");
    println!("{}", coord.metrics().report());
    coord.shutdown();
    println!("gnn_layer OK");
}
