//! SpMM server under concurrent load: start the coordinator, fire mixed
//! traffic against several registered matrices from many client threads,
//! and print the latency histogram + throughput report.
//!
//! ```
//! cargo run --release --example spmm_server [-- pjrt]
//! ```

use cutespmm::coordinator::{BatchPolicy, Config, Coordinator, EnginePolicy};
use cutespmm::formats::Dense;
use cutespmm::gen::named;
use cutespmm::runtime;
use cutespmm::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "pjrt");
    let pjrt_svc = if use_pjrt && runtime::artifacts_available() {
        Some(runtime::PjrtService::start(runtime::default_artifacts_dir()).expect("pjrt"))
    } else {
        None
    };
    let engine = if pjrt_svc.is_some() { EnginePolicy::PreferPjrt } else { EnginePolicy::Native };

    let coord = Arc::new(Coordinator::start(
        Config {
            workers: 4,
            queue_capacity: 4096,
            batch: BatchPolicy {
                max_batch_cols: 128,
                max_batch_reqs: 16,
                max_delay: Duration::from_millis(2),
            },
            engine,
            qos: None,
            artifact_dir: None,
            ..Default::default()
        },
        pjrt_svc.as_ref().map(|s| s.handle()),
    ));

    // register a small model zoo (scaled recipes keep the demo fast)
    let mut ids = Vec::new();
    for name in ["cora", "citeseer", "pubmed", "PROTEINS_full"] {
        let spec = named::scaled(name, if name == "PROTEINS_full" { 4 } else { 1 }).unwrap();
        let coo = spec.generate();
        let id = coord.register(&spec.name, &coo);
        let e = coord.registry().get(id).unwrap();
        println!(
            "registered {:<16} {}x{} nnz={} synergy={} prep={:.1}ms",
            e.name,
            e.rows,
            e.cols,
            e.nnz,
            e.synergy.name(),
            e.preprocess_time.as_secs_f64() * 1e3
        );
        ids.push((id, coo.cols));
    }

    // 8 client threads × 50 requests of mixed widths
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let coord = coord.clone();
            let ids = ids.clone();
            s.spawn(move || {
                let mut rng = Rng::new(1000 + t);
                for i in 0..50 {
                    let (id, cols) = ids[(t as usize + i) % ids.len()];
                    let n = [16, 32, 64][i % 3];
                    let b = Dense::random(cols, n, &mut rng);
                    let resp = coord.call(id, b).expect("request failed");
                    assert_eq!(resp.c.cols, n);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let m = coord.metrics();
    println!("\n400 requests over 4 matrices in {wall:.3} s ({:.0} req/s)", 400.0 / wall);
    println!("{}", m.report());
    println!("\nlatency histogram (µs upper bound -> count):");
    for (ub, count) in m.request_latency.snapshot() {
        println!("  <= {ub:>8} µs : {}", "#".repeat((count as usize).min(60)));
    }
    println!("spmm_server OK");
}
