//! Consistent-hash ring: maps matrix fingerprints to an ordered list of
//! distinct shards (primary first, replicas after).
//!
//! Virtual nodes smooth the key distribution (each shard owns many small
//! arcs instead of one big one), and consistent hashing keeps placements
//! stable: a matrix's primary never changes because an unrelated shard
//! was added — exactly the property that makes Acc-SpMM-style expensive
//! preprocessing artifacts worth replicating instead of rebuilding.

/// splitmix64 — the same mixer the fault layer and planner use for
/// deterministic, well-distributed hashing.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// A fixed-membership consistent-hash ring over `shards` shards.
pub struct Ring {
    /// (position, shard) pairs sorted by position.
    vnodes: Vec<(u64, usize)>,
    shards: usize,
}

impl Ring {
    /// Build a ring with `vnodes_per_shard` virtual nodes per shard.
    /// Positions are deterministic (pure function of shard index), so
    /// every router instance agrees on placement.
    pub fn new(shards: usize, vnodes_per_shard: usize) -> Ring {
        assert!(shards > 0, "a ring needs at least one shard");
        let per = vnodes_per_shard.max(1);
        let mut vnodes = Vec::with_capacity(shards * per);
        for s in 0..shards {
            for v in 0..per {
                let pos = splitmix64((s as u64) << 32 | v as u64);
                vnodes.push((pos, s));
            }
        }
        vnodes.sort_unstable();
        Ring { vnodes, shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The distinct shards owning `key`, in ring order: `[0]` is the
    /// primary, `[1]` the first replica, and so on — every shard appears
    /// exactly once.
    pub fn order(&self, key: u64) -> Vec<usize> {
        let pos = splitmix64(key);
        let start = self.vnodes.partition_point(|&(p, _)| p < pos);
        let mut out = Vec::with_capacity(self.shards);
        let mut seen = vec![false; self.shards];
        for i in 0..self.vnodes.len() {
            let (_, s) = self.vnodes[(start + i) % self.vnodes.len()];
            if !seen[s] {
                seen[s] = true;
                out.push(s);
                if out.len() == self.shards {
                    break;
                }
            }
        }
        out
    }

    /// The primary shard for `key`.
    pub fn primary(&self, key: u64) -> usize {
        self.order(key)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_lists_every_shard_exactly_once() {
        let ring = Ring::new(5, 16);
        for key in 0..200u64 {
            let mut order = ring.order(key * 0x9e3779b97f4a7c15);
            assert_eq!(order.len(), 5);
            order.sort_unstable();
            assert_eq!(order, vec![0, 1, 2, 3, 4]);
        }
    }

    #[test]
    fn placement_is_deterministic() {
        let a = Ring::new(4, 32);
        let b = Ring::new(4, 32);
        for key in 0..100u64 {
            assert_eq!(a.order(key), b.order(key));
        }
    }

    #[test]
    fn keys_spread_across_shards() {
        let ring = Ring::new(4, 32);
        let mut counts = [0usize; 4];
        for key in 0..4000u64 {
            counts[ring.primary(splitmix64(key))] += 1;
        }
        // with 32 vnodes/shard the spread is rough but no shard should be
        // starved or own a majority
        for (s, &c) in counts.iter().enumerate() {
            assert!(c > 400, "shard {s} starved: {c}/4000");
            assert!(c < 2200, "shard {s} overloaded: {c}/4000");
        }
    }

    #[test]
    fn replica_differs_from_primary() {
        let ring = Ring::new(3, 16);
        for key in 0..100u64 {
            let order = ring.order(key.wrapping_mul(0x2545F4914F6CDD1D));
            assert_ne!(order[0], order[1]);
        }
    }

    #[test]
    fn single_shard_ring_degenerates_cleanly() {
        let ring = Ring::new(1, 8);
        assert_eq!(ring.order(42), vec![0]);
    }
}
