//! Shard layer: a consistent-hashed, replicated router over N
//! network-served coordinator instances ([`crate::net`]).
//!
//! - [`ring`] — deterministic consistent-hash ring (vnodes, splitmix64):
//!   matrices place by [`crate::planner::fingerprint`], and every router
//!   agrees on the placement order.
//! - [`router`] — the [`router::ShardRouter`]: replication-aware
//!   registration, breaker-probed shard health, idempotent request ids
//!   with replica failover (zero lost, zero duplicated), abrupt
//!   [`router::ShardRouter::kill_shard`] for chaos and ordered
//!   [`router::ShardRouter::drain_shard`] through the QoS shutdown path.

pub mod ring;
pub mod router;

pub use ring::Ring;
pub use router::{DrainReport, RouterCounters, RouterSnapshot, ShardConfig, ShardRouter};
