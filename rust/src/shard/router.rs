//! Shard router: N coordinator+server instances behind one submit API,
//! with consistent-hash placement, replication, health-probed failover,
//! and idempotent retries.
//!
//! The invariant everything here serves: **every submitted request
//! resolves exactly once** — with a served result or a typed error —
//! no matter which shard dies, stalls, or drops responses mid-flight.
//!
//! - Placement: matrices hash to shards by [`crate::planner::fingerprint`]
//!   over the [`Ring`]; the first `replicas` live shards in ring order
//!   each register (and preprocess) the matrix, so losing one shard never
//!   forces an HRPB rebuild on the request path.
//! - Health: a probe loop pings every shard through the PR 9 breaker
//!   state machine (3 consecutive probe faults open the breaker; an open
//!   breaker re-probes every [`PROBE_INTERVAL`]-th tick) — routing
//!   prefers breaker-closed replicas but will use any live one.
//! - Failover: request ids are allocated once and reused across retries
//!   (the idempotency key). Transport-shaped failures and timed-out
//!   requests redispatch to a replica under the *same* id; the first
//!   completion wins and late arrivals are suppressed by the outstanding
//!   table — zero lost, zero duplicated.
//! - Drain: [`ShardRouter::drain_shard`] re-replicates the shard's
//!   matrices, then funnels in-flight work through the coordinator's QoS
//!   shutdown path, and only then closes the listener (the ordering test
//!   below pins this).

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::ring::Ring;
use crate::coordinator::breaker::Route;
use crate::coordinator::{
    BatchPolicy, Breaker, BreakerState, Config, Coordinator, MatrixId, ServeError,
};
use crate::formats::{Coo, Dense};
use crate::net::client::{CallResult, Connection};
use crate::net::server::{Server, ServerConfig};
use crate::net::wire::WireRequest;
use crate::planner;
use crate::qos::{Priority, QosConfig, RejectReason};

/// Router tuning.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    pub shards: usize,
    /// Replication factor: how many shards register each matrix.
    pub replicas: usize,
    pub workers_per_shard: usize,
    /// Per-shard QoS admission bound.
    pub queue_capacity: usize,
    /// Per-shard QoS overload watermark (0.0 disables).
    pub watermark_s: f64,
    /// Per-connection in-flight window on each shard server.
    pub window: usize,
    pub batch: BatchPolicy,
    /// Unacked requests older than this are redispatched (recovers
    /// dropped responses).
    pub request_timeout: Duration,
    pub probe_interval: Duration,
    pub probe_timeout: Duration,
    /// Total dispatch attempts per request before a typed failure.
    pub max_attempts: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 2,
            replicas: 2,
            workers_per_shard: 2,
            queue_capacity: 512,
            watermark_s: 0.0,
            window: 256,
            batch: BatchPolicy::default(),
            request_timeout: Duration::from_secs(2),
            probe_interval: Duration::from_millis(25),
            probe_timeout: Duration::from_millis(500),
            max_attempts: 4,
        }
    }
}

/// Monotonic counters (read by the load experiment's invariant checks).
#[derive(Default)]
pub struct RouterCounters {
    pub requests: AtomicU64,
    /// Requests resolved with a served result.
    pub acked: AtomicU64,
    /// Requests resolved with a typed error.
    pub errors: AtomicU64,
    /// Redispatches triggered by a transport-shaped completion.
    pub failovers: AtomicU64,
    /// Redispatches triggered by the request-timeout reaper.
    pub retries: AtomicU64,
    /// Late completions for already-resolved ids — would-be duplicates.
    pub duplicates_suppressed: AtomicU64,
}

/// Plain-number snapshot of [`RouterCounters`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RouterSnapshot {
    pub requests: u64,
    pub acked: u64,
    pub errors: u64,
    pub failovers: u64,
    pub retries: u64,
    pub duplicates_suppressed: u64,
}

impl RouterCounters {
    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            acked: self.acked.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            duplicates_suppressed: self.duplicates_suppressed.load(Ordering::Relaxed),
        }
    }
}

/// What [`ShardRouter::drain_shard`] did, in order — the graceful-drain
/// ordering contract as data.
#[derive(Clone, Debug)]
pub struct DrainReport {
    /// Always `["mark-draining", "replicate-matrices", "qos-drain",
    /// "listener-closed"]` on success.
    pub steps: Vec<&'static str>,
    /// Matrices re-registered on a new replica because this shard held
    /// one of their copies.
    pub reassigned: usize,
}

type Callback = Box<dyn FnOnce(CallResult) + Send>;

struct Outstanding {
    matrix: String,
    b: Dense,
    priority: Priority,
    deadline_us: u64,
    attempts: usize,
    /// Shards already tried for this request (avoided on retry while an
    /// untried live replica exists).
    tried: Vec<usize>,
    dispatched_at: Instant,
    done: Callback,
}

struct Placement {
    /// Ring-ordered shard indices holding this matrix.
    targets: Vec<usize>,
}

struct Shard {
    name: String,
    addr: SocketAddr,
    coord: Arc<Coordinator>,
    conn: Connection,
    breaker: Breaker,
    alive: AtomicBool,
    server: Mutex<Option<Server>>,
}

struct Inner {
    cfg: ShardConfig,
    ring: Ring,
    shards: Vec<Shard>,
    placements: Mutex<HashMap<String, Placement>>,
    /// Source matrices, kept so a draining shard's copies can be
    /// re-registered on a replacement replica.
    sources: Mutex<HashMap<String, Coo>>,
    outstanding: Mutex<HashMap<u64, Outstanding>>,
    counters: RouterCounters,
    next_id: AtomicU64,
    closing: AtomicBool,
    /// Completion-channel sender; `None` once shutdown has begun.
    completion_tx: Mutex<Option<Sender<(u64, CallResult)>>>,
}

/// Is this error worth a replica retry? Transport failures (lost or
/// hostile connection), coordinator shutdown, and shutdown-shaped QoS
/// rejections all mean "this shard can no longer answer" rather than
/// "this request is bad".
fn retryable(e: &ServeError) -> bool {
    e.is_transport()
        || matches!(e, ServeError::Shutdown)
        || matches!(e, ServeError::Shed(r) if r.reason == RejectReason::Shutdown)
}

/// The running router.
pub struct ShardRouter {
    inner: Arc<Inner>,
    probe_stop: Arc<AtomicBool>,
    probe: Mutex<Option<std::thread::JoinHandle<()>>>,
    completion: Mutex<Option<std::thread::JoinHandle<()>>>,
    shut: AtomicBool,
}

impl ShardRouter {
    /// Boot `cfg.shards` coordinator+server+connection trios (named
    /// "shard-0".."shard-N" — the `net_drop@shard-i` fault keys) plus the
    /// probe/reaper and completion threads.
    pub fn start(cfg: ShardConfig) -> std::io::Result<ShardRouter> {
        assert!(cfg.shards > 0, "need at least one shard");
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let name = format!("shard-{i}");
            let coord = Arc::new(Coordinator::start(
                Config {
                    workers: cfg.workers_per_shard,
                    batch: cfg.batch,
                    qos: Some(QosConfig {
                        queue_capacity: cfg.queue_capacity,
                        watermark_s: cfg.watermark_s,
                        default_deadline: None,
                    }),
                    ..Default::default()
                },
                None,
            ));
            let server = Server::start(
                Arc::clone(&coord),
                ServerConfig {
                    name: name.clone(),
                    window: cfg.window,
                    ..Default::default()
                },
            )?;
            let addr = server.addr();
            let conn = Connection::connect(addr)?;
            shards.push(Shard {
                name,
                addr,
                coord,
                conn,
                breaker: Breaker::new(),
                alive: AtomicBool::new(true),
                server: Mutex::new(Some(server)),
            });
        }
        let (tx, rx) = channel();
        let ring = Ring::new(cfg.shards, 32);
        let inner = Arc::new(Inner {
            cfg,
            ring,
            shards,
            placements: Mutex::new(HashMap::new()),
            sources: Mutex::new(HashMap::new()),
            outstanding: Mutex::new(HashMap::new()),
            counters: RouterCounters::default(),
            next_id: AtomicU64::new(1),
            closing: AtomicBool::new(false),
            completion_tx: Mutex::new(Some(tx)),
        });
        let completion = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || completion_loop(inner, rx))
        };
        let probe_stop = Arc::new(AtomicBool::new(false));
        let probe = {
            let inner = Arc::clone(&inner);
            let stop = Arc::clone(&probe_stop);
            std::thread::spawn(move || probe_loop(inner, stop))
        };
        Ok(ShardRouter {
            inner,
            probe_stop,
            probe: Mutex::new(Some(probe)),
            completion: Mutex::new(Some(completion)),
            shut: AtomicBool::new(false),
        })
    }

    pub fn counters(&self) -> &RouterCounters {
        &self.inner.counters
    }

    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    pub fn shard_addr(&self, i: usize) -> SocketAddr {
        self.inner.shards[i].addr
    }

    pub fn breaker_state(&self, i: usize) -> BreakerState {
        self.inner.shards[i].breaker.state()
    }

    /// Deepest per-shard admission queue right now — the load
    /// experiment's bounded-queue-depth invariant samples this.
    pub fn max_queue_depth(&self) -> usize {
        self.inner
            .shards
            .iter()
            .map(|s| s.coord.metrics().queue_depth.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }

    /// Register a matrix on its `replicas` ring-placed shards (each
    /// preprocesses its own copy). Returns the placement.
    pub fn register(&self, name: &str, coo: &Coo) -> Vec<usize> {
        let key = planner::fingerprint(coo);
        let targets: Vec<usize> = self
            .inner
            .ring
            .order(key)
            .into_iter()
            .filter(|&s| self.inner.shards[s].alive.load(Ordering::SeqCst))
            .take(self.inner.cfg.replicas.max(1))
            .collect();
        assert!(!targets.is_empty(), "no live shard to place {name}");
        for &t in &targets {
            self.inner.shards[t].coord.register(name, coo);
        }
        self.inner
            .sources
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(name.to_string(), coo.clone());
        self.inner
            .placements
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(name.to_string(), Placement { targets: targets.clone() });
        targets
    }

    /// Current placement of a matrix (primary first).
    pub fn placement(&self, name: &str) -> Option<Vec<usize>> {
        self.inner
            .placements
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(name)
            .map(|p| p.targets.clone())
    }

    /// Submit a request; `done` resolves exactly once. Returns the
    /// request id (the idempotency key reused across any failover).
    pub fn submit(
        &self,
        matrix: &str,
        b: Dense,
        priority: Priority,
        deadline_us: u64,
        done: impl FnOnce(CallResult) + Send + 'static,
    ) -> u64 {
        let inner = &self.inner;
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
        inner.counters.requests.fetch_add(1, Ordering::Relaxed);
        let target = {
            let placements = inner.placements.lock().unwrap_or_else(|p| p.into_inner());
            match placements.get(matrix) {
                Some(p) => pick_target(inner, &p.targets, &[]),
                None => {
                    drop(placements);
                    inner.counters.errors.fetch_add(1, Ordering::Relaxed);
                    done(Err(ServeError::UnknownMatrix(MatrixId(u64::MAX))));
                    return id;
                }
            }
        };
        let Some(target) = target else {
            inner.counters.errors.fetch_add(1, Ordering::Relaxed);
            done(Err(ServeError::Protocol { detail: "no live replica".into() }));
            return id;
        };
        let req = WireRequest {
            request_id: id,
            priority,
            deadline_us,
            matrix: matrix.to_string(),
            b: b.clone(),
        };
        inner.outstanding.lock().unwrap_or_else(|p| p.into_inner()).insert(
            id,
            Outstanding {
                matrix: matrix.to_string(),
                b,
                priority,
                deadline_us,
                attempts: 1,
                tried: vec![target],
                dispatched_at: Instant::now(),
                done: Box::new(done),
            },
        );
        dispatch(inner, target, &req);
        id
    }

    /// Submit and wait.
    pub fn call(&self, matrix: &str, b: Dense, priority: Priority) -> CallResult {
        let (tx, rx) = channel();
        self.submit(matrix, b, priority, 0, move |r| {
            let _ = tx.send(r);
        });
        rx.recv()
            .unwrap_or_else(|_| Err(ServeError::Protocol { detail: "router gone".into() }))
    }

    /// Chaos: kill shard `i` abruptly — sockets cut first, so computed
    /// but unwritten responses are genuinely lost. Its unacked requests
    /// fail over to replicas under their original ids.
    pub fn kill_shard(&self, i: usize) {
        let shard = &self.inner.shards[i];
        shard.alive.store(false, Ordering::SeqCst);
        if let Some(server) = shard.server.lock().unwrap_or_else(|p| p.into_inner()).take() {
            server.kill();
        }
        // belt and braces: fail anything still pending on the connection
        // (the reader usually beats us to it when the sockets die)
        shard.conn.close();
    }

    /// Graceful drain of shard `i`: stop routing to it, re-replicate its
    /// matrices, complete in-flight work through the QoS shutdown path,
    /// then close the listener. The returned report records the order.
    pub fn drain_shard(&self, i: usize) -> DrainReport {
        let inner = &self.inner;
        let shard = &inner.shards[i];
        let mut steps = Vec::with_capacity(4);
        // 1. no new dispatches pick this shard
        shard.alive.store(false, Ordering::SeqCst);
        steps.push("mark-draining");
        // 2. every matrix with a copy here gets a replacement replica
        //    *before* this shard stops serving — reads keep their
        //    redundancy through the drain
        let affected: Vec<(String, Vec<usize>)> = {
            let placements = inner.placements.lock().unwrap_or_else(|p| p.into_inner());
            placements
                .iter()
                .filter(|(_, p)| p.targets.contains(&i))
                .map(|(n, p)| (n.clone(), p.targets.clone()))
                .collect()
        };
        let mut reassigned = 0;
        for (name, targets) in affected {
            let coo = {
                let sources = inner.sources.lock().unwrap_or_else(|p| p.into_inner());
                sources.get(&name).cloned()
            };
            let Some(coo) = coo else { continue };
            let key = planner::fingerprint(&coo);
            let replacement = inner.ring.order(key).into_iter().find(|&s| {
                s != i
                    && !targets.contains(&s)
                    && inner.shards[s].alive.load(Ordering::SeqCst)
            });
            let mut new_targets: Vec<usize> = targets.into_iter().filter(|&s| s != i).collect();
            if let Some(r) = replacement {
                // preprocess on the replacement before the placement flips
                inner.shards[r].coord.register(&name, &coo);
                new_targets.push(r);
            }
            if !new_targets.is_empty() {
                inner
                    .placements
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .insert(name, Placement { targets: new_targets });
                reassigned += 1;
            }
        }
        steps.push("replicate-matrices");
        // 3. in-flight work on this shard completes (or is typed-rejected)
        //    via the coordinator's QoS shutdown path, and every produced
        //    response is written out
        if let Some(server) = shard.server.lock().unwrap_or_else(|p| p.into_inner()).take() {
            server.drain();
        }
        steps.push("qos-drain");
        // 4. only now is the listener gone (drain closed it on return)
        steps.push("listener-closed");
        DrainReport { steps, reassigned }
    }

    /// Stop everything: drain remaining shards, resolve any stragglers
    /// with typed shutdown errors, and join the service threads.
    pub fn shutdown(&self) {
        if self.shut.swap(true, Ordering::SeqCst) {
            return;
        }
        let inner = &self.inner;
        inner.closing.store(true, Ordering::SeqCst);
        self.probe_stop.store(true, Ordering::SeqCst);
        if let Some(p) = self.probe.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = p.join();
        }
        for shard in &inner.shards {
            shard.alive.store(false, Ordering::SeqCst);
            if let Some(server) = shard.server.lock().unwrap_or_else(|p| p.into_inner()).take() {
                server.drain();
            }
            shard.conn.close();
        }
        // anything still outstanding (e.g. responses dropped by chaos and
        // not yet reaped) resolves now, exactly once, with a typed error
        let stragglers: Vec<Outstanding> = {
            let mut o = inner.outstanding.lock().unwrap_or_else(|p| p.into_inner());
            o.drain().map(|(_, e)| e).collect()
        };
        for e in stragglers {
            inner.counters.errors.fetch_add(1, Ordering::Relaxed);
            (e.done)(Err(ServeError::Shutdown));
        }
        // closing the channel lets the completion thread drain and exit
        drop(inner.completion_tx.lock().unwrap_or_else(|p| p.into_inner()).take());
        if let Some(c) = self.completion.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = c.join();
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Choose a dispatch target from `targets`: prefer untried live shards
/// with a closed breaker, then any untried live shard, then any live
/// shard at all.
fn pick_target(inner: &Inner, targets: &[usize], tried: &[usize]) -> Option<usize> {
    let live = |&s: &usize| inner.shards[s].alive.load(Ordering::SeqCst);
    let closed = |&s: &usize| inner.shards[s].breaker.state() == BreakerState::Closed;
    targets
        .iter()
        .copied()
        .find(|s| live(s) && closed(s) && !tried.contains(s))
        .or_else(|| targets.iter().copied().find(|s| live(s) && !tried.contains(s)))
        .or_else(|| targets.iter().copied().find(live))
}

/// Fire one request at a shard. Completions (including synchronous
/// dead-connection failures) funnel into the completion channel under the
/// request id.
fn dispatch(inner: &Arc<Inner>, target: usize, req: &WireRequest) {
    let tx = {
        let guard = inner.completion_tx.lock().unwrap_or_else(|p| p.into_inner());
        (*guard).clone()
    };
    let Some(tx) = tx else { return };
    let id = req.request_id;
    inner.shards[target].conn.submit_callback(req, move |result| {
        let _ = tx.send((id, result));
    });
}

/// Redispatch `id` to another replica (same id — idempotent), or resolve
/// it with `err` when retries are exhausted / shutdown is in progress.
fn retry_or_fail(inner: &Arc<Inner>, id: u64, err: ServeError, is_timeout: bool) {
    let action = {
        let mut outstanding = inner.outstanding.lock().unwrap_or_else(|p| p.into_inner());
        let Some(entry) = outstanding.get_mut(&id) else {
            // already resolved: a late completion racing the reaper
            inner.counters.duplicates_suppressed.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let closing = inner.closing.load(Ordering::SeqCst);
        if closing || entry.attempts >= inner.cfg.max_attempts {
            let entry = outstanding.remove(&id).expect("checked above");
            Err((entry.done, err))
        } else {
            let target = {
                let placements = inner.placements.lock().unwrap_or_else(|p| p.into_inner());
                placements
                    .get(&entry.matrix)
                    .and_then(|p| pick_target(inner, &p.targets, &entry.tried))
            };
            match target {
                Some(t) => {
                    entry.attempts += 1;
                    if !entry.tried.contains(&t) {
                        entry.tried.push(t);
                    }
                    entry.dispatched_at = Instant::now();
                    let counter = if is_timeout {
                        &inner.counters.retries
                    } else {
                        &inner.counters.failovers
                    };
                    counter.fetch_add(1, Ordering::Relaxed);
                    Ok((
                        t,
                        WireRequest {
                            request_id: id,
                            priority: entry.priority,
                            deadline_us: entry.deadline_us,
                            matrix: entry.matrix.clone(),
                            b: entry.b.clone(),
                        },
                    ))
                }
                None => {
                    let entry = outstanding.remove(&id).expect("checked above");
                    Err((entry.done, ServeError::Protocol { detail: "no live replica".into() }))
                }
            }
        }
    };
    match action {
        Ok((target, req)) => dispatch(inner, target, &req),
        Err((done, err)) => {
            inner.counters.errors.fetch_add(1, Ordering::Relaxed);
            done(Err(err));
        }
    }
}

fn completion_loop(inner: Arc<Inner>, rx: Receiver<(u64, CallResult)>) {
    while let Ok((id, result)) = rx.recv() {
        match result {
            Ok(ok) => {
                let entry = inner
                    .outstanding
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .remove(&id);
                match entry {
                    Some(e) => {
                        inner.counters.acked.fetch_add(1, Ordering::Relaxed);
                        (e.done)(Ok(ok));
                    }
                    // the retry already won: suppress the duplicate
                    None => {
                        inner.counters.duplicates_suppressed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(e) if retryable(&e) => retry_or_fail(&inner, id, e, false),
            Err(e) => {
                // serving-semantics error (shed, shape, engine fault...):
                // a replica would answer the same way — resolve it
                let entry = inner
                    .outstanding
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .remove(&id);
                match entry {
                    Some(en) => {
                        inner.counters.errors.fetch_add(1, Ordering::Relaxed);
                        (en.done)(Err(e));
                    }
                    None => {
                        inner.counters.duplicates_suppressed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

/// Health probes + request-timeout reaper, one tick per
/// `cfg.probe_interval`.
fn probe_loop(inner: Arc<Inner>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(inner.cfg.probe_interval);
        if stop.load(Ordering::SeqCst) {
            break;
        }
        for shard in &inner.shards {
            if !shard.alive.load(Ordering::SeqCst) {
                continue;
            }
            let route = shard.breaker.route();
            if route == Route::Reject {
                continue;
            }
            match shard.conn.ping(shard.name.as_bytes(), inner.cfg.probe_timeout) {
                Ok(_) => shard.breaker.record_success(route),
                Err(_) => {
                    let _ = shard.breaker.record_fault(route);
                }
            }
        }
        // reap requests that have gone unacked past the timeout — this is
        // what recovers a net_drop'd response: same id, next replica
        let now = Instant::now();
        let expired: Vec<u64> = {
            let outstanding = inner.outstanding.lock().unwrap_or_else(|p| p.into_inner());
            outstanding
                .iter()
                .filter(|(_, o)| now.duration_since(o.dispatched_at) > inner.cfg.request_timeout)
                .map(|(&id, _)| id)
                .collect()
        };
        for id in expired {
            retry_or_fail(
                &inner,
                id,
                ServeError::Protocol { detail: "request timed out awaiting a response".into() },
                true,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame;
    use crate::util::rng::Rng;
    use std::net::TcpStream;

    fn router(shards: usize) -> ShardRouter {
        ShardRouter::start(ShardConfig {
            shards,
            request_timeout: Duration::from_millis(600),
            probe_interval: Duration::from_millis(10),
            ..Default::default()
        })
        .expect("router boots on loopback")
    }

    fn register_matrices(r: &ShardRouter, n: usize) -> Vec<String> {
        let mut names = Vec::new();
        for m in 0..n {
            let coo = Coo::random(64, 96, 0.05, &mut Rng::new(1000 + m as u64));
            let name = format!("m{m}");
            let targets = r.register(&name, &coo);
            assert_eq!(targets.len(), 2.min(r.shard_count()));
            names.push(name);
        }
        names
    }

    fn b_operand(seed: u64, cols: usize) -> Dense {
        Dense::random(96, cols, &mut Rng::new(seed))
    }

    #[test]
    fn routes_requests_to_placed_shards_and_serves() {
        let r = router(3);
        let names = register_matrices(&r, 4);
        for (i, name) in names.iter().enumerate() {
            let ok = r.call(name, b_operand(i as u64, 4), Priority::Normal).expect("served");
            assert_eq!(ok.c.rows, 64);
            assert_eq!(ok.c.cols, 4);
        }
        let snap = r.counters().snapshot();
        assert_eq!(snap.acked, 4);
        assert_eq!(snap.errors, 0);
        r.shutdown();
    }

    #[test]
    fn unknown_matrix_resolves_with_a_typed_error() {
        let r = router(2);
        let err = r.call("never-registered", b_operand(1, 2), Priority::Normal).unwrap_err();
        assert_eq!(err.kind(), "unknown_matrix");
        r.shutdown();
    }

    #[test]
    fn killed_shard_fails_over_zero_lost_zero_duplicated() {
        let r = router(3);
        let names = register_matrices(&r, 6);
        // warm every placement
        for name in &names {
            r.call(name, b_operand(9, 2), Priority::Normal).expect("warm call");
        }
        // a wave of async requests across all matrices...
        let (tx, rx) = channel();
        let total = 60u64;
        for i in 0..total {
            let name = names[(i as usize) % names.len()].clone();
            let tx = tx.clone();
            r.submit(&name, b_operand(i, 4), Priority::Normal, 0, move |res| {
                let _ = tx.send(res);
            });
        }
        drop(tx);
        // ...and a mid-flight kill of shard 0
        r.kill_shard(0);
        let mut acked = 0u64;
        let mut typed_errors = 0u64;
        for _ in 0..total {
            match rx.recv_timeout(Duration::from_secs(30)).expect("every request resolves") {
                Ok(ok) => {
                    assert_eq!(ok.c.rows, 64);
                    acked += 1;
                }
                Err(e) => {
                    // an exhausted-retry path is allowed, but it must be
                    // typed — and with 2 replicas it should be rare
                    let _ = e.kind();
                    typed_errors += 1;
                }
            }
        }
        // zero lost: every one of the 60 resolved exactly once
        assert_eq!(acked + typed_errors, total);
        let warm = names.len() as u64;
        let snap = r.counters().snapshot();
        assert_eq!(snap.acked + snap.errors, snap.requests);
        // zero duplicated to the caller: the outstanding table swallowed
        // any late double-completion (warm calls were all acked)
        assert_eq!(snap.acked, acked + warm);
        // with every matrix replicated on a live shard, virtually
        // everything should be served
        assert!(
            typed_errors <= total / 10,
            "too many failover losses: {typed_errors}/{total} (counters: {snap:?})"
        );
        r.shutdown();
    }

    /// Satellite: the graceful-drain ordering contract.
    #[test]
    fn graceful_drain_replicates_then_qos_drains_then_closes_listener() {
        let r = router(3);
        let names = register_matrices(&r, 5);
        // find a shard that is primary for at least one matrix
        let victim = r.placement(&names[0]).unwrap()[0];
        let victim_addr = r.shard_addr(victim);
        // keep requests in flight while the drain happens
        let (tx, rx) = channel();
        let total = 30u64;
        for i in 0..total {
            let name = names[(i as usize) % names.len()].clone();
            let tx = tx.clone();
            r.submit(&name, b_operand(i, 4), Priority::Normal, 0, move |res| {
                let _ = tx.send(res);
            });
        }
        drop(tx);
        let report = r.drain_shard(victim);
        // the ordering contract, as recorded by the drain itself
        assert_eq!(
            report.steps,
            vec!["mark-draining", "replicate-matrices", "qos-drain", "listener-closed"]
        );
        // every matrix that lived on the victim was handed to a replica
        assert!(report.reassigned > 0, "victim held no matrices — test setup broken");
        for name in &names {
            let placement = r.placement(name).unwrap();
            assert!(!placement.contains(&victim), "{name} still placed on the drained shard");
            assert!(!placement.is_empty());
        }
        // in-flight work all resolved — served, or typed-rejected and
        // failed over; nothing lost
        let mut resolved = 0u64;
        for _ in 0..total {
            let res = rx.recv_timeout(Duration::from_secs(30)).expect("resolves through drain");
            if let Err(e) = &res {
                let _ = e.kind(); // typed, not a hang or a panic
            }
            resolved += 1;
        }
        assert_eq!(resolved, total);
        // the listener is actually closed (step 4 was not a lie): a fresh
        // connect must be refused or immediately dropped
        match TcpStream::connect(victim_addr) {
            Err(_) => {}
            Ok(mut s) => {
                let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
                let err = frame::decode(&mut s).expect_err("drained listener still serving");
                assert!(!err.recoverable());
            }
        }
        // and the drained shard's matrices still serve from replicas
        for name in &names {
            r.call(name, b_operand(77, 2), Priority::Normal).expect("replica serves post-drain");
        }
        r.shutdown();
    }

    #[test]
    fn request_ids_are_unique_and_reused_only_for_retries() {
        let r = router(2);
        register_matrices(&r, 1);
        let mut ids = Vec::new();
        for i in 0..20u64 {
            let id = r.submit("m0", b_operand(i, 2), Priority::Normal, 0, |_| {});
            ids.push(id);
        }
        let mut deduped = ids.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), ids.len(), "request ids must be unique");
        r.shutdown();
    }
}
