//! HRPB structural statistics — everything the paper's §4 analysis and §6.4
//! synergy classification read off the representation: brick density `α`,
//! brick-column reuse `β`, active brick/block counts, storage footprint.

use crate::hrpb::Hrpb;

/// Structural statistics of a built HRPB instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HrpbStats {
    /// Stored nonzeros.
    pub nnz: usize,
    /// Row panels (M / TM).
    pub num_panels: usize,
    /// Non-empty row panels.
    pub active_panels: usize,
    /// `(TM, TK)` blocks.
    pub num_blocks: usize,
    /// Active `(brick_m, brick_k)` bricks at the instance's geometry.
    pub num_bricks: usize,
    /// Occupied brick columns summed over blocks (a brick column is one of
    /// the `TK/brick_k` column groups of a block; occupied if it holds at
    /// least one active brick).
    pub num_brick_cols: usize,
    /// The paper's α: average nonzero density of *active* bricks,
    /// `nnz / (num_bricks * bits)` ∈ [1/bits, 1] where `bits` is the
    /// geometry's `brick_m·brick_k`.
    pub alpha: f64,
    /// The paper's β (Eq. 5): average active bricks per occupied brick
    /// column, `num_bricks / num_brick_cols` ∈ [1, TM/brick_m].
    pub beta: f64,
    /// Bytes of the packed stream (values + metadata, the DRAM traffic for A).
    pub packed_bytes: usize,
    /// Bytes of matrix-level metadata (blockedRowPtr + sizePtr + activeCols).
    pub meta_bytes: usize,
    /// Zero-fill ratio: MMA-fed element slots over stored nonzeros
    /// (`1/α`) — how much dense work the TCU does per real nonzero.
    pub fill_ratio: f64,
}

impl HrpbStats {
    /// CSR storage of the same matrix for compression-ratio comparisons:
    /// per nonzero an `f32` value plus a `u32` column id, plus the
    /// `(rows + 1)`-entry `u32` row pointer. The 4-byte index width is the
    /// crate-wide CSR assumption ([`crate::formats::Csr`] stores `u32`
    /// indices), valid for matrices with fewer than 2³² rows/cols/nnz.
    pub fn csr_bytes(&self, rows: usize) -> usize {
        use std::mem::size_of;
        self.nnz * (size_of::<f32>() + size_of::<u32>()) + (rows + 1) * size_of::<u32>()
    }
}

/// Blocks at/above which [`compute`] fans out over block ranges on the
/// exec worker pool; below it the dispatch overhead exceeds the scan.
const PARALLEL_MIN_BLOCKS: usize = 4096;

/// Compute statistics from a built instance. Large instances scan their
/// blocks in parallel on the persistent worker pool
/// ([`crate::spmm::exec::WorkerPool`]) — the per-block quantities are
/// associative counts, so the result is identical to [`compute_serial`]
/// (equivalence-tested).
pub fn compute(hrpb: &Hrpb) -> HrpbStats {
    if hrpb.blocks.len() >= PARALLEL_MIN_BLOCKS {
        compute_parallel(hrpb)
    } else {
        compute_serial(hrpb)
    }
}

/// Single-threaded reference the parallel path is tested against.
pub fn compute_serial(hrpb: &Hrpb) -> HrpbStats {
    let (num_bricks, num_brick_cols) = scan_blocks(hrpb, 0, hrpb.blocks.len());
    finish(hrpb, num_bricks, num_brick_cols)
}

/// Parallel block-range scan on the shared worker pool.
pub fn compute_parallel(hrpb: &Hrpb) -> HrpbStats {
    use crate::spmm::exec::WorkerPool;
    use std::sync::atomic::{AtomicUsize, Ordering};
    let nb = hrpb.blocks.len();
    let pool = WorkerPool::global();
    let parts = (pool.threads() + 1).clamp(1, nb.max(1));
    let chunk = crate::util::bits::ceil_div(nb.max(1), parts);
    let bricks = AtomicUsize::new(0);
    let brick_cols = AtomicUsize::new(0);
    pool.run(parts, &|p| {
        let b0 = (p * chunk).min(nb);
        let b1 = ((p + 1) * chunk).min(nb);
        let (nb_part, nc_part) = scan_blocks(hrpb, b0, b1);
        bricks.fetch_add(nb_part, Ordering::Relaxed);
        brick_cols.fetch_add(nc_part, Ordering::Relaxed);
    });
    finish(
        hrpb,
        bricks.load(std::sync::atomic::Ordering::Relaxed),
        brick_cols.load(std::sync::atomic::Ordering::Relaxed),
    )
}

/// Brick / occupied-brick-column counts of blocks `[b0, b1)`.
fn scan_blocks(hrpb: &Hrpb, b0: usize, b1: usize) -> (usize, usize) {
    let brick_cols_per_block = hrpb.tk / hrpb.geometry.brick_k;
    let mut num_bricks = 0usize;
    let mut num_brick_cols = 0usize;
    for block in &hrpb.blocks[b0..b1] {
        num_bricks += block.num_bricks();
        for c in 0..brick_cols_per_block {
            if block.col_ptr[c + 1] > block.col_ptr[c] {
                num_brick_cols += 1;
            }
        }
    }
    (num_bricks, num_brick_cols)
}

/// Shared tail: panel activity scan (cheap, O(panels)) + derived ratios.
fn finish(hrpb: &Hrpb, num_bricks: usize, num_brick_cols: usize) -> HrpbStats {
    let active_panels = (0..hrpb.num_panels())
        .filter(|&p| hrpb.blocked_row_ptr[p + 1] > hrpb.blocked_row_ptr[p])
        .count();
    let brick_slots = (num_bricks * hrpb.geometry.bits()) as f64;
    let alpha = if num_bricks == 0 { 0.0 } else { hrpb.nnz as f64 / brick_slots };
    let beta = if num_brick_cols == 0 { 0.0 } else { num_bricks as f64 / num_brick_cols as f64 };
    HrpbStats {
        nnz: hrpb.nnz,
        num_panels: hrpb.num_panels(),
        active_panels,
        num_blocks: hrpb.num_blocks(),
        num_bricks,
        num_brick_cols,
        alpha,
        beta,
        packed_bytes: hrpb.packed.len(),
        meta_bytes: hrpb.blocked_row_ptr.len() * 4
            + hrpb.size_ptr.len() * 8
            + hrpb.active_cols.len() * 4,
        fill_ratio: if alpha == 0.0 { 0.0 } else { 1.0 / alpha },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::build_from_coo;
    use crate::params::BrickGeometry;
    use crate::util::rng::Rng;

    #[test]
    fn single_nonzero_brick_alpha() {
        let coo = Coo::from_triplets(16, 16, &[(3, 2, 1.0)]);
        let s = compute(&build_from_coo(&coo));
        assert_eq!(s.num_bricks, 1);
        assert!((s.alpha - 1.0 / BrickGeometry::DEFAULT.bits() as f64).abs() < 1e-12);
        assert_eq!(s.beta, 1.0);
    }

    #[test]
    fn full_brick_alpha_one() {
        let mut t = Vec::new();
        for r in 0..16 {
            for c in 0..4 {
                t.push((r, c, 1.0f32));
            }
        }
        let coo = Coo::from_triplets(16, 16, &t);
        let s = compute(&build_from_coo(&coo));
        assert_eq!(s.num_bricks, 1);
        assert_eq!(s.alpha, 1.0);
        assert_eq!(s.fill_ratio, 1.0);
    }

    #[test]
    fn alpha_bounds_hold_randomly() {
        let mut rng = Rng::new(14);
        for seed in 0..10 {
            let coo = Coo::random(64, 128, 0.01 + 0.02 * seed as f64, &mut rng);
            if coo.nnz() == 0 {
                continue;
            }
            let s = compute(&build_from_coo(&coo));
            let lo = 1.0 / BrickGeometry::DEFAULT.bits() as f64;
            assert!(s.alpha >= lo - 1e-12 && s.alpha <= 1.0, "alpha {}", s.alpha);
            assert!(s.beta >= 1.0 - 1e-12, "beta {}", s.beta);
        }
    }

    #[test]
    fn beta_counts_column_sharing() {
        // two bricks stacked in the same brick column of a TM=32 panel
        let coo = Coo::from_triplets(32, 8, &[(0, 0, 1.0), (20, 0, 2.0)]);
        let csr = crate::formats::Csr::from_coo(&coo);
        let hrpb = crate::hrpb::builder::build_with(&csr, 32, 16);
        let s = compute(&hrpb);
        assert_eq!(s.num_bricks, 2);
        assert_eq!(s.num_brick_cols, 1);
        assert_eq!(s.beta, 2.0);
    }

    #[test]
    fn empty_matrix_stats() {
        let coo = Coo::new(16, 16);
        let s = compute(&build_from_coo(&coo));
        assert_eq!(s.nnz, 0);
        assert_eq!(s.num_bricks, 0);
        assert_eq!(s.alpha, 0.0);
    }

    #[test]
    fn parallel_compute_matches_serial_reference() {
        // sizes straddle several pool-part boundaries (incl. a matrix big
        // enough that `compute` itself takes the parallel path: > 4096
        // panels of one block each)
        for (rows, cols, density, seed) in
            [(64usize, 64usize, 0.1, 70u64), (900, 300, 0.05, 71), (80_000, 64, 0.004, 72)]
        {
            let mut rng = Rng::new(seed);
            let coo = Coo::random(rows, cols, density, &mut rng);
            let hrpb = build_from_coo(&coo);
            let serial = compute_serial(&hrpb);
            let parallel = compute_parallel(&hrpb);
            assert_eq!(serial, parallel, "{rows}x{cols}");
            assert_eq!(compute(&hrpb), serial, "dispatching wrapper agrees");
        }
    }

    #[test]
    fn parallel_compute_handles_the_empty_instance() {
        let hrpb = build_from_coo(&Coo::new(16, 16));
        assert_eq!(compute_parallel(&hrpb), compute_serial(&hrpb));
    }

    #[test]
    fn csr_bytes_is_derived_from_element_sizes() {
        let coo = Coo::from_triplets(10, 10, &[(0, 0, 1.0), (5, 5, 2.0), (9, 9, 3.0)]);
        let s = compute(&build_from_coo(&coo));
        // 3 nnz x (4B value + 4B col id) + 11 x 4B row ptr
        assert_eq!(s.csr_bytes(10), 3 * 8 + 11 * 4);
    }

    #[test]
    fn meta_bytes_positive_and_packed_covers_values() {
        let mut rng = Rng::new(15);
        let coo = Coo::random(48, 48, 0.15, &mut rng);
        let s = compute(&build_from_coo(&coo));
        assert!(s.packed_bytes >= s.nnz * 4);
        assert!(s.meta_bytes > 0);
    }
}
