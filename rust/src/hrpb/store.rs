//! On-disk HRPB artifact store — the cross-restart half of §6.3's
//! amortization argument.
//!
//! Artifacts are keyed by the planner's structural matrix fingerprint
//! ([`crate::planner::fingerprint`]) and written with the atomic
//! write-to-temp-then-rename idiom, so a crash mid-write can never leave a
//! half-written file under a live key. Loads are corruption-tolerant: a
//! truncated, bit-flipped, version-bumped or shape-mismatched artifact is
//! counted as `invalidated`, deleted, and reported as a miss — the caller
//! rebuilds from source and re-persists; serving never crashes on a bad
//! cache entry.
//!
//! The hit / miss / invalidated counters are mirrored into the coordinator
//! metrics report (`artifacts=[...]`), so a restarted node's cold-start
//! behavior is observable.
//!
//! Every filesystem touch goes through [`with_retry`]: a transient read or
//! write error is retried up to [`IO_ATTEMPTS`] times with bounded,
//! deterministically-jittered backoff, so a flaky disk or NFS blip
//! warm-starts on the retry instead of silently falling back to a cold
//! build. `NotFound` is never retried (an absent artifact is an ordinary
//! miss, not a fault). The retry loop doubles as the chaos harness's
//! artifact injection point: [`crate::fault::artifact_io`] can substitute
//! an injected error for the real operation, and
//! [`crate::fault::checksum_flip`] can corrupt loaded bytes in flight —
//! both keyed by the artifact path.

use crate::hrpb::serialize::{self, Artifact};
use crate::hrpb::{Hrpb, HrpbStats};
use crate::planner::Plan;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Attempts per filesystem operation (1 initial + retries).
pub const IO_ATTEMPTS: u32 = 3;

/// Backoff before retry r is `IO_BACKOFF_BASE_US << (r-1)` plus a
/// deterministic sub-base jitter, so total added latency is bounded
/// (< `(2^retries + 1) * base` µs) and reproducible in tests.
pub const IO_BACKOFF_BASE_US: u64 = 200;

/// Deterministic backoff with jitter: FNV-1a over the operation key mixed
/// with the attempt number — no clocks, no global RNG, same delays on
/// every run.
fn backoff_us(key: &str, attempt: u32) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h = (h ^ attempt as u64).wrapping_mul(0x100000001b3);
    (IO_BACKOFF_BASE_US << (attempt - 1)) + h % IO_BACKOFF_BASE_US
}

/// Run `op`, retrying transient errors with bounded backoff. `NotFound`
/// returns immediately (a miss is not a fault). The fault-injection check
/// runs once per attempt *in place of* the operation, so an injected
/// `nth=1` error consumes attempt 1 and the real operation succeeds on
/// attempt 2 — exactly the transient-blip shape the retry exists for.
fn with_retry<T>(
    what: &str,
    path: &Path,
    mut op: impl FnMut() -> std::io::Result<T>,
) -> std::io::Result<T> {
    let key = path.display().to_string();
    let mut attempt = 1;
    loop {
        let result = match crate::fault::artifact_io(&key) {
            Some(injected) => Err(injected),
            None => op(),
        };
        match result {
            Ok(v) => return Ok(v),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Err(e),
            Err(e) if attempt < IO_ATTEMPTS => {
                let sleep_us = backoff_us(&key, attempt);
                eprintln!(
                    "warning: artifact {what} {} failed (attempt {attempt}/{IO_ATTEMPTS}), \
                     retrying in {sleep_us}us: {e}",
                    path.display()
                );
                std::thread::sleep(Duration::from_micros(sleep_us));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Snapshot of the store's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Artifacts loaded successfully.
    pub hits: u64,
    /// Keys with no artifact on disk.
    pub misses: u64,
    /// Artifacts found but rejected (corrupt, stale version, shape
    /// mismatch) and removed.
    pub invalidated: u64,
}

/// A directory of persisted HRPB artifacts, keyed by matrix fingerprint.
pub struct ArtifactStore {
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidated: AtomicU64,
    tmp_seq: AtomicU64,
}

impl ArtifactStore {
    /// Open (creating if needed) the artifact directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore, String> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("artifact dir {}: {e}", dir.display()))?;
        Ok(ArtifactStore {
            dir,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            invalidated: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Final path of the artifact for `fingerprint`.
    pub fn path_for(&self, fingerprint: u64) -> PathBuf {
        self.dir.join(format!("hrpb-{fingerprint:016x}.bin"))
    }

    pub fn contains(&self, fingerprint: u64) -> bool {
        self.path_for(fingerprint).is_file()
    }

    /// Load the artifact for `fingerprint`, or `None` (counted as a miss).
    /// A present-but-bad artifact counts as `invalidated`, is deleted so the
    /// next save rewrites it, and returns `None`. A present-but-*unreadable*
    /// artifact (permissions, I/O error) is NOT a silent miss — it counts as
    /// `invalidated` and warns, so a deploy that breaks warm start is
    /// visible in the `artifacts=[...]` metrics instead of masquerading as
    /// an ordinary cold start on every restart.
    pub fn load(&self, fingerprint: u64) -> Option<Artifact> {
        let path = self.path_for(fingerprint);
        let mut bytes = match with_retry("read", &path, || std::fs::read(&path)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Err(e) => {
                eprintln!("warning: artifact {} unreadable: {e}", path.display());
                self.invalidate(&path);
                return None;
            }
        };
        crate::fault::checksum_flip(&path.display().to_string(), &mut bytes);
        match serialize::decode(&bytes) {
            Ok(a) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(a)
            }
            Err(_) => {
                self.invalidate(&path);
                None
            }
        }
    }

    /// [`ArtifactStore::load`] plus a full identity check against the source
    /// matrix: shape, nnz and the full-content digest
    /// ([`crate::hrpb::serialize::content_digest`]). The fingerprint the
    /// store keys files by samples values, so a matrix whose values changed
    /// at non-sampled indices still lands on the same key — the digest
    /// check is what guarantees a stale artifact is invalidated instead of
    /// silently serving old values.
    pub fn load_matching(
        &self,
        fingerprint: u64,
        rows: usize,
        cols: usize,
        nnz: usize,
        digest: u64,
    ) -> Option<Artifact> {
        let a = self.load(fingerprint)?;
        if a.hrpb.rows != rows || a.hrpb.cols != cols || a.hrpb.nnz != nnz || a.digest != digest {
            // the hit was provisional; reclassify it as an invalidation
            self.hits.fetch_sub(1, Ordering::Relaxed);
            self.invalidate(&self.path_for(fingerprint));
            return None;
        }
        Some(a)
    }

    fn invalidate(&self, path: &Path) {
        self.invalidated.fetch_add(1, Ordering::Relaxed);
        let _ = std::fs::remove_file(path);
    }

    /// Persist an artifact atomically: write to a unique temp file in the
    /// same directory, then rename over the final path. `digest` is the
    /// source matrix's full-content digest, verified on load.
    pub fn save(
        &self,
        fingerprint: u64,
        hrpb: &Hrpb,
        stats: &HrpbStats,
        digest: u64,
        plan: Option<&Plan>,
    ) -> Result<(), String> {
        let bytes = serialize::encode(hrpb, stats, digest, plan);
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!(".tmp-{fingerprint:016x}-{}-{seq}", std::process::id()));
        let path = self.path_for(fingerprint);
        with_retry("write", &tmp, || std::fs::write(&tmp, &bytes))
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        with_retry("rename", &path, || std::fs::rename(&tmp, &path)).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            format!("rename {} -> {}: {e}", tmp.display(), path.display())
        })
    }

    /// Fingerprints of every artifact currently on disk (for `prep`
    /// reporting; order unspecified).
    pub fn list(&self) -> Vec<u64> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return Vec::new() };
        entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name();
                let name = name.to_str()?;
                let hex = name.strip_prefix("hrpb-")?.strip_suffix(".bin")?;
                u64::from_str_radix(hex, 16).ok()
            })
            .collect()
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            invalidated: self.invalidated.load(Ordering::Relaxed),
        }
    }
}

/// Unique per-test artifact directory (removed if it already exists).
/// Shared by every unit-test module that exercises the store so the
/// naming/cleanup scheme lives in one place.
#[cfg(test)]
pub(crate) fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "cutespmm_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::{build_from_coo, stats};
    use crate::hrpb::serialize::content_digest;
    use crate::planner::fingerprint;
    use crate::util::rng::Rng;

    fn tmp_store(tag: &str) -> ArtifactStore {
        ArtifactStore::open(test_dir(&format!("store_{tag}"))).unwrap()
    }

    fn build(coo: &Coo) -> (crate::hrpb::Hrpb, HrpbStats) {
        let h = build_from_coo(coo);
        let s = stats::compute(&h);
        (h, s)
    }

    #[test]
    fn save_then_load_hits() {
        let store = tmp_store("hit");
        let coo = Coo::random(96, 96, 0.1, &mut Rng::new(40));
        let fp = fingerprint(&coo);
        assert!(store.load(fp).is_none(), "empty store must miss");
        let (h, s) = build(&coo);
        let d = content_digest(&coo);
        store.save(fp, &h, &s, d, None).unwrap();
        assert!(store.contains(fp));
        let a = store.load_matching(fp, coo.rows, coo.cols, coo.nnz(), d).unwrap();
        assert_eq!(a.hrpb.packed, h.packed);
        assert_eq!(store.stats(), StoreStats { hits: 1, misses: 1, invalidated: 0 });
        assert_eq!(store.list(), vec![fp]);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn corrupt_artifact_is_invalidated_not_fatal() {
        let store = tmp_store("corrupt");
        let coo = Coo::random(64, 64, 0.15, &mut Rng::new(41));
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        let d = content_digest(&coo);
        store.save(fp, &h, &s, d, None).unwrap();
        // flip a byte in the middle of the file
        let path = store.path_for(fp);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        assert!(store.load(fp).is_none());
        assert_eq!(store.stats().invalidated, 1);
        assert!(!store.contains(fp), "bad artifact must be removed");
        // a rebuild + save recovers
        store.save(fp, &h, &s, d, None).unwrap();
        assert!(store.load(fp).is_some());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn shape_mismatch_is_invalidated() {
        let store = tmp_store("shape");
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(42));
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        let d = content_digest(&coo);
        store.save(fp, &h, &s, d, None).unwrap();
        // same key, different claimed shape -> collision treated as stale
        assert!(store.load_matching(fp, 128, 64, coo.nnz(), d).is_none());
        let st = store.stats();
        assert_eq!(st.hits, 0);
        assert_eq!(st.invalidated, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn changed_values_at_non_sampled_indices_are_not_served_stale() {
        // the fingerprint samples every (nnz/512)-th value, so a matrix
        // with > 512 nonzeros whose values change at a non-sampled index
        // keeps the same key — the content digest must reject the artifact
        let store = tmp_store("stale");
        let coo = Coo::random(128, 128, 0.1, &mut Rng::new(44));
        assert!(coo.nnz() >= 1024, "test needs a sampling stride > 1");
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        store.save(fp, &h, &s, content_digest(&coo), None).unwrap();

        let mut changed = coo.clone();
        changed.values[1] += 1.0; // index 1 is never sampled when stride > 1
        assert_eq!(fingerprint(&changed), fp, "premise: same fingerprint key");
        assert_ne!(content_digest(&changed), content_digest(&coo));
        let got = store.load_matching(
            fp,
            changed.rows,
            changed.cols,
            changed.nnz(),
            content_digest(&changed),
        );
        assert!(got.is_none(), "stale values must not be served");
        assert_eq!(store.stats().invalidated, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn truncated_file_is_invalidated() {
        let store = tmp_store("trunc");
        let coo = Coo::random(48, 48, 0.2, &mut Rng::new(43));
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        store.save(fp, &h, &s, content_digest(&coo), None).unwrap();
        let path = store.path_for(fp);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert!(store.load(fp).is_none());
        assert_eq!(store.stats().invalidated, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        for attempt in 1..IO_ATTEMPTS {
            let a = backoff_us("hrpb-cafe.bin", attempt);
            assert_eq!(a, backoff_us("hrpb-cafe.bin", attempt), "same inputs, same delay");
            let floor = IO_BACKOFF_BASE_US << (attempt - 1);
            assert!((floor..floor + IO_BACKOFF_BASE_US).contains(&a), "attempt {attempt}: {a}");
        }
        // jitter actually varies with the key
        assert_ne!(backoff_us("hrpb-cafe.bin", 1), backoff_us("hrpb-beef.bin", 1));
    }

    #[test]
    fn injected_transient_read_error_still_warm_starts() {
        let _g = crate::fault::session_guard();
        let store = tmp_store("retry");
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(45));
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        let d = content_digest(&coo);
        store.save(fp, &h, &s, d, None).unwrap();
        // the first touch of the artifact path errors; the retry reads it
        let plan = crate::fault::FaultPlan::parse("artifact_io@hrpb-:nth=1", 7).unwrap();
        crate::fault::install(&plan);
        let got = store.load_matching(fp, coo.rows, coo.cols, coo.nnz(), d);
        crate::fault::disable();
        assert!(got.is_some(), "a transient IO error must warm-start via the retry");
        assert_eq!(store.stats(), StoreStats { hits: 1, misses: 0, invalidated: 0 });
        assert_eq!(crate::fault::fired(crate::fault::Point::ArtifactIo), 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn persistent_read_errors_exhaust_retries_and_invalidate() {
        let _g = crate::fault::session_guard();
        let store = tmp_store("persistent");
        let coo = Coo::random(48, 48, 0.15, &mut Rng::new(46));
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        let d = content_digest(&coo);
        store.save(fp, &h, &s, d, None).unwrap();
        let plan = crate::fault::FaultPlan::parse("artifact_io@hrpb-:rate=1", 7).unwrap();
        crate::fault::install(&plan);
        let got = store.load(fp);
        let fired = crate::fault::fired(crate::fault::Point::ArtifactIo);
        crate::fault::disable();
        assert!(got.is_none());
        assert_eq!(fired, IO_ATTEMPTS as u64, "every attempt consumed by the injected fault");
        assert_eq!(store.stats().invalidated, 1, "unreadable is loud, not a silent miss");
        // once the fault clears, a rebuild + save recovers
        store.save(fp, &h, &s, d, None).unwrap();
        assert!(store.load_matching(fp, coo.rows, coo.cols, coo.nnz(), d).is_some());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn injected_checksum_flip_invalidates_instead_of_crashing() {
        let _g = crate::fault::session_guard();
        let store = tmp_store("flip");
        let coo = Coo::random(64, 64, 0.12, &mut Rng::new(47));
        let fp = fingerprint(&coo);
        let (h, s) = build(&coo);
        let d = content_digest(&coo);
        store.save(fp, &h, &s, d, None).unwrap();
        let plan = crate::fault::FaultPlan::parse("checksum_flip@hrpb-:nth=1", 7).unwrap();
        crate::fault::install(&plan);
        let got = store.load(fp);
        crate::fault::disable();
        assert!(got.is_none(), "a corrupted read must invalidate, not serve garbage");
        assert_eq!(store.stats().invalidated, 1);
        // the bad file was removed; rebuild + save recovers cleanly
        store.save(fp, &h, &s, d, None).unwrap();
        assert!(store.load(fp).is_some());
        let _ = std::fs::remove_dir_all(store.dir());
    }
}
