//! HRPB decompression — reconstruct dense / COO forms and the zero-filled
//! dense-brick arrays fed to the PJRT artifacts.
//!
//! The GPU kernel performs this decode per-brick in registers (Algorithm 1
//! lines 30-38); here it is used for verification and to produce the
//! TPU-adapted feed (DESIGN.md §Hardware-Adaptation: pattern decode moves to
//! pack/feed time because the MXU has no per-lane popcount).

use crate::formats::{Coo, Dense};
use crate::hrpb::Hrpb;
use crate::util::bits::pattern_iter;

/// Reconstruct the dense matrix (oracle use; asserts a sane size).
pub fn to_dense(hrpb: &Hrpb) -> Dense {
    let coo = to_coo(hrpb);
    coo.to_dense()
}

/// Reconstruct COO triplets from the structured blocks. A build-time row
/// permutation ([`Hrpb::perm`]) is inverted here, so the result is always
/// in **original** row order regardless of how the HRPB was packed.
pub fn to_coo(hrpb: &Hrpb) -> Coo {
    let scatter = hrpb.perm.as_deref();
    let geo = hrpb.geometry;
    let mut coo = Coo::new(hrpb.rows, hrpb.cols);
    for p in 0..hrpb.num_panels() {
        let r0 = p * hrpb.tm;
        for block in hrpb.panel_blocks(p) {
            let brick_cols = hrpb.tk / geo.brick_k;
            let mut vi = 0usize;
            for bc in 0..brick_cols {
                let (s, e) = (block.col_ptr[bc] as usize, block.col_ptr[bc + 1] as usize);
                for j in s..e {
                    let br = block.rows[j] as usize;
                    for (r, c, idx) in pattern_iter(geo, block.patterns[j]) {
                        let structural = r0 + br * geo.brick_m + r;
                        let row = scatter
                            .map_or(structural, |pm| pm.new_to_old[structural] as usize);
                        let slot = bc * geo.brick_k + c;
                        let col = block.active_cols[slot] as usize;
                        coo.push(row, col, block.values[vi + idx]);
                    }
                    vi += block.patterns[j].count_ones() as usize;
                }
            }
        }
    }
    coo.normalize();
    coo
}

/// The zero-filled dense-brick feed for the PJRT `hrpb_spmm` artifact
/// (contract shared with `python/compile/pack.py`):
///
/// * `blocks`      — f32, `num_blocks * TM * TK`, block-major
/// * `active_cols` — i32, `num_blocks * TK` (padding repeats a real column)
/// * `panel_ids`   — i32, `num_blocks`
#[derive(Clone, Debug)]
pub struct DenseBrickFeed {
    pub num_blocks: usize,
    pub tm: usize,
    pub tk: usize,
    pub blocks: Vec<f32>,
    pub active_cols: Vec<i32>,
    pub panel_ids: Vec<i32>,
}

/// Decode to the dense-brick feed form. The feed stays in *structural*
/// (packed) row order and the PJRT artifact has no scatter stage, so a
/// permuted HRPB must never reach it — enforced here rather than left as a
/// comment-level invariant (in practice the PJRT policy registers
/// unplanned, and only planner-gated registrations attach a permutation).
///
/// # Panics
/// Panics when `hrpb` carries a build-time row permutation.
pub fn to_feed(hrpb: &Hrpb) -> DenseBrickFeed {
    assert!(
        hrpb.perm.is_none(),
        "to_feed cannot scatter rows: permuted HRPBs are not PJRT-servable"
    );
    let (tm, tk) = (hrpb.tm, hrpb.tk);
    let nb = hrpb.num_blocks();
    let mut blocks = vec![0f32; nb * tm * tk];
    let mut panel_ids = vec![0i32; nb];
    let active_cols: Vec<i32> = hrpb.active_cols.iter().map(|&c| c as i32).collect();

    for p in 0..hrpb.num_panels() {
        let (bs, be) =
            (hrpb.blocked_row_ptr[p] as usize, hrpb.blocked_row_ptr[p + 1] as usize);
        for b in bs..be {
            panel_ids[b] = p as i32;
            let block = &hrpb.blocks[b];
            let out = &mut blocks[b * tm * tk..(b + 1) * tm * tk];
            let geo = hrpb.geometry;
            let brick_cols = tk / geo.brick_k;
            let mut vi = 0usize;
            for bc in 0..brick_cols {
                let (s, e) = (block.col_ptr[bc] as usize, block.col_ptr[bc + 1] as usize);
                for j in s..e {
                    let br = block.rows[j] as usize;
                    for (r, c, idx) in pattern_iter(geo, block.patterns[j]) {
                        let row = br * geo.brick_m + r;
                        let slot = bc * geo.brick_k + c;
                        out[row * tk + slot] = block.values[vi + idx];
                    }
                    vi += block.patterns[j].count_ones() as usize;
                }
            }
        }
    }
    DenseBrickFeed { num_blocks: nb, tm, tk, blocks, active_cols, panel_ids }
}

impl DenseBrickFeed {
    /// Reference SpMM over the feed (mirrors the contract comment in
    /// `python/compile/pack.py`) — used to cross-check the PJRT path.
    pub fn spmm_ref(&self, num_panels: usize, b: &Dense) -> Dense {
        let mut c = Dense::zeros(num_panels * self.tm, b.cols);
        for blk in 0..self.num_blocks {
            let p = self.panel_ids[blk] as usize;
            let a = &self.blocks[blk * self.tm * self.tk..(blk + 1) * self.tm * self.tk];
            let cols = &self.active_cols[blk * self.tk..(blk + 1) * self.tk];
            for r in 0..self.tm {
                for (s, &col) in cols.iter().enumerate() {
                    let av = a[r * self.tk + s];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(col as usize);
                    let crow = c.row_mut(p * self.tm + r);
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
        c
    }

    /// Pad out to a shape bucket's NB with inert all-zero blocks
    /// (mirrors `pad_to_bucket` in python).
    pub fn pad_to(&mut self, nb: usize) {
        assert!(self.num_blocks <= nb, "feed NB {} exceeds bucket {}", self.num_blocks, nb);
        self.blocks.resize(nb * self.tm * self.tk, 0.0);
        self.active_cols.resize(nb * self.tk, 0);
        self.panel_ids.resize(nb, 0);
        self.num_blocks = nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Csr;
    use crate::hrpb::{build, build_from_coo};
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    #[test]
    fn coo_roundtrip_preserves_everything() {
        let mut rng = Rng::new(11);
        let coo = Coo::random(80, 120, 0.07, &mut rng);
        let hrpb = build_from_coo(&coo);
        let back = to_coo(&hrpb);
        assert_eq!(back.to_dense(), coo.to_dense());
    }

    #[test]
    fn feed_matches_dense_spmm() {
        let mut rng = Rng::new(12);
        let coo = Coo::random(60, 90, 0.1, &mut rng);
        let hrpb = build_from_coo(&coo);
        let feed = to_feed(&hrpb);
        let b = Dense::random(90, 32, &mut rng);
        let got = feed.spmm_ref(hrpb.num_panels(), &b);
        let want = coo.to_dense().matmul(&b);
        // got has TM-padded rows
        for r in 0..60 {
            for c in 0..32 {
                assert!((got[(r, c)] - want[(r, c)]).abs() < 1e-3, "({r},{c})");
            }
        }
    }

    #[test]
    fn feed_padding_is_inert() {
        let mut rng = Rng::new(13);
        let coo = Coo::random(32, 64, 0.1, &mut rng);
        let hrpb = build_from_coo(&coo);
        let mut feed = to_feed(&hrpb);
        let b = Dense::random(64, 16, &mut rng);
        let before = feed.spmm_ref(hrpb.num_panels(), &b);
        feed.pad_to(feed.num_blocks + 17);
        let after = feed.spmm_ref(hrpb.num_panels(), &b);
        assert_eq!(before.max_abs_diff(&after), 0.0);
    }

    #[test]
    fn prop_decode_inverts_build() {
        let g = SparseGen { max_m: 64, max_k: 64, max_density: 0.3 };
        check("decode inverts build", 40, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let hrpb = build(&Csr::from_coo(&coo));
            to_dense(&hrpb).max_abs_diff(&coo.to_dense()) == 0.0
        });
    }
}
