//! Versioned, checksummed binary serialization of [`Hrpb`] artifacts.
//!
//! §6.3's amortization argument assumes HRPB preprocessing is paid once and
//! reused over hundreds-to-thousands of SpMM invocations. Within one process
//! the registry delivers that; across process restarts it used to be lost —
//! every node restart re-paid the full build for every registered matrix.
//! This module makes the preprocessed form a durable artifact: the packed
//! byte stream, matrix-level metadata, [`HrpbStats`] and (optionally) the
//! planner's [`Plan`] serialize into one self-validating binary blob that
//! [`crate::hrpb::store::ArtifactStore`] persists on disk.
//!
//! Design points:
//!
//! * **Near-memcpy load.** The file reuses the existing 8-aligned
//!   `packed`/`size_ptr` layout verbatim: every section starts on an 8-byte
//!   boundary, so loading is header parse + section memcpy. The structured
//!   [`Block`]s are *derived* data — they are reconstructed from the packed
//!   stream on load (no sorting, no compaction), which keeps the file at
//!   half the size and the warm path far below a rebuild.
//! * **Self-validating.** A 64-bit FNV-1a checksum covers the magic, version,
//!   flags and the entire payload; decode additionally bounds-checks every
//!   section length and re-derives block invariants. Any mismatch is a typed
//!   `Err`, never a panic — callers treat a bad artifact as a cache miss and
//!   rebuild (see the store's corruption-tolerant load).
//! * **Versioned.** `VERSION` gates the layout. v3 adds the optional
//!   build-time row permutation ([`crate::reorder`], flag bit 2) and the
//!   plan's reorder-gains tail; v4 adds the brick geometry (a section after
//!   the stats block plus a plan tail). v2/v3 artifacts (no geometry
//!   fields) still load as the default geometry — decode accepts all three,
//!   so a deploy does not invalidate a warm artifact directory. Anything
//!   older or newer is a typed `Err` and the store rebuilds.
//!
//! [`Block`]: crate::hrpb::Block

use crate::gpumodel::Bound;
use crate::hrpb::{Block, Hrpb, HrpbStats};
use crate::params::BrickGeometry;
use crate::planner::{Plan, RankedChoice};
use crate::spmm::Algo;
use crate::synergy::Synergy;
use crate::util::bits::{ceil_div, round_up};

/// File magic (8 bytes).
pub const MAGIC: &[u8; 8] = b"CTSPHRPB";

/// Layout version; bump on any format change.
/// v2: plans carry the execution runtime's column-slab width.
/// v3: optional row permutation section + plan reorder-gains tail.
/// v4: brick geometry (wire id after the stats section + plan tail).
pub const VERSION: u32 = 4;

/// Oldest version [`decode`] still accepts (v2 = v3 minus the permutation
/// section and the plan's reorder tail; v3 = v4 minus the geometry fields,
/// decoded as [`BrickGeometry::DEFAULT`]).
pub const MIN_VERSION: u32 = 2;

const FLAG_HAS_PLAN: u32 = 1;
const FLAG_HAS_PERM: u32 = 2;

/// Header length in bytes; every section after it starts 8-aligned.
const HEADER_LEN: usize = 104;

/// A deserialized artifact: the HRPB plus everything registration would
/// otherwise recompute.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub hrpb: Hrpb,
    pub stats: HrpbStats,
    /// Full-content digest of the source matrix ([`content_digest`]);
    /// compared on load so a stale artifact can never serve wrong values.
    pub digest: u64,
    /// The plan computed at build time, when registration was planned.
    pub plan: Option<Plan>,
}

/// Full-content digest of a matrix: shape plus **every** entry's indices
/// and value bits. The planner's structural fingerprint deliberately
/// samples values (interchangeable plans), which makes it too weak to be
/// the durable identity of a value-carrying artifact — two matrices with
/// the same sparsity pattern but different values at non-sampled indices
/// collide there. The store keys files by the fingerprint but verifies
/// this digest on load.
pub fn content_digest(coo: &crate::formats::Coo) -> u64 {
    let mut h = FNV_OFFSET;
    let mut mix = |v: u64| h = (h ^ v).wrapping_mul(FNV_PRIME);
    mix(coo.rows as u64);
    mix(coo.cols as u64);
    mix(coo.nnz() as u64);
    for i in 0..coo.nnz() {
        mix(coo.row_idx[i] as u64);
        mix(coo.col_idx[i] as u64);
        mix(coo.values[i].to_bits() as u64);
    }
    h
}

// ---------------------------------------------------------------- checksum

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Checksum over the whole file except the checksum field itself
/// (bytes `[0, 16)` and `[24, len)`).
fn file_checksum(bytes: &[u8]) -> u64 {
    fnv1a(fnv1a(FNV_OFFSET, &bytes[..16]), &bytes[24..])
}

// ------------------------------------------------------------------ encode

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn pad8(out: &mut Vec<u8>) {
    while out.len() % 8 != 0 {
        out.push(0);
    }
}

/// Serialize an HRPB (+ stats, + optional plan) into the artifact format.
/// `digest` is the source matrix's [`content_digest`], verified on load.
pub fn encode(hrpb: &Hrpb, stats: &HrpbStats, digest: u64, plan: Option<&Plan>) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        HEADER_LEN
            + hrpb.blocked_row_ptr.len() * 4
            + hrpb.size_ptr.len() * 8
            + hrpb.active_cols.len() * 4
            + hrpb.packed.len()
            + 128,
    );
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    let mut flags = 0u32;
    if plan.is_some() {
        flags |= FLAG_HAS_PLAN;
    }
    if hrpb.perm.is_some() {
        flags |= FLAG_HAS_PERM;
    }
    put_u32(&mut out, flags);
    put_u64(&mut out, 0); // checksum, patched below
    for v in [hrpb.rows, hrpb.cols, hrpb.tm, hrpb.tk, hrpb.nnz] {
        put_u64(&mut out, v as u64);
    }
    put_u64(&mut out, digest);
    for v in [
        hrpb.blocked_row_ptr.len(),
        hrpb.size_ptr.len(),
        hrpb.active_cols.len(),
        hrpb.packed.len(),
    ] {
        put_u64(&mut out, v as u64);
    }
    debug_assert_eq!(out.len(), HEADER_LEN);

    for &v in &hrpb.blocked_row_ptr {
        put_u32(&mut out, v);
    }
    pad8(&mut out);
    for &v in &hrpb.size_ptr {
        put_u64(&mut out, v);
    }
    for &v in &hrpb.active_cols {
        put_u32(&mut out, v);
    }
    pad8(&mut out);
    // the packed stream, byte-for-byte; it starts 8-aligned in the file
    // exactly as `pack` keeps it 8-aligned in memory
    out.extend_from_slice(&hrpb.packed);
    pad8(&mut out);

    // v3: build-time row permutation (forward map only; the inverse is
    // re-derived — and re-validated — on load)
    if let Some(perm) = &hrpb.perm {
        debug_assert_eq!(perm.len(), hrpb.rows);
        for &v in &perm.new_to_old {
            put_u32(&mut out, v);
        }
        pad8(&mut out);
    }

    // stats: 11 fixed 8-byte fields
    for v in [
        stats.nnz,
        stats.num_panels,
        stats.active_panels,
        stats.num_blocks,
        stats.num_bricks,
        stats.num_brick_cols,
    ] {
        put_u64(&mut out, v as u64);
    }
    put_f64(&mut out, stats.alpha);
    put_f64(&mut out, stats.beta);
    put_u64(&mut out, stats.packed_bytes as u64);
    put_u64(&mut out, stats.meta_bytes as u64);
    put_f64(&mut out, stats.fill_ratio);

    // v4: the brick geometry this HRPB was built with (wire id). A v3 file
    // is this file minus these 4 bytes (and minus the plan's geometry
    // tail); decode defaults both to BrickGeometry::DEFAULT below v4.
    put_u32(&mut out, hrpb.geometry.id());

    if let Some(plan) = plan {
        put_str(&mut out, plan.engine.name());
        put_u64(&mut out, plan.width as u64);
        put_u64(&mut out, plan.slab_width as u64);
        put_f64(&mut out, plan.predicted_s);
        put_f64(&mut out, plan.predicted_s_per_col);
        put_f64(&mut out, plan.alpha);
        out.push(synergy_index(plan.synergy));
        put_u64(&mut out, plan.fingerprint);
        put_str(&mut out, &plan.rationale);
        put_u32(&mut out, plan.ranked.len() as u32);
        for c in &plan.ranked {
            put_str(&mut out, c.algo.name());
            put_f64(&mut out, c.modeled_s);
            put_f64(&mut out, c.calibrated_s);
            put_f64(&mut out, c.predicted_s);
            out.push(bound_index(c.bound));
        }
        // v3 tail: the reorder decision + gains. Appended before the v4
        // tail so a v2 file is byte-identical to a v3 file truncated
        // before this tail.
        match plan.reorder {
            Some(g) => {
                out.push(1);
                for v in [g.alpha_before, g.alpha_after, g.beta_before, g.beta_after, g.seconds]
                {
                    put_f64(&mut out, v);
                }
            }
            None => out.push(0),
        }
        // v4 tail: the plan's geometry knob. Appended LAST, following the
        // same append-only precedent.
        put_u32(&mut out, plan.geometry.id());
    }

    let ck = file_checksum(&out);
    out[16..24].copy_from_slice(&ck.to_le_bytes());
    out
}

fn synergy_index(s: Synergy) -> u8 {
    Synergy::all().iter().position(|&x| x == s).unwrap() as u8
}

fn bound_index(b: Bound) -> u8 {
    Bound::all().iter().position(|&x| x == b).unwrap() as u8
}

// ------------------------------------------------------------------ decode

/// Bounds-checked little-endian cursor; every failure is a typed error so
/// truncated or hostile input can never panic or over-allocate.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| format!("artifact truncated at byte {}", self.pos))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize64(&mut self) -> Result<usize, String> {
        usize::try_from(self.u64()?).map_err(|_| "artifact field exceeds usize".to_string())
    }

    fn str(&mut self) -> Result<String, String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "artifact string not UTF-8".to_string())
    }

    fn align8(&mut self) -> Result<(), String> {
        let target = round_up(self.pos, 8);
        self.take(target - self.pos)?;
        Ok(())
    }
}

fn read_u32s(r: &mut Reader, n: usize) -> Result<Vec<u32>, String> {
    let bytes = r.take(n.checked_mul(4).ok_or("artifact section overflows")?)?;
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn read_u64s(r: &mut Reader, n: usize) -> Result<Vec<u64>, String> {
    let bytes = r.take(n.checked_mul(8).ok_or("artifact section overflows")?)?;
    Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
}

/// Deserialize an artifact, verifying magic, version, checksum and every
/// structural invariant the rest of the crate relies on. Errors are
/// descriptive; callers treat any `Err` as "rebuild from source".
pub fn decode(bytes: &[u8]) -> Result<Artifact, String> {
    if bytes.len() < HEADER_LEN {
        return Err(format!("artifact too short ({} bytes)", bytes.len()));
    }
    if &bytes[..8] != MAGIC {
        return Err("artifact magic mismatch".into());
    }
    let mut r = Reader { bytes, pos: 8 };
    let version = r.u32()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(format!(
            "artifact version {version} outside supported {MIN_VERSION}..={VERSION}"
        ));
    }
    let flags = r.u32()?;
    if version < 3 && flags & FLAG_HAS_PERM != 0 {
        return Err("artifact v2 cannot carry a permutation".into());
    }
    let stored_ck = r.u64()?;
    if file_checksum(bytes) != stored_ck {
        return Err("artifact checksum mismatch".into());
    }
    let rows = r.usize64()?;
    let cols = r.usize64()?;
    let tm = r.usize64()?;
    let tk = r.usize64()?;
    let nnz = r.usize64()?;
    let digest = r.u64()?;
    let brp_len = r.usize64()?;
    let size_ptr_len = r.usize64()?;
    let active_cols_len = r.usize64()?;
    let packed_len = r.usize64()?;

    if tm == 0 || tm > 256 {
        return Err(format!("artifact TM {tm} invalid"));
    }
    if tk == 0 {
        return Err(format!("artifact TK {tk} invalid"));
    }
    // checked arithmetic: crafted headers (rows near usize::MAX) must Err,
    // never overflow-panic — the module contract is no-panic on any input
    let expected_brp = rows
        .max(1)
        .checked_add(tm - 1)
        .map(|v| v / tm + 1)
        .ok_or("artifact rows overflow")?;
    if brp_len != expected_brp {
        return Err("artifact blocked_row_ptr length inconsistent with rows/TM".into());
    }
    let num_blocks = size_ptr_len
        .checked_sub(1)
        .ok_or("artifact size_ptr empty")?;
    if Some(active_cols_len) != num_blocks.checked_mul(tk) {
        return Err("artifact active_cols length inconsistent with blocks*TK".into());
    }

    let blocked_row_ptr = read_u32s(&mut r, brp_len)?;
    r.align8()?;
    let size_ptr = read_u64s(&mut r, size_ptr_len)?;
    let active_cols = read_u32s(&mut r, active_cols_len)?;
    r.align8()?;
    let packed = r.take(packed_len)?.to_vec();
    r.align8()?;

    let perm = if flags & FLAG_HAS_PERM != 0 {
        let forward = read_u32s(&mut r, rows)?;
        r.align8()?;
        let p = crate::reorder::RowPermutation::from_new_to_old(forward)
            .map_err(|e| format!("artifact permutation: {e}"))?;
        Some(p)
    } else {
        None
    };

    if *blocked_row_ptr.last().unwrap() as usize != num_blocks {
        return Err("artifact blocked_row_ptr tail != block count".into());
    }
    if blocked_row_ptr[0] != 0 || blocked_row_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err("artifact blocked_row_ptr not monotone".into());
    }
    if size_ptr[0] != 0 || *size_ptr.last().unwrap() as usize != packed_len {
        return Err("artifact size_ptr endpoints invalid".into());
    }
    if size_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err("artifact size_ptr not monotone".into());
    }
    if active_cols.iter().any(|&c| c as usize >= cols) {
        return Err("artifact active column out of range".into());
    }

    let stats = HrpbStats {
        nnz: r.usize64()?,
        num_panels: r.usize64()?,
        active_panels: r.usize64()?,
        num_blocks: r.usize64()?,
        num_bricks: r.usize64()?,
        num_brick_cols: r.usize64()?,
        alpha: r.f64()?,
        beta: r.f64()?,
        packed_bytes: r.usize64()?,
        meta_bytes: r.usize64()?,
        fill_ratio: r.f64()?,
    };

    // v4: the build geometry; earlier versions predate the catalog and are
    // by definition the default shape
    let geometry = if version >= 4 {
        BrickGeometry::from_id(r.u32()?).ok_or("artifact geometry id invalid")?
    } else {
        BrickGeometry::DEFAULT
    };
    if tm % geometry.brick_m != 0 {
        return Err(format!("artifact TM {tm} not a multiple of brick_m {}", geometry.brick_m));
    }
    if tk % geometry.brick_k != 0 {
        return Err(format!("artifact TK {tk} not a multiple of brick_k {}", geometry.brick_k));
    }

    let plan =
        if flags & FLAG_HAS_PLAN != 0 { Some(decode_plan(&mut r, version)?) } else { None };

    // reconstruct the structured blocks from the packed stream — the
    // near-memcpy inverse of `pack::pack` (no sorting, no compaction);
    // blocks are independent, so large artifacts reconstruct in parallel
    // just like the builder builds panels in parallel
    let blocks = reconstruct_blocks(&packed, &size_ptr, &active_cols, geometry, tm, tk)?;
    let total_nnz: usize = blocks.iter().map(|b| b.nnz()).sum();
    if total_nnz != nnz {
        return Err(format!("artifact nnz mismatch: blocks {total_nnz} vs header {nnz}"));
    }

    let hrpb = Hrpb {
        rows,
        cols,
        tm,
        tk,
        geometry,
        nnz,
        blocks,
        blocked_row_ptr,
        packed,
        size_ptr,
        active_cols,
        perm: perm.map(std::sync::Arc::new),
    };
    Ok(Artifact { hrpb, stats, digest, plan })
}

/// Reconstruct every structured block from the packed stream, fanning out
/// over block ranges when the artifact is large enough to be worth it.
fn reconstruct_blocks(
    packed: &[u8],
    size_ptr: &[u64],
    active_cols: &[u32],
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
) -> Result<Vec<Block>, String> {
    let num_blocks = size_ptr.len() - 1;
    let decode_range = |b0: usize, b1: usize| -> Result<Vec<Block>, String> {
        let mut out = Vec::with_capacity(b1 - b0);
        for b in b0..b1 {
            let span = &packed[size_ptr[b] as usize..size_ptr[b + 1] as usize];
            let block = decode_block(span, &active_cols[b * tk..(b + 1) * tk], geo, tm, tk)
                .map_err(|e| format!("artifact block {b}: {e}"))?;
            out.push(block);
        }
        Ok(out)
    };

    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(num_blocks.max(1));
    if threads <= 1 || num_blocks < 4096 {
        return decode_range(0, num_blocks);
    }
    let chunk = ceil_div(num_blocks, threads);
    let parts: Vec<Result<Vec<Block>, String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let decode_range = &decode_range;
                let b0 = (t * chunk).min(num_blocks);
                let b1 = ((t + 1) * chunk).min(num_blocks);
                s.spawn(move || decode_range(b0, b1))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("artifact decode worker panicked"))
            .collect()
    });
    let mut blocks = Vec::with_capacity(num_blocks);
    for part in parts {
        blocks.extend(part?);
    }
    Ok(blocks)
}

/// Parse one packed block back into structured form. `padded_cols` is the
/// block's TK-padded active-column slice; padding repeats the last real
/// column while real columns are strictly increasing, so the first
/// non-increase marks the padding boundary.
fn decode_block(
    span: &[u8],
    padded_cols: &[u32],
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
) -> Result<Block, String> {
    let brick_cols = tk / geo.brick_k;
    let bricks_per_col = tm / geo.brick_m;
    let mut r = Reader { bytes: span, pos: 0 };
    let col_ptr: Vec<u16> = read_u16s(&mut r, brick_cols + 1)?;
    let num_bricks = col_ptr[brick_cols] as usize;
    if col_ptr[0] != 0 || col_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err("col_ptr not monotone".into());
    }
    let rows = r.take(num_bricks)?.to_vec();
    if rows.iter().any(|&br| br as usize >= bricks_per_col) {
        return Err("brick row out of range".into());
    }
    r.align8()?;
    let patterns = read_u64s(&mut r, num_bricks)?;
    let vnnz: usize = patterns.iter().map(|p| p.count_ones() as usize).sum();
    let vbytes = r.take(vnnz * 4)?;
    let values: Vec<f32> =
        vbytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();

    let mut n_active = 1usize;
    while n_active < padded_cols.len() && padded_cols[n_active] > padded_cols[n_active - 1] {
        n_active += 1;
    }
    Ok(Block {
        active_cols: padded_cols[..n_active].to_vec(),
        col_ptr,
        rows,
        patterns,
        values,
    })
}

/// `col_ptr` is stored as u16s inside the packed stream.
fn read_u16s(r: &mut Reader, n: usize) -> Result<Vec<u16>, String> {
    let bytes = r.take(n * 2)?;
    Ok(bytes.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
}

fn decode_plan(r: &mut Reader, version: u32) -> Result<Plan, String> {
    let engine = parse_algo(&r.str()?)?;
    let width = r.usize64()?;
    let slab_width = r.usize64()?;
    let predicted_s = r.f64()?;
    let predicted_s_per_col = r.f64()?;
    let alpha = r.f64()?;
    let synergy = *Synergy::all()
        .get(r.u8()? as usize)
        .ok_or("artifact synergy index out of range")?;
    let fingerprint = r.u64()?;
    let rationale = r.str()?;
    let n_ranked = r.u32()? as usize;
    if n_ranked > 64 {
        return Err("artifact ranked table implausibly large".into());
    }
    let mut ranked = Vec::with_capacity(n_ranked);
    for _ in 0..n_ranked {
        let algo = parse_algo(&r.str()?)?;
        let modeled_s = r.f64()?;
        let calibrated_s = r.f64()?;
        let predicted_s = r.f64()?;
        let bound = *Bound::all()
            .get(r.u8()? as usize)
            .ok_or("artifact bound index out of range")?;
        ranked.push(RankedChoice { algo, modeled_s, calibrated_s, predicted_s, bound });
    }
    // v3 tail: reorder decision + gains (absent in v2 -> None)
    let reorder = if version >= 3 && r.u8()? != 0 {
        Some(crate::reorder::Gains {
            alpha_before: r.f64()?,
            alpha_after: r.f64()?,
            beta_before: r.f64()?,
            beta_after: r.f64()?,
            seconds: r.f64()?,
        })
    } else {
        None
    };
    // v4 tail: the plan's geometry knob (pre-catalog plans are default)
    let geometry = if version >= 4 {
        BrickGeometry::from_id(r.u32()?).ok_or("artifact plan geometry id invalid")?
    } else {
        BrickGeometry::DEFAULT
    };
    Ok(Plan {
        engine,
        width,
        predicted_s,
        predicted_s_per_col,
        slab_width,
        geometry,
        alpha,
        synergy,
        ranked,
        rationale,
        fingerprint,
        reorder,
    })
}

fn parse_algo(name: &str) -> Result<Algo, String> {
    Algo::parse(name).ok_or_else(|| format!("artifact names unknown engine '{name}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::gpumodel::Machine;
    use crate::hrpb::{build_from_coo, decode as hrpb_decode, stats};
    use crate::planner::Planner;
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    fn artifact_for(coo: &Coo, with_plan: bool) -> (Hrpb, HrpbStats, u64, Option<Plan>) {
        let hrpb = build_from_coo(coo);
        let s = stats::compute(&hrpb);
        let plan = with_plan.then(|| (*Planner::new(Machine::a100()).plan(coo)).clone());
        (hrpb, s, content_digest(coo), plan)
    }

    fn assert_hrpb_eq(a: &Hrpb, b: &Hrpb) {
        assert_eq!((a.rows, a.cols, a.tm, a.tk, a.nnz), (b.rows, b.cols, b.tm, b.tk, b.nnz));
        assert_eq!(a.blocked_row_ptr, b.blocked_row_ptr);
        assert_eq!(a.size_ptr, b.size_ptr);
        assert_eq!(a.active_cols, b.active_cols);
        assert_eq!(a.packed, b.packed, "packed stream must be byte-identical");
        assert_eq!(a.blocks, b.blocks);
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let coo = Coo::random(128, 200, 0.06, &mut Rng::new(30));
        let (hrpb, s, digest, plan) = artifact_for(&coo, true);
        let bytes = encode(&hrpb, &s, digest, plan.as_ref());
        let art = decode(&bytes).unwrap();
        assert_hrpb_eq(&art.hrpb, &hrpb);
        assert_eq!(art.stats, s);
        assert_eq!(art.digest, digest);
        art.hrpb.validate().unwrap();
        assert_eq!(
            hrpb_decode::to_dense(&art.hrpb).max_abs_diff(&coo.to_dense()),
            0.0,
            "decode::to_dense must be unchanged"
        );
        // re-encode of the decoded artifact reproduces the file exactly
        let again = encode(&art.hrpb, &art.stats, art.digest, art.plan.as_ref());
        assert_eq!(bytes, again, "encode(decode(x)) must equal x");
    }

    #[test]
    fn plan_roundtrips_exactly() {
        let coo = Coo::random(96, 96, 0.15, &mut Rng::new(31));
        let (hrpb, s, digest, mut plan) = artifact_for(&coo, true);
        // a calibrated (non-auto) slab width must survive the round trip
        plan.as_mut().unwrap().slab_width = 96;
        let want = plan.clone().unwrap();
        let art = decode(&encode(&hrpb, &s, digest, plan.as_ref())).unwrap();
        let got = art.plan.unwrap();
        assert_eq!(got.engine, want.engine);
        assert_eq!(got.width, want.width);
        assert_eq!(got.slab_width, want.slab_width);
        assert_eq!(got.predicted_s, want.predicted_s);
        assert_eq!(got.predicted_s_per_col, want.predicted_s_per_col);
        assert_eq!(got.alpha, want.alpha);
        assert_eq!(got.synergy, want.synergy);
        assert_eq!(got.rationale, want.rationale);
        assert_eq!(got.fingerprint, want.fingerprint);
        assert_eq!(got.ranked.len(), want.ranked.len());
        for (g, w) in got.ranked.iter().zip(&want.ranked) {
            assert_eq!(g.algo, w.algo);
            assert_eq!(g.modeled_s, w.modeled_s);
            assert_eq!(g.calibrated_s, w.calibrated_s);
            assert_eq!(g.predicted_s, w.predicted_s);
            assert_eq!(g.bound, w.bound);
        }
    }

    #[test]
    fn planless_artifact_roundtrips() {
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(32));
        let (hrpb, s, digest, _) = artifact_for(&coo, false);
        let art = decode(&encode(&hrpb, &s, digest, None)).unwrap();
        assert!(art.plan.is_none());
        assert_hrpb_eq(&art.hrpb, &hrpb);
    }

    #[test]
    fn empty_matrix_roundtrips() {
        let coo = Coo::new(48, 32);
        let (hrpb, s, digest, _) = artifact_for(&coo, false);
        let art = decode(&encode(&hrpb, &s, digest, None)).unwrap();
        assert_hrpb_eq(&art.hrpb, &hrpb);
        art.hrpb.validate().unwrap();
    }

    #[test]
    fn prop_roundtrip_over_sparse_corpus() {
        let g = SparseGen { max_m: 70, max_k: 90, max_density: 0.25 };
        check("artifact roundtrip", 40, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let hrpb = build_from_coo(&coo);
            let s = stats::compute(&hrpb);
            let d = content_digest(&coo);
            let bytes = encode(&hrpb, &s, d, None);
            let Ok(art) = decode(&bytes) else { return false };
            art.hrpb.validate().is_ok()
                && art.digest == d
                && art.hrpb.packed == hrpb.packed
                && art.hrpb.blocks == hrpb.blocks
                && encode(&art.hrpb, &art.stats, art.digest, None) == bytes
                && hrpb_decode::to_dense(&art.hrpb).max_abs_diff(&coo.to_dense()) == 0.0
        });
    }

    /// Patch an encoded artifact's version field and repair the checksum —
    /// used to reconstruct genuine v2/v3 files from v4 encodes.
    fn as_version(mut bytes: Vec<u8>, version: u32) -> Vec<u8> {
        bytes[8..12].copy_from_slice(&version.to_le_bytes());
        let ck = file_checksum(&bytes);
        bytes[16..24].copy_from_slice(&ck.to_le_bytes());
        bytes
    }

    /// Byte offset of the v4 geometry section — right after the stats
    /// block (11 fixed 8-byte fields).
    fn geometry_section_off(hrpb: &Hrpb) -> usize {
        let mut off = HEADER_LEN + hrpb.blocked_row_ptr.len() * 4;
        off = round_up(off, 8);
        off += hrpb.size_ptr.len() * 8 + hrpb.active_cols.len() * 4;
        off = round_up(off, 8);
        off += hrpb.packed.len();
        off = round_up(off, 8);
        if hrpb.perm.is_some() {
            off += hrpb.rows * 4;
            off = round_up(off, 8);
        }
        off + 11 * 8
    }

    /// Reconstruct a genuine v3 file from a v4 encode: drop the 4-byte
    /// geometry section and (when a plan is present) the 4-byte plan
    /// geometry tail, then patch version + checksum.
    fn strip_to_v3(bytes: &[u8], hrpb: &Hrpb, has_plan: bool) -> Vec<u8> {
        let off = geometry_section_off(hrpb);
        let mut out = bytes.to_vec();
        out.drain(off..off + 4);
        if has_plan {
            out.truncate(out.len() - 4);
        }
        as_version(out, 3)
    }

    #[test]
    fn v2_planless_artifacts_still_load() {
        let coo = Coo::random(64, 80, 0.1, &mut Rng::new(36));
        let (hrpb, s, digest, _) = artifact_for(&coo, false);
        let v3 = strip_to_v3(&encode(&hrpb, &s, digest, None), &hrpb, false);
        let v2 = as_version(v3, 2);
        let art = decode(&v2).expect("v2 artifact must load");
        assert_hrpb_eq(&art.hrpb, &hrpb);
        assert!(art.hrpb.perm.is_none());
        assert!(art.plan.is_none());
        assert_eq!(art.stats, s);
        assert_eq!(art.hrpb.geometry, BrickGeometry::DEFAULT);
    }

    #[test]
    fn v2_plan_bearing_artifacts_still_load() {
        let coo = Coo::random(72, 72, 0.12, &mut Rng::new(37));
        let (hrpb, s, digest, plan) = artifact_for(&coo, true);
        assert!(plan.as_ref().unwrap().reorder.is_none(), "fixture premise");
        let v3 = strip_to_v3(&encode(&hrpb, &s, digest, plan.as_ref()), &hrpb, true);
        // the v3 reorder tail of a reorder-less plan is exactly one byte;
        // dropping it reconstructs the v2 byte layout
        let v2 = as_version(v3[..v3.len() - 1].to_vec(), 2);
        let art = decode(&v2).expect("v2 plan-bearing artifact must load");
        assert_hrpb_eq(&art.hrpb, &hrpb);
        let got = art.plan.expect("plan survives");
        let want = plan.unwrap();
        assert_eq!(got.engine, want.engine);
        assert_eq!(got.slab_width, want.slab_width);
        assert!(got.reorder.is_none(), "v2 plans have no reorder decision");
        assert_eq!(got.geometry, BrickGeometry::DEFAULT);
    }

    #[test]
    fn v3_artifacts_load_as_the_default_geometry_bit_identically() {
        let coo = Coo::random(128, 160, 0.07, &mut Rng::new(44));
        let (hrpb, s, digest, plan) = artifact_for(&coo, true);
        let v4 = encode(&hrpb, &s, digest, plan.as_ref());
        let v3 = strip_to_v3(&v4, &hrpb, true);
        let art = decode(&v3).expect("v3 artifact must load");
        assert_eq!(art.hrpb.geometry, BrickGeometry::DEFAULT);
        assert_eq!(art.plan.as_ref().unwrap().geometry, BrickGeometry::DEFAULT);
        assert_hrpb_eq(&art.hrpb, &hrpb);
        assert_eq!(art.stats, s);
        // the loaded HRPB serves bit-identically to the freshly built one
        let b = crate::formats::Dense::random(coo.cols, 24, &mut Rng::new(45));
        let fresh = crate::spmm::hrpb::HrpbEngine::prepare(&coo).spmm(&b);
        let loaded = crate::spmm::hrpb::HrpbEngine::from_hrpb(art.hrpb).spmm(&b);
        assert_eq!(loaded.max_abs_diff(&fresh), 0.0, "v3 load must serve bit-identically");
    }

    #[test]
    fn v4_roundtrips_every_catalog_geometry() {
        let coo = Coo::random(96, 128, 0.08, &mut Rng::new(46));
        let csr = crate::formats::Csr::from_coo(&coo);
        for geo in BrickGeometry::CATALOG {
            let hrpb = crate::hrpb::build_with_geometry(&csr, geo, 16, 16);
            let s = stats::compute(&hrpb);
            let d = content_digest(&coo);
            let bytes = encode(&hrpb, &s, d, None);
            let art = decode(&bytes).unwrap_or_else(|e| panic!("{geo}: {e}"));
            assert_eq!(art.hrpb.geometry, geo);
            assert_hrpb_eq(&art.hrpb, &hrpb);
            art.hrpb.validate().unwrap();
            assert_eq!(
                hrpb_decode::to_dense(&art.hrpb).max_abs_diff(&coo.to_dense()),
                0.0,
                "{geo}"
            );
            assert_eq!(encode(&art.hrpb, &art.stats, art.digest, None), bytes, "{geo}");
        }
    }

    #[test]
    fn invalid_geometry_id_is_rejected() {
        let coo = Coo::random(32, 32, 0.2, &mut Rng::new(47));
        let (hrpb, s, digest, _) = artifact_for(&coo, false);
        let mut bytes = encode(&hrpb, &s, digest, None);
        let off = geometry_section_off(&hrpb);
        // 16x8: 128 pattern bits, structurally impossible
        bytes[off..off + 4].copy_from_slice(&(16u32 | 8 << 8).to_le_bytes());
        let ck = file_checksum(&bytes);
        bytes[16..24].copy_from_slice(&ck.to_le_bytes());
        let e = decode(&bytes).unwrap_err();
        assert!(e.contains("geometry"), "{e}");
    }

    #[test]
    fn v2_with_a_permutation_flag_is_rejected() {
        let coo = Coo::random(32, 32, 0.2, &mut Rng::new(38));
        let (hrpb, s, digest, _) = artifact_for(&coo, false);
        let mut bytes = encode(&hrpb, &s, digest, None);
        bytes[12..16].copy_from_slice(&2u32.to_le_bytes()); // FLAG_HAS_PERM
        let bytes = as_version(bytes, 2);
        let e = decode(&bytes).unwrap_err();
        assert!(e.contains("permutation"), "{e}");
    }

    #[test]
    fn permutation_roundtrips_with_gains() {
        use crate::params::{TK, TM};
        let spec = crate::gen::MatrixSpec {
            name: "t".into(),
            rows: 160,
            family: crate::gen::Family::BlockDiag { unit: 16, unit_density: 0.7 },
            seed: 40,
        };
        let coo = crate::reorder::RowPermutation::random(160, &mut Rng::new(41))
            .apply_coo(&spec.generate());
        let csr = crate::formats::Csr::from_coo(&coo);
        let prop = crate::reorder::propose(&csr, TM, TK);
        assert!(!prop.perm.is_identity(), "fixture premise: a real permutation");
        let hrpb = crate::reorder::build_reordered(&csr, prop.perm.clone(), TM, TK, 2);
        let s = stats::compute(&hrpb);
        let mut plan = (*Planner::new(Machine::a100()).plan(&coo)).clone();
        plan.reorder = Some(prop.gains(0.0125));
        let digest = content_digest(&coo);

        let bytes = encode(&hrpb, &s, digest, Some(&plan));
        let art = decode(&bytes).unwrap();
        assert_eq!(art.hrpb.perm.as_deref(), Some(&prop.perm), "permutation roundtrips");
        art.hrpb.validate().unwrap();
        let got = art.plan.unwrap().reorder.expect("gains roundtrip");
        assert_eq!(got, prop.gains(0.0125));
        // decode of the loaded artifact still lands in ORIGINAL row order
        assert_eq!(
            hrpb_decode::to_dense(&art.hrpb).max_abs_diff(&coo.to_dense()),
            0.0,
            "perm-bearing artifact decodes to the original matrix"
        );
        // re-encode reproduces the file exactly (incl. the perm section)
        let again = encode(&art.hrpb, &art.stats, art.digest, art.plan.as_ref());
        assert_eq!(bytes, again);
    }

    #[test]
    fn corrupt_permutation_section_is_rejected() {
        use crate::params::{TK, TM};
        let coo = Coo::random(96, 64, 0.1, &mut Rng::new(42));
        let csr = crate::formats::Csr::from_coo(&coo);
        let prop = crate::reorder::propose(&csr, TM, TK);
        let hrpb = crate::reorder::build_reordered(&csr, prop.perm, TM, TK, 2);
        let s = stats::compute(&hrpb);
        let mut bytes = encode(&hrpb, &s, content_digest(&coo), None);
        // duplicate one forward-map entry: bijection check must fire even
        // with a repaired checksum
        let perm_off = {
            // header + brp (+pad) + size_ptr + active_cols (+pad) + packed (+pad)
            let brp = hrpb.blocked_row_ptr.len() * 4;
            let mut off = HEADER_LEN + brp;
            off = crate::util::bits::round_up(off, 8);
            off += hrpb.size_ptr.len() * 8 + hrpb.active_cols.len() * 4;
            off = crate::util::bits::round_up(off, 8);
            off += hrpb.packed.len();
            crate::util::bits::round_up(off, 8)
        };
        let first: [u8; 4] = bytes[perm_off..perm_off + 4].try_into().unwrap();
        bytes[perm_off + 4..perm_off + 8].copy_from_slice(&first);
        let ck = file_checksum(&bytes);
        bytes[16..24].copy_from_slice(&ck.to_le_bytes());
        let e = decode(&bytes).unwrap_err();
        assert!(e.contains("permutation"), "{e}");
    }

    #[test]
    fn truncation_is_rejected_everywhere() {
        let coo = Coo::random(64, 80, 0.1, &mut Rng::new(33));
        let (hrpb, s, digest, plan) = artifact_for(&coo, true);
        let bytes = encode(&hrpb, &s, digest, plan.as_ref());
        // every strict prefix must fail cleanly (no panic, no Ok)
        let step = (bytes.len() / 97).max(1);
        for len in (0..bytes.len()).step_by(step) {
            assert!(decode(&bytes[..len]).is_err(), "prefix of {len} bytes decoded");
        }
    }

    #[test]
    fn bit_flips_are_rejected() {
        let coo = Coo::random(48, 64, 0.12, &mut Rng::new(34));
        let (hrpb, s, digest, plan) = artifact_for(&coo, true);
        let bytes = encode(&hrpb, &s, digest, plan.as_ref());
        let step = (bytes.len() / 113).max(1);
        for pos in (0..bytes.len()).step_by(step) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(decode(&bad).is_err(), "bit flip at byte {pos} decoded");
        }
    }

    #[test]
    fn version_bump_invalidates() {
        let coo = Coo::random(32, 32, 0.2, &mut Rng::new(35));
        let (hrpb, s, digest, _) = artifact_for(&coo, false);
        let mut bytes = encode(&hrpb, &s, digest, None);
        bytes[8..12].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let e = decode(&bytes).unwrap_err();
        assert!(e.contains("version"), "{e}");
    }
}
