//! HRPB construction — the paper's Fig. 3 pipeline: row-panel split, active
//! column compaction ("collect all active columns and place them together
//! towards the left"), block formation, brick pattern encoding, BlkCSC value
//! packing.
//!
//! This is the preprocessing whose overhead §6.3 measures; it runs once per
//! matrix on the host and is amortized over hundreds-to-thousands of SpMM
//! invocations (GNN epochs, LOBPCG iterations).

use crate::formats::{Coo, Csr};
use crate::hrpb::{pack, Block, Hrpb};
use crate::params::{BrickGeometry, TK, TM};
use crate::util::bits::{ceil_div, pattern_set};

/// Build with the paper's default tile sizes (TM=16, TK=16).
pub fn build(csr: &Csr) -> Hrpb {
    build_with(csr, TM, TK)
}

/// Build from COO (convenience).
pub fn build_from_coo(coo: &Coo) -> Hrpb {
    build(&Csr::from_coo(coo))
}

/// Build with explicit tile sizes and the default brick geometry.
/// Used by the §4 TM/TK ablation.
pub fn build_with(csr: &Csr, tm: usize, tk: usize) -> Hrpb {
    build_with_geometry(csr, BrickGeometry::DEFAULT, tm, tk)
}

/// Build with explicit tile sizes *and* brick geometry (`tm`, `tk` must be
/// brick multiples of the geometry).
pub fn build_with_geometry(csr: &Csr, geo: BrickGeometry, tm: usize, tk: usize) -> Hrpb {
    assert_tiles(geo, tm, tk);
    let num_panels = ceil_div(csr.rows.max(1), tm);
    let mut blocks: Vec<Block> = Vec::new();
    let mut blocked_row_ptr: Vec<u32> = Vec::with_capacity(num_panels + 1);
    blocked_row_ptr.push(0);

    // scratch reused across panels to avoid per-panel allocation
    let mut entries: Vec<(u32, u8, f32)> = Vec::new(); // (col, row-in-panel, val)

    for p in 0..num_panels {
        build_panel(csr, geo, tm, tk, p, &mut entries, &mut blocks);
        blocked_row_ptr.push(blocks.len() as u32);
    }
    finish(csr, geo, tm, tk, blocks, blocked_row_ptr)
}

/// Parallel variant of [`build_with`] (default geometry).
pub fn build_with_parallel(csr: &Csr, tm: usize, tk: usize, threads: usize) -> Hrpb {
    build_with_geometry_parallel(csr, BrickGeometry::DEFAULT, tm, tk, threads)
}

/// Parallel variant of [`build_with_geometry`]: row panels are independent,
/// so contiguous panel ranges build on scoped worker threads and the
/// per-panel block lists are stitched back in panel order. The result is
/// **byte-identical** to the serial build — both paths run the same
/// per-panel construction ([`build_panel`]) and the same deterministic
/// packing pass.
pub fn build_with_geometry_parallel(
    csr: &Csr,
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
    threads: usize,
) -> Hrpb {
    assert_tiles(geo, tm, tk);
    let num_panels = ceil_div(csr.rows.max(1), tm);
    let threads = threads.clamp(1, num_panels);
    if threads <= 1 {
        return build_with_geometry(csr, geo, tm, tk);
    }
    let chunk = ceil_div(num_panels, threads);
    let parts: Vec<(Vec<Block>, Vec<u32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let p0 = (t * chunk).min(num_panels);
                let p1 = ((t + 1) * chunk).min(num_panels);
                s.spawn(move || {
                    let mut entries: Vec<(u32, u8, f32)> = Vec::new();
                    let mut blocks: Vec<Block> = Vec::new();
                    let mut counts: Vec<u32> = Vec::with_capacity(p1 - p0);
                    for p in p0..p1 {
                        let before = blocks.len();
                        build_panel(csr, geo, tm, tk, p, &mut entries, &mut blocks);
                        counts.push((blocks.len() - before) as u32);
                    }
                    (blocks, counts)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("panel build worker panicked"))
            .collect()
    });

    let mut blocks: Vec<Block> = Vec::new();
    let mut blocked_row_ptr: Vec<u32> = Vec::with_capacity(num_panels + 1);
    blocked_row_ptr.push(0);
    for (part_blocks, counts) in parts {
        for c in counts {
            let next = *blocked_row_ptr.last().unwrap() + c;
            blocked_row_ptr.push(next);
        }
        blocks.extend(part_blocks);
    }
    finish(csr, geo, tm, tk, blocks, blocked_row_ptr)
}

/// Parallel build from COO with the paper's default tiles, sized for this
/// host (the registry's build path).
pub fn build_from_coo_parallel(coo: &Coo) -> Hrpb {
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    build_with_parallel(&Csr::from_coo(coo), TM, TK, threads)
}

fn assert_tiles(geo: BrickGeometry, tm: usize, tk: usize) {
    let (bm, bk) = (geo.brick_m, geo.brick_k);
    assert!(bm >= 1 && bk >= 1 && geo.bits() <= 64, "brick pattern must fit a u64 word: {geo}");
    assert!(tm % bm == 0 && tm > 0, "TM must be a positive multiple of {bm}");
    // row-in-panel offsets are stored as u8 throughout the builder and the
    // packed stream; a larger TM would silently truncate rows
    assert!(tm <= 256, "TM must be <= 256 (row-in-panel offsets are u8), got {tm}");
    assert!(tk % bk == 0 && tk > 0, "TK must be a positive multiple of {bk}");
}

/// Build the blocks of row panel `p`, appending to `blocks`. `entries` is
/// caller-owned scratch reused across panels. Panels are fully independent:
/// this is the unit both the serial and the parallel builder share.
fn build_panel(
    csr: &Csr,
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
    p: usize,
    entries: &mut Vec<(u32, u8, f32)>,
    blocks: &mut Vec<Block>,
) {
    let r0 = p * tm;
    let r1 = ((p + 1) * tm).min(csr.rows);

    // gather the panel's entries sorted by (col, row): per-row CSR slices
    // are already col-sorted, so a single sort by col with stable row
    // order suffices.
    entries.clear();
    for r in r0..r1 {
        for (c, v) in csr.row_entries(r) {
            entries.push((c, (r - r0) as u8, v));
        }
    }
    entries.sort_unstable_by_key(|&(c, r, _)| (c, r));

    // walk active columns in compacted order, emitting a block every
    // `tk` distinct columns
    let mut i = 0usize;
    while i < entries.len() {
        // collect the next <= tk active columns into one block
        let mut active_cols: Vec<u32> = Vec::with_capacity(tk);
        let block_start = i;
        let mut j = i;
        while j < entries.len() {
            let col = entries[j].0;
            if active_cols.last() != Some(&col) {
                if active_cols.len() == tk {
                    break;
                }
                active_cols.push(col);
            }
            j += 1;
        }
        let block_entries = &entries[block_start..j];
        i = j;

        blocks.push(build_block(block_entries, &active_cols, geo, tm, tk));
    }
}

/// Shared tail of both builders: wrap the blocks and run the packing pass.
fn finish(
    csr: &Csr,
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
    blocks: Vec<Block>,
    blocked_row_ptr: Vec<u32>,
) -> Hrpb {
    let mut hrpb = Hrpb {
        rows: csr.rows,
        cols: csr.cols,
        tm,
        tk,
        geometry: geo,
        nnz: csr.nnz(),
        blocks,
        blocked_row_ptr,
        packed: Vec::new(),
        size_ptr: Vec::new(),
        active_cols: Vec::new(),
        perm: None,
    };
    pack::pack(&mut hrpb);
    hrpb
}

/// Build one structured block from its (col, row, val) entries (col-major
/// sorted) and the compacted active-column list.
fn build_block(
    entries: &[(u32, u8, f32)],
    active_cols: &[u32],
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
) -> Block {
    let brick_cols = tk / geo.brick_k;
    let bricks_per_col = tm / geo.brick_m;

    // dense per-block brick grid of patterns; small (brick_cols x
    // bricks_per_col <= 8x2 for the evaluated sizes)
    let mut patterns = vec![0u64; brick_cols * bricks_per_col];
    // compacted column index of each original column
    // (active_cols is sorted, binary search)
    let col_slot = |c: u32| active_cols.binary_search(&c).expect("column must be active") as usize;

    for &(c, r, _) in entries {
        let slot = col_slot(c);
        let bc = slot / geo.brick_k;
        let br = r as usize / geo.brick_m;
        patterns[bc * bricks_per_col + br] = pattern_set(
            geo,
            patterns[bc * bricks_per_col + br],
            r as usize % geo.brick_m,
            slot % geo.brick_k,
        );
    }

    // emit active bricks in CSC order and fill values row-major per brick
    let mut col_ptr: Vec<u16> = Vec::with_capacity(brick_cols + 1);
    col_ptr.push(0);
    let mut rows: Vec<u8> = Vec::new();
    let mut out_patterns: Vec<u64> = Vec::new();
    let mut brick_value_base: Vec<usize> = Vec::new(); // parallel to out_patterns
    let mut total_nnz = 0usize;
    for bc in 0..brick_cols {
        for br in 0..bricks_per_col {
            let p = patterns[bc * bricks_per_col + br];
            if p != 0 {
                rows.push(br as u8);
                out_patterns.push(p);
                brick_value_base.push(total_nnz);
                total_nnz += p.count_ones() as usize;
            }
        }
        col_ptr.push(rows.len() as u16);
    }

    // place values: for entry at (row r, slot) inside brick (br, bc), its
    // value index is base(brick) + prefix_count(pattern, bit)
    let mut values = vec![0f32; total_nnz];
    // map (bc, br) -> active-brick index for value placement
    let mut brick_index = vec![usize::MAX; brick_cols * bricks_per_col];
    {
        let mut k = 0usize;
        for bc in 0..brick_cols {
            let (s, e) = (col_ptr[bc] as usize, col_ptr[bc + 1] as usize);
            for j in s..e {
                brick_index[bc * bricks_per_col + rows[j] as usize] = k;
                k += 1;
            }
        }
    }
    for &(c, r, v) in entries {
        let slot = col_slot(c);
        let bc = slot / geo.brick_k;
        let br = r as usize / geo.brick_m;
        let bi = brick_index[bc * bricks_per_col + br];
        let bit = crate::util::bits::brick_bit(geo, r as usize % geo.brick_m, slot % geo.brick_k);
        let idx = brick_value_base[bi] + crate::util::bits::prefix_count(out_patterns[bi], bit);
        values[idx] = v;
    }

    Block { active_cols: active_cols.to_vec(), col_ptr, rows, patterns: out_patterns, values }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Dense;
    use crate::hrpb::decode;
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    fn roundtrip(coo: &Coo) -> bool {
        let hrpb = build_from_coo(coo);
        hrpb.validate().unwrap();
        decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()) == 0.0
    }

    #[test]
    fn tiny_known_matrix() {
        // one panel, columns {3, 40} active -> compacted into one block
        let coo = Coo::from_triplets(16, 64, &[(0, 3, 1.0), (5, 40, 2.0), (15, 3, 3.0)]);
        let hrpb = build_from_coo(&coo);
        assert_eq!(hrpb.num_panels(), 1);
        assert_eq!(hrpb.num_blocks(), 1);
        let blk = &hrpb.blocks[0];
        assert_eq!(blk.active_cols, vec![3, 40]);
        // both active columns land in brick column 0 (slots 0 and 1)
        assert_eq!(blk.num_bricks(), 1);
        assert_eq!(blk.nnz(), 3);
        assert!(roundtrip(&coo));
    }

    #[test]
    fn multiple_blocks_when_many_active_cols() {
        // 20 active columns in one panel -> 2 blocks (16 + 4)
        let t: Vec<(usize, usize, f32)> = (0..20).map(|c| (c % 16, c * 3, 1.0 + c as f32)).collect();
        let coo = Coo::from_triplets(16, 64, &t);
        let hrpb = build_from_coo(&coo);
        assert_eq!(hrpb.num_blocks(), 2);
        assert_eq!(hrpb.blocks[0].active_cols.len(), 16);
        assert_eq!(hrpb.blocks[1].active_cols.len(), 4);
        assert!(roundtrip(&coo));
    }

    #[test]
    fn empty_panels_have_no_blocks() {
        let coo = Coo::from_triplets(64, 32, &[(0, 0, 1.0), (63, 31, 2.0)]);
        let hrpb = build_from_coo(&coo);
        assert_eq!(hrpb.num_panels(), 4);
        assert_eq!(hrpb.panel_blocks(0).len(), 1);
        assert_eq!(hrpb.panel_blocks(1).len(), 0);
        assert_eq!(hrpb.panel_blocks(2).len(), 0);
        assert_eq!(hrpb.panel_blocks(3).len(), 1);
    }

    #[test]
    fn compaction_reduces_blocks_vs_no_compaction() {
        // nonzeros in columns 0, 100, 200, ... 1500: compacted they fit one
        // block; un-compacted tiling would need 100 blocks' worth of span
        let t: Vec<(usize, usize, f32)> = (0..16).map(|i| (i, i * 100, 1.0)).collect();
        let coo = Coo::from_triplets(16, 1600, &t);
        let hrpb = build_from_coo(&coo);
        assert_eq!(hrpb.num_blocks(), 1);
    }

    #[test]
    fn csc_brick_order_within_block() {
        // entries in brick columns 0 and 2 (slots 0-3 and 8-11)
        let coo = Coo::from_triplets(
            16,
            32,
            &[(0, 0, 1.0), (1, 1, 2.0), (0, 8, 3.0), (2, 9, 4.0), (3, 2, 5.0)],
        );
        let hrpb = build_from_coo(&coo);
        let blk = &hrpb.blocks[0];
        // 5 active columns -> slots {0:c0, 1:c1, 2:c2, 3:c8, 4:c9};
        // brick col 0 holds c0,c1,c2,c8 and brick col 1 holds c9
        assert_eq!(blk.active_cols, vec![0, 1, 2, 8, 9]);
        assert_eq!(blk.col_ptr[0], 0);
        assert!(blk.num_bricks() >= 1);
        assert!(roundtrip(&coo));
    }

    #[test]
    fn tm32_builds_and_roundtrips() {
        let mut rng = Rng::new(20);
        let coo = Coo::random(96, 128, 0.08, &mut rng);
        let csr = Csr::from_coo(&coo);
        let hrpb = build_with(&csr, 32, 16);
        hrpb.validate().unwrap();
        assert_eq!(hrpb.num_panels(), 3);
        assert_eq!(decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()), 0.0);
    }

    #[test]
    fn tk32_builds_and_roundtrips() {
        let mut rng = Rng::new(21);
        let coo = Coo::random(64, 200, 0.1, &mut rng);
        let csr = Csr::from_coo(&coo);
        let hrpb = build_with(&csr, 16, 32);
        hrpb.validate().unwrap();
        assert_eq!(decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()), 0.0);
    }

    #[test]
    fn prop_build_roundtrip_random_sparse() {
        let g = SparseGen { max_m: 70, max_k: 90, max_density: 0.25 };
        check("hrpb build/decode roundtrip", 50, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            if coo.nnz() == 0 {
                return true; // builder on empty matrix: no blocks
            }
            let hrpb = build_from_coo(&coo);
            hrpb.validate().is_ok()
                && decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()) == 0.0
        });
    }

    #[test]
    #[should_panic(expected = "TM must be <= 256")]
    fn tm_above_256_is_rejected_not_truncated() {
        // 512 is a brick_m multiple, so before the guard it sailed past the
        // assert and silently truncated `(r - r0) as u8` for rows >= 256
        let coo = Coo::from_triplets(512, 16, &[(0, 0, 1.0), (300, 1, 2.0)]);
        let _ = build_with(&Csr::from_coo(&coo), 512, 16);
    }

    #[test]
    fn tm_256_is_the_largest_legal_panel() {
        // rows 0 and 255 land in the same panel; row-in-panel 255 is the
        // last representable u8 offset
        let coo = Coo::from_triplets(300, 32, &[(0, 0, 1.0), (255, 3, 2.0), (299, 7, 3.0)]);
        let hrpb = build_with(&Csr::from_coo(&coo), 256, 16);
        hrpb.validate().unwrap();
        assert_eq!(hrpb.num_panels(), 2);
        assert_eq!(decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()), 0.0);
    }

    fn assert_identical(a: &Hrpb, b: &Hrpb) {
        assert_eq!((a.rows, a.cols, a.tm, a.tk, a.nnz), (b.rows, b.cols, b.tm, b.tk, b.nnz));
        assert_eq!(a.blocked_row_ptr, b.blocked_row_ptr);
        assert_eq!(a.blocks, b.blocks);
        assert_eq!(a.size_ptr, b.size_ptr);
        assert_eq!(a.active_cols, b.active_cols);
        assert_eq!(a.packed, b.packed, "parallel build must be byte-identical");
    }

    #[test]
    fn parallel_build_is_byte_identical_to_serial() {
        let mut rng = Rng::new(22);
        let coo = Coo::random(777, 300, 0.05, &mut rng);
        let csr = Csr::from_coo(&coo);
        let serial = build_with(&csr, 16, 16);
        for threads in [1usize, 2, 3, 8, 1000] {
            let parallel = build_with_parallel(&csr, 16, 16, threads);
            assert_identical(&serial, &parallel);
        }
        serial.validate().unwrap();
    }

    #[test]
    fn prop_parallel_equals_serial() {
        let g = SparseGen { max_m: 90, max_k: 110, max_density: 0.25 };
        check("parallel == serial build", 40, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let csr = Csr::from_coo(&coo);
            let serial = build_with(&csr, 16, 16);
            let parallel = build_with_parallel(&csr, 16, 16, 3);
            serial.blocked_row_ptr == parallel.blocked_row_ptr
                && serial.blocks == parallel.blocks
                && serial.size_ptr == parallel.size_ptr
                && serial.active_cols == parallel.active_cols
                && serial.packed == parallel.packed
        });
    }

    #[test]
    fn parallel_build_from_coo_roundtrips() {
        let mut rng = Rng::new(23);
        let coo = Coo::random(400, 256, 0.04, &mut rng);
        let hrpb = build_from_coo_parallel(&coo);
        hrpb.validate().unwrap();
        assert_eq!(decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()), 0.0);
    }

    #[test]
    fn catalog_geometries_build_roundtrip_and_parallel_matches() {
        let mut rng = Rng::new(77);
        let coo = Coo::random(100, 120, 0.08, &mut rng);
        let csr = Csr::from_coo(&coo);
        for geo in BrickGeometry::CATALOG {
            let hrpb = build_with_geometry(&csr, geo, TM, TK);
            hrpb.validate().unwrap();
            assert_eq!(hrpb.geometry, geo);
            assert_eq!(
                decode::to_dense(&hrpb).max_abs_diff(&coo.to_dense()),
                0.0,
                "{geo}: decode roundtrip"
            );
            let parallel = build_with_geometry_parallel(&csr, geo, TM, TK, 3);
            assert_eq!(hrpb.packed, parallel.packed, "{geo}: parallel byte-identity");
            assert_eq!(hrpb.blocks, parallel.blocks, "{geo}");
        }
    }

    #[test]
    fn dense_matrix_has_alpha_one() {
        let d = Dense::from_vec(16, 16, vec![1.0; 256]);
        let coo = Coo::from_dense(&d);
        let hrpb = build_from_coo(&coo);
        let stats = crate::hrpb::stats::compute(&hrpb);
        assert_eq!(stats.alpha, 1.0);
        assert_eq!(hrpb.num_blocks(), 1);
    }
}
