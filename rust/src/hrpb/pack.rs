//! BlkCSC byte packing — the paper's `packedBlocks` memory chunk (Fig. 5).
//!
//! Each block is serialized into a flat byte run so the whole matrix streams
//! through memory exactly the way the GPU kernel streams it from DRAM into
//! shared memory (Algorithm 1 line 17: one coalesced copy per block). The
//! layout keeps every field naturally aligned so the native engine can read
//! it in place without copying:
//!
//! ```text
//! offset 0                       col_ptr   [brick_cols + 1] u16
//! next                           rows      [num_bricks]      u8
//! pad to 8-byte boundary
//! next                           patterns  [num_bricks]      u64
//! next                           values    [nnz]             f32
//! pad to 8-byte boundary         (so the following block stays aligned)
//! ```
//!
//! `num_bricks` and `nnz` are not stored: `num_bricks = col_ptr[brick_cols]`
//! and `nnz = Σ popcount(pattern)`, mirroring the paper's decision to keep
//! the metadata minimal (§3.2 calls `colPtr`/`patterns`/`rows` collectively
//! "metadata").

use crate::hrpb::{Block, Hrpb};
use crate::params::BrickGeometry;
use crate::util::bits::round_up;
use std::borrow::Cow;

/// Byte size of one packed block for the given geometry and tile shape.
pub fn packed_size(block: &Block, geo: BrickGeometry, tk: usize) -> usize {
    let brick_cols = tk / geo.brick_k;
    let nb = block.num_bricks();
    let mut off = (brick_cols + 1) * 2; // col_ptr u16
    off += nb; // rows u8
    off = round_up(off, 8);
    off += nb * 8; // patterns u64
    off += block.nnz() * 4; // values f32
    round_up(off, 8)
}

/// Serialize every structured block into `hrpb.packed` / `hrpb.size_ptr` and
/// fill the matrix-level `active_cols` array (TK-padded per block).
pub fn pack(hrpb: &mut Hrpb) {
    let tk = hrpb.tk;
    let geo = hrpb.geometry;
    let total: usize = hrpb.blocks.iter().map(|b| packed_size(b, geo, tk)).sum();
    let mut packed = Vec::with_capacity(total);
    let mut size_ptr = Vec::with_capacity(hrpb.blocks.len() + 1);
    let mut active_cols = Vec::with_capacity(hrpb.blocks.len() * tk);
    size_ptr.push(0u64);

    for block in &hrpb.blocks {
        let start = packed.len();
        // col_ptr
        for &cp in &block.col_ptr {
            packed.extend_from_slice(&cp.to_le_bytes());
        }
        // rows
        packed.extend_from_slice(&block.rows);
        // pad to 8
        while packed.len() % 8 != 0 {
            packed.push(0);
        }
        // patterns
        for &p in &block.patterns {
            packed.extend_from_slice(&p.to_le_bytes());
        }
        // values
        for &v in &block.values {
            packed.extend_from_slice(&v.to_le_bytes());
        }
        while packed.len() % 8 != 0 {
            packed.push(0);
        }
        debug_assert_eq!(packed.len() - start, packed_size(block, geo, tk));
        size_ptr.push(packed.len() as u64);

        // TK-padded active columns; padding repeats the last real column so
        // every slot is an in-range row id of B (it carries only zeros).
        let last = *block.active_cols.last().expect("block has >= 1 active column");
        active_cols.extend_from_slice(&block.active_cols);
        active_cols.extend(std::iter::repeat(last).take(tk - block.active_cols.len()));
    }

    hrpb.packed = packed;
    hrpb.size_ptr = size_ptr;
    hrpb.active_cols = active_cols;
}

/// A view of one packed block (what the native engine reads on the hot
/// path — the in-shared-memory form of Algorithm 1 line 18's cast).
///
/// Fields are `Cow` slices: borrowed (zero-copy) when the underlying byte
/// run is naturally aligned — the case for every freshly packed `Hrpb` —
/// and owned copies otherwise (e.g. when an artifact was loaded from disk
/// into a `Vec<u8>` whose base alignment the allocator doesn't promise).
#[derive(Debug)]
pub struct PackedBlockView<'a> {
    pub col_ptr: Cow<'a, [u16]>,
    pub rows: &'a [u8],
    pub patterns: Cow<'a, [u64]>,
    pub values: Cow<'a, [f32]>,
}

/// Decode the packed bytes of block `b`, borrowing in place when aligned.
///
/// `pack` keeps every field naturally aligned *relative to the Vec base*;
/// the base itself is only as aligned as the allocator makes it. When a
/// field's absolute address is misaligned (a `Vec<u8>` loaded from disk is
/// all the serialized artifact path has), the field is copied out instead of
/// cast — behavior matches this documented contract in both cases.
pub fn view(hrpb: &Hrpb, b: usize) -> PackedBlockView<'_> {
    let tk = hrpb.tk;
    let brick_cols = tk / hrpb.geometry.brick_k;
    let bytes = &hrpb.packed[hrpb.size_ptr[b] as usize..hrpb.size_ptr[b + 1] as usize];

    let cp_len = brick_cols + 1;
    let (cp_bytes, rest) = bytes.split_at(cp_len * 2);
    let col_ptr = cast_slice::<u16>(cp_bytes, cp_len);
    let num_bricks = col_ptr[brick_cols] as usize;

    let rows = &rest[..num_bricks];
    let mut off = cp_len * 2 + num_bricks;
    off = round_up(off, 8);
    let patterns = cast_slice::<u64>(&bytes[off..off + num_bricks * 8], num_bricks);
    off += num_bricks * 8;
    let nnz: usize = patterns.iter().map(|p| p.count_ones() as usize).sum();
    let values = cast_slice::<f32>(&bytes[off..off + nnz * 4], nnz);

    PackedBlockView { col_ptr, rows, patterns, values }
}

/// Reinterpret a little-endian byte slice as `[T]`: a borrowed in-place cast
/// when the address is aligned for `T`, an owned element-wise copy when it
/// is not (the documented fallback; `read_unaligned` has identical
/// semantics to the cast).
fn cast_slice<T: Copy>(bytes: &[u8], len: usize) -> Cow<'_, [T]> {
    assert_eq!(bytes.len(), len * std::mem::size_of::<T>());
    let ptr = bytes.as_ptr();
    if ptr as usize % std::mem::align_of::<T>() == 0 {
        // SAFETY: length and alignment checked above; T is plain-old-data
        // (u16/u64/f32) with no invalid bit patterns.
        Cow::Borrowed(unsafe { std::slice::from_raw_parts(ptr as *const T, len) })
    } else {
        let mut out = Vec::with_capacity(len);
        for i in 0..len {
            // SAFETY: i * size_of::<T>() + size_of::<T>() <= bytes.len() by
            // the length assert; read_unaligned has no alignment requirement.
            let v = unsafe { (ptr.add(i * std::mem::size_of::<T>()) as *const T).read_unaligned() };
            out.push(v);
        }
        Cow::Owned(out)
    }
}

/// Verify the byte stream decodes back to the structured blocks (used by
/// `Hrpb::validate` and the property tests).
pub fn validate_packed(hrpb: &Hrpb) -> Result<(), String> {
    if hrpb.size_ptr.len() != hrpb.blocks.len() + 1 {
        return Err("size_ptr length".into());
    }
    if *hrpb.size_ptr.last().unwrap_or(&0) as usize != hrpb.packed.len() {
        return Err("size_ptr tail != packed length".into());
    }
    for (b, block) in hrpb.blocks.iter().enumerate() {
        let v = view(hrpb, b);
        if v.col_ptr.as_ref() != block.col_ptr.as_slice() {
            return Err(format!("block {b}: packed col_ptr mismatch"));
        }
        if v.rows != block.rows.as_slice() {
            return Err(format!("block {b}: packed rows mismatch"));
        }
        if v.patterns.as_ref() != block.patterns.as_slice() {
            return Err(format!("block {b}: packed patterns mismatch"));
        }
        if v.values.as_ref() != block.values.as_slice() {
            return Err(format!("block {b}: packed values mismatch"));
        }
        let padded = hrpb.block_active_cols(b);
        if &padded[..block.active_cols.len()] != block.active_cols.as_slice() {
            return Err(format!("block {b}: active_cols prefix mismatch"));
        }
        let last = *block.active_cols.last().unwrap();
        if padded[block.active_cols.len()..].iter().any(|&c| c != last) {
            return Err(format!("block {b}: active_cols padding not last-repeat"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::build_from_coo;
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    #[test]
    fn packed_roundtrip_random() {
        let mut rng = Rng::new(7);
        let coo = Coo::random(128, 256, 0.05, &mut rng);
        let hrpb = build_from_coo(&coo);
        validate_packed(&hrpb).unwrap();
    }

    #[test]
    fn packed_size_matches_stream() {
        let mut rng = Rng::new(8);
        let coo = Coo::random(64, 64, 0.2, &mut rng);
        let hrpb = build_from_coo(&coo);
        for (b, block) in hrpb.blocks.iter().enumerate() {
            let span = (hrpb.size_ptr[b + 1] - hrpb.size_ptr[b]) as usize;
            assert_eq!(span, packed_size(block, hrpb.geometry, hrpb.tk));
        }
    }

    #[test]
    fn packed_roundtrip_across_the_catalog() {
        let mut rng = Rng::new(10);
        let coo = Coo::random(96, 128, 0.07, &mut rng);
        let csr = crate::formats::Csr::from_coo(&coo);
        for geo in BrickGeometry::CATALOG {
            let hrpb = crate::hrpb::build_with_geometry(&csr, geo, 16, 16);
            validate_packed(&hrpb).unwrap_or_else(|e| panic!("{geo}: {e}"));
        }
    }

    #[test]
    fn blocks_are_eight_aligned() {
        // every block starts at an 8-aligned *offset*; base alignment of the
        // Vec is the allocator's business and `view` no longer relies on it
        let mut rng = Rng::new(9);
        let coo = Coo::random(96, 96, 0.1, &mut rng);
        let hrpb = build_from_coo(&coo);
        for &off in &hrpb.size_ptr {
            assert_eq!(off % 8, 0);
        }
    }

    #[test]
    fn cast_slice_borrows_when_aligned_and_copies_when_not() {
        // the same 3 u64 values written at an aligned and a misaligned
        // offset of one buffer: the aligned read borrows in place, the
        // misaligned read takes the documented copy fallback — identical
        // values either way
        let vals = [0x0102030405060708u64, 0x1112131415161718, u64::MAX];
        let mut buf = vec![0u8; 64];
        let base = buf.as_ptr() as usize;
        let aligned_at = (8 - base % 8) % 8;
        let misaligned_at = aligned_at + 25; // 25 ≢ 0 (mod 8)
        for (i, v) in vals.iter().enumerate() {
            buf[aligned_at + i * 8..aligned_at + (i + 1) * 8].copy_from_slice(&v.to_le_bytes());
            buf[misaligned_at + i * 8..misaligned_at + (i + 1) * 8]
                .copy_from_slice(&v.to_le_bytes());
        }

        let aligned = cast_slice::<u64>(&buf[aligned_at..aligned_at + 24], 3);
        assert!(matches!(aligned, Cow::Borrowed(_)));
        assert_eq!(aligned.as_ref(), &vals);

        let off = &buf[misaligned_at..misaligned_at + 24];
        assert_ne!(off.as_ptr() as usize % 8, 0, "test needs a misaligned slice");
        let copied = cast_slice::<u64>(off, 3);
        assert!(matches!(copied, Cow::Owned(_)));
        assert_eq!(copied.as_ref(), &vals);
    }

    #[test]
    fn prop_pack_view_roundtrip() {
        let g = SparseGen { max_m: 50, max_k: 80, max_density: 0.3 };
        check("pack/view roundtrip", 40, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            if coo.nnz() == 0 {
                return true;
            }
            let hrpb = build_from_coo(&coo);
            validate_packed(&hrpb).is_ok()
        });
    }

    #[test]
    fn empty_matrix_packs_to_nothing() {
        let coo = Coo::new(32, 32);
        let hrpb = build_from_coo(&coo);
        assert!(hrpb.packed.is_empty());
        assert_eq!(hrpb.size_ptr, vec![0]);
        validate_packed(&hrpb).unwrap();
    }
}
