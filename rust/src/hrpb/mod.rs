//! HRPB — the paper's Hierarchical Row-Panel-Blocking sparse representation
//! (§3.2, Figs 3-5).
//!
//! A matrix is cut into row panels of height `TM`. Inside each panel, the
//! *active* columns (those with at least one nonzero) are compacted to the
//! left and grouped into `(TM, TK)` blocks; each block subdivides into
//! `(brick_m, brick_k)` bricks — the instance's
//! [`BrickGeometry`](crate::params::BrickGeometry), 16×4 by default — whose
//! nonzero layout is a 64-bit pattern.
//! Nonzero values are stored per block in brick-CSC order (brick columns
//! left-to-right, bricks top-to-bottom within a column, values row-major
//! within a brick).
//!
//! Two forms coexist:
//! * [`Block`] / panel views — structured, used by the builder and tests;
//! * the packed byte stream ([`Hrpb::packed`], mirroring the paper's
//!   `packedBlocks` + `sizePtr` + `blockedRowPtr` + `activeCols`) — what the
//!   native engine actually reads on the hot path, exactly as the GPU kernel
//!   streams `packedBlocks` from DRAM through shared memory.

pub mod builder;
pub mod decode;
pub mod pack;
pub mod serialize;
pub mod stats;
pub mod store;

pub use builder::{
    build, build_from_coo, build_from_coo_parallel, build_with_geometry,
    build_with_geometry_parallel, build_with_parallel,
};
pub use serialize::Artifact;
pub use stats::HrpbStats;
pub use store::{ArtifactStore, StoreStats};

use crate::params::BrickGeometry;

/// One `(TM, TK)` block in structured form (paper Fig. 4).
///
/// Active bricks are kept in brick-CSC order. `col_ptr[c]..col_ptr[c+1]`
/// indexes the active bricks of brick-column `c`; `rows[j]` is the brick-row
/// of active brick `j`; `patterns[j]` its nonzero mask (low `geo.bits()`
/// bits of a u64 word); `values` concatenates every active brick's nonzeros
/// (row-major within a brick).
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    /// Original column ids of this block's slots (compaction map); length
    /// `<= TK`, unpadded.
    pub active_cols: Vec<u32>,
    /// `TK/brick_k + 1` entries.
    pub col_ptr: Vec<u16>,
    /// Brick-row index of each active brick (`< TM/brick_m`).
    pub rows: Vec<u8>,
    /// Nonzero pattern word of each active brick.
    pub patterns: Vec<u64>,
    /// Nonzero values in brick-CSC, row-major-within-brick order.
    pub values: Vec<f32>,
}

impl Block {
    /// Number of active bricks.
    pub fn num_bricks(&self) -> usize {
        self.patterns.len()
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Check structural invariants (property tests).
    pub fn validate(&self, geo: BrickGeometry, tm: usize, tk: usize) -> Result<(), String> {
        let bricks_per_col = tm / geo.brick_m;
        let brick_cols = tk / geo.brick_k;
        if self.col_ptr.len() != brick_cols + 1 {
            return Err("col_ptr length".into());
        }
        if self.col_ptr[0] != 0 || *self.col_ptr.last().unwrap() as usize != self.num_bricks() {
            return Err("col_ptr endpoints".into());
        }
        if self.rows.len() != self.num_bricks() {
            return Err("rows length".into());
        }
        if self.active_cols.is_empty() || self.active_cols.len() > tk {
            return Err(format!("active_cols length {}", self.active_cols.len()));
        }
        let mut nnz = 0usize;
        for c in 0..brick_cols {
            let (s, e) = (self.col_ptr[c] as usize, self.col_ptr[c + 1] as usize);
            if s > e {
                return Err("col_ptr not monotone".into());
            }
            for j in s..e {
                if self.rows[j] as usize >= bricks_per_col {
                    return Err("brick row out of range".into());
                }
                if j > s && self.rows[j - 1] >= self.rows[j] {
                    return Err("bricks not sorted within column".into());
                }
                if self.patterns[j] == 0 {
                    return Err("active brick with empty pattern".into());
                }
                nnz += self.patterns[j].count_ones() as usize;
            }
        }
        if nnz != self.values.len() {
            return Err(format!("pattern nnz {nnz} != values {}", self.values.len()));
        }
        Ok(())
    }
}

/// The matrix-level HRPB container (paper Fig. 5).
#[derive(Clone, Debug)]
pub struct Hrpb {
    /// Original matrix shape.
    pub rows: usize,
    pub cols: usize,
    /// Tile parameters this instance was built with.
    pub tm: usize,
    pub tk: usize,
    /// Brick geometry this instance was built with (pattern word layout,
    /// brick grid shape, packed-stream framing all depend on it).
    pub geometry: BrickGeometry,
    /// Total stored nonzeros.
    pub nnz: usize,
    /// Structured blocks, panel-major (kept for verification & decoding).
    pub blocks: Vec<Block>,
    /// `blocks` index range of each row panel: `blocked_row_ptr[p] ..
    /// blocked_row_ptr[p+1]` (paper's `blockedRowPtr`, length M/TM + 1).
    pub blocked_row_ptr: Vec<u32>,
    /// Byte stream of all packed blocks (paper's `packedBlocks`).
    pub packed: Vec<u8>,
    /// Byte offset of each block in `packed` (paper's `sizePtr`).
    pub size_ptr: Vec<u64>,
    /// Active column ids, `TK`-padded per block (paper's `activeCols`;
    /// padding slots repeat the block's last real column — they carry no
    /// values, so any in-range id is safe).
    pub active_cols: Vec<u32>,
    /// Build-time row permutation ([`crate::reorder`]): structural row `i`
    /// of this HRPB holds original matrix row `perm.new_to_old[i]`. `None`
    /// = natural order. The native engine scatters its output through this
    /// map in the kernel epilogue, so `spmm` results always come back in
    /// original row order; [`decode`] honors it the same way.
    pub perm: Option<std::sync::Arc<crate::reorder::RowPermutation>>,
}

impl Hrpb {
    /// Number of row panels.
    pub fn num_panels(&self) -> usize {
        self.blocked_row_ptr.len() - 1
    }

    /// Number of blocks.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Blocks of row panel `p`.
    pub fn panel_blocks(&self, p: usize) -> &[Block] {
        let (s, e) = (self.blocked_row_ptr[p] as usize, self.blocked_row_ptr[p + 1] as usize);
        &self.blocks[s..e]
    }

    /// `TK`-padded active-column slice of block `b`.
    pub fn block_active_cols(&self, b: usize) -> &[u32] {
        &self.active_cols[b * self.tk..(b + 1) * self.tk]
    }

    /// Validate the whole structure (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.blocked_row_ptr.len() != crate::util::bits::ceil_div(self.rows.max(1), self.tm) + 1 {
            return Err("blocked_row_ptr length".into());
        }
        if *self.blocked_row_ptr.last().unwrap() as usize != self.blocks.len() {
            return Err("blocked_row_ptr tail".into());
        }
        if self.size_ptr.len() != self.blocks.len() + 1 {
            return Err("size_ptr length".into());
        }
        if self.active_cols.len() != self.blocks.len() * self.tk {
            return Err("active_cols length".into());
        }
        let mut nnz = 0usize;
        for (i, blk) in self.blocks.iter().enumerate() {
            blk.validate(self.geometry, self.tm, self.tk)
                .map_err(|e| format!("block {i}: {e}"))?;
            for &c in &blk.active_cols {
                if c as usize >= self.cols {
                    return Err(format!("block {i}: column {c} out of range"));
                }
            }
            nnz += blk.nnz();
        }
        if nnz != self.nnz {
            return Err(format!("nnz mismatch: blocks {nnz} vs header {}", self.nnz));
        }
        if let Some(perm) = &self.perm {
            if perm.len() != self.rows {
                return Err(format!(
                    "permutation spans {} rows, matrix has {}",
                    perm.len(),
                    self.rows
                ));
            }
            perm.validate()?;
        }
        pack::validate_packed(self)?;
        Ok(())
    }
}
