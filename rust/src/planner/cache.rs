//! Concurrent plan cache — repeat registrations of the same matrix reuse the
//! ranked plan instead of re-running profile + cost models.
//!
//! Keys are structural fingerprints ([`super::fingerprint`]) combined with
//! the planning width, so the same matrix planned at two widths holds two
//! entries. Online feedback invalidates by bumping a generation counter:
//! entries stamped with an older generation are treated as misses and
//! replaced, so demotions propagate without a stop-the-world flush.

use super::Plan;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Cache statistics snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub generation: u64,
}

#[derive(Default)]
pub struct PlanCache {
    entries: RwLock<HashMap<(u64, usize), (u64, Arc<Plan>)>>,
    hits: AtomicU64,
    misses: AtomicU64,
    generation: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Current invalidation generation.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Invalidate every cached plan (feedback demoted an engine, or the
    /// calibration changed): plans stamped before the bump become misses.
    pub fn invalidate(&self) {
        self.generation.fetch_add(1, Ordering::Relaxed);
    }

    /// Look a plan up; counts a hit only when the entry is current.
    pub fn get(&self, fingerprint: u64, width: usize) -> Option<Arc<Plan>> {
        let generation = self.generation();
        let guard = self.entries.read().unwrap();
        match guard.get(&(fingerprint, width)) {
            Some((stamp, plan)) if *stamp == generation => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(plan.clone())
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a plan under the current generation.
    pub fn insert(&self, fingerprint: u64, width: usize, plan: Arc<Plan>) {
        let generation = self.generation();
        self.entries.write().unwrap().insert((fingerprint, width), (generation, plan));
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.entries.read().unwrap().len(),
            generation: self.generation(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::Algo;
    use crate::synergy::Synergy;

    fn dummy_plan(engine: Algo) -> Arc<Plan> {
        Arc::new(Plan {
            engine,
            width: 128,
            predicted_s: 1e-4,
            predicted_s_per_col: 1e-6,
            slab_width: 0,
            reorder: None,
            alpha: 0.5,
            synergy: Synergy::High,
            ranked: Vec::new(),
            rationale: "test".to_string(),
            fingerprint: 7,
        })
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let cache = PlanCache::new();
        assert!(cache.get(7, 128).is_none());
        cache.insert(7, 128, dummy_plan(Algo::Hrpb));
        let got = cache.get(7, 128).unwrap();
        assert_eq!(got.engine, Algo::Hrpb);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn width_is_part_of_the_key() {
        let cache = PlanCache::new();
        cache.insert(7, 128, dummy_plan(Algo::Hrpb));
        assert!(cache.get(7, 32).is_none());
        assert!(cache.get(8, 128).is_none());
        assert!(cache.get(7, 128).is_some());
    }

    #[test]
    fn invalidate_turns_hits_into_misses() {
        let cache = PlanCache::new();
        cache.insert(7, 128, dummy_plan(Algo::Hrpb));
        assert!(cache.get(7, 128).is_some());
        cache.invalidate();
        assert!(cache.get(7, 128).is_none(), "stale generation must miss");
        // re-inserting under the new generation makes it hit again
        cache.insert(7, 128, dummy_plan(Algo::Sputnik));
        assert_eq!(cache.get(7, 128).unwrap().engine, Algo::Sputnik);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = std::sync::Arc::new(PlanCache::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let cache = cache.clone();
                s.spawn(move || {
                    for i in 0..50 {
                        cache.insert(t * 100 + i, 128, dummy_plan(Algo::Csr));
                        let _ = cache.get(t * 100 + i, 128);
                    }
                });
            }
        });
        assert_eq!(cache.stats().entries, 200);
    }
}
