//! Calibration — anchor the analytical cost model to this host.
//!
//! The `gpumodel` predictions are *modeled GPU* times; the engines this repo
//! executes are the CPU re-hosts. The relative structure (who wins on which
//! matrix) transfers, the absolute scale does not. A calibration pass times
//! every candidate engine on sampled matrices at a sampled width, and stores
//! the per-engine ratio `measured / modeled` as a multiplicative correction.
//! Corrected predictions are in *this machine's* seconds, which makes the
//! online observed-vs-predicted feedback meaningful.
//!
//! Profiles persist as JSON (`util::json`; serde is unavailable offline) so
//! repeat runs on the same machine skip the micro-benchmark.

use crate::formats::{Coo, Dense};
use crate::gen::{Family, MatrixSpec};
use crate::gpumodel::{algos, Machine, MatrixProfile};
use crate::params::BrickGeometry;
use crate::spmm::{Algo, SpmmEngine};
use crate::util::json::{self, Json};
use crate::util::stats::geomean;
use crate::util::timer::measure;
use std::path::Path;

/// Per-engine model correction for one (machine, host) pair.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Multiplier on the modeled time, indexed by [`Algo::index`].
    pub scale: [f64; Algo::COUNT],
    /// False for the identity profile (predictions stay in modeled-GPU
    /// space; the feedback loop stays disarmed to avoid spurious demotion).
    pub calibrated: bool,
    /// Dense width the micro-benchmark sampled.
    pub width: usize,
    /// Machine model the corrections were measured against.
    pub machine: String,
    /// HRPB column-slab width the sweep measured fastest on this host
    /// (`0` = unswept: the engine's cache model chooses per call). Recorded
    /// into every [`crate::planner::Plan`] this calibration produces.
    pub slab_width: usize,
    /// Measured per-catalog-geometry runtime ratio against the default
    /// brick shape on the FEM-regime sample
    /// (`measured(geometry) / measured(16x4)`; `1.0` = unswept/identity),
    /// indexed by catalog position ([`BrickGeometry::CATALOG`]). Recorded so
    /// the geometry experiment and `plan --json` consumers can sanity-check
    /// the exact pricer's predicted savings against host timings.
    pub geometry_scale: [f64; BrickGeometry::CATALOG.len()],
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration::identity()
    }
}

impl Calibration {
    /// The identity profile: modeled times pass through unchanged.
    pub fn identity() -> Calibration {
        Calibration {
            scale: [1.0; Algo::COUNT],
            calibrated: false,
            width: 0,
            machine: String::new(),
            slab_width: 0,
            geometry_scale: [1.0; BrickGeometry::CATALOG.len()],
        }
    }

    pub fn scale_for(&self, algo: Algo) -> f64 {
        self.scale[algo.index()]
    }

    pub fn to_json(&self) -> Json {
        let scales: Vec<(&str, Json)> = Algo::all()
            .into_iter()
            .map(|a| (a.name(), Json::num(self.scale[a.index()])))
            .collect();
        let names: Vec<String> = BrickGeometry::CATALOG.iter().map(|g| g.name()).collect();
        let geos: Vec<(&str, Json)> = names
            .iter()
            .zip(self.geometry_scale)
            .map(|(n, s)| (n.as_str(), Json::num(s)))
            .collect();
        Json::obj(vec![
            ("machine", Json::str(self.machine.clone())),
            ("width", Json::num(self.width as f64)),
            ("calibrated", Json::Bool(self.calibrated)),
            ("slab_width", Json::num(self.slab_width as f64)),
            ("geometry_scale", Json::obj(geos)),
            ("scale", Json::obj(scales)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Calibration, String> {
        let machine = j
            .get("machine")
            .and_then(|m| m.as_str())
            .ok_or("calibration: missing machine")?
            .to_string();
        let width = j.get("width").and_then(|w| w.as_usize()).unwrap_or(0);
        let calibrated = matches!(j.get("calibrated"), Some(Json::Bool(true)));
        // profiles written before the exec runtime lack the field: 0 = auto
        let slab_width = j.get("slab_width").and_then(|w| w.as_usize()).unwrap_or(0);
        // profiles written before the geometry catalog lack this one too:
        // identity ratios (unswept)
        let mut geometry_scale = [1.0; BrickGeometry::CATALOG.len()];
        if let Some(gs) = j.get("geometry_scale") {
            for (i, g) in BrickGeometry::CATALOG.iter().enumerate() {
                if let Some(v) = gs.get(&g.name()).and_then(|v| v.as_f64()) {
                    if v.is_finite() && v > 0.0 {
                        geometry_scale[i] = v;
                    }
                }
            }
        }
        let scales = j.get("scale").ok_or("calibration: missing scale")?;
        let mut scale = [1.0; Algo::COUNT];
        for a in Algo::all() {
            if let Some(s) = scales.get(a.name()).and_then(|v| v.as_f64()) {
                if s.is_finite() && s > 0.0 {
                    scale[a.index()] = s;
                }
            }
        }
        Ok(Calibration { scale, calibrated, width, machine, slab_width, geometry_scale })
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        std::fs::write(path, self.to_json().to_string()).map_err(|e| e.to_string())
    }

    pub fn load(path: &Path) -> Result<Calibration, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Calibration::from_json(&json::parse(&text)?)
    }
}

/// The matrices the micro-benchmark samples: one per synergy regime so every
/// engine is timed in the regime it is expected to win (or lose) in.
fn sample_specs(rows: usize) -> Vec<MatrixSpec> {
    vec![
        MatrixSpec {
            name: "calib-fem".into(),
            rows,
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
            seed: 0xCA11B0,
        },
        MatrixSpec {
            name: "calib-mesh".into(),
            rows,
            family: Family::Mesh { dims: 2 },
            seed: 0xCA11B1,
        },
        MatrixSpec {
            name: "calib-rmat".into(),
            rows,
            family: Family::Rmat { edge_factor: 6, skew: 0.57 },
            seed: 0xCA11B2,
        },
    ]
}

/// Slab widths the calibration sweep measures, plus `0` (the engine's
/// auto/cache-model choice) as the baseline candidate.
pub const SLAB_SWEEP: [usize; 5] = [0, 32, 64, 128, 256];

/// Sweep [`SLAB_SWEEP`] on one sample matrix at `width` and return the
/// fastest slab setting (`0` = auto). Timed through `spmm_into` with a
/// reused output buffer so allocation noise never biases the pick.
fn sweep_slab_width(coo: &Coo, width: usize) -> usize {
    use crate::spmm::hrpb::{ExecOpts, HrpbEngine};
    let engine = HrpbEngine::prepare(coo);
    let b = Dense::from_vec(coo.cols, width, vec![0.5; coo.cols * width]);
    let mut out = Dense::zeros(coo.rows, width);
    let mut best = (f64::INFINITY, 0usize);
    for ts in SLAB_SWEEP {
        if ts > width {
            continue; // indistinguishable from a single slab at this width
        }
        let meas = measure(1, 3, || {
            engine.spmm_into_opts(&b, &mut out, ExecOpts { pooled: true, slab_width: ts });
        });
        if meas.median_s < best.0 {
            best = (meas.median_s, ts);
        }
    }
    best.1
}

/// Sweep the brick-geometry catalog on one sample matrix at `width`: build
/// an HRPB engine per catalog entry and time `spmm_into` with a reused
/// buffer, returning each entry's runtime ratio against the default shape
/// (entry 0, always `1.0`). The pricer predicts geometry wins from brick
/// counts; this records how those predictions land in host seconds.
fn sweep_geometry(coo: &Coo, width: usize) -> [f64; BrickGeometry::CATALOG.len()] {
    use crate::spmm::hrpb::{ExecOpts, HrpbEngine};
    let b = Dense::from_vec(coo.cols, width, vec![0.5; coo.cols * width]);
    let mut out = Dense::zeros(coo.rows, width);
    let mut times = [0.0f64; BrickGeometry::CATALOG.len()];
    for (t, &geo) in times.iter_mut().zip(&BrickGeometry::CATALOG) {
        let engine = HrpbEngine::prepare_with_geometry(coo, geo);
        let meas = measure(1, 3, || {
            engine.spmm_into_opts(&b, &mut out, ExecOpts { pooled: true, slab_width: 0 });
        });
        *t = meas.median_s;
    }
    let base = times[0].max(1e-12);
    times.map(|t| (t / base).max(1e-12))
}

/// Time `candidates` on sampled matrices at `width` and derive per-engine
/// corrections against `machine`'s model. `rows` sizes the samples (the CLI
/// uses ~16k; tests shrink it). When the HRPB engine is among the
/// candidates, the pass also sweeps its column-slab widths ([`SLAB_SWEEP`])
/// and the brick-geometry catalog ([`BrickGeometry::CATALOG`]), recording
/// the host's fastest slab setting and per-geometry runtime ratios.
pub fn microbenchmark(
    machine: &Machine,
    width: usize,
    rows: usize,
    candidates: &[Algo],
) -> Calibration {
    let mut ratios: Vec<Vec<f64>> = vec![Vec::new(); Algo::COUNT];
    let mut slab_width = 0usize;
    let mut geometry_scale = [1.0; BrickGeometry::CATALOG.len()];
    let mut slab_swept = false;
    for spec in sample_specs(rows.max(256)) {
        let coo: Coo = spec.generate();
        if coo.nnz() == 0 {
            continue;
        }
        let profile = MatrixProfile::compute(&coo);
        let b = Dense::from_vec(coo.cols, width, vec![0.5; coo.cols * width]);
        let mut out = Dense::zeros(coo.rows, width);
        for &algo in candidates {
            let modeled = algos::predict(algo, &profile, width, machine).time_s;
            if !(modeled > 0.0) {
                continue;
            }
            let engine: Box<dyn SpmmEngine> = algo.prepare(&coo);
            // spmm_into with a reused buffer: time the kernel, not the
            // allocator (the serving hot path is allocation-free too)
            let meas = measure(1, 3, || {
                engine.spmm_into(&b, &mut out);
            });
            ratios[algo.index()].push(meas.median_s / modeled);
        }
        // slab sweep on the first (FEM-regime) sample — the regime where
        // the HRPB engine actually serves
        if !slab_swept && candidates.contains(&Algo::Hrpb) {
            slab_width = sweep_slab_width(&coo, width);
            geometry_scale = sweep_geometry(&coo, width);
            slab_swept = true;
        }
    }
    let mut scale = [1.0; Algo::COUNT];
    for a in Algo::all() {
        let rs = &ratios[a.index()];
        if !rs.is_empty() {
            scale[a.index()] = geomean(rs).max(1e-12);
        }
    }
    Calibration {
        scale,
        calibrated: true,
        width,
        machine: machine.name.to_string(),
        slab_width,
        geometry_scale,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_inert() {
        let c = Calibration::identity();
        assert!(!c.calibrated);
        for a in Algo::all() {
            assert_eq!(c.scale_for(a), 1.0);
        }
    }

    #[test]
    fn json_round_trip() {
        let mut c = Calibration::identity();
        c.scale[Algo::Hrpb.index()] = 123.5;
        c.scale[Algo::Csr.index()] = 0.25;
        c.calibrated = true;
        c.width = 64;
        c.machine = "A100".to_string();
        c.slab_width = 128;
        c.geometry_scale[2] = 0.75;
        let back = Calibration::from_json(&c.to_json()).unwrap();
        assert!(back.calibrated);
        assert_eq!(back.width, 64);
        assert_eq!(back.machine, "A100");
        assert_eq!(back.slab_width, 128);
        assert_eq!(back.geometry_scale[2], 0.75);
        assert_eq!(back.geometry_scale[0], 1.0);
        assert_eq!(back.scale_for(Algo::Hrpb), 123.5);
        assert_eq!(back.scale_for(Algo::Csr), 0.25);
        assert_eq!(back.scale_for(Algo::Coo), 1.0);
    }

    #[test]
    fn save_load_round_trip() {
        let mut c = Calibration::identity();
        c.calibrated = true;
        c.machine = "RTX-4090".to_string();
        c.scale[Algo::Sputnik.index()] = 42.0;
        let path = std::env::temp_dir().join("cutespmm_calib_test/profile.json");
        c.save(&path).unwrap();
        let back = Calibration::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.machine, "RTX-4090");
        assert_eq!(back.scale_for(Algo::Sputnik), 42.0);
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(Calibration::load(Path::new("/nonexistent/profile.json")).is_err());
        assert!(Calibration::from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn pre_runtime_profiles_parse_with_auto_slab() {
        // a profile written before the slab knob existed must still load
        let mut c = Calibration::identity();
        c.calibrated = true;
        c.machine = "A100".into();
        let Json::Obj(mut m) = c.to_json() else { panic!("object") };
        m.remove("slab_width");
        m.remove("geometry_scale");
        let back = Calibration::from_json(&Json::Obj(m)).unwrap();
        assert_eq!(back.slab_width, 0, "missing field defaults to auto");
        assert_eq!(
            back.geometry_scale,
            [1.0; BrickGeometry::CATALOG.len()],
            "missing geometry sweep defaults to identity ratios"
        );
    }

    #[test]
    fn microbenchmark_produces_positive_scales_and_a_swept_slab() {
        // tiny samples: this is a structure test, not a timing test
        let c = microbenchmark(&Machine::a100(), 16, 256, &[Algo::Csr, Algo::Hrpb]);
        assert!(c.calibrated);
        assert!(c.scale_for(Algo::Csr) > 0.0);
        assert!(c.scale_for(Algo::Hrpb) > 0.0);
        // untimed engines keep the identity scale
        assert_eq!(c.scale_for(Algo::Dense), 1.0);
        // the sweep ran and picked a setting from the candidate set
        assert!(SLAB_SWEEP.contains(&c.slab_width), "slab {}", c.slab_width);
        // the geometry sweep ran: ratios are positive and anchored at the
        // default shape
        assert_eq!(c.geometry_scale[0], 1.0, "default shape is the baseline");
        assert!(c.geometry_scale.iter().all(|&s| s > 0.0));

        // without the HRPB candidate there is nothing to sweep
        let no_hrpb = microbenchmark(&Machine::a100(), 16, 256, &[Algo::Csr]);
        assert_eq!(no_hrpb.slab_width, 0);
        assert_eq!(no_hrpb.geometry_scale, [1.0; BrickGeometry::CATALOG.len()]);
    }
}
