//! Synergy-driven adaptive engine selection.
//!
//! The paper's central observation (§6.4, Table 1) is that the packed brick
//! density α — via `OI_shmem = 512·α` — predicts when the TCU path beats the
//! best scalar-core SpMM. This module *acts* on that prediction: at
//! registration time a [`Planner`] combines the [`crate::synergy`]
//! classification with [`crate::gpumodel`] predicted runtimes for every
//! executable engine and produces a [`Plan`] — a ranked engine table plus
//! the chosen engine and a human-readable rationale.
//!
//! Layers on top of the ranking:
//!
//! * **Calibration** ([`calibrate`]) — an optional micro-benchmark pass that
//!   times candidate engines on sampled matrices and rescales the analytical
//!   model into this host's seconds, persisted per machine profile.
//! * **Plan cache** ([`cache`]) — plans are memoized by structural matrix
//!   fingerprint, so repeat registrations are free.
//! * **Online feedback** ([`feedback`]) — workers report observed batch
//!   latency; an engine whose observed/predicted ratio drifts past the
//!   demotion threshold is penalized in future plans and cached plans are
//!   invalidated.
//!
//! The serving layer consumes this through `EnginePolicy::Auto`
//! ([`crate::coordinator`]); the `cutespmm plan` CLI subcommand prints the
//! ranked table directly.

pub mod cache;
pub mod calibrate;
pub mod feedback;

pub use cache::{CacheStats, PlanCache};
pub use calibrate::Calibration;
pub use feedback::{DriftSnapshot, FeedbackTracker};

use crate::formats::Coo;
use crate::gpumodel::{algos, Bound, Machine, MatrixProfile};
use crate::hrpb::Hrpb;
use crate::params::BrickGeometry;
use crate::spmm::Algo;
use crate::synergy::Synergy;
use std::sync::{Arc, RwLock};

/// Engines the planner ranks. `Dense` is excluded: materializing the
/// zero-filled operand is the ablation strawman, never a serving choice.
pub const CANDIDATES: [Algo; 6] =
    [Algo::Hrpb, Algo::TcGnn, Algo::Csr, Algo::Coo, Algo::Sputnik, Algo::GeSpmm];

/// One row of the ranked engine table.
#[derive(Clone, Copy, Debug)]
pub struct RankedChoice {
    pub algo: Algo,
    /// Raw analytical model time (modeled-GPU seconds).
    pub modeled_s: f64,
    /// Calibration-corrected time (no feedback penalty) — what observed
    /// latency is compared against.
    pub calibrated_s: f64,
    /// Calibration- and penalty-corrected time the ranking sorts by.
    pub predicted_s: f64,
    /// What bounds the kernel in the model.
    pub bound: Bound,
}

/// An executable per-matrix plan.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The chosen engine.
    pub engine: Algo,
    /// Dense width the plan was evaluated at.
    pub width: usize,
    /// Calibration-corrected predicted time of the chosen engine at
    /// `width`. Deliberately excludes the feedback penalty: the feedback
    /// loop compares observed latency against this, and folding the
    /// penalty in would make the drift signal self-referential (a demoted
    /// engine would immediately look healthy again and flap).
    pub predicted_s: f64,
    /// `predicted_s / width` — the coordinator scales this by the fused
    /// batch width to get a per-batch prediction for the feedback loop, and
    /// the QoS admission layer scales it by a request's width for its
    /// cost-aware shedding and wait estimates (see [`crate::qos`]).
    pub predicted_s_per_col: f64,
    /// Column-slab width for the HRPB engine's execution runtime
    /// ([`crate::spmm::exec::slab`]): `0` = auto (the engine's cache model
    /// chooses per call), otherwise the width the calibration sweep measured
    /// fastest on this host. The registry installs it on the engine at
    /// registration time; artifacts round-trip it.
    pub slab_width: usize,
    /// Brick geometry the HRPB was (or is to be) built with
    /// ([`crate::params::BrickGeometry`]): the registry prices the whole
    /// catalog from CSR before building and installs the winner here, so
    /// `alpha`, `synergy` and the ranked HRPB row all describe the structure
    /// at *this* shape. Artifacts round-trip it (format v4).
    pub geometry: BrickGeometry,
    /// Row-reorder knob ([`crate::reorder`]): `Some` when the
    /// similarity-clustered permutation is active for this matrix, carrying
    /// the α/β before/after and the one-time cost. When set, `alpha` and
    /// `synergy` describe the *post-reorder* structure — the one the HRPB
    /// engine actually executes. Artifacts round-trip it (format v3).
    pub reorder: Option<crate::reorder::Gains>,
    /// Packed brick density of the matrix.
    pub alpha: f64,
    /// Table 1 class of `alpha`.
    pub synergy: Synergy,
    /// All candidates, fastest first.
    pub ranked: Vec<RankedChoice>,
    /// Why this engine (synergy class + model margin).
    pub rationale: String,
    /// Structural fingerprint the plan is cached under.
    pub fingerprint: u64,
}

impl Plan {
    /// Machine-readable form of the ranked-engine table, consumed by
    /// `cutespmm plan --json` so scripts can parse the decision.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("engine", Json::str(self.engine.name())),
            ("width", Json::num(self.width as f64)),
            ("predicted_s", Json::num(self.predicted_s)),
            ("predicted_s_per_col", Json::num(self.predicted_s_per_col)),
            ("slab_width", Json::num(self.slab_width as f64)),
            ("geometry", Json::str(self.geometry.name())),
            ("reorder", Json::Bool(self.reorder.is_some())),
            (
                "reorder_gains",
                match self.reorder {
                    Some(g) => Json::obj(vec![
                        ("alpha_before", Json::num(g.alpha_before)),
                        ("alpha_after", Json::num(g.alpha_after)),
                        ("beta_before", Json::num(g.beta_before)),
                        ("beta_after", Json::num(g.beta_after)),
                        ("seconds", Json::num(g.seconds)),
                    ]),
                    None => Json::Null,
                },
            ),
            ("alpha", Json::num(self.alpha)),
            ("synergy", Json::str(self.synergy.name())),
            ("rationale", Json::str(self.rationale.clone())),
            ("fingerprint", Json::str(format!("{:016x}", self.fingerprint))),
            (
                "ranked",
                Json::arr(self.ranked.iter().enumerate().map(|(i, c)| {
                    Json::obj(vec![
                        ("rank", Json::num((i + 1) as f64)),
                        ("engine", Json::str(c.algo.name())),
                        ("modeled_s", Json::num(c.modeled_s)),
                        ("calibrated_s", Json::num(c.calibrated_s)),
                        ("predicted_s", Json::num(c.predicted_s)),
                        ("bound", Json::str(c.bound.name())),
                        ("chosen", Json::Bool(c.algo == self.engine)),
                    ])
                })),
            ),
        ])
    }
}

/// Planner tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    /// Machine whose cost model ranks the engines.
    pub machine: Machine,
    /// Dense width plans are evaluated at.
    pub width: usize,
    /// High synergy: keep the TCU path while it is within this factor of
    /// the model's fastest candidate (Table 1 says TCUs win decisively;
    /// only an overwhelming model verdict overrides it).
    pub high_synergy_slack: f64,
    /// Low synergy: route to scalar cores unless the model puts the TCU
    /// path below this fraction of the best scalar time.
    pub low_synergy_margin: f64,
    /// Master switch for similarity-clustered row reordering
    /// ([`crate::reorder`]); `false` never activates a permutation.
    pub reorder_enabled: bool,
    /// The reorder activation cost threshold: the predicted post-reorder α
    /// must be at least this factor times the arrival-order α. Below it,
    /// the per-call brick-work saving cannot pay back the one-time
    /// signature + clustering + permuted-rebuild pass over the §6.3
    /// amortization horizon (hundreds-to-thousands of SpMM calls), so the
    /// planner leaves the row order alone.
    pub reorder_min_gain: f64,
    /// Matrices below this row count never reorder — the permutation would
    /// span too few panels for the α estimate (or the win) to matter.
    pub reorder_min_rows: usize,
    /// Master switch for adaptive brick-geometry selection; `false` always
    /// builds at [`BrickGeometry::DEFAULT`].
    pub geometry_enabled: bool,
    /// Geometry activation threshold: a non-default catalog entry is chosen
    /// only when the exact pre-build pricer predicts it cuts the brick-MMA
    /// work (`num_bricks × bits`) by at least this factor versus the
    /// default. At 1.0 the chooser would flap on noise-level ties; the
    /// default demands a real predicted win before deviating from the
    /// paper's 16×4 shape.
    pub geometry_min_gain: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            machine: Machine::a100(),
            width: 128,
            high_synergy_slack: 1.10,
            low_synergy_margin: 0.77,
            reorder_enabled: true,
            reorder_min_gain: 1.10,
            reorder_min_rows: 256,
            geometry_enabled: true,
            geometry_min_gain: 1.05,
        }
    }
}

/// Structural fingerprint of a matrix: shape, nnz, and a strided sample of
/// the (row, col, value-bits) stream. Two structurally identical matrices
/// collide (that is the point — their plans are interchangeable).
pub fn fingerprint(coo: &Coo) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x100000001b3)
    }
    let mut h = 0xcbf29ce484222325u64;
    h = mix(h, coo.rows as u64);
    h = mix(h, coo.cols as u64);
    let nnz = coo.nnz();
    h = mix(h, nnz as u64);
    let stride = (nnz / 512).max(1);
    let mut i = 0;
    while i < nnz {
        h = mix(h, coo.row_idx[i] as u64);
        h = mix(h, coo.col_idx[i] as u64);
        h = mix(h, coo.values[i].to_bits() as u64);
        i += stride;
    }
    h
}

/// The synergy-gated decision rule over a ranked `(algo, predicted_s)` table
/// (sorted fastest first). Pure so the bench experiments can replay it over
/// precomputed corpus records.
pub fn choose(
    ranked: &[(Algo, f64)],
    synergy: Synergy,
    alpha: f64,
    high_synergy_slack: f64,
    low_synergy_margin: f64,
) -> (Algo, String) {
    assert!(!ranked.is_empty(), "no candidates to choose from");
    let (best_algo, best_t) = ranked[0];
    let hrpb = ranked.iter().find(|(a, _)| *a == Algo::Hrpb).copied();
    let scalar = ranked
        .iter()
        .find(|(a, _)| Algo::scalar_core().contains(a))
        .copied();
    let oi = 512.0 * alpha;
    match synergy {
        Synergy::Low => match (hrpb, scalar) {
            (Some((_, t_h)), Some((_, t_sc))) if t_h < low_synergy_margin * t_sc => (
                Algo::Hrpb,
                format!(
                    "low synergy (α={alpha:.4}, OI_shmem={oi:.0}) but the model favors the \
                     TCU path by {:.2}x — overriding Table 1",
                    t_sc / t_h
                ),
            ),
            (_, Some((sc, _))) => (
                sc,
                format!(
                    "low synergy (α={alpha:.4} < 12.5%, OI_shmem={oi:.0} ≤ 64): \
                     ≤1 B-reuse per shared-memory load, scalar cores win (Table 1)"
                ),
            ),
            _ => (best_algo, format!("low synergy (α={alpha:.4}): fastest candidate")),
        },
        Synergy::Medium => (
            best_algo,
            format!(
                "medium synergy (α={alpha:.4}, OI_shmem={oi:.0} in [32, 64)): \
                 contested regime, fastest of {} modeled candidates",
                ranked.len()
            ),
        ),
        Synergy::High => match hrpb {
            Some((_, t_h)) if t_h <= high_synergy_slack * best_t => (
                Algo::Hrpb,
                format!(
                    "high synergy (α={alpha:.4} ≥ 25%, OI_shmem={oi:.0} > 64): \
                     TCUs win decisively (Table 1)"
                ),
            ),
            Some((_, t_h)) => (
                best_algo,
                format!(
                    "high synergy but the model puts the TCU path {:.2}x behind — \
                     deferring to the fastest candidate",
                    t_h / best_t
                ),
            ),
            None => (best_algo, format!("high synergy (α={alpha:.4}): fastest candidate")),
        },
    }
}

/// The planner: ranks engines per matrix, caches plans, absorbs calibration
/// and online feedback. Thread-safe; the coordinator shares one behind an
/// `Arc`.
pub struct Planner {
    config: PlannerConfig,
    calibration: RwLock<Calibration>,
    cache: PlanCache,
    feedback: FeedbackTracker,
    /// Per-catalog-entry drift strikes for the geometry feedback loop
    /// (indexed by [`BrickGeometry::catalog_index`]); an entry at or above
    /// [`GEOMETRY_DEMOTE_STRIKES`] is demoted and the chooser skips it.
    geometry_strikes: RwLock<[u8; BrickGeometry::CATALOG.len()]>,
}

/// Observed/predicted latency ratio that counts as a geometry drift strike.
const GEOMETRY_DRIFT_RATIO: f64 = 1.5;

/// Consecutive-ish drift strikes (healthy observations decay one) that
/// demote a catalog geometry from future plans.
const GEOMETRY_DEMOTE_STRIKES: u8 = 3;

impl Planner {
    pub fn new(machine: Machine) -> Planner {
        Planner::with_config(PlannerConfig { machine, ..Default::default() })
    }

    pub fn with_config(config: PlannerConfig) -> Planner {
        Planner {
            config,
            calibration: RwLock::new(Calibration::identity()),
            cache: PlanCache::new(),
            feedback: FeedbackTracker::default(),
            geometry_strikes: RwLock::new([0; BrickGeometry::CATALOG.len()]),
        }
    }

    pub fn machine(&self) -> &Machine {
        &self.config.machine
    }

    pub fn width(&self) -> usize {
        self.config.width
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn feedback(&self) -> &FeedbackTracker {
        &self.feedback
    }

    pub fn calibration(&self) -> Calibration {
        self.calibration.read().unwrap().clone()
    }

    /// Install a calibration profile (loaded from disk or freshly measured)
    /// and invalidate every cached plan.
    pub fn set_calibration(&self, c: Calibration) {
        *self.calibration.write().unwrap() = c;
        self.cache.invalidate();
    }

    /// Run the micro-benchmark calibration pass on this host and install the
    /// result. `rows` sizes the sample matrices (~16k for a faithful
    /// profile; smaller for quick runs).
    pub fn calibrate(&self, rows: usize) -> Calibration {
        let c = calibrate::microbenchmark(
            &self.config.machine,
            self.config.width,
            rows,
            &CANDIDATES,
        );
        self.set_calibration(c.clone());
        c
    }

    /// Seed the plan cache with a plan restored from a persisted HRPB
    /// artifact ([`crate::hrpb::store`]): warm-started registrations make
    /// repeat registrations of the same structure cache hits without ever
    /// re-running the ranking pass. The plan is keyed exactly as
    /// [`Planner::plan`] would key it — by its own fingerprint and width.
    pub fn seed_plan(&self, plan: Arc<Plan>) {
        self.cache.insert(plan.fingerprint, plan.width, plan);
    }

    /// Plan for a matrix; cached by fingerprint.
    pub fn plan(&self, coo: &Coo) -> Arc<Plan> {
        let fp = fingerprint(coo);
        if let Some(plan) = self.cache.get(fp, self.config.width) {
            return plan;
        }
        let profile = MatrixProfile::compute(coo);
        let plan = Arc::new(self.plan_profile(fp, &profile));
        self.cache.insert(fp, self.config.width, plan.clone());
        plan
    }

    /// Plan reusing an already-built HRPB (the registry builds it anyway).
    pub fn plan_with_hrpb(&self, coo: &Coo, hrpb: &Hrpb) -> Arc<Plan> {
        let fp = fingerprint(coo);
        if let Some(plan) = self.cache.get(fp, self.config.width) {
            return plan;
        }
        let profile = MatrixProfile::with_hrpb(coo, hrpb);
        let plan = Arc::new(self.plan_profile(fp, &profile));
        self.cache.insert(fp, self.config.width, plan.clone());
        plan
    }

    /// Plan from a caller-assembled profile, cached by fingerprint — the
    /// registry's reorder path annotates the profile with the activation
    /// gains ([`MatrixProfile::reorder`]) before planning, so the plan's
    /// knob reflects what was actually built. A cached plan whose reorder
    /// knob disagrees with the profile's annotation (e.g. `plan()` memoized
    /// an arrival-order ranking before the registry activated the
    /// permutation) is recomputed and replaces the cache entry — serving a
    /// stale knob would mis-route the engine and mis-price QoS admission
    /// against the structure that was not built.
    pub fn plan_assembled(&self, fp: u64, profile: &MatrixProfile) -> Arc<Plan> {
        if let Some(plan) = self.cache.get(fp, self.config.width) {
            if plan.reorder.is_some() == profile.reorder.is_some()
                && plan.geometry == profile.geometry
            {
                return plan;
            }
        }
        let plan = Arc::new(self.plan_profile(fp, profile));
        self.cache.insert(fp, self.config.width, plan.clone());
        plan
    }

    /// The reorder activation gate — pure over a proposal's predicted
    /// numbers so tests can drive it with synthetic signatures/stats. It
    /// never activates when the predicted α gain is below the configured
    /// cost threshold ([`PlannerConfig::reorder_min_gain`]), when the
    /// permutation is trivial or strictly adds brick work, when the
    /// matrix is too small to amortize the one-time pass, or when even the
    /// post-reorder α stays in the Low synergy class — a permutation that
    /// cannot lift the matrix out of Low can never flip serving onto the
    /// TCU path, so it would pay the clustering and rebuild cost for a
    /// structure no engine executes.
    pub fn gate_reorder(&self, proposal: &crate::reorder::Proposal) -> bool {
        let c = &self.config;
        c.reorder_enabled
            && proposal.rows() >= c.reorder_min_rows
            && !proposal.perm.is_identity()
            && proposal.after.num_bricks < proposal.before.num_bricks
            && proposal.after.alpha >= proposal.before.alpha * c.reorder_min_gain
            && Synergy::from_alpha(proposal.after.alpha) != Synergy::Low
    }

    /// The brick-geometry chooser — pure over the exact pre-build pricer's
    /// per-geometry panel stats ([`crate::reorder::stats::price_catalog`]),
    /// so the registry can decide the shape *before* building anything.
    /// Picks the catalog entry with the least predicted brick-MMA work
    /// (`num_bricks × bits`, the kernel's executed-FLOP volume), but only
    /// deviates from [`BrickGeometry::DEFAULT`] when the predicted saving
    /// clears [`PlannerConfig::geometry_min_gain`] — it never activates a
    /// non-default shape the pricer predicts no gain for. Demoted
    /// geometries (see [`Planner::observe_geometry`]) are skipped.
    pub fn choose_geometry(
        &self,
        priced: &[(BrickGeometry, crate::reorder::PanelStats)],
    ) -> BrickGeometry {
        let default_slots = priced
            .iter()
            .find(|(g, _)| g.is_default())
            .map(|(g, s)| s.brick_slots(*g))
            .unwrap_or(0);
        if !self.config.geometry_enabled || default_slots == 0 {
            return BrickGeometry::DEFAULT;
        }
        let mut best = BrickGeometry::DEFAULT;
        let mut best_slots = default_slots;
        for &(g, ref s) in priced {
            if g.is_default() || self.geometry_demoted(g) {
                continue;
            }
            let slots = s.brick_slots(g);
            if slots < best_slots
                && default_slots as f64 >= slots as f64 * self.config.geometry_min_gain
            {
                best = g;
                best_slots = slots;
            }
        }
        best
    }

    /// Is this catalog geometry currently demoted by the feedback loop?
    /// The default shape is never demoted — it is the fallback.
    pub fn geometry_demoted(&self, geo: BrickGeometry) -> bool {
        match geo.catalog_index() {
            Some(i) if !geo.is_default() => {
                self.geometry_strikes.read().unwrap()[i] >= GEOMETRY_DEMOTE_STRIKES
            }
            _ => false,
        }
    }

    /// Report an observed batch latency for a matrix served at a non-default
    /// geometry. Mirrors [`Planner::observe`]: armed only once a real
    /// calibration is installed. A mispredicted geometry (observed drifting
    /// past [`GEOMETRY_DRIFT_RATIO`]× predicted) accumulates strikes and is
    /// demoted from future [`Planner::choose_geometry`] calls; cached plans
    /// are invalidated so affected matrices re-plan at the default shape.
    /// Healthy observations decay one strike.
    pub fn observe_geometry(&self, geo: BrickGeometry, predicted_s: f64, observed_s: f64) {
        if !self.calibration.read().unwrap().calibrated {
            return;
        }
        let Some(i) = geo.catalog_index() else { return };
        if geo.is_default() {
            return;
        }
        let mut strikes = self.geometry_strikes.write().unwrap();
        if observed_s > predicted_s * GEOMETRY_DRIFT_RATIO {
            strikes[i] = strikes[i].saturating_add(1);
            if strikes[i] == GEOMETRY_DEMOTE_STRIKES {
                drop(strikes);
                self.cache.invalidate();
            }
        } else {
            strikes[i] = strikes[i].saturating_sub(1);
        }
    }

    /// Rank + choose from a precomputed profile (no caching).
    pub fn plan_profile(&self, fingerprint: u64, profile: &MatrixProfile) -> Plan {
        let n = self.config.width;
        let calibration = self.calibration.read().unwrap();
        let slab_width = calibration.slab_width;
        let mut ranked: Vec<RankedChoice> = CANDIDATES
            .iter()
            .map(|&algo| {
                let pred = algos::predict(algo, profile, n, &self.config.machine);
                let calibrated = pred.time_s * calibration.scale_for(algo);
                RankedChoice {
                    algo,
                    modeled_s: pred.time_s,
                    calibrated_s: calibrated,
                    predicted_s: calibrated * self.feedback.penalty(algo),
                    bound: pred.bound,
                }
            })
            .collect();
        drop(calibration);
        ranked.sort_by(|a, b| a.predicted_s.total_cmp(&b.predicted_s));

        let alpha = profile.hrpb.alpha;
        let synergy = profile.synergy();
        let pairs: Vec<(Algo, f64)> = ranked.iter().map(|c| (c.algo, c.predicted_s)).collect();
        let (engine, rationale) = choose(
            &pairs,
            synergy,
            alpha,
            self.config.high_synergy_slack,
            self.config.low_synergy_margin,
        );
        // penalty-free: this is the baseline observed latency is judged
        // against (see the field docs on `Plan::predicted_s`)
        let predicted_s = ranked
            .iter()
            .find(|c| c.algo == engine)
            .map(|c| c.calibrated_s)
            .unwrap_or(ranked[0].calibrated_s);
        Plan {
            engine,
            width: n,
            predicted_s,
            predicted_s_per_col: predicted_s / n.max(1) as f64,
            slab_width,
            geometry: profile.geometry,
            reorder: profile.reorder,
            alpha,
            synergy,
            ranked,
            rationale,
            fingerprint,
        }
    }

    /// Report an observed batch execution. Demotion only arms once a real
    /// calibration is installed — against the identity profile, predictions
    /// are modeled-GPU times and every CPU observation would look drifted.
    pub fn observe(&self, algo: Algo, predicted_s: f64, observed_s: f64) {
        if !self.calibration.read().unwrap().calibrated {
            return;
        }
        if self.feedback.observe(algo, predicted_s, observed_s) {
            self.cache.invalidate();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    /// Deterministic high-synergy matrix: fully dense 16x16 blocks (every
    /// brick full, α = 1.0), one block per row panel.
    fn full_brick_matrix(panels: usize) -> Coo {
        let mut t = Vec::new();
        for p in 0..panels {
            for r in 0..16 {
                for c in 0..16 {
                    t.push((p * 16 + r, (p % 4) * 16 + c, 1.0 + (r + c) as f32 * 0.01));
                }
            }
        }
        Coo::from_triplets(panels * 16, 64, &t)
    }

    /// Deterministic low-synergy matrix: one nonzero per brick (α = 1/64),
    /// scattered on the diagonal.
    fn lone_nnz_matrix(panels: usize) -> Coo {
        let t: Vec<(usize, usize, f32)> =
            (0..panels).map(|p| (p * 16, (p * 16) % 1024, 1.0)).collect();
        Coo::from_triplets(panels * 16, 1024, &t)
    }

    /// Medium boundary: exactly 8 of 64 slots per brick (α = 0.125).
    fn boundary_matrix(panels: usize) -> Coo {
        let mut t = Vec::new();
        for p in 0..panels {
            for r in 0..8 {
                t.push((p * 16 + r, 0usize, 1.0f32));
            }
        }
        Coo::from_triplets(panels * 16, 64, &t)
    }

    #[test]
    fn high_synergy_routes_to_hrpb() {
        let planner = Planner::new(Machine::a100());
        let coo = full_brick_matrix(256);
        let plan = planner.plan(&coo);
        assert_eq!(plan.synergy, Synergy::High);
        assert!((plan.alpha - 1.0).abs() < 1e-12);
        assert_eq!(plan.engine, Algo::Hrpb, "rationale: {}", plan.rationale);
        assert!(plan.rationale.contains("high synergy"));
        assert!(plan.predicted_s > 0.0);
    }

    #[test]
    fn low_synergy_routes_to_a_scalar_engine() {
        let planner = Planner::new(Machine::a100());
        let coo = lone_nnz_matrix(64);
        let plan = planner.plan(&coo);
        assert_eq!(plan.synergy, Synergy::Low);
        assert!(
            Algo::scalar_core().contains(&plan.engine),
            "low synergy chose {} ({})",
            plan.engine.name(),
            plan.rationale
        );
        assert!(plan.rationale.contains("scalar") || plan.rationale.contains("low synergy"));
    }

    #[test]
    fn boundary_alpha_is_medium_and_planned() {
        let planner = Planner::new(Machine::a100());
        let coo = boundary_matrix(32);
        let plan = planner.plan(&coo);
        assert!((plan.alpha - 0.125).abs() < 1e-12, "alpha {}", plan.alpha);
        assert_eq!(plan.synergy, Synergy::Medium);
        // medium is the model-decides regime: chosen == fastest candidate
        assert_eq!(plan.engine, plan.ranked[0].algo);
    }

    #[test]
    fn ranked_table_is_sorted_and_complete() {
        let planner = Planner::new(Machine::a100());
        let plan = planner.plan(&full_brick_matrix(32));
        assert_eq!(plan.ranked.len(), CANDIDATES.len());
        for pair in plan.ranked.windows(2) {
            assert!(pair[0].predicted_s <= pair[1].predicted_s);
        }
        for algo in CANDIDATES {
            assert!(plan.ranked.iter().any(|c| c.algo == algo));
        }
    }

    #[test]
    fn plan_cache_hits_on_repeat_registration() {
        let planner = Planner::new(Machine::a100());
        let coo = full_brick_matrix(48);
        let p1 = planner.plan(&coo);
        let p2 = planner.plan(&coo);
        assert!(Arc::ptr_eq(&p1, &p2), "second plan must come from the cache");
        let stats = planner.cache().stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        // a different matrix misses
        let _ = planner.plan(&lone_nnz_matrix(48));
        assert_eq!(planner.cache().stats().misses, 2);
    }

    #[test]
    fn fingerprint_distinguishes_structure() {
        let a = full_brick_matrix(32);
        let b = lone_nnz_matrix(32);
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&full_brick_matrix(32)));
    }

    #[test]
    fn prop_fingerprint_is_deterministic_and_value_sensitive() {
        let g = SparseGen { max_m: 60, max_k: 80, max_density: 0.2 };
        check("fingerprint deterministic", 30, &g, |case| {
            let a = Coo::from_triplets(case.m, case.k, &case.triplets);
            let b = Coo::from_triplets(case.m, case.k, &case.triplets);
            fingerprint(&a) == fingerprint(&b)
        });
        let mut rng = Rng::new(77);
        let coo = Coo::random(64, 64, 0.1, &mut rng);
        if coo.nnz() > 0 {
            let mut bumped = coo.clone();
            bumped.values[0] += 1.0;
            assert_ne!(fingerprint(&coo), fingerprint(&bumped));
        }
    }

    #[test]
    fn plan_json_roundtrips() {
        use crate::util::json::{parse, Json};
        let planner = Planner::new(Machine::a100());
        let plan = planner.plan(&full_brick_matrix(32));
        let text = plan.to_json().to_string();
        let doc = parse(&text).unwrap();
        assert_eq!(doc.get("engine").unwrap().as_str(), Some(plan.engine.name()));
        assert_eq!(doc.get("synergy").unwrap().as_str(), Some(plan.synergy.name()));
        assert_eq!(doc.get("width").unwrap().as_usize(), Some(plan.width));
        assert_eq!(doc.get("slab_width").unwrap().as_usize(), Some(plan.slab_width));
        assert_eq!(
            doc.get("geometry").unwrap().as_str(),
            Some(plan.geometry.name().as_str())
        );
        let ranked = doc.get("ranked").unwrap().as_arr().unwrap();
        assert_eq!(ranked.len(), plan.ranked.len());
        let chosen = ranked
            .iter()
            .filter(|r| r.get("chosen") == Some(&Json::Bool(true)))
            .count();
        assert_eq!(chosen, 1, "exactly one ranked row is marked chosen");
    }

    /// Synthetic proposal with controlled before/after α and brick counts
    /// (built from signatures only indirectly — the gate is pure over the
    /// priced numbers, which is exactly what it sees in production).
    fn synthetic_proposal(
        rows: usize,
        identity: bool,
        alpha_before: f64,
        alpha_after: f64,
        bricks_before: usize,
        bricks_after: usize,
    ) -> crate::reorder::Proposal {
        use crate::reorder::{PanelStats, RowPermutation};
        let perm = if identity || rows < 2 {
            RowPermutation::identity(rows)
        } else {
            let mut fwd: Vec<u32> = (0..rows as u32).collect();
            fwd.rotate_left(1);
            RowPermutation::from_new_to_old(fwd).unwrap()
        };
        let stats = |alpha: f64, bricks: usize| PanelStats {
            nnz: 1000,
            num_blocks: bricks.div_ceil(4).max(1),
            num_bricks: bricks,
            num_brick_cols: bricks,
            alpha,
            beta: 1.0,
        };
        crate::reorder::Proposal {
            perm,
            before: stats(alpha_before, bricks_before),
            after: stats(alpha_after, bricks_after),
        }
    }

    /// The acceptance-criterion gate property: the planner NEVER activates
    /// reordering when the predicted α gain is below its cost threshold —
    /// and the other guards (trivial perm, added work, tiny matrices,
    /// master switch) hold too.
    #[test]
    fn reorder_gate_never_activates_below_the_cost_threshold() {
        let planner = Planner::new(Machine::a100());
        let gain = planner.config.reorder_min_gain;
        // clearly above the threshold, landing in Medium synergy: activates
        let good = synthetic_proposal(1024, false, 0.15, 0.15 * (gain + 0.5), 4000, 800);
        assert!(planner.gate_reorder(&good));
        // sweep α gains straddling the threshold: below it must never fire
        for below in [0.5, 0.9, 1.0, gain - 0.01] {
            let p = synthetic_proposal(1024, false, 0.15, 0.15 * below, 4000, 3999);
            assert!(!planner.gate_reorder(&p), "gain {below} is below the cost threshold");
        }
        // at/above threshold but with MORE brick work: still refused
        let regress = synthetic_proposal(1024, false, 0.15, 0.15 * (gain + 0.5), 4000, 4001);
        assert!(!planner.gate_reorder(&regress), "added brick work must veto");
        // a big relative gain that still leaves the matrix in the Low
        // class: the TCU path can never win there, so no activation
        let still_low = synthetic_proposal(1024, false, 0.02, 0.08, 4000, 1000);
        assert!(!planner.gate_reorder(&still_low), "post-reorder Low must veto");
        // identity permutation: nothing to activate
        let trivial = synthetic_proposal(1024, true, 0.15, 0.5, 4000, 800);
        assert!(!planner.gate_reorder(&trivial));
        // too small to amortize
        let tiny = synthetic_proposal(64, false, 0.15, 0.5, 400, 80);
        assert!(!planner.gate_reorder(&tiny));
        // master switch off
        let off = Planner::with_config(PlannerConfig {
            reorder_enabled: false,
            ..Default::default()
        });
        assert!(!off.gate_reorder(&good));
    }

    /// The cache-coherence rule of [`Planner::plan_assembled`]: a memoized
    /// arrival-order plan must not be served for a reorder-annotated
    /// profile (and vice versa) — the knob reflects what was built.
    #[test]
    fn plan_assembled_recomputes_on_reorder_knob_mismatch() {
        let planner = Planner::new(Machine::a100());
        let coo = full_brick_matrix(48);
        let fp = fingerprint(&coo);
        // memoize the arrival-order plan first (plan() path)
        let stale = planner.plan(&coo);
        assert!(stale.reorder.is_none());

        let mut profile = MatrixProfile::compute(&coo);
        profile.reorder = Some(crate::reorder::Gains {
            alpha_before: 0.05,
            alpha_after: 0.30,
            beta_before: 1.0,
            beta_after: 1.0,
            seconds: 0.01,
        });
        let fresh = planner.plan_assembled(fp, &profile);
        assert!(fresh.reorder.is_some(), "stale arrival-order plan must be replaced");
        // the replacement is now the cached truth
        let again = planner.plan_assembled(fp, &profile);
        assert!(Arc::ptr_eq(&fresh, &again), "matching knob hits the cache");
    }

    #[test]
    fn plan_json_carries_the_reorder_knob() {
        use crate::util::json::parse;
        let planner = Planner::new(Machine::a100());
        let mut plan = (*planner.plan(&full_brick_matrix(32))).clone();
        assert!(plan.reorder.is_none());
        let doc = parse(&plan.to_json().to_string()).unwrap();
        assert_eq!(doc.get("reorder"), Some(&crate::util::json::Json::Bool(false)));

        plan.reorder = Some(crate::reorder::Gains {
            alpha_before: 0.04,
            alpha_after: 0.31,
            beta_before: 1.0,
            beta_after: 1.0,
            seconds: 0.02,
        });
        let doc = parse(&plan.to_json().to_string()).unwrap();
        assert_eq!(doc.get("reorder"), Some(&crate::util::json::Json::Bool(true)));
        let g = doc.get("reorder_gains").unwrap();
        assert_eq!(g.get("alpha_before").unwrap().as_f64(), Some(0.04));
        assert_eq!(g.get("alpha_after").unwrap().as_f64(), Some(0.31));
    }

    /// Synthetic per-geometry panel stats with a given brick count — the
    /// chooser only reads `brick_slots`, which is `num_bricks × bits`.
    fn priced_stats(bricks: usize) -> crate::reorder::PanelStats {
        crate::reorder::PanelStats {
            nnz: 1000,
            num_blocks: bricks.div_ceil(4).max(1),
            num_bricks: bricks,
            num_brick_cols: bricks,
            alpha: 0.2,
            beta: 1.0,
        }
    }

    /// The geometry acceptance property: the chooser NEVER activates a
    /// non-default shape the pricer predicts no (or sub-threshold) gain for.
    #[test]
    fn geometry_chooser_never_activates_without_predicted_gain() {
        let planner = Planner::new(Machine::a100());
        let g88 = BrickGeometry::CATALOG[1];
        let g84 = BrickGeometry::CATALOG[2];
        let g81t = BrickGeometry::CATALOG[3];
        // default: 100 bricks × 64 bits = 6400 slots; 8x4 at 100 bricks is
        // 3200 slots (2x predicted win) -> activates; the rest predict more
        // work and must not be picked.
        let priced = vec![
            (BrickGeometry::DEFAULT, priced_stats(100)),
            (g88, priced_stats(110)),
            (g84, priced_stats(100)),
            (g81t, priced_stats(900)),
        ];
        assert_eq!(planner.choose_geometry(&priced), g84);
        // an exact tie predicts no gain: stay on the default shape
        let tie = vec![(BrickGeometry::DEFAULT, priced_stats(100)), (g88, priced_stats(100))];
        assert_eq!(planner.choose_geometry(&tie), BrickGeometry::DEFAULT);
        // a real but sub-threshold saving (6240 vs 6400 slots, 1.026x) must
        // not clear the 1.05x activation gate either
        let slight = vec![(BrickGeometry::DEFAULT, priced_stats(100)), (g84, priced_stats(195))];
        assert_eq!(planner.choose_geometry(&slight), BrickGeometry::DEFAULT);
        // master switch off
        let off = Planner::with_config(PlannerConfig {
            geometry_enabled: false,
            ..Default::default()
        });
        assert_eq!(off.choose_geometry(&priced), BrickGeometry::DEFAULT);
        // degenerate tables fall back to the default shape
        assert_eq!(planner.choose_geometry(&[]), BrickGeometry::DEFAULT);
    }

    #[test]
    fn geometry_demotion_falls_back_to_the_default_shape() {
        let planner = Planner::new(Machine::a100());
        let mut cal = Calibration::identity();
        cal.calibrated = true;
        cal.machine = "A100".to_string();
        planner.set_calibration(cal);

        let g84 = BrickGeometry::CATALOG[2];
        let priced = vec![(BrickGeometry::DEFAULT, priced_stats(100)), (g84, priced_stats(100))];
        assert_eq!(planner.choose_geometry(&priced), g84);

        let gen_before = planner.cache().generation();
        for _ in 0..3 {
            planner.observe_geometry(g84, 1e-3, 1e-2); // 10x drift
        }
        assert!(planner.geometry_demoted(g84));
        assert!(planner.cache().generation() > gen_before, "demotion must invalidate plans");
        assert_eq!(
            planner.choose_geometry(&priced),
            BrickGeometry::DEFAULT,
            "a demoted geometry must lose future plans"
        );
        // the default shape is the fallback and never demotes
        for _ in 0..5 {
            planner.observe_geometry(BrickGeometry::DEFAULT, 1e-3, 1e-2);
        }
        assert!(!planner.geometry_demoted(BrickGeometry::DEFAULT));
    }

    #[test]
    fn observe_geometry_is_inert_without_calibration() {
        let planner = Planner::new(Machine::a100());
        let g = BrickGeometry::CATALOG[1];
        for _ in 0..10 {
            planner.observe_geometry(g, 1e-6, 1.0);
        }
        assert!(!planner.geometry_demoted(g));
    }

    /// The cache-coherence rule extends to geometry: a memoized default-shape
    /// plan must not be served for a profile rebuilt at another geometry.
    #[test]
    fn plan_assembled_recomputes_on_geometry_mismatch() {
        let planner = Planner::new(Machine::a100());
        let coo = full_brick_matrix(48);
        let fp = fingerprint(&coo);
        let stale = planner.plan(&coo);
        assert!(stale.geometry.is_default());

        let mut profile = MatrixProfile::compute(&coo);
        profile.geometry = BrickGeometry::CATALOG[2];
        let fresh = planner.plan_assembled(fp, &profile);
        assert_eq!(
            fresh.geometry,
            BrickGeometry::CATALOG[2],
            "stale default-shape plan must be replaced"
        );
        let again = planner.plan_assembled(fp, &profile);
        assert!(Arc::ptr_eq(&fresh, &again), "matching geometry hits the cache");
    }

    #[test]
    fn feedback_demotion_reroutes_and_invalidates() {
        let planner = Planner::new(Machine::a100());
        // arm the feedback loop with an identity-but-calibrated profile
        let mut cal = Calibration::identity();
        cal.calibrated = true;
        cal.machine = "A100".to_string();
        planner.set_calibration(cal);

        let coo = full_brick_matrix(256);
        let before = planner.plan(&coo);
        assert_eq!(before.engine, Algo::Hrpb);
        let gen_before = planner.cache().generation();

        // observed 10x slower than predicted, repeatedly -> demotion
        for _ in 0..10 {
            planner.observe(Algo::Hrpb, before.predicted_s, before.predicted_s * 10.0);
        }
        assert!(planner.feedback().is_demoted(Algo::Hrpb));
        assert!(planner.cache().generation() > gen_before, "demotion must invalidate plans");

        let after = planner.plan(&coo);
        assert_ne!(
            after.engine,
            Algo::Hrpb,
            "a 10x-drifted HRPB must lose its marginal win ({})",
            after.rationale
        );
    }

    #[test]
    fn observe_is_inert_without_calibration() {
        let planner = Planner::new(Machine::a100());
        for _ in 0..20 {
            planner.observe(Algo::Hrpb, 1e-6, 1.0);
        }
        assert!(!planner.feedback().is_demoted(Algo::Hrpb));
    }

    #[test]
    fn planner_agrees_with_model_oracle_on_corpus_sample() {
        // oracle := the model's fastest candidate. The synergy gates may
        // override it at the extremes; the satellite requirement is >= 80%
        // agreement over a stratified corpus sample.
        let planner = Planner::new(Machine::a100());
        let all = crate::gen::corpus::specs(crate::gen::corpus::CorpusScale::Quick, 42);
        let step = (all.len() / 24).max(1);
        let mut agree = 0usize;
        let mut total = 0usize;
        for spec in all.iter().step_by(step) {
            let mut small = spec.clone();
            small.rows = 2500;
            if let crate::gen::Family::Community { ref mut communities, .. } = small.family {
                *communities = (*communities).min(250);
            }
            let coo = small.generate();
            if coo.nnz() == 0 {
                continue;
            }
            let profile = MatrixProfile::compute(&coo);
            let plan = planner.plan_profile(0, &profile);
            total += 1;
            if plan.engine == plan.ranked[0].algo {
                agree += 1;
            }
        }
        assert!(total >= 10, "sample too small: {total}");
        let rate = agree as f64 / total as f64;
        assert!(rate >= 0.8, "planner/oracle agreement {rate:.2} over {total} matrices");
    }
}
