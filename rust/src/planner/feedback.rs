//! Online feedback — observed batch latency vs the plan's prediction.
//!
//! Workers report `(engine, predicted, observed)` after every batch, where
//! `predicted` is the plan's calibration-only estimate (never the
//! penalty-adjusted one — comparing against a penalized prediction would
//! make the drift signal self-referential and demotion would flap). The
//! tracker keeps an EWMA of the observed/predicted ratio per engine; once an
//! engine's ratio drifts past the demotion threshold (with enough samples to
//! trust it), the engine is *demoted*: its EWMA becomes a multiplicative
//! penalty on future predictions, so matrices registered from then on route
//! away from the drifting engine unless it wins by more than the penalty
//! (already-registered entries keep their engine; see
//! `coordinator::EnginePolicy::Auto`). Demotion is sticky until the
//! engine's observed ratio recovers below the threshold.

use crate::spmm::Algo;
use std::sync::Mutex;

/// Predictions at or below this are degenerate (a real kernel launch is
/// never sub-picosecond): the observation is discarded rather than letting
/// an effectively-infinite observed/predicted ratio spuriously demote the
/// engine.
const MIN_PREDICTED_S: f64 = 1e-12;

/// Cap on a single observation's ratio so one wild sample (or a tiny but
/// nonzero prediction) cannot poison the EWMA beyond recovery.
const MAX_RATIO: f64 = 1e6;

/// Per-engine drift state.
#[derive(Clone, Copy, Debug)]
struct Lane {
    /// EWMA of observed/predicted (1.0 = model is exact).
    ewma: f64,
    samples: u64,
    demoted: bool,
}

impl Default for Lane {
    fn default() -> Self {
        Lane { ewma: 1.0, samples: 0, demoted: false }
    }
}

/// Snapshot of one engine's drift state.
#[derive(Clone, Copy, Debug)]
pub struct DriftSnapshot {
    pub algo: Algo,
    pub ratio: f64,
    pub samples: u64,
    pub demoted: bool,
}

pub struct FeedbackTracker {
    lanes: Mutex<[Lane; Algo::COUNT]>,
    /// Demote when the EWMA ratio exceeds this (4.0 = observed 4x slower
    /// than predicted).
    demote_ratio: f64,
    /// Ignore drift until this many observations (early batches are noisy).
    min_samples: u64,
    /// EWMA smoothing weight for new observations.
    smoothing: f64,
}

impl Default for FeedbackTracker {
    fn default() -> Self {
        FeedbackTracker::new(4.0, 8)
    }
}

impl FeedbackTracker {
    pub fn new(demote_ratio: f64, min_samples: u64) -> FeedbackTracker {
        FeedbackTracker {
            lanes: Mutex::new(std::array::from_fn(|_| Lane::default())),
            demote_ratio,
            min_samples,
            smoothing: 0.25,
        }
    }

    /// Record one observation. Returns `true` when this observation flipped
    /// the engine's demotion state (the caller invalidates cached plans).
    /// Degenerate predictions (zero, negative, NaN, or sub-picosecond) are
    /// ignored and extreme ratios are clamped — see `MIN_PREDICTED_S` and
    /// `MAX_RATIO`.
    pub fn observe(&self, algo: Algo, predicted_s: f64, observed_s: f64) -> bool {
        if !(predicted_s > MIN_PREDICTED_S) || !(observed_s > 0.0) {
            return false;
        }
        let ratio = (observed_s / predicted_s).min(MAX_RATIO);
        if !ratio.is_finite() {
            return false;
        }
        let mut lanes = self.lanes.lock().unwrap();
        let lane = &mut lanes[algo.index()];
        lane.samples += 1;
        lane.ewma = if lane.samples == 1 {
            ratio
        } else {
            lane.ewma * (1.0 - self.smoothing) + ratio * self.smoothing
        };
        let should_demote = lane.samples >= self.min_samples && lane.ewma > self.demote_ratio;
        let flipped = should_demote != lane.demoted;
        lane.demoted = should_demote;
        flipped
    }

    /// Multiplicative penalty the planner applies to this engine's predicted
    /// time (1.0 while healthy; the drifted EWMA once demoted).
    pub fn penalty(&self, algo: Algo) -> f64 {
        let lanes = self.lanes.lock().unwrap();
        let lane = lanes[algo.index()];
        if lane.demoted {
            lane.ewma.max(self.demote_ratio)
        } else {
            1.0
        }
    }

    pub fn is_demoted(&self, algo: Algo) -> bool {
        self.lanes.lock().unwrap()[algo.index()].demoted
    }

    /// Drift state for every engine with at least one observation.
    pub fn snapshot(&self) -> Vec<DriftSnapshot> {
        let lanes = self.lanes.lock().unwrap();
        Algo::all()
            .into_iter()
            .filter(|a| lanes[a.index()].samples > 0)
            .map(|a| {
                let lane = lanes[a.index()];
                DriftSnapshot {
                    algo: a,
                    ratio: lane.ewma,
                    samples: lane.samples,
                    demoted: lane.demoted,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_engine_keeps_unit_penalty() {
        let fb = FeedbackTracker::new(4.0, 4);
        for _ in 0..20 {
            assert!(!fb.observe(Algo::Hrpb, 1e-3, 1.1e-3));
        }
        assert_eq!(fb.penalty(Algo::Hrpb), 1.0);
        assert!(!fb.is_demoted(Algo::Hrpb));
    }

    #[test]
    fn drifting_engine_is_demoted_after_min_samples() {
        let fb = FeedbackTracker::new(4.0, 4);
        let mut flipped_at = None;
        for i in 0..10 {
            if fb.observe(Algo::Sputnik, 1e-3, 8e-3) {
                flipped_at = Some(i);
                break;
            }
        }
        // ratio is constant 8x, so demotion lands exactly at min_samples
        assert_eq!(flipped_at, Some(3));
        assert!(fb.is_demoted(Algo::Sputnik));
        assert!(fb.penalty(Algo::Sputnik) >= 4.0);
        // other engines are untouched
        assert_eq!(fb.penalty(Algo::Hrpb), 1.0);
    }

    #[test]
    fn recovery_lifts_the_demotion() {
        let fb = FeedbackTracker::new(4.0, 2);
        for _ in 0..4 {
            fb.observe(Algo::Csr, 1e-3, 9e-3);
        }
        assert!(fb.is_demoted(Algo::Csr));
        // sustained accurate observations pull the EWMA back under the bar
        let mut recovered = false;
        for _ in 0..64 {
            if fb.observe(Algo::Csr, 1e-3, 1e-3) {
                recovered = true;
                break;
            }
        }
        assert!(recovered);
        assert!(!fb.is_demoted(Algo::Csr));
        assert_eq!(fb.penalty(Algo::Csr), 1.0);
    }

    #[test]
    fn nonpositive_observations_are_ignored() {
        let fb = FeedbackTracker::default();
        assert!(!fb.observe(Algo::Coo, 0.0, 1.0));
        assert!(!fb.observe(Algo::Coo, 1.0, 0.0));
        assert!(fb.snapshot().is_empty());
    }

    #[test]
    fn degenerate_predictions_cannot_demote() {
        let fb = FeedbackTracker::new(4.0, 1);
        // a zero/near-zero predicted time would yield an effectively
        // infinite ratio; such observations are discarded entirely
        for _ in 0..16 {
            assert!(!fb.observe(Algo::Hrpb, 1e-300, 1.0));
            assert!(!fb.observe(Algo::Hrpb, 0.0, 1.0));
            assert!(!fb.observe(Algo::Hrpb, f64::NAN, 1.0));
        }
        assert!(!fb.is_demoted(Algo::Hrpb));
        assert!(fb.snapshot().is_empty(), "degenerate samples must not count");

        // a small-but-valid prediction still counts, with the ratio clamped
        // so the EWMA stays finite and recoverable
        fb.observe(Algo::Hrpb, 1e-9, 1e9);
        let snap = fb.snapshot();
        assert_eq!(snap.len(), 1);
        assert!(snap[0].ratio.is_finite());
        assert!(snap[0].ratio <= 1e6, "ratio {} not clamped", snap[0].ratio);
        assert!(fb.is_demoted(Algo::Hrpb), "a genuinely drifted engine still demotes");
    }

    #[test]
    fn snapshot_reports_observed_lanes_only() {
        let fb = FeedbackTracker::default();
        fb.observe(Algo::Hrpb, 1e-3, 2e-3);
        fb.observe(Algo::Csr, 1e-3, 1e-3);
        let snap = fb.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap.iter().any(|s| s.algo == Algo::Hrpb && (s.ratio - 2.0).abs() < 1e-9));
    }
}
