//! Experiment drivers — one per paper table/figure (DESIGN.md §4 index).
//! Each returns a rendered report and drops CSV rows under `results/`.

use crate::bench::corpus_run::{self, Record};
use crate::bench::render::{self, box_entry, BoxEntry};
use crate::formats::Dense;
use crate::gen::corpus::CorpusScale;
use crate::gen::{named, Family, MatrixSpec};
use crate::gpumodel::{algos, Machine, MatrixProfile};
use crate::qos::{self, BoundedDualQueue, Priority, RejectReason, ShedPolicy, Ticket};
use crate::spmm::{Algo, SpmmEngine};
use crate::synergy::Synergy;
use crate::util::stats;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

/// CLI-set results-dir override (`--out-dir`); beats the environment.
static RESULTS_DIR_OVERRIDE: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Route every driver's CSV/JSON output to `dir` for the rest of the
/// process (the `--out-dir` flag). Drivers print the paths they write, so
/// the override keeps what is printed and what is written in agreement.
pub fn set_results_dir(dir: PathBuf) {
    *RESULTS_DIR_OVERRIDE.lock().unwrap() = Some(dir);
}

/// Where CSVs and machine-readable records land. Precedence: the
/// `--out-dir` flag, then `CUTESPMM_RESULTS_DIR`, then the legacy
/// `CUTESPMM_RESULTS` name, then `<crate>/results`.
pub fn results_dir() -> PathBuf {
    if let Some(dir) = RESULTS_DIR_OVERRIDE.lock().unwrap().clone() {
        return dir;
    }
    std::env::var_os("CUTESPMM_RESULTS_DIR")
        .or_else(|| std::env::var_os("CUTESPMM_RESULTS"))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results"))
}

/// Write a CSV under [`results_dir`], warning on stderr instead of failing
/// silently (several drivers used to print `results/` paths whose writes
/// had been dropped on the floor).
fn write_csv_or_warn(path: &Path, headers: &[&str], rows: &[Vec<String>]) {
    if let Err(e) = render::write_csv(path, headers, rows) {
        eprintln!("warning: cannot write {}: {e}", path.display());
    }
}

/// Write a machine-readable record, warning on stderr on failure (stdout
/// stays byte-identical either way).
fn write_json_or_warn(path: &Path, text: &str) {
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    if let Err(e) = std::fs::write(path, text) {
        eprintln!("warning: cannot write {}: {e}", path.display());
    }
}

const MACHINES: [&str; 2] = ["A100", "RTX-4090"];

/// Fig. 2 — TC-GNN vs Best-SC scatter at N = 128 on both GPUs.
pub fn fig2(records: &[Record]) -> String {
    let mut out = String::from("== Fig 2: TC-GNN vs Best-SC (N=128) ==\n");
    let mut csv = Vec::new();
    for m in MACHINES {
        let pts: Vec<(f64, f64)> = records
            .iter()
            .filter_map(|r| {
                let tc = r.get(m, 128, Algo::TcGnn)?.gflops;
                let best = r.best_sc(m, 128)?.gflops;
                csv.push(vec![
                    r.name.clone(),
                    m.to_string(),
                    format!("{tc:.1}"),
                    format!("{best:.1}"),
                ]);
                Some((best / 1000.0, tc / 1000.0))
            })
            .collect();
        let wins = pts.iter().filter(|(b, t)| t > b).count();
        out.push_str(&format!(
            "\n[{m}] matrices={} tcgnn_wins={} ({:.1}%)\n",
            pts.len(),
            wins,
            100.0 * wins as f64 / pts.len().max(1) as f64
        ));
        out.push_str(&render::scatter(&pts, 56, 16, "Best-SC TFLOPs", "TC-GNN TFLOPs"));
    }
    out.push_str("\npaper shape: TC-GNN loses on (almost) every matrix; on the A100 it wins none.\n");
    write_csv_or_warn(
        &results_dir().join("fig2.csv"),
        &["matrix", "machine", "tcgnn_gflops", "best_sc_gflops"],
        &csv,
    );
    out
}

/// Fig. 7 — modeled OI (512α) vs cuTeSpMM throughput, N ∈ {32, 128, 512}.
pub fn fig7(records: &[Record]) -> String {
    let mut out = String::from("== Fig 7: OI_shmem (512α) vs cuTeSpMM GFLOPs ==\n");
    let mut csv = Vec::new();
    for m in MACHINES {
        for n in [32usize, 128, 512] {
            let mut ois = Vec::new();
            let mut gfs = Vec::new();
            let mut pts = Vec::new();
            for r in records {
                if let Some(c) = r.get(m, n, Algo::Hrpb) {
                    let oi = 512.0 * r.alpha;
                    ois.push(oi);
                    gfs.push(c.gflops);
                    pts.push((oi, c.gflops));
                    csv.push(vec![
                        r.name.clone(),
                        m.to_string(),
                        n.to_string(),
                        format!("{oi:.2}"),
                        format!("{:.1}", c.gflops),
                    ]);
                }
            }
            let pearson = stats::pearson(&ois, &gfs);
            let spearman = stats::spearman(&ois, &gfs);
            out.push_str(&format!(
                "\n[{m}, N={n}] pearson={pearson:.3} spearman={spearman:.3}\n"
            ));
            if n == 128 {
                out.push_str(&render::scatter(&pts, 56, 14, "OI_shmem = 512α", "GFLOPs"));
            }
        }
    }
    out.push_str("\npaper shape: OI_shmem strongly correlated with achieved GFLOPs.\n");
    write_csv_or_warn(
        &results_dir().join("fig7.csv"),
        &["matrix", "machine", "n", "oi_shmem", "cutespmm_gflops"],
        &csv,
    );
    out
}

/// Fig. 9 — box plots over synergy groups × N × {cuTeSpMM, Best-SC, TC-GNN}.
pub fn fig9(records: &[Record]) -> String {
    let mut out = String::from("== Fig 9: throughput distribution by synergy group ==\n");
    let mut csv = Vec::new();
    for m in MACHINES {
        for n in [32usize, 128, 512] {
            out.push_str(&format!("\n[{m}, N={n}]\n"));
            let mut entries: Vec<BoxEntry> = Vec::new();
            for syn in Synergy::all() {
                let grab = |f: &dyn Fn(&Record) -> Option<f64>| -> Vec<f64> {
                    records.iter().filter(|r| r.synergy == syn).filter_map(|r| f(r)).collect()
                };
                let cute = grab(&|r| r.get(m, n, Algo::Hrpb).map(|c| c.gflops));
                let best = grab(&|r| r.best_sc(m, n).map(|c| c.gflops));
                let tcgnn = grab(&|r| r.get(m, n, Algo::TcGnn).map(|c| c.gflops));
                for (algo, vals) in [("cutespmm", &cute), ("best-sc", &best), ("tcgnn", &tcgnn)] {
                    if vals.is_empty() {
                        continue;
                    }
                    let bs = stats::box_stats(vals);
                    csv.push(vec![
                        m.to_string(),
                        n.to_string(),
                        syn.name().to_string(),
                        algo.to_string(),
                        format!("{:.1}", bs.q25),
                        format!("{:.1}", bs.median),
                        format!("{:.1}", bs.q75),
                    ]);
                }
                entries.push(box_entry(format!("{}/cute", syn.name()), &cute));
                entries.push(box_entry(format!("{}/best-sc", syn.name()), &best));
                entries.push(box_entry(format!("{}/tcgnn", syn.name()), &tcgnn));
            }
            out.push_str(&render::boxplot(&entries, "GFLOPs"));
        }
    }
    out.push_str(
        "\npaper shape: cuTeSpMM > TC-GNN at every percentile everywhere; \
         cuTeSpMM > Best-SC decisively on High synergy, competitive on Medium/Low.\n",
    );
    write_csv_or_warn(
        &results_dir().join("fig9.csv"),
        &["machine", "n", "synergy", "algo", "q1", "median", "q3"],
        &csv,
    );
    out
}

/// Fig. 10 — geomean speedup over Best-SC, binned rows × synergy.
pub fn fig10(records: &[Record]) -> String {
    let row_bins: [(&str, usize, usize); 4] = [
        ("10k-30k", 0, 30_000),
        ("30k-80k", 30_000, 80_000),
        ("80k-160k", 80_000, 160_000),
        (">160k", 160_000, usize::MAX),
    ];
    let mut out = String::from("== Fig 10: speedup over Best-SC (geomean per bin), N=128 ==\n");
    let mut csv = Vec::new();
    for m in MACHINES {
        for (algo, label) in [(Algo::Hrpb, "cuTeSpMM"), (Algo::TcGnn, "TC-GNN")] {
            let mut grid = Vec::new();
            for (bin_name, lo, hi) in row_bins {
                let mut row = Vec::new();
                for syn in Synergy::all() {
                    let speedups: Vec<f64> = records
                        .iter()
                        .filter(|r| r.synergy == syn && r.rows >= lo && r.rows < hi)
                        .filter_map(|r| {
                            let a = r.get(m, 128, algo)?.gflops;
                            let b = r.best_sc(m, 128)?.gflops;
                            Some(a / b)
                        })
                        .collect();
                    let g = if speedups.is_empty() { f64::NAN } else { stats::geomean(&speedups) };
                    row.push(g);
                    csv.push(vec![
                        m.to_string(),
                        label.to_string(),
                        bin_name.to_string(),
                        syn.name().to_string(),
                        format!("{g:.3}"),
                    ]);
                }
                grid.push(row);
            }
            out.push_str(&format!("\n[{m}] {label} / Best-SC\n"));
            out.push_str(&render::heatmap(
                &row_bins.iter().map(|b| b.0.to_string()).collect::<Vec<_>>(),
                &Synergy::all().iter().map(|s| s.name().to_string()).collect::<Vec<_>>(),
                &grid,
            ));
        }
    }
    out.push_str(
        "\npaper shape: cuTeSpMM speedup grows with synergy and with row count; \
         TC-GNN stays below 0.5x everywhere.\n",
    );
    write_csv_or_warn(
        &results_dir().join("fig10.csv"),
        &["machine", "algo", "row_bin", "synergy", "geomean_speedup"],
        &csv,
    );
    out
}

/// Table 1 — synergy class definition (a definition, printed for the record).
pub fn table1() -> String {
    let mut rows = Vec::new();
    for s in Synergy::all() {
        let (lo, hi) = s.alpha_range();
        rows.push(vec![
            s.name().to_string(),
            format!("[{:.1}%, {:.1}%{}", lo * 100.0, hi * 100.0, if s == Synergy::High { "]" } else { ")" }),
        ]);
    }
    format!("== Table 1: synergy ranges ==\n{}", render::table(&["Synergy", "Range"], &rows))
}

/// Table 2 — corpus synergy counts (paper: 666 / 198 / 235 of 1099).
pub fn table2(records: &[Record]) -> String {
    let counts = corpus_run::synergy_counts(records);
    let total: usize = counts.iter().map(|&(_, c)| c).sum();
    let mut rows: Vec<Vec<String>> = counts
        .iter()
        .map(|&(s, c)| vec![s.name().to_string(), c.to_string()])
        .collect();
    rows.push(vec!["Total".into(), total.to_string()]);
    write_csv_or_warn(
        &results_dir().join("table2.csv"),
        &["synergy", "count"],
        &rows,
    );
    format!(
        "== Table 2: corpus synergy counts (paper: Low 666 / Med 198 / High 235 of 1099) ==\n{}",
        render::table(&["Synergy", "# of Matrices"], &rows)
    )
}

/// Tables 3/4 — named GNN matrices: GFLOPs for cuTeSpMM / TC-GNN / Best-SC.
pub fn table34(table: usize) -> String {
    let (matrices, machine, ns) = if table == 3 {
        (named::table3(), Machine::rtx4090(), [32usize, 64, 128])
    } else {
        (named::table4(), Machine::a100(), [32usize, 128, 512])
    };
    let mut out = format!(
        "== Table {table}: named GNN matrices on {} (GFLOPs) ==\n",
        machine.name
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for nm in &matrices {
        let coo = nm.spec.generate();
        let p = MatrixProfile::compute(&coo);
        let mut row = vec![nm.name.to_string()];
        for &n in &ns {
            let cute = algos::predict(Algo::Hrpb, &p, n, &machine).gflops;
            let tcgnn = algos::predict(Algo::TcGnn, &p, n, &machine).gflops;
            let (_, best) = algos::predict_best_sc(&p, n, &machine);
            row.push(format!("{cute:.0}"));
            row.push(format!("{tcgnn:.0}"));
            row.push(format!("{:.0}", best.gflops));
            csv.push(vec![
                nm.name.to_string(),
                n.to_string(),
                format!("{cute:.1}"),
                format!("{tcgnn:.1}"),
                format!("{:.1}", best.gflops),
            ]);
        }
        rows.push(row);
    }
    let mut headers = vec!["Matrix"];
    let labels: Vec<String> = ns
        .iter()
        .flat_map(|n| {
            vec![format!("cute(n={n})"), format!("tcgnn(n={n})"), format!("bestSC(n={n})")]
        })
        .collect();
    headers.extend(labels.iter().map(|s| s.as_str()));
    out.push_str(&render::table(&headers, &rows));
    out.push_str("\npaper shape: cuTeSpMM >> TC-GNN on every row; cuTeSpMM vs Best-SC mixed at n=32, ahead for most rows at n=128.\n");
    write_csv_or_warn(
        &results_dir().join(format!("table{table}.csv")),
        &["matrix", "n", "cutespmm", "tcgnn", "best_sc"],
        &csv,
    );
    out
}

/// §6.3 — measured preprocessing overhead vs one SpMM vs matrix read.
pub fn preprocessing() -> String {
    use crate::util::timer::time_once;
    let mut out = String::from(
        "== §6.3: preprocessing overhead (measured on this CPU, scaled matrices) ==\n",
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for name in ["cora", "citeseer", "pubmed", "artist", "PROTEINS_full"] {
        let Some(spec) = named::scaled(name, 1) else { continue };
        let coo = spec.generate();
        // write + read MatrixMarket to measure IO
        let tmp = std::env::temp_dir().join(format!("cutespmm_{name}.mtx"));
        crate::formats::mtx::write_mtx(&tmp, &coo, None).unwrap();
        let (read_coo, t_read) = time_once(|| crate::formats::mtx::read_mtx(&tmp).unwrap());
        let _ = std::fs::remove_file(&tmp);
        let (engine, t_prep) =
            time_once(|| crate::spmm::hrpb::HrpbEngine::prepare(&read_coo));
        let b = Dense::from_vec(coo.cols, 128, vec![0.5; coo.cols * 128]);
        let _ = engine.spmm(&b); // warm
        let (_, t_spmm) = time_once(|| engine.spmm(&b));
        rows.push(vec![
            name.to_string(),
            format!("{}", coo.nnz()),
            format!("{:.3}", t_prep * 1e3),
            format!("{:.3}", t_spmm * 1e3),
            format!("{:.1}", t_prep / t_spmm),
            format!("{:.3}", t_read * 1e3),
            format!("{:.2}", t_prep / t_read),
        ]);
        csv.push(vec![
            name.to_string(),
            coo.nnz().to_string(),
            format!("{t_prep}"),
            format!("{t_spmm}"),
            format!("{t_read}"),
        ]);
    }
    out.push_str(&render::table(
        &["matrix", "nnz", "prep(ms)", "spmm(ms,N=128)", "prep/spmm", "read(ms)", "prep/read"],
        &rows,
    ));
    out.push_str(
        "\npaper shape: preprocessing ~1-2 orders above one SpMM (N=128) but below matrix read time.\n",
    );
    write_csv_or_warn(
        &results_dir().join("preprocessing.csv"),
        &["matrix", "nnz", "prep_s", "spmm_s", "read_s"],
        &csv,
    );
    out
}

/// §4 ablation — TM/TK/TN tile-size sweep via HRPB stats + the OI model.
pub fn ablation_tiles() -> String {
    let mut out = String::from("== §4 ablation: tile-size sweep (modeled, A100, N=128) ==\n");
    let machine = Machine::a100();
    let mk = |name: &str| -> MatrixSpec {
        named::scaled(name, 4).unwrap()
    };
    let mut rows = Vec::new();
    for spec in [mk("amazon0505"), mk("DD"), mk("soc-BlogCatalog")] {
        let coo = spec.generate();
        let csr = crate::formats::Csr::from_coo(&coo);
        // TM sweep (the Fig 8 discussion): alpha drops as TM grows
        for (tm, tk) in [(16usize, 16usize), (32, 16), (16, 8), (16, 32)] {
            let hrpb = crate::hrpb::builder::build_with(&csr, tm, tk);
            let s = crate::hrpb::stats::compute(&hrpb);
            let oi = crate::synergy::model(&s, 128);
            rows.push(vec![
                spec.name.clone(),
                format!("{tm}"),
                format!("{tk}"),
                format!("{:.4}", s.alpha),
                format!("{:.2}", s.beta),
                format!("{:.1}", oi.oi_shmem),
            ]);
        }
        // TN sweep at fixed TM/TK (the Eq. 3/4 balance argument)
        let hrpb = crate::hrpb::builder::build_with(&csr, 16, 16);
        let s = crate::hrpb::stats::compute(&hrpb);
        for tn in [8usize, 16, 32, 64] {
            let oi = crate::synergy::model_with(&s, 128, tn);
            rows.push(vec![
                spec.name.clone(),
                "16".into(),
                "16".into(),
                format!("TN={tn}"),
                format!("{:.2}", oi.shmem_trans_a / oi.shmem_trans_b.max(1e-9)),
                format!("{:.1}", oi.oi_shmem),
            ]);
        }
    }
    out.push_str(&render::table(
        &["matrix", "TM", "TK", "alpha|TN", "beta|A:B", "OI_shmem"],
        &rows,
    ));
    out.push_str(&format!(
        "\npaper choice: TM=16, TK=16, TN=32 (balances A/B shared traffic; larger TM drops alpha).\nmachine ref: {}\n",
        machine.name
    ));
    write_csv_or_warn(
        &results_dir().join("ablation_tiles.csv"),
        &["matrix", "tm", "tk", "alpha_or_tn", "beta_or_ratio", "oi"],
        &rows.iter().map(|r| r.clone()).collect::<Vec<_>>(),
    );
    out
}

/// §5 ablation — load balancing schemes, measured on the native engine.
pub fn ablation_loadbalance() -> String {
    use crate::loadbalance as lb;
    use crate::spmm::hrpb::HrpbEngine;
    use crate::util::timer::measure;

    let mut out = String::from("== §5 ablation: load balancing (measured, native engine) ==\n");
    // skewed matrix: one very heavy panel + many light ones
    let mut t = Vec::new();
    let mut rng = crate::util::rng::Rng::new(77);
    for c in 0..6000usize {
        t.push((c % 16, (c * 7) % 20_000, rng.nz_value()));
    }
    for r in (16..40_000).step_by(16) {
        for j in 0..3 {
            t.push((r + j % 16, (r * 13 + j * 101) % 20_000, rng.nz_value()));
        }
    }
    let coo = crate::formats::Coo::from_triplets(40_000, 20_000, &t);
    let hrpb = crate::hrpb::build_from_coo(&coo);
    let b = Dense::from_vec(20_000, 64, vec![0.25; 20_000 * 64]);

    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let dev = lb::Device { num_sms: workers, blocks_per_sm: 1 };
    let schemes: Vec<(&str, lb::Schedule)> = vec![
        ("none", lb::schedule_none(&hrpb)),
        ("sorted", lb::schedule_sorted(&hrpb)),
        ("avg-split", lb::schedule_avg_split(&hrpb)),
        ("wave-aware", lb::schedule_wave_aware(&hrpb, dev)),
    ];
    let mut rows = Vec::new();
    let mut out_buf = Dense::zeros(40_000, 64);
    for (name, schedule) in schemes {
        let units = schedule.units.len();
        let atomics = schedule.atomic_units;
        let crit = schedule.critical_path();
        let engine = HrpbEngine::with_schedule(hrpb.clone(), schedule);
        // spmm_into with a reused buffer: time the kernel, not the allocator
        let meas = measure(1, 5, || {
            engine.spmm_into(&b, &mut out_buf);
        });
        rows.push(vec![
            name.to_string(),
            units.to_string(),
            atomics.to_string(),
            crit.to_string(),
            format!("{:.3}", meas.mean_s * 1e3),
            format!("{:.1}", engine.flops(64) / meas.mean_s / 1e9),
        ]);
    }
    out.push_str(&render::table(
        &["scheme", "units", "atomic_units", "critical_path", "time(ms)", "GFLOPs"],
        &rows,
    ));
    out.push_str(
        "\npaper shape: wave-aware splits only what waves cannot absorb — fewer atomic \
         units than avg-split at comparable or better makespan.\n",
    );
    write_csv_or_warn(
        &results_dir().join("ablation_loadbalance.csv"),
        &["scheme", "units", "atomic_units", "critical_path", "time_ms", "gflops"],
        &rows,
    );
    out
}

/// Aggregate-throughput comparison of engine-selection policies over one
/// `(machine, n)` slice of the corpus records.
#[derive(Clone, Copy, Debug)]
pub struct AutoPolicySummary {
    pub matrices: usize,
    /// Fraction of matrices where the synergy-gated choice equals the
    /// model's fastest candidate.
    pub agreement: f64,
    /// Aggregate useful throughput (total FLOPs / total modeled time) per
    /// policy.
    pub auto_gflops: f64,
    pub oracle_gflops: f64,
    pub hrpb_gflops: f64,
    pub best_sc_gflops: f64,
    pub tcgnn_gflops: f64,
    /// How many matrices Auto routed to each engine ([`Algo::index`]).
    pub routed: [usize; Algo::COUNT],
}

/// Replay the planner's synergy-gated decision rule
/// ([`crate::planner::choose`]) over the records at `(machine, n)`.
pub fn auto_policy_summary(records: &[Record], machine: &str, n: usize) -> Option<AutoPolicySummary> {
    use crate::planner::{self, PlannerConfig};

    let cfg = PlannerConfig::default();
    // (flops, time) accumulators: auto, oracle, hrpb, best-sc, tcgnn
    let mut agg: [(f64, f64); 5] = [(0.0, 0.0); 5];
    let mut routed = [0usize; Algo::COUNT];
    let (mut agree, mut total) = (0usize, 0usize);
    for r in records {
        let cells: Vec<(Algo, f64)> = planner::CANDIDATES
            .iter()
            .filter_map(|&a| r.get(machine, n, a).map(|c| (a, c.time_s)))
            .collect();
        if cells.len() != planner::CANDIDATES.len() {
            continue;
        }
        let mut ranked = cells.clone();
        ranked.sort_by(|a, b| a.1.total_cmp(&b.1));
        let (chosen, _why) = planner::choose(
            &ranked,
            r.synergy,
            r.alpha,
            cfg.high_synergy_slack,
            cfg.low_synergy_margin,
        );
        let time_of =
            |algo: Algo| cells.iter().find(|(a, _)| *a == algo).map(|(_, t)| *t).unwrap();
        let best_sc = cells
            .iter()
            .filter(|(a, _)| Algo::scalar_core().contains(a))
            .map(|(_, t)| *t)
            .fold(f64::INFINITY, f64::min);
        let flops = 2.0 * r.nnz as f64 * n as f64;
        for (slot, t) in [
            (0, time_of(chosen)),
            (1, ranked[0].1),
            (2, time_of(Algo::Hrpb)),
            (3, best_sc),
            (4, time_of(Algo::TcGnn)),
        ] {
            agg[slot].0 += flops;
            agg[slot].1 += t;
        }
        routed[chosen.index()] += 1;
        total += 1;
        if chosen == ranked[0].0 {
            agree += 1;
        }
    }
    if total == 0 {
        return None;
    }
    let gflops = |slot: usize| agg[slot].0 / agg[slot].1 / 1e9;
    Some(AutoPolicySummary {
        matrices: total,
        agreement: agree as f64 / total as f64,
        auto_gflops: gflops(0),
        oracle_gflops: gflops(1),
        hrpb_gflops: gflops(2),
        best_sc_gflops: gflops(3),
        tcgnn_gflops: gflops(4),
        routed,
    })
}

/// Auto-policy experiment — Auto vs fixed policies vs the per-matrix oracle
/// (fastest candidate everywhere), over the synthetic corpus.
pub fn auto_policy(records: &[Record]) -> String {
    let mut out = String::from(
        "== Auto policy: synergy-driven engine selection vs fixed policies (modeled) ==\n",
    );
    let mut csv = Vec::new();
    for m in MACHINES {
        for n in [32usize, 128, 512] {
            let Some(s) = auto_policy_summary(records, m, n) else { continue };
            out.push_str(&format!(
                "\n[{m}, N={n}] {} matrices, planner/oracle agreement {:.1}%\n",
                s.matrices,
                100.0 * s.agreement
            ));
            let mut rows = Vec::new();
            for (g, label) in [
                (s.auto_gflops, "auto"),
                (s.oracle_gflops, "oracle"),
                (s.hrpb_gflops, "hrpb-always"),
                (s.best_sc_gflops, "best-sc-always"),
                (s.tcgnn_gflops, "tcgnn-always"),
            ] {
                rows.push(vec![
                    label.to_string(),
                    format!("{g:.0}"),
                    format!("{:.3}", g / s.oracle_gflops),
                ]);
                csv.push(vec![
                    m.to_string(),
                    n.to_string(),
                    label.to_string(),
                    format!("{g:.1}"),
                    format!("{:.4}", g / s.oracle_gflops),
                ]);
            }
            out.push_str(&render::table(&["policy", "agg GFLOPs", "vs oracle"], &rows));
            out.push_str("auto routing: ");
            for a in crate::planner::CANDIDATES {
                if s.routed[a.index()] > 0 {
                    out.push_str(&format!("{}={} ", a.name(), s.routed[a.index()]));
                }
            }
            out.push('\n');
        }
    }
    out.push_str(
        "\npaper shape: Auto tracks the oracle (within 10%) while every fixed policy \
         pays for its losing regime: TCU-always loses on Low synergy, Best-SC-always \
         loses on High.\n",
    );
    write_csv_or_warn(
        &results_dir().join("auto_policy.csv"),
        &["machine", "n", "policy", "agg_gflops", "vs_oracle"],
        &csv,
    );
    out
}

/// Generator-corpus recipes for the artifact prep experiment — one per
/// structural regime, sized so the HRPB build dominates fixed overheads.
pub(crate) fn prep_specs() -> Vec<MatrixSpec> {
    vec![
        MatrixSpec {
            name: "prep-fem".into(),
            rows: 24_576,
            family: Family::Banded { bandwidth: 32, band_fill: 0.65, noise: 0.01 },
            seed: 0xFEED0,
        },
        MatrixSpec {
            name: "prep-mesh".into(),
            rows: 32_768,
            family: Family::Mesh { dims: 2 },
            seed: 0xFEED1,
        },
        MatrixSpec {
            name: "prep-rmat".into(),
            rows: 16_384,
            family: Family::Rmat { edge_factor: 8, skew: 0.57 },
            seed: 0xFEED2,
        },
        MatrixSpec {
            name: "prep-banded-sparse".into(),
            rows: 24_576,
            family: Family::Banded { bandwidth: 64, band_fill: 0.25, noise: 0.05 },
            seed: 0xFEED3,
        },
    ]
}

/// One matrix's measurements in the prep experiment.
#[derive(Clone, Debug)]
pub struct PrepOutcome {
    pub matrix: String,
    pub nnz: usize,
    /// Serial [`crate::hrpb::builder::build_with`] wall time.
    pub serial_build_s: f64,
    /// Parallel build wall time at this host's thread count.
    pub parallel_build_s: f64,
    /// Parallel output byte-identical to serial?
    pub parallel_identical: bool,
    /// One-time reorder proposal cost (signatures + clustering + pricing,
    /// [`crate::reorder::propose`]) — the cold-build report now splits
    /// build vs. reorder so the activation gate's cost side is measured,
    /// not assumed.
    pub reorder_s: f64,
    /// Cold registration (build + stats + persist) through a store-backed
    /// registry.
    pub cold_register_s: f64,
    /// Warm registration (artifact load) through a fresh store-backed
    /// registry — min of two runs to shave scheduler noise.
    pub warm_register_s: f64,
    /// Whether the warm registration actually hit the store.
    pub warm_hit: bool,
    /// Size of the persisted artifact on disk.
    pub artifact_bytes: u64,
}

/// Run the prep experiment against `dir` (created, reused within the run).
pub fn prep_outcomes(dir: &std::path::Path) -> Vec<PrepOutcome> {
    use crate::coordinator::Registry;
    use crate::formats::Csr;
    use crate::hrpb::{builder, ArtifactStore};
    use crate::params::{TK, TM};
    use crate::planner::fingerprint;
    use crate::util::timer::time_once;
    use std::sync::Arc;

    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let specs = prep_specs();
    let progress = crate::bench::harness::Progress::start("prep", specs.len());
    let mut out = Vec::new();
    for (i, spec) in specs.into_iter().enumerate() {
        progress.cell(i, &spec.name);
        let coo = spec.generate();
        if coo.nnz() == 0 {
            continue;
        }
        let csr = Csr::from_coo(&coo);
        let (serial, serial_build_s) = time_once(|| builder::build_with(&csr, TM, TK));
        let (parallel, parallel_build_s) =
            time_once(|| builder::build_with_parallel(&csr, TM, TK, threads));
        let (_proposal, reorder_s) = time_once(|| crate::reorder::propose(&csr, TM, TK));
        let parallel_identical = serial.packed == parallel.packed
            && serial.size_ptr == parallel.size_ptr
            && serial.blocked_row_ptr == parallel.blocked_row_ptr
            && serial.active_cols == parallel.active_cols
            && serial.blocks == parallel.blocks;

        let store = Arc::new(ArtifactStore::open(dir).expect("open artifact store"));
        let fp = fingerprint(&coo);
        // cold: make sure no artifact is present, then register once
        let _ = std::fs::remove_file(store.path_for(fp));
        let cold_reg = Registry::with_store(store.clone());
        let (_, cold_register_s) = time_once(|| cold_reg.register(&spec.name, &coo));
        let artifact_bytes = std::fs::metadata(store.path_for(fp)).map(|m| m.len()).unwrap_or(0);

        // warm: fresh registries (simulated restarts) against the same dir
        let mut warm_register_s = f64::INFINITY;
        for _ in 0..2 {
            let warm_reg = Registry::with_store(store.clone());
            let (_, t) = time_once(|| warm_reg.register(&spec.name, &coo));
            warm_register_s = warm_register_s.min(t);
        }
        let warm_hit = store.stats().hits >= 2;

        out.push(PrepOutcome {
            matrix: spec.name.clone(),
            nnz: coo.nnz(),
            serial_build_s,
            parallel_build_s,
            parallel_identical,
            reorder_s,
            cold_register_s,
            warm_register_s,
            warm_hit,
            artifact_bytes,
        });
    }
    out
}

/// Artifact prep experiment — cold vs warm registration and serial vs
/// parallel HRPB build over the generator corpus (the §6.3 amortization
/// story, extended across process restarts).
pub fn prep() -> String {
    let dir = std::env::temp_dir().join(format!("cutespmm_prep_exp_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let outcomes = prep_outcomes(&dir);
    let _ = std::fs::remove_dir_all(&dir);
    prep_report(&outcomes)
}

/// Render the prep experiment report (split from [`prep`] so tests can run
/// the measurement suite once and exercise the rendering on the same data).
pub fn prep_report(outcomes: &[PrepOutcome]) -> String {
    let mut out = String::from(
        "== prep: persistent HRPB artifacts — cold vs warm registration, serial vs parallel build ==\n",
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let (mut cold_total, mut warm_total) = (0.0f64, 0.0f64);
    for o in outcomes {
        cold_total += o.cold_register_s;
        warm_total += o.warm_register_s;
        rows.push(vec![
            o.matrix.clone(),
            o.nnz.to_string(),
            format!("{:.2}", o.serial_build_s * 1e3),
            format!("{:.2}", o.parallel_build_s * 1e3),
            format!("{:.2}x", o.serial_build_s / o.parallel_build_s.max(1e-12)),
            if o.parallel_identical { "yes".into() } else { "NO".into() },
            format!("{:.2}", o.reorder_s * 1e3),
            format!("{:.2}", o.cold_register_s * 1e3),
            format!("{:.2}", o.warm_register_s * 1e3),
            format!("{:.1}x", o.cold_register_s / o.warm_register_s.max(1e-12)),
            format!("{}", o.artifact_bytes / 1024),
        ]);
        csv.push(vec![
            o.matrix.clone(),
            o.nnz.to_string(),
            format!("{}", o.serial_build_s),
            format!("{}", o.parallel_build_s),
            o.parallel_identical.to_string(),
            format!("{}", o.reorder_s),
            format!("{}", o.cold_register_s),
            format!("{}", o.warm_register_s),
            o.warm_hit.to_string(),
            o.artifact_bytes.to_string(),
        ]);
    }
    out.push_str(&render::table(
        &[
            "matrix",
            "nnz",
            "serial(ms)",
            "parallel(ms)",
            "build speedup",
            "identical",
            "reorder(ms)",
            "cold reg(ms)",
            "warm reg(ms)",
            "warm speedup",
            "artifact(KiB)",
        ],
        &rows,
    ));
    out.push_str(&format!(
        "\ntotals: cold {:.2} ms vs warm {:.2} ms -> warm registration {:.1}x faster \
         (acceptance floor: 5x)\n",
        cold_total * 1e3,
        warm_total * 1e3,
        cold_total / warm_total.max(1e-12),
    ));
    out.push_str(
        "expected shape: warm start skips the entire build+plan pass (file read + near-memcpy \
         decode), and the parallel build scales with panels across cores while staying \
         byte-identical to the serial result. The reorder column is the one-time similarity \
         pass the activation gate weighs against its predicted gain — the cold-build cost now \
         reports its build vs. reorder split.\n",
    );
    write_csv_or_warn(
        &results_dir().join("prep.csv"),
        &[
            "matrix",
            "nnz",
            "serial_build_s",
            "parallel_build_s",
            "parallel_identical",
            "reorder_s",
            "cold_register_s",
            "warm_register_s",
            "warm_hit",
            "artifact_bytes",
        ],
        &csv,
    );
    out
}

/// Matrices for the exec-runtime experiment: one per structural regime,
/// sized so the SpMM hot loop (not fixed overheads) dominates.
pub(crate) fn exec_specs(quick: bool) -> Vec<MatrixSpec> {
    let scale = if quick { 1usize } else { 4 };
    vec![
        MatrixSpec {
            name: "exec-fem".into(),
            rows: 4096 * scale,
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
            seed: 0xE8EC0,
        },
        MatrixSpec {
            name: "exec-mesh".into(),
            rows: 6144 * scale,
            family: Family::Mesh { dims: 2 },
            seed: 0xE8EC1,
        },
        MatrixSpec {
            name: "exec-rmat".into(),
            rows: 3072 * scale,
            family: Family::Rmat { edge_factor: 8, skew: 0.57 },
            seed: 0xE8EC2,
        },
    ]
}

/// Dense widths the exec experiment sweeps (the serving-scale axis).
pub const EXEC_WIDTHS: [usize; 5] = [32, 64, 128, 256, 512];

/// One (matrix, N) cell of the exec experiment: the four execution modes —
/// {spawn-per-call, pooled} × {unblocked, slab-blocked} — timed on the same
/// HRPB engine, plus the auto slab width and a correctness bound.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    pub matrix: String,
    pub nnz: usize,
    pub n: usize,
    /// Slab width the cache model chose for this N.
    pub slab_width: usize,
    /// Seed behavior: scoped-spawn per call, full-width kernel, fresh
    /// output allocation per call.
    pub spawn_unblocked_s: f64,
    /// Spawn per call, slab-blocked kernel.
    pub spawn_blocked_s: f64,
    /// Persistent pool, full-width kernel, reused output buffer.
    pub pooled_unblocked_s: f64,
    /// The runtime default: pool + slabs + `spmm_into` reuse.
    pub pooled_blocked_s: f64,
    /// Worst relative error of any mode against the CSR reference.
    pub max_rel_err: f64,
}

impl ExecOutcome {
    /// The headline ratio: runtime default vs seed behavior.
    pub fn speedup(&self) -> f64 {
        self.spawn_unblocked_s / self.pooled_blocked_s.max(1e-12)
    }
}

/// Run the exec experiment measurements. `quick` shrinks the matrices and
/// sample counts (CI smoke), keeping the full width sweep.
pub fn exec_outcomes(quick: bool) -> Vec<ExecOutcome> {
    exec_outcomes_for(&exec_specs(quick), &EXEC_WIDTHS, if quick { 3 } else { 5 })
}

/// Measurement core, parameterized so tests can run a tiny grid (debug-mode
/// `cargo test` cannot afford the full serving-scale sweep).
pub fn exec_outcomes_for(
    specs: &[MatrixSpec],
    widths: &[usize],
    samples: usize,
) -> Vec<ExecOutcome> {
    use crate::spmm::exec::slab;
    use crate::spmm::hrpb::{ExecOpts, HrpbEngine};
    use crate::util::timer::measure;

    let progress = crate::bench::harness::Progress::start("exec", specs.len());
    let mut out = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        progress.cell(i, &spec.name);
        let coo = spec.generate();
        if coo.nnz() == 0 {
            continue;
        }
        let engine = HrpbEngine::prepare(&coo);
        let reference = Algo::Csr.prepare(&coo);
        for &n in widths {
            let b = Dense::from_vec(coo.cols, n, vec![0.25; coo.cols * n]);
            let want = reference.spmm(&b);
            let mut reused = Dense::zeros(coo.rows, n);
            let mut max_rel_err = 0.0f64;
            let mut time_mode = |pooled: bool, slab_width: usize, reuse: bool| -> f64 {
                let opts = ExecOpts { pooled, slab_width };
                max_rel_err = max_rel_err.max(engine.spmm_opts(&b, opts).rel_fro_error(&want));
                let meas = measure(1, samples, || {
                    if reuse {
                        engine.spmm_into_opts(&b, &mut reused, opts);
                    } else {
                        let _ = engine.spmm_opts(&b, opts);
                    }
                });
                meas.median_s
            };
            // seed behavior: spawn per call, unblocked, allocating output
            let spawn_unblocked_s = time_mode(false, usize::MAX, false);
            let spawn_blocked_s = time_mode(false, 0, false);
            // runtime: persistent pool + spmm_into buffer reuse
            let pooled_unblocked_s = time_mode(true, usize::MAX, true);
            let pooled_blocked_s = time_mode(true, 0, true);
            out.push(ExecOutcome {
                matrix: spec.name.clone(),
                nnz: coo.nnz(),
                n,
                slab_width: slab::choose(n),
                spawn_unblocked_s,
                spawn_blocked_s,
                pooled_unblocked_s,
                pooled_blocked_s,
                max_rel_err,
            });
        }
    }
    out
}

/// Write the machine-readable perf-trajectory record the CI uploads.
fn write_exec_json(outcomes: &[ExecOutcome], geomean_256: f64) -> std::path::PathBuf {
    use crate::util::json::Json;
    let threads =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let doc = Json::obj(vec![
        ("bench", Json::str("exec_runtime")),
        ("pr", Json::num(4.0)),
        ("host_threads", Json::num(threads as f64)),
        ("widths", Json::arr(EXEC_WIDTHS.iter().map(|&n| Json::num(n as f64)))),
        // a grid without N=256 has no headline figure; 0.0 keeps the JSON
        // valid (NaN is not JSON)
        (
            "geomean_speedup_n256",
            Json::num(if geomean_256.is_finite() { geomean_256 } else { 0.0 }),
        ),
        ("acceptance_floor_n256", Json::num(1.3)),
        (
            "cases",
            Json::arr(outcomes.iter().map(|o| {
                Json::obj(vec![
                    ("matrix", Json::str(o.matrix.clone())),
                    ("nnz", Json::num(o.nnz as f64)),
                    ("n", Json::num(o.n as f64)),
                    ("slab_width", Json::num(o.slab_width as f64)),
                    ("spawn_unblocked_s", Json::num(o.spawn_unblocked_s)),
                    ("spawn_blocked_s", Json::num(o.spawn_blocked_s)),
                    ("pooled_unblocked_s", Json::num(o.pooled_unblocked_s)),
                    ("pooled_blocked_s", Json::num(o.pooled_blocked_s)),
                    ("speedup", Json::num(o.speedup())),
                    ("max_rel_err", Json::num(o.max_rel_err)),
                ])
            })),
        ),
    ]);
    let path = results_dir().join("BENCH_PR4.json");
    write_json_or_warn(&path, &doc.to_string());
    path
}

/// Exec-runtime experiment — blocked-vs-unblocked × pooled-vs-spawn over the
/// width sweep, emitting `BENCH_PR4.json` (the start of the perf
/// trajectory).
pub fn exec(quick: bool) -> String {
    let outcomes = exec_outcomes(quick);
    exec_report(&outcomes)
}

/// Render the exec experiment (split so tests measure once and reuse).
pub fn exec_report(outcomes: &[ExecOutcome]) -> String {
    let mut out = String::from(
        "== exec: zero-allocation blocked runtime — pool + column slabs vs spawn-per-call ==\n",
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut speedups_256 = Vec::new();
    for o in outcomes {
        if o.n == 256 {
            speedups_256.push(o.speedup());
        }
        rows.push(vec![
            o.matrix.clone(),
            o.n.to_string(),
            o.slab_width.to_string(),
            format!("{:.3}", o.spawn_unblocked_s * 1e3),
            format!("{:.3}", o.spawn_blocked_s * 1e3),
            format!("{:.3}", o.pooled_unblocked_s * 1e3),
            format!("{:.3}", o.pooled_blocked_s * 1e3),
            format!("{:.2}x", o.speedup()),
            format!("{:.1e}", o.max_rel_err),
        ]);
        csv.push(vec![
            o.matrix.clone(),
            o.nnz.to_string(),
            o.n.to_string(),
            o.slab_width.to_string(),
            format!("{}", o.spawn_unblocked_s),
            format!("{}", o.spawn_blocked_s),
            format!("{}", o.pooled_unblocked_s),
            format!("{}", o.pooled_blocked_s),
            format!("{:.4}", o.speedup()),
            format!("{:.2e}", o.max_rel_err),
        ]);
    }
    out.push_str(&render::table(
        &[
            "matrix",
            "N",
            "slab",
            "spawn+unblk(ms)",
            "spawn+blk(ms)",
            "pool+unblk(ms)",
            "pool+blk(ms)",
            "speedup",
            "max_rel_err",
        ],
        &rows,
    ));
    let geomean_256 =
        if speedups_256.is_empty() { f64::NAN } else { stats::geomean(&speedups_256) };
    out.push_str(&format!(
        "\nblocked+pooled vs unblocked spawn-per-call at N=256: geomean {:.2}x \
         (acceptance floor: 1.3x)\n",
        geomean_256
    ));
    out.push_str(
        "expected shape: the pool removes the per-call spawn tax (biggest at small N, where \
         the kernel is short), slabs restore C-tile/B-row L1 residency (biggest at large N), \
         and spmm_into makes the steady state allocation-free; every mode stays within 1e-5 \
         of the CSR reference.\n",
    );
    write_csv_or_warn(
        &results_dir().join("exec.csv"),
        &[
            "matrix",
            "nnz",
            "n",
            "slab_width",
            "spawn_unblocked_s",
            "spawn_blocked_s",
            "pooled_unblocked_s",
            "pooled_blocked_s",
            "speedup",
            "max_rel_err",
        ],
        &csv,
    );
    let json_path = write_exec_json(outcomes, geomean_256);
    out.push_str(&format!("machine-readable record -> {}\n", json_path.display()));
    out
}

/// The reorder-corpus families: structured matrices whose *arrival row
/// order hides the structure* (generated clustered, then row-shuffled
/// deterministically), plus a genuinely scattered power-law control. The
/// shuffle is what makes the A/B honest — reordering can only win by
/// *recovering* latent similarity, and the rmat control shows the gate
/// declining when there is none to recover.
pub(crate) fn reorder_specs(quick: bool) -> Vec<(&'static str, MatrixSpec, bool)> {
    let s = if quick { 1usize } else { 3 };
    vec![
        (
            "scattered",
            MatrixSpec {
                name: "reorder-scattered".into(),
                rows: 4096 * s,
                family: Family::BlockDiag { unit: 16, unit_density: 0.7 },
                seed: 0x5E0D0,
            },
            true,
        ),
        (
            "community",
            MatrixSpec {
                name: "reorder-community".into(),
                rows: 4096 * s,
                family: Family::Community {
                    communities: 256 * s,
                    intra_degree: 12,
                    inter_frac: 0.05,
                },
                seed: 0x5E0D1,
            },
            true,
        ),
        (
            "banded",
            MatrixSpec {
                name: "reorder-banded".into(),
                rows: 4096 * s,
                family: Family::Banded { bandwidth: 16, band_fill: 0.55, noise: 0.01 },
                seed: 0x5E0D2,
            },
            true,
        ),
        (
            "rmat",
            MatrixSpec {
                name: "reorder-rmat".into(),
                rows: 3072 * s,
                family: Family::Rmat { edge_factor: 8, skew: 0.57 },
                seed: 0x5E0D3,
            },
            false,
        ),
    ]
}

/// One (family, matrix) cell of the reorder A/B: the same HRPB engine in
/// arrival order vs. similarity-clustered order, with the planner's
/// activation verdict and the measured α/β lift.
#[derive(Clone, Debug)]
pub struct ReorderOutcome {
    pub family: String,
    pub matrix: String,
    pub nnz: usize,
    pub n: usize,
    /// The planner gate's verdict ([`crate::planner::Planner::gate_reorder`]).
    pub activated: bool,
    pub alpha_before: f64,
    pub alpha_after: f64,
    pub beta_before: f64,
    pub beta_after: f64,
    /// One-time proposal cost (signatures + clustering + pricing).
    pub reorder_s: f64,
    /// `spmm_into` median, arrival order.
    pub original_s: f64,
    /// `spmm_into` median, reordered (equals `original_s` when the gate
    /// declined — the A/B charges no phantom win).
    pub reordered_s: f64,
    /// Worst relative error of either order against the CSR reference.
    pub max_rel_err: f64,
}

impl ReorderOutcome {
    /// The headline ratio: arrival order vs. similarity-clustered order.
    pub fn speedup(&self) -> f64 {
        self.original_s / self.reordered_s.max(1e-12)
    }
}

/// Run the reorder A/B at the default scale. `quick` shrinks the matrices
/// and sample counts (CI smoke).
pub fn reorder_outcomes(quick: bool) -> Vec<ReorderOutcome> {
    reorder_outcomes_for(&reorder_specs(quick), 128, if quick { 3 } else { 5 })
}

/// Measurement core, parameterized so debug-mode tests can afford a tiny
/// grid.
pub fn reorder_outcomes_for(
    specs: &[(&'static str, MatrixSpec, bool)],
    n: usize,
    samples: usize,
) -> Vec<ReorderOutcome> {
    use crate::formats::Csr;
    use crate::params::{TK, TM};
    use crate::planner::Planner;
    use crate::reorder::{self, RowPermutation};
    use crate::spmm::hrpb::HrpbEngine;
    use crate::util::rng::Rng;
    use crate::util::timer::{measure, time_once};

    let planner = Planner::new(Machine::a100());
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let progress = crate::bench::harness::Progress::start("reorder", specs.len());
    let mut out = Vec::new();
    for (i, (family, spec, shuffle)) in specs.iter().enumerate() {
        progress.cell(i, &format!("{family}/{}", spec.name));
        let mut coo = spec.generate();
        if coo.nnz() == 0 {
            continue;
        }
        if *shuffle {
            coo = RowPermutation::random(coo.rows, &mut Rng::new(spec.seed ^ 0x51))
                .apply_coo(&coo);
        }
        let csr = Csr::from_coo(&coo);
        let (proposal, reorder_s) = time_once(|| reorder::propose(&csr, TM, TK));
        let activated = planner.gate_reorder(&proposal);

        let engine_orig =
            HrpbEngine::from_hrpb(crate::hrpb::builder::build_with_parallel(&csr, TM, TK, threads));
        let reference = Algo::Csr.prepare(&coo);
        let b = Dense::from_vec(coo.cols, n, vec![0.25; coo.cols * n]);
        let want = reference.spmm(&b);
        let mut reused = Dense::zeros(coo.rows, n);
        let mut max_rel_err = engine_orig.spmm(&b).rel_fro_error(&want);
        let original_s = measure(1, samples, || {
            engine_orig.spmm_into(&b, &mut reused);
        })
        .median_s;
        let (alpha_after, beta_after, reordered_s) = if activated {
            let engine_reord = HrpbEngine::from_hrpb(reorder::build_reordered(
                &csr,
                proposal.perm.clone(),
                TM,
                TK,
                threads,
            ));
            max_rel_err = max_rel_err.max(engine_reord.spmm(&b).rel_fro_error(&want));
            let t = measure(1, samples, || {
                engine_reord.spmm_into(&b, &mut reused);
            })
            .median_s;
            (proposal.after.alpha, proposal.after.beta, t)
        } else {
            (proposal.before.alpha, proposal.before.beta, original_s)
        };
        out.push(ReorderOutcome {
            family: family.to_string(),
            matrix: spec.name.clone(),
            nnz: coo.nnz(),
            n,
            activated,
            alpha_before: proposal.before.alpha,
            alpha_after,
            beta_before: proposal.before.beta,
            beta_after,
            reorder_s,
            original_s,
            reordered_s,
            max_rel_err,
        });
    }
    out
}

/// Write the machine-readable perf-trajectory record the CI uploads.
fn write_reorder_json(outcomes: &[ReorderOutcome], geomean_lowmed: f64) -> std::path::PathBuf {
    use crate::util::json::Json;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let doc = Json::obj(vec![
        ("bench", Json::str("reorder")),
        ("pr", Json::num(5.0)),
        ("host_threads", Json::num(threads as f64)),
        // a run with no scattered/community cells has no headline; 0.0
        // keeps the JSON valid (NaN is not JSON)
        (
            "geomean_speedup_lowmed",
            Json::num(if geomean_lowmed.is_finite() { geomean_lowmed } else { 0.0 }),
        ),
        ("acceptance_floor_lowmed", Json::num(1.2)),
        (
            "cases",
            Json::arr(outcomes.iter().map(|o| {
                Json::obj(vec![
                    ("family", Json::str(o.family.clone())),
                    ("matrix", Json::str(o.matrix.clone())),
                    ("nnz", Json::num(o.nnz as f64)),
                    ("n", Json::num(o.n as f64)),
                    ("activated", Json::Bool(o.activated)),
                    ("alpha_before", Json::num(o.alpha_before)),
                    ("alpha_after", Json::num(o.alpha_after)),
                    ("beta_before", Json::num(o.beta_before)),
                    ("beta_after", Json::num(o.beta_after)),
                    ("reorder_s", Json::num(o.reorder_s)),
                    ("original_s", Json::num(o.original_s)),
                    ("reordered_s", Json::num(o.reordered_s)),
                    ("speedup", Json::num(o.speedup())),
                    ("max_rel_err", Json::num(o.max_rel_err)),
                ])
            })),
        ),
    ]);
    let path = results_dir().join("BENCH_PR5.json");
    write_json_or_warn(&path, &doc.to_string());
    path
}

/// Reorder experiment — {original, reordered} × {scattered, community,
/// banded, rmat}, emitting `BENCH_PR5.json`.
pub fn reorder(quick: bool) -> String {
    let outcomes = reorder_outcomes(quick);
    reorder_report(&outcomes)
}

/// Render the reorder experiment (split so tests measure once and reuse).
pub fn reorder_report(outcomes: &[ReorderOutcome]) -> String {
    let mut out = String::from(
        "== reorder: similarity-clustered HRPB packing — arrival order vs reordered ==\n",
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut lowmed_speedups = Vec::new();
    for o in outcomes {
        if o.family == "scattered" || o.family == "community" {
            lowmed_speedups.push(o.speedup());
        }
        rows.push(vec![
            o.family.clone(),
            o.matrix.clone(),
            o.n.to_string(),
            if o.activated { "yes".into() } else { "no".into() },
            format!("{:.4}", o.alpha_before),
            format!("{:.4}", o.alpha_after),
            format!("{:.2}", o.beta_before),
            format!("{:.2}", o.beta_after),
            format!("{:.2}", o.reorder_s * 1e3),
            format!("{:.3}", o.original_s * 1e3),
            format!("{:.3}", o.reordered_s * 1e3),
            format!("{:.2}x", o.speedup()),
            format!("{:.1e}", o.max_rel_err),
        ]);
        csv.push(vec![
            o.family.clone(),
            o.matrix.clone(),
            o.nnz.to_string(),
            o.n.to_string(),
            o.activated.to_string(),
            format!("{}", o.alpha_before),
            format!("{}", o.alpha_after),
            format!("{}", o.beta_before),
            format!("{}", o.beta_after),
            format!("{}", o.reorder_s),
            format!("{}", o.original_s),
            format!("{}", o.reordered_s),
            format!("{:.4}", o.speedup()),
            format!("{:.2e}", o.max_rel_err),
        ]);
    }
    out.push_str(&render::table(
        &[
            "family",
            "matrix",
            "N",
            "reorder",
            "alpha_pre",
            "alpha_post",
            "beta_pre",
            "beta_post",
            "reorder(ms)",
            "orig(ms)",
            "reord(ms)",
            "speedup",
            "max_rel_err",
        ],
        &rows,
    ));
    let geomean_lowmed = if lowmed_speedups.is_empty() {
        f64::NAN
    } else {
        stats::geomean(&lowmed_speedups)
    };
    out.push_str(&format!(
        "\nreordered vs arrival order on the scattered/community (low/medium-synergy) \
         families: geomean {:.2}x (acceptance floor: 1.2x)\n",
        geomean_lowmed
    ));
    out.push_str(
        "expected shape: the shuffled families recover their latent clustering (α rises \
         several-fold, brick count — and with it decode + C-row traffic — drops), the rmat \
         control either declines activation or gains little, results stay within 1e-5 of the \
         CSR reference in both orders, and output rows always come back in original order \
         (the scatter epilogue, not a post-pass).\n",
    );
    write_csv_or_warn(
        &results_dir().join("reorder.csv"),
        &[
            "family",
            "matrix",
            "nnz",
            "n",
            "activated",
            "alpha_before",
            "alpha_after",
            "beta_before",
            "beta_after",
            "reorder_s",
            "original_s",
            "reordered_s",
            "speedup",
            "max_rel_err",
        ],
        &csv,
    );
    let json_path = write_reorder_json(outcomes, geomean_lowmed);
    out.push_str(&format!("machine-readable record -> {}\n", json_path.display()));
    out
}

/// The geometry-corpus families: unstructured/scattered matrices where
/// most default-shape brick slots are zero-fill (the exact pricer should
/// find a smaller catalog shape), plus a dense-block control whose slots
/// price (near-)identically at every catalog shape — the chooser must stay
/// at the default 16x4 there.
pub(crate) fn geometry_specs(quick: bool) -> Vec<(&'static str, MatrixSpec)> {
    let s = if quick { 1usize } else { 3 };
    vec![
        (
            "scattered",
            MatrixSpec {
                name: "geometry-scattered".into(),
                rows: 4096 * s,
                family: Family::Random { avg_degree: 2 },
                seed: 0x6E00,
            },
        ),
        (
            "powerlaw",
            MatrixSpec {
                name: "geometry-powerlaw".into(),
                rows: 3072 * s,
                family: Family::Rmat { edge_factor: 8, skew: 0.57 },
                seed: 0x6E01,
            },
        ),
        (
            "blockdense",
            MatrixSpec {
                name: "geometry-blockdense".into(),
                rows: 4096 * s,
                family: Family::BlockDiag { unit: 16, unit_density: 0.7 },
                seed: 0x6E02,
            },
        ),
    ]
}

/// One (family, matrix) cell of the brick-geometry A/B: the same matrix
/// served at the fixed default 16x4 shape vs. the planner-picked catalog
/// shape, with the pre-build pricer slot counts that drove the choice.
#[derive(Clone, Debug)]
pub struct GeometryOutcome {
    pub family: String,
    pub matrix: String,
    pub nnz: usize,
    pub n: usize,
    /// The planner's pick ([`crate::planner::Planner::choose_geometry`]).
    pub chosen: crate::params::BrickGeometry,
    /// Pre-build pricer work proxy (brick slots = bricks × pattern bits)
    /// at the default shape…
    pub slots_default: usize,
    /// …and at the chosen shape.
    pub slots_chosen: usize,
    /// One-time cost of pricing the whole catalog from CSR.
    pub price_s: f64,
    /// `spmm_into` median at the fixed default geometry.
    pub fixed_s: f64,
    /// `spmm_into` median at the chosen geometry (equals `fixed_s` when
    /// the chooser stayed at the default — the A/B charges no phantom win).
    pub picked_s: f64,
    /// Worst relative error of either shape against the CSR reference.
    pub max_rel_err: f64,
}

impl GeometryOutcome {
    /// Did the chooser deviate from the default shape?
    pub fn activated(&self) -> bool {
        !self.chosen.is_default()
    }

    /// Pricer-predicted work ratio (default slots over chosen slots).
    pub fn predicted_gain(&self) -> f64 {
        self.slots_default as f64 / self.slots_chosen.max(1) as f64
    }

    /// The headline ratio: fixed 16x4 vs. planner-picked shape.
    pub fn speedup(&self) -> f64 {
        self.fixed_s / self.picked_s.max(1e-12)
    }
}

/// Run the geometry A/B at the default scale. `quick` shrinks the matrices
/// and sample counts (CI smoke).
pub fn geometry_outcomes(quick: bool) -> Vec<GeometryOutcome> {
    let cache = crate::bench::harness::SuiteCache::open("geometry_driver");
    geometry_outcomes_for(&geometry_specs(quick), 128, if quick { 3 } else { 5 }, cache.as_ref())
}

/// Measurement core, parameterized so debug-mode tests can afford a tiny
/// grid. With a [`SuiteCache`](crate::bench::harness::SuiteCache), every
/// engine build routes through the suite-run artifact store: the
/// planner-picked cell of a matrix whose chosen shape is the default
/// serves the already-built 16x4 artifact (a hit) instead of rebuilding,
/// and its round-trip result is folded into the cell's correctness check.
pub fn geometry_outcomes_for(
    specs: &[(&'static str, MatrixSpec)],
    n: usize,
    samples: usize,
    cache: Option<&crate::bench::harness::SuiteCache>,
) -> Vec<GeometryOutcome> {
    use crate::bench::harness::Progress;
    use crate::formats::Csr;
    use crate::params::{BrickGeometry, TK, TM};
    use crate::planner::Planner;
    use crate::spmm::hrpb::HrpbEngine;
    use crate::util::timer::{measure, time_once};

    let planner = Planner::new(Machine::a100());
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let progress = Progress::start("geometry", specs.len());
    let mut out = Vec::new();
    for (i, (family, spec)) in specs.iter().enumerate() {
        progress.cell(i, &format!("{family}/{}", spec.name));
        let coo = spec.generate();
        if coo.nnz() == 0 {
            continue;
        }
        let csr = Csr::from_coo(&coo);
        let (priced, price_s) = time_once(|| crate::reorder::price_catalog(&csr, None, TM, TK));
        let chosen = planner.choose_geometry(&priced);
        let slots = |geo: BrickGeometry| {
            priced.iter().find(|(g, _)| *g == geo).map(|(g, s)| s.brick_slots(*g)).unwrap_or(0)
        };

        let build = |geo: BrickGeometry| match cache {
            Some(c) => c.engine(&coo, &csr, geo, threads),
            None => HrpbEngine::from_hrpb(crate::hrpb::build_with_geometry_parallel(
                &csr, geo, TM, TK, threads,
            )),
        };
        let fixed = build(BrickGeometry::DEFAULT);
        let reference = Algo::Csr.prepare(&coo);
        let b = Dense::from_vec(coo.cols, n, vec![0.25; coo.cols * n]);
        let want = reference.spmm(&b);
        let mut reused = Dense::zeros(coo.rows, n);
        let mut max_rel_err = fixed.spmm(&b).rel_fro_error(&want);
        let fixed_s = measure(1, samples, || {
            fixed.spmm_into(&b, &mut reused);
        })
        .median_s;
        let picked_s = if chosen.is_default() {
            // the planner-picked cell lands on the shape already built:
            // with a cache, serve it from the artifact (a store hit — the
            // "same matrix+geometry builds once" contract) and verify the
            // round trip; either way charge no phantom win
            if cache.is_some() {
                let served = build(BrickGeometry::DEFAULT);
                max_rel_err = max_rel_err.max(served.spmm(&b).rel_fro_error(&want));
            }
            fixed_s
        } else {
            let picked = build(chosen);
            max_rel_err = max_rel_err.max(picked.spmm(&b).rel_fro_error(&want));
            measure(1, samples, || {
                picked.spmm_into(&b, &mut reused);
            })
            .median_s
        };
        out.push(GeometryOutcome {
            family: family.to_string(),
            matrix: spec.name.clone(),
            nnz: coo.nnz(),
            n,
            chosen,
            slots_default: slots(BrickGeometry::DEFAULT),
            slots_chosen: slots(chosen),
            price_s,
            fixed_s,
            picked_s,
            max_rel_err,
        });
    }
    out
}

/// Write the machine-readable perf-trajectory record the CI uploads.
fn write_geometry_json(
    outcomes: &[GeometryOutcome],
    geomean_unstructured: f64,
) -> std::path::PathBuf {
    use crate::util::json::Json;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let doc = Json::obj(vec![
        ("bench", Json::str("geometry")),
        ("pr", Json::num(8.0)),
        ("host_threads", Json::num(threads as f64)),
        // a run with no scattered/powerlaw cells has no headline; 0.0
        // keeps the JSON valid (NaN is not JSON)
        (
            "geomean_speedup_unstructured",
            Json::num(if geomean_unstructured.is_finite() { geomean_unstructured } else { 0.0 }),
        ),
        ("acceptance_floor_unstructured", Json::num(1.0)),
        (
            "cases",
            Json::arr(outcomes.iter().map(|o| {
                Json::obj(vec![
                    ("family", Json::str(o.family.clone())),
                    ("matrix", Json::str(o.matrix.clone())),
                    ("nnz", Json::num(o.nnz as f64)),
                    ("n", Json::num(o.n as f64)),
                    ("chosen", Json::str(o.chosen.name())),
                    ("activated", Json::Bool(o.activated())),
                    ("slots_default", Json::num(o.slots_default as f64)),
                    ("slots_chosen", Json::num(o.slots_chosen as f64)),
                    ("predicted_gain", Json::num(o.predicted_gain())),
                    ("price_s", Json::num(o.price_s)),
                    ("fixed_s", Json::num(o.fixed_s)),
                    ("picked_s", Json::num(o.picked_s)),
                    ("speedup", Json::num(o.speedup())),
                    ("max_rel_err", Json::num(o.max_rel_err)),
                ])
            })),
        ),
    ]);
    let path = results_dir().join("BENCH_PR8.json");
    write_json_or_warn(&path, &doc.to_string());
    path
}

/// Brick-geometry experiment — planner-picked catalog shape vs. fixed 16x4
/// across {scattered, powerlaw, blockdense}, emitting `BENCH_PR8.json`.
pub fn geometry(quick: bool) -> String {
    let outcomes = geometry_outcomes(quick);
    geometry_report(&outcomes)
}

/// Render the geometry experiment (split so tests measure once and reuse).
pub fn geometry_report(outcomes: &[GeometryOutcome]) -> String {
    let mut out = String::from(
        "== geometry: adaptive brick shape — planner-picked catalog geometry vs fixed 16x4 ==\n",
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut unstructured_speedups = Vec::new();
    for o in outcomes {
        if o.family == "scattered" || o.family == "powerlaw" {
            unstructured_speedups.push(o.speedup());
        }
        rows.push(vec![
            o.family.clone(),
            o.matrix.clone(),
            o.n.to_string(),
            o.chosen.name(),
            if o.activated() { "yes".into() } else { "no".into() },
            o.slots_default.to_string(),
            o.slots_chosen.to_string(),
            format!("{:.2}x", o.predicted_gain()),
            format!("{:.2}", o.price_s * 1e3),
            format!("{:.3}", o.fixed_s * 1e3),
            format!("{:.3}", o.picked_s * 1e3),
            format!("{:.2}x", o.speedup()),
            format!("{:.1e}", o.max_rel_err),
        ]);
        csv.push(vec![
            o.family.clone(),
            o.matrix.clone(),
            o.nnz.to_string(),
            o.n.to_string(),
            o.chosen.name(),
            o.activated().to_string(),
            o.slots_default.to_string(),
            o.slots_chosen.to_string(),
            format!("{:.4}", o.predicted_gain()),
            format!("{}", o.price_s),
            format!("{}", o.fixed_s),
            format!("{}", o.picked_s),
            format!("{:.4}", o.speedup()),
            format!("{:.2e}", o.max_rel_err),
        ]);
    }
    out.push_str(&render::table(
        &[
            "family",
            "matrix",
            "N",
            "chosen",
            "adaptive",
            "slots_16x4",
            "slots_chosen",
            "predicted",
            "price(ms)",
            "fixed(ms)",
            "picked(ms)",
            "speedup",
            "max_rel_err",
        ],
        &rows,
    ));
    let geomean_unstructured = if unstructured_speedups.is_empty() {
        f64::NAN
    } else {
        stats::geomean(&unstructured_speedups)
    };
    out.push_str(&format!(
        "\nplanner-picked geometry vs fixed 16x4 on the scattered/powerlaw (unstructured) \
         families: geomean {:.2}x (acceptance floor: 1.0x)\n",
        geomean_unstructured
    ));
    out.push_str(
        "expected shape: on the unstructured families most 16x4 brick slots are zero-fill, \
         so the pre-build pricer finds a smaller catalog shape (typically the transposed \
         8x1) with a large predicted slot reduction and the picked engine serves at least \
         as fast; the dense-block control prices (near-)identically at every shape and the \
         chooser stays at 16x4, charging no phantom win; both shapes stay within 1e-5 of \
         the CSR reference on every cell.\n",
    );
    write_csv_or_warn(
        &results_dir().join("geometry.csv"),
        &[
            "family",
            "matrix",
            "nnz",
            "n",
            "chosen",
            "activated",
            "slots_default",
            "slots_chosen",
            "predicted_gain",
            "price_s",
            "fixed_s",
            "picked_s",
            "speedup",
            "max_rel_err",
        ],
        &csv,
    );
    let json_path = write_geometry_json(outcomes, geomean_unstructured);
    out.push_str(&format!("machine-readable record -> {}\n", json_path.display()));
    out
}

/// One arrival in the QoS saturation trace.
struct SimReq {
    at_s: f64,
    cost_s: f64,
    priority: Priority,
    expensive: bool,
    deadline_s: Option<f64>,
}

/// Deterministic saturation trace: arrivals at a fixed interval sized for
/// ~1.3x offered load on one drain lane; 20% of requests hit an expensive
/// (low-synergy) matrix at 10x the cheap cost, 20% ride the high-priority
/// lane, and 30% carry a tight 2ms deadline (the rest get 20ms).
fn qos_trace(n: usize, seed: u64) -> Vec<SimReq> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let cheap = 50e-6;
    let dear = 500e-6;
    let mean = 0.8 * cheap + 0.2 * dear;
    let dt = mean / 1.3;
    (0..n)
        .map(|i| {
            let expensive = rng.chance(0.2);
            SimReq {
                at_s: i as f64 * dt,
                cost_s: if expensive { dear } else { cheap },
                priority: if rng.chance(0.2) { Priority::High } else { Priority::Normal },
                expensive,
                deadline_s: Some(if rng.chance(0.3) { 2e-3 } else { 20e-3 }),
            }
        })
        .collect()
}

/// An admission policy under test in the saturation study.
pub struct SimPolicy {
    pub name: &'static str,
    /// Hard queue bound (`usize::MAX` models the unbounded baseline).
    pub capacity: usize,
    /// Queued-work watermark; `0.0` disables cost-aware shedding.
    pub watermark_s: f64,
    /// `false` collapses everything onto the normal lane (FIFO baselines).
    pub use_priority: bool,
    /// Whether requests' deadlines participate in admission.
    pub use_deadline: bool,
}

/// One policy's outcome over the shared arrival trace.
#[derive(Clone, Debug)]
pub struct QosOutcome {
    pub policy: &'static str,
    pub capacity: usize,
    pub offered: usize,
    pub completed: usize,
    /// Sheds per lane ([`Priority::index`]).
    pub shed_lane: [u64; Priority::COUNT],
    /// Sheds per reason ([`RejectReason::index`]).
    pub shed_by_reason: [u64; RejectReason::COUNT],
    /// Deepest the queue ever got.
    pub max_depth: usize,
    pub p50_wait_ms: f64,
    pub p99_wait_ms: f64,
    pub high_p99_wait_ms: f64,
}

fn drain_until(
    queue: &mut BoundedDualQueue<(f64, Priority)>,
    server_free_at: &mut f64,
    until: f64,
    waits: &mut Vec<f64>,
    high_waits: &mut Vec<f64>,
) {
    while queue.depth() > 0 && *server_free_at <= until {
        let Some((ticket, (enq_s, priority))) = queue.pop() else { break };
        let start = (*server_free_at).max(enq_s);
        let wait = start - enq_s;
        waits.push(wait);
        if priority == Priority::High {
            high_waits.push(wait);
        }
        *server_free_at = start + ticket.cost_s;
    }
}

/// Replay the trace against one admission policy: a single server drains
/// the queue in priority order; admission runs the real
/// [`crate::qos::admit`] rule over the live queue state.
fn simulate_qos(policy: &SimPolicy, trace: &[SimReq]) -> QosOutcome {
    let shed_policy = ShedPolicy { capacity: policy.capacity, watermark_s: policy.watermark_s };
    let mut queue: BoundedDualQueue<(f64, Priority)> =
        BoundedDualQueue::new(policy.capacity);
    let mut server_free_at = 0.0f64;
    let mut waits: Vec<f64> = Vec::new();
    let mut high_waits: Vec<f64> = Vec::new();
    let mut shed_by_reason = [0u64; RejectReason::COUNT];
    let mut shed_lane = [0u64; Priority::COUNT];
    let mut max_depth = 0usize;

    for r in trace {
        drain_until(&mut queue, &mut server_free_at, r.at_s, &mut waits, &mut high_waits);
        let priority = if policy.use_priority { r.priority } else { Priority::Normal };
        let mut ticket = Ticket::new(priority, r.cost_s);
        ticket.expensive = r.expensive;
        if policy.use_deadline {
            ticket.deadline = r.deadline_s.map(Duration::from_secs_f64);
        }
        // mirror AdmissionQueue::submit exactly: the wait estimate counts
        // the lane the request actually waits behind (plus work already past
        // the queue), while the watermark sees the whole pipeline
        let backlog_s = (server_free_at - r.at_s).max(0.0);
        let lane_ahead_s = match priority {
            Priority::High => queue.lane_cost_s(Priority::High),
            Priority::Normal => queue.queued_cost_s(),
        };
        let est_wait = qos::estimate_wait(lane_ahead_s + backlog_s, 1);
        let outstanding_s = queue.queued_cost_s() + backlog_s;
        match qos::admit(&shed_policy, queue.depth(), outstanding_s, &ticket, est_wait) {
            Ok(()) => {
                queue.push(ticket, (r.at_s, priority)).expect("admit() bounds the queue");
                max_depth = max_depth.max(queue.depth());
            }
            Err(reason) => {
                shed_by_reason[reason.index()] += 1;
                shed_lane[priority.index()] += 1;
            }
        }
    }
    drain_until(&mut queue, &mut server_free_at, f64::INFINITY, &mut waits, &mut high_waits);

    waits.sort_by(|a, b| a.total_cmp(b));
    high_waits.sort_by(|a, b| a.total_cmp(b));
    let pct = |v: &[f64], p: f64| {
        if v.is_empty() { 0.0 } else { stats::percentile_sorted(v, p) * 1e3 }
    };
    QosOutcome {
        policy: policy.name,
        capacity: policy.capacity,
        offered: trace.len(),
        completed: waits.len(),
        shed_lane,
        shed_by_reason,
        max_depth,
        p50_wait_ms: pct(&waits, 50.0),
        p99_wait_ms: pct(&waits, 99.0),
        high_p99_wait_ms: pct(&high_waits, 99.0),
    }
}

/// The three policies the saturation study compares, over one shared trace:
/// unbounded FIFO, bounded reject-on-full, and the full QoS layer.
pub fn qos_saturation_outcomes() -> Vec<QosOutcome> {
    let trace = qos_trace(4000, 4242);
    let capacity = 64;
    let watermark_s = 2e-3;
    [
        SimPolicy {
            name: "unbounded",
            capacity: usize::MAX,
            watermark_s: 0.0,
            use_priority: false,
            use_deadline: false,
        },
        SimPolicy {
            name: "reject-on-full",
            capacity,
            watermark_s: 0.0,
            use_priority: false,
            use_deadline: false,
        },
        SimPolicy {
            name: "qos",
            capacity,
            watermark_s,
            use_priority: true,
            use_deadline: true,
        },
    ]
    .iter()
    .map(|p| simulate_qos(p, &trace))
    .collect()
}

/// QoS saturation experiment — offered load ~1.3x drain capacity, replayed
/// deterministically against the three admission policies.
pub fn qos_saturation() -> String {
    let outcomes = qos_saturation_outcomes();
    qos_report(&outcomes)
}

/// Render the QoS saturation report (split from [`qos_saturation`] so the
/// harness and tests can run the simulation once and reuse the outcomes).
pub fn qos_report(outcomes: &[QosOutcome]) -> String {
    let mut out = String::from(
        "== QoS saturation: bounded priority admission vs baselines (1.3x offered load) ==\n",
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for o in outcomes {
        let cap = if o.capacity == usize::MAX {
            "inf".to_string()
        } else {
            o.capacity.to_string()
        };
        let sheds: u64 = o.shed_by_reason.iter().sum();
        rows.push(vec![
            o.policy.to_string(),
            cap.clone(),
            format!("{}/{}", o.completed, o.offered),
            format!("{}", sheds),
            format!("{}h/{}n", o.shed_lane[Priority::High.index()], o.shed_lane[Priority::Normal.index()]),
            format!(
                "{}/{}/{}",
                o.shed_by_reason[RejectReason::QueueFull.index()],
                o.shed_by_reason[RejectReason::Overload.index()],
                o.shed_by_reason[RejectReason::DeadlineUnmeetable.index()],
            ),
            o.max_depth.to_string(),
            format!("{:.2}", o.p50_wait_ms),
            format!("{:.2}", o.p99_wait_ms),
            format!("{:.2}", o.high_p99_wait_ms),
        ]);
        csv.push(vec![
            o.policy.to_string(),
            cap,
            o.offered.to_string(),
            o.completed.to_string(),
            o.shed_lane[Priority::High.index()].to_string(),
            o.shed_lane[Priority::Normal.index()].to_string(),
            o.shed_by_reason[RejectReason::QueueFull.index()].to_string(),
            o.shed_by_reason[RejectReason::Overload.index()].to_string(),
            o.shed_by_reason[RejectReason::DeadlineUnmeetable.index()].to_string(),
            o.max_depth.to_string(),
            format!("{:.4}", o.p50_wait_ms),
            format!("{:.4}", o.p99_wait_ms),
            format!("{:.4}", o.high_p99_wait_ms),
        ]);
    }
    out.push_str(&render::table(
        &[
            "policy",
            "cap",
            "completed",
            "shed",
            "shed(lane)",
            "shed(full/over/ddl)",
            "max_depth",
            "p50_wait(ms)",
            "p99_wait(ms)",
            "high_p99(ms)",
        ],
        &rows,
    ));
    out.push_str(
        "\nexpected shape: unbounded queue depth grows without bound and tail wait explodes; \
         reject-on-full caps depth but sheds blindly; qos holds depth at/below its bound, \
         sheds cost-aware (normal-lane, low-synergy first) with typed rejections, and keeps \
         p99 queue wait lowest — high lane lowest of all.\n",
    );
    write_csv_or_warn(
        &results_dir().join("qos_saturation.csv"),
        &[
            "policy",
            "capacity",
            "offered",
            "completed",
            "shed_high",
            "shed_normal",
            "shed_full",
            "shed_overload",
            "shed_deadline",
            "max_depth",
            "p50_wait_ms",
            "p99_wait_ms",
            "high_p99_wait_ms",
        ],
        &csv,
    );
    out
}

/// One tracing mode's outcome over the shared serving workload: closed-loop
/// QoS submission waves against a live coordinator, with tracing off,
/// sampled, or full.
#[derive(Clone, Debug)]
pub struct TraceOutcome {
    pub mode: &'static str,
    pub requests: usize,
    pub served: usize,
    pub shed: usize,
    pub wall_s: f64,
    pub req_per_s: f64,
    /// Spans drained after the run (0 for the disabled modes).
    pub spans: usize,
    /// Spans lost to ring overflow.
    pub dropped: u64,
    /// Summed `exec` span duration — reconciled against `observed_us`.
    pub exec_span_us: u64,
    /// Engine-lane observed time over the same run (the metrics side of
    /// the reconciliation).
    pub observed_us: u64,
}

/// Run the trace-overhead experiment measurements. `quick` shrinks the
/// matrix and request count (CI smoke).
pub fn trace_outcomes(quick: bool) -> Vec<TraceOutcome> {
    if quick {
        trace_outcomes_for(600, 192)
    } else {
        trace_outcomes_for(3000, 768)
    }
}

/// Measurement core: the same QoS serving workload under four trace modes —
/// `baseline` and `off` are both untraced (their delta is run-to-run
/// noise; `off` vs `baseline` is the disabled-gate cost the ≤ 2%
/// acceptance budget bounds), `sampled` records 10% of request trees, and
/// `full` records everything including kernel spans and writes the
/// Perfetto-loadable sample export.
pub fn trace_outcomes_for(rows: usize, requests: usize) -> Vec<TraceOutcome> {
    use crate::coordinator::{Config, Coordinator};
    use crate::trace::{self, TraceConfig};
    use crate::util::rng::Rng;
    use std::time::Instant;

    // tracing state is process-global: one session at a time
    let _session = trace::session_guard();

    let spec = MatrixSpec {
        name: "trace-banded".into(),
        rows,
        family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
        seed: 0x72ACE,
    };
    let coo = spec.generate();
    let off = TraceConfig::default();
    let modes: [(&'static str, TraceConfig); 4] = [
        ("baseline", off),
        ("off", off),
        (
            "sampled",
            TraceConfig { enabled: true, sample_rate: 0.1, kernel: false, ring_capacity: 1 << 16 },
        ),
        (
            "full",
            TraceConfig { enabled: true, sample_rate: 1.0, kernel: true, ring_capacity: 1 << 16 },
        ),
    ];

    let mut out = Vec::new();
    for (mode, tcfg) in modes {
        // leftover spans from a previous mode must not leak into this one
        trace::disable();
        let _ = trace::drain();
        let coord = Coordinator::start(
            Config {
                workers: 2,
                qos: Some(qos::QosConfig {
                    queue_capacity: 512,
                    watermark_s: 0.0,
                    default_deadline: None,
                }),
                trace: tcfg,
                ..Default::default()
            },
            None,
        );
        let id = coord.register(&spec.name, &coo);
        let mut rng = Rng::new(0x72ACE2);
        let b = Dense::random(coo.cols, 16, &mut rng);
        let mut served = 0usize;
        let mut shed = 0usize;
        let mut sent = 0usize;
        let t_wall = Instant::now();
        while sent < requests {
            let wave = 64.min(requests - sent);
            let mut pending = Vec::with_capacity(wave);
            for i in 0..wave {
                let priority =
                    if (sent + i) % 4 == 0 { Priority::High } else { Priority::Normal };
                match coord.submit_qos(id, b.clone(), priority, None) {
                    Ok(rx) => pending.push(rx),
                    Err(_) => shed += 1,
                }
            }
            sent += wave;
            for rx in pending {
                if matches!(rx.recv(), Ok(Ok(_))) {
                    served += 1;
                }
            }
        }
        let wall_s = t_wall.elapsed().as_secs_f64();
        let observed_us: u64 =
            coord.metrics().engine_snapshot().iter().map(|l| l.observed_us).sum();
        coord.shutdown();
        let tr = trace::drain();
        trace::disable();
        if mode == "full" {
            let sample = results_dir().join("sample.trace.json");
            if let Err(e) = tr.write_chrome(&sample) {
                eprintln!("warning: cannot write {}: {e}", sample.display());
            }
        }
        out.push(TraceOutcome {
            mode,
            requests,
            served,
            shed,
            wall_s,
            req_per_s: served as f64 / wall_s.max(1e-9),
            spans: tr.spans.len(),
            dropped: tr.dropped,
            exec_span_us: tr.sum_dur_us("exec"),
            observed_us,
        });
    }
    out
}

/// Write the machine-readable overhead record the CI uploads.
fn write_trace_json(
    outcomes: &[TraceOutcome],
    overhead: &[(&'static str, f64)],
    reconcile_pct: f64,
) -> std::path::PathBuf {
    use crate::util::json::Json;
    let mut doc = vec![("bench", Json::str("trace_overhead")), ("pr", Json::num(6.0))];
    for (mode, pct) in overhead {
        let key: &'static str = match *mode {
            "off" => "overhead_off_pct",
            "sampled" => "overhead_sampled_pct",
            "full" => "overhead_full_pct",
            _ => continue,
        };
        doc.push((key, Json::num(*pct)));
    }
    doc.push(("exec_reconcile_pct", Json::num(reconcile_pct)));
    doc.push(("acceptance_overhead_off_pct", Json::num(2.0)));
    doc.push(("acceptance_reconcile_pct", Json::num(5.0)));
    doc.push((
        "cases",
        Json::arr(outcomes.iter().map(|o| {
            Json::obj(vec![
                ("mode", Json::str(o.mode)),
                ("requests", Json::num(o.requests as f64)),
                ("served", Json::num(o.served as f64)),
                ("shed", Json::num(o.shed as f64)),
                ("wall_s", Json::num(o.wall_s)),
                ("req_per_s", Json::num(o.req_per_s)),
                ("spans", Json::num(o.spans as f64)),
                ("dropped", Json::num(o.dropped as f64)),
                ("exec_span_us", Json::num(o.exec_span_us as f64)),
                ("observed_us", Json::num(o.observed_us as f64)),
            ])
        })),
    ));
    let path = results_dir().join("BENCH_PR6.json");
    write_json_or_warn(&path, &Json::obj(doc).to_string());
    path
}

/// Trace-overhead experiment — the QoS serving workload with tracing off /
/// sampled / full, emitting `BENCH_PR6.json` and a Perfetto-loadable
/// `sample.trace.json`.
pub fn trace_overhead(quick: bool) -> String {
    let outcomes = trace_outcomes(quick);
    trace_report(&outcomes)
}

/// Render the trace experiment (split so tests measure once and reuse).
pub fn trace_report(outcomes: &[TraceOutcome]) -> String {
    let mut out = String::from(
        "== trace: observability overhead — off / sampled / full vs untraced baseline ==\n",
    );
    let baseline_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.req_per_s)
        .unwrap_or(f64::NAN);
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut overhead: Vec<(&'static str, f64)> = Vec::new();
    let mut reconcile_pct = 0.0;
    for o in outcomes {
        let oh_pct = 100.0 * (baseline_rps - o.req_per_s) / baseline_rps.max(1e-9);
        if o.mode != "baseline" {
            overhead.push((o.mode, oh_pct));
        }
        if o.mode == "full" && o.observed_us > 0 {
            reconcile_pct = 100.0 * (o.exec_span_us as f64 - o.observed_us as f64).abs()
                / o.observed_us as f64;
        }
        rows.push(vec![
            o.mode.to_string(),
            format!("{}/{}", o.served, o.requests),
            o.shed.to_string(),
            format!("{:.1}", o.wall_s * 1e3),
            format!("{:.0}", o.req_per_s),
            if o.mode == "baseline" { "-".into() } else { format!("{oh_pct:+.1}%") },
            o.spans.to_string(),
            o.dropped.to_string(),
        ]);
        csv.push(vec![
            o.mode.to_string(),
            o.requests.to_string(),
            o.served.to_string(),
            o.shed.to_string(),
            format!("{}", o.wall_s),
            format!("{:.2}", o.req_per_s),
            o.spans.to_string(),
            o.dropped.to_string(),
            o.exec_span_us.to_string(),
            o.observed_us.to_string(),
        ]);
    }
    out.push_str(&render::table(
        &["mode", "served", "shed", "wall(ms)", "req/s", "overhead", "spans", "dropped"],
        &rows,
    ));
    if let Some((_, off_pct)) = overhead.iter().find(|(m, _)| *m == "off") {
        out.push_str(&format!(
            "\ndisabled-tracing overhead: {off_pct:+.1}% (acceptance budget: 2.0%; \
             `off` differs from `baseline` only by run-to-run noise — both run the \
             same one-relaxed-load gates)\n"
        ));
    }
    out.push_str(&format!(
        "exec-span reconciliation (full mode): summed exec spans vs engine-lane \
         observed_us differ by {reconcile_pct:.1}% (acceptance: 5%; equal by \
         construction — both read the same batch timestamps)\n",
    ));
    out.push_str(
        "methodology: same closed-loop QoS workload per mode (fresh coordinator, same \
         matrix, 64-deep submission waves); overhead is the req/s delta vs the untraced \
         baseline run, so it includes sampling hashes, span recording, and ring resets \
         — everything a production deployment would pay.\n",
    );
    write_csv_or_warn(
        &results_dir().join("trace.csv"),
        &[
            "mode",
            "requests",
            "served",
            "shed",
            "wall_s",
            "req_per_s",
            "spans",
            "dropped",
            "exec_span_us",
            "observed_us",
        ],
        &csv,
    );
    let json_path = write_trace_json(outcomes, &overhead, reconcile_pct);
    out.push_str(&format!("machine-readable record -> {}\n", json_path.display()));
    out.push_str(&format!(
        "perfetto sample -> {} (open at https://ui.perfetto.dev)\n",
        results_dir().join("sample.trace.json").display()
    ));
    out
}

// ---------------------------------------------------------------- chaos

/// One chaos mode's measured outcome: full typed-error accounting for the
/// fault wave, then recovery throughput after the fault clears.
pub struct ChaosOutcome {
    pub mode: &'static str,
    /// Fault-wave submissions (alternating victim / clean matrix).
    pub requests: usize,
    pub served: usize,
    pub victim_served: usize,
    pub clean_served: usize,
    /// Typed `engine_fault` replies observed during the fault wave.
    pub engine_faults: usize,
    /// Typed `quarantined` rejections observed during the fault wave.
    pub quarantined: usize,
    pub shed: usize,
    /// Submissions that never produced a reply — must be 0 (the
    /// no-lost-response invariant).
    pub lost: usize,
    /// Non-shed errors on the *clean* matrix — must be 0 (isolation).
    pub clean_errors: usize,
    pub wall_s: f64,
    /// Clean-matrix closed-loop throughput after `fault::disable()`.
    pub recovered_rps: f64,
    /// Victim breaker state at the end of the run ("closed" when absent
    /// from the metrics mirror).
    pub breaker_state: &'static str,
    pub fallback_requests: u64,
    pub breaker_opens: u64,
    /// Faults the injection facility actually fired this mode.
    pub injected: u64,
    pub artifact_hits: u64,
    pub artifact_invalidated: u64,
}

/// Run the chaos experiment measurements. `quick` shrinks the matrix and
/// request count (CI smoke).
pub fn chaos_outcomes(quick: bool) -> Vec<ChaosOutcome> {
    if quick {
        chaos_outcomes_for(256, 160)
    } else {
        chaos_outcomes_for(768, 384)
    }
}

/// Measurement core: the same closed-loop QoS workload — two same-shape
/// matrices, one fault-targeted "victim" and one "clean" bystander — under
/// each injected fault mode. Every mode starts a fresh coordinator against
/// a shared artifact directory (the baseline mode populates it, later
/// modes warm-start — which gives the artifact fault modes a real load
/// path to inject into), arms one deterministic
/// [`crate::fault::FaultPlan`], serves a fault wave with full typed-error
/// accounting, clears the fault, lets the victim breaker re-close, and
/// measures clean-matrix recovery throughput.
pub fn chaos_outcomes_for(rows: usize, requests: usize) -> Vec<ChaosOutcome> {
    use crate::coordinator::{breaker, BatchPolicy, Config, Coordinator};
    use crate::fault;
    use crate::util::rng::Rng;
    use std::time::Instant;

    // fault-injection state is process-global: one chaos session at a time
    let _session = fault::session_guard();

    let victim_spec = MatrixSpec {
        name: "victim".into(),
        rows,
        family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
        seed: 0xC4A05,
    };
    let clean_spec = MatrixSpec {
        name: "clean".into(),
        rows,
        family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.01 },
        seed: 0xC4A06,
    };
    let victim_coo = victim_spec.generate();
    let clean_coo = clean_spec.generate();
    let art_dir = std::env::temp_dir().join(format!("cutespmm_chaos_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&art_dir);

    let modes: [(&'static str, Option<&'static str>); 6] = [
        ("baseline", None),
        // the primary engine panics on the victim only: the breaker opens,
        // the CSR fallback takes over, the clean matrix never notices
        ("kernel_panic", Some("kernel_panic@cutespmm@victim:rate=1")),
        // every engine panics on the victim (the target matches the
        // fallback key "csr@victim" too): the matrix is quarantined
        ("fallback_panic", Some("kernel_panic@victim:rate=1")),
        // one transient artifact read error: the store's retry warm-starts
        ("artifact_io", Some("artifact_io@hrpb-:nth=1")),
        // one corrupted artifact read: invalidate + rebuild, not a crash
        ("checksum_flip", Some("checksum_flip@hrpb-:nth=1")),
        // stalled kernels on the victim: slow, but every reply arrives
        ("slow_exec", Some("slow_exec@cutespmm@victim:rate=0.5")),
    ];

    let mut out = Vec::new();
    for (mode, plan_spec) in modes {
        fault::disable();
        let coord = Coordinator::start(
            Config {
                workers: 2,
                // small batches so a fault storm spans several batches and
                // the breaker's consecutive-fault count is exercised
                batch: BatchPolicy {
                    max_batch_cols: 128,
                    max_batch_reqs: 4,
                    max_delay: Duration::from_millis(1),
                },
                qos: Some(qos::QosConfig {
                    queue_capacity: 512,
                    watermark_s: 0.0,
                    default_deadline: None,
                }),
                artifact_dir: Some(art_dir.clone()),
                ..Default::default()
            },
            None,
        );
        if let Some(spec) = plan_spec {
            let plan = fault::FaultPlan::parse(spec, 0xC4A0).expect("chaos plans parse");
            fault::install(&plan);
        }
        let victim = coord.register(&victim_spec.name, &victim_coo);
        let clean = coord.register(&clean_spec.name, &clean_coo);
        let mut rng = Rng::new(0xC4A07);
        let b = Dense::random(victim_coo.cols, 16, &mut rng);

        // --- fault wave: every submission must land in exactly one bucket
        let (mut served, mut victim_served, mut clean_served) = (0usize, 0usize, 0usize);
        let (mut engine_faults, mut quarantined, mut shed) = (0usize, 0usize, 0usize);
        let (mut lost, mut clean_errors) = (0usize, 0usize);
        let t_wall = Instant::now();
        let mut sent = 0usize;
        while sent < requests {
            let wave = 64.min(requests - sent);
            let mut pending = Vec::with_capacity(wave);
            for i in 0..wave {
                let n = sent + i;
                let to_victim = n % 2 == 0;
                let id = if to_victim { victim } else { clean };
                let priority = if n % 4 == 0 { Priority::High } else { Priority::Normal };
                match coord.submit_qos(id, b.clone(), priority, None) {
                    Ok(rx) => pending.push((to_victim, rx)),
                    Err((e, _)) => match e.kind() {
                        "shed" => shed += 1,
                        "quarantined" => quarantined += 1,
                        _ if to_victim => {}
                        _ => clean_errors += 1,
                    },
                }
            }
            sent += wave;
            for (to_victim, rx) in pending {
                match rx.recv() {
                    Err(_) => lost += 1,
                    Ok(Ok(_)) => {
                        served += 1;
                        if to_victim {
                            victim_served += 1;
                        } else {
                            clean_served += 1;
                        }
                    }
                    Ok(Err(e)) => {
                        match e.kind() {
                            "engine_fault" => engine_faults += 1,
                            "quarantined" => quarantined += 1,
                            "shed" => shed += 1,
                            _ => {}
                        }
                        if !to_victim && e.kind() != "shed" {
                            clean_errors += 1;
                        }
                    }
                }
            }
        }
        let wall_s = t_wall.elapsed().as_secs_f64();

        // --- fault cleared: give the victim breaker a probe window so an
        // opened breaker can re-close (quarantine stays terminal)
        let injected = fault::fired_total();
        fault::disable();
        for _ in 0..2 * breaker::PROBE_INTERVAL + 4 {
            if let Ok(rx) = coord.submit_qos(victim, b.clone(), Priority::Normal, None) {
                let _ = rx.recv();
            }
        }

        // --- recovery: clean-matrix closed loop, same shape in every mode
        // so recovered_rps is comparable against the baseline mode's
        let recovery = (requests / 2).max(32);
        let t_rec = Instant::now();
        let mut recovered = 0usize;
        let mut rec_sent = 0usize;
        while rec_sent < recovery {
            let wave = 64.min(recovery - rec_sent);
            let mut pending = Vec::with_capacity(wave);
            for _ in 0..wave {
                if let Ok(rx) = coord.submit_qos(clean, b.clone(), Priority::Normal, None) {
                    pending.push(rx);
                }
            }
            rec_sent += wave;
            for rx in pending {
                if matches!(rx.recv(), Ok(Ok(_))) {
                    recovered += 1;
                }
            }
        }
        let recovered_rps = recovered as f64 / t_rec.elapsed().as_secs_f64().max(1e-9);

        let snap = coord.metrics().snapshot();
        let breaker_state = snap
            .breakers
            .iter()
            .find(|e| e.matrix == "victim")
            .map(|e| e.state)
            .unwrap_or("closed");
        coord.shutdown();
        out.push(ChaosOutcome {
            mode,
            requests: sent,
            served,
            victim_served,
            clean_served,
            engine_faults,
            quarantined,
            shed,
            lost,
            clean_errors,
            wall_s,
            recovered_rps,
            breaker_state,
            fallback_requests: snap.faults.fallback_requests,
            breaker_opens: snap.faults.opens,
            injected,
            artifact_hits: snap.artifact_hits,
            artifact_invalidated: snap.artifact_invalidated,
        });
    }
    fault::disable();
    let _ = std::fs::remove_dir_all(&art_dir);
    out
}

/// Write the machine-readable chaos record the CI uploads and gates on.
fn write_chaos_json(outcomes: &[ChaosOutcome], recovery_gap_pct: f64) -> PathBuf {
    use crate::util::json::Json;
    let lost: usize = outcomes.iter().map(|o| o.lost).sum();
    let isolation: usize = outcomes.iter().map(|o| o.clean_errors).sum();
    let doc = vec![
        ("bench", Json::str("chaos")),
        ("pr", Json::num(9.0)),
        ("recovery_gap_pct", Json::num(recovery_gap_pct)),
        ("acceptance_recovery_gap_pct", Json::num(10.0)),
        ("lost_responses", Json::num(lost as f64)),
        ("isolation_violations", Json::num(isolation as f64)),
        (
            "cases",
            Json::arr(outcomes.iter().map(|o| {
                Json::obj(vec![
                    ("mode", Json::str(o.mode)),
                    ("requests", Json::num(o.requests as f64)),
                    ("served", Json::num(o.served as f64)),
                    ("victim_served", Json::num(o.victim_served as f64)),
                    ("clean_served", Json::num(o.clean_served as f64)),
                    ("engine_faults", Json::num(o.engine_faults as f64)),
                    ("quarantined", Json::num(o.quarantined as f64)),
                    ("shed", Json::num(o.shed as f64)),
                    ("lost", Json::num(o.lost as f64)),
                    ("clean_errors", Json::num(o.clean_errors as f64)),
                    ("wall_s", Json::num(o.wall_s)),
                    ("recovered_rps", Json::num(o.recovered_rps)),
                    ("breaker_state", Json::str(o.breaker_state)),
                    ("fallback_requests", Json::num(o.fallback_requests as f64)),
                    ("breaker_opens", Json::num(o.breaker_opens as f64)),
                    ("injected", Json::num(o.injected as f64)),
                    ("artifact_hits", Json::num(o.artifact_hits as f64)),
                    ("artifact_invalidated", Json::num(o.artifact_invalidated as f64)),
                ])
            })),
        ),
    ];
    let path = results_dir().join("BENCH_PR9.json");
    write_json_or_warn(&path, &Json::obj(doc).to_string());
    path
}

/// Chaos experiment — deterministic fault injection against the serving
/// stack (panic containment, breakers, quarantine, artifact retry),
/// emitting `BENCH_PR9.json`.
pub fn chaos(quick: bool) -> String {
    let outcomes = chaos_outcomes(quick);
    chaos_report(&outcomes)
}

/// Render the chaos experiment (split so tests measure once and reuse).
pub fn chaos_report(outcomes: &[ChaosOutcome]) -> String {
    let mut out = String::from(
        "== chaos: fault injection — containment, breakers, quarantine, recovery ==\n",
    );
    let baseline_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.recovered_rps)
        .unwrap_or(f64::NAN);
    let mut recovery_gap_pct = f64::NAN;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for o in outcomes {
        let gap_pct = 100.0 * (baseline_rps - o.recovered_rps) / baseline_rps.max(1e-9);
        if o.mode == "kernel_panic" {
            recovery_gap_pct = gap_pct;
        }
        rows.push(vec![
            o.mode.to_string(),
            format!("{}/{}", o.served, o.requests),
            o.engine_faults.to_string(),
            o.quarantined.to_string(),
            o.shed.to_string(),
            o.lost.to_string(),
            o.breaker_state.to_string(),
            format!("{:.0}", o.recovered_rps),
            if o.mode == "baseline" { "-".into() } else { format!("{gap_pct:+.1}%") },
            o.injected.to_string(),
        ]);
        csv.push(vec![
            o.mode.to_string(),
            o.requests.to_string(),
            o.served.to_string(),
            o.victim_served.to_string(),
            o.clean_served.to_string(),
            o.engine_faults.to_string(),
            o.quarantined.to_string(),
            o.shed.to_string(),
            o.lost.to_string(),
            o.clean_errors.to_string(),
            format!("{}", o.wall_s),
            format!("{:.2}", o.recovered_rps),
            o.breaker_state.to_string(),
            o.fallback_requests.to_string(),
            o.breaker_opens.to_string(),
            o.injected.to_string(),
        ]);
    }
    out.push_str(&render::table(
        &[
            "mode",
            "served",
            "faults",
            "quar",
            "shed",
            "lost",
            "breaker",
            "recov req/s",
            "gap",
            "injected",
        ],
        &rows,
    ));
    let lost: usize = outcomes.iter().map(|o| o.lost).sum();
    let isolation: usize = outcomes.iter().map(|o| o.clean_errors).sum();
    out.push_str(&format!(
        "\nno-lost-response invariant: {lost} submissions without a typed reply \
         (must be 0 — every request ends in exactly one Ok / typed error)\n"
    ));
    out.push_str(&format!(
        "isolation invariant: {isolation} non-shed errors on the clean matrix across \
         all fault modes (must be 0 — faults stay pinned to the injected matrix)\n"
    ));
    out.push_str(&format!(
        "post-fault recovery: kernel_panic clean-matrix throughput within \
         {recovery_gap_pct:+.1}% of baseline after the fault cleared (acceptance: 10%; \
         measured in release `experiment chaos` — debug runs assert the invariants \
         above, not timing)\n"
    ));
    out.push_str(
        "methodology: per mode, a fresh coordinator serves a closed-loop QoS workload \
         alternating between a fault-targeted victim matrix and a clean bystander; one \
         seeded FaultPlan is armed for the fault wave, cleared, a probe window lets the \
         breaker re-close, and recovery req/s is measured on the clean matrix.\n",
    );
    write_csv_or_warn(
        &results_dir().join("chaos.csv"),
        &[
            "mode",
            "requests",
            "served",
            "victim_served",
            "clean_served",
            "engine_faults",
            "quarantined",
            "shed",
            "lost",
            "clean_errors",
            "wall_s",
            "recovered_rps",
            "breaker_state",
            "fallback_requests",
            "breaker_opens",
            "injected",
        ],
        &csv,
    );
    let json_path = write_chaos_json(outcomes, recovery_gap_pct);
    out.push_str(&format!("machine-readable record -> {}\n", json_path.display()));
    out
}

// ----------------------------------------------------------------- load

/// One load mode's measured outcome: closed-loop client accounting over
/// the shard router (every submission must resolve exactly once at the
/// caller), tail latency, queue-depth boundedness, and live-shard
/// recovery throughput.
pub struct LoadOutcome {
    pub mode: &'static str,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Main-wave submissions (excludes warm-up and recovery calls).
    pub requests: u64,
    /// Submissions resolved with a served result.
    pub acked: u64,
    /// Submissions resolved with a typed error (includes shed).
    pub errors: u64,
    pub shed: u64,
    /// Submissions that never resolved at the caller — must be 0.
    pub lost: u64,
    /// Caller-visible resolutions beyond one per submission — must be 0.
    pub duplicates: u64,
    /// Router redispatches after transport-shaped completions.
    pub failovers: u64,
    /// Router redispatches by the request-timeout reaper.
    pub retries: u64,
    /// Late completions the router suppressed (would-be duplicates).
    pub suppressed: u64,
    /// Served requests per wall second during the main wave.
    pub sustained_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Deepest per-shard QoS queue a 0.5 ms sampler ever observed.
    pub max_queue_depth: usize,
    /// The per-shard QoS admission bound the sampler is checked against.
    pub queue_capacity: usize,
    /// Closed-loop throughput on matrices placed off shard 0 after the
    /// wave (and after the kill, in `shard_kill` mode).
    pub recovered_rps: f64,
    /// Faults the injection facility fired this mode.
    pub injected: u64,
    pub wall_s: f64,
}

/// Run the load experiment measurements. `quick` shrinks the client count
/// (CI smoke); the full run drives thousands of concurrent clients.
pub fn load_outcomes(quick: bool) -> Vec<LoadOutcome> {
    if quick {
        load_outcomes_for(256, 2)
    } else {
        load_outcomes_for(2048, 3)
    }
}

/// Measurement core: `clients` concurrent closed-loop clients (each
/// submits, waits for its resolution, submits again — `per_client` times)
/// against a fresh 3-shard, 2-replica [`crate::shard::ShardRouter`] per
/// mode. Modes: `baseline`; `saturation` (admission capacity squeezed to
/// 64 so the wave sheds — proves the queue is bounded, not that it grows);
/// `shard_kill` (shard 0 killed abruptly mid-wave — unacked requests fail
/// over under their original ids); `net_stall` / `net_drop` (seeded
/// [`crate::fault::FaultPlan`]s on shard 0's response writer).
pub fn load_outcomes_for(clients: usize, per_client: usize) -> Vec<LoadOutcome> {
    use crate::coordinator::BatchPolicy;
    use crate::fault;
    use crate::formats::Coo;
    use crate::shard::{ShardConfig, ShardRouter};
    use crate::util::rng::Rng;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::mpsc::{channel, Sender};
    use std::sync::Arc;
    use std::time::Instant;

    // fault-injection state is process-global: one session at a time
    let _session = fault::session_guard();

    let modes: [(&'static str, Option<&'static str>); 5] = [
        ("baseline", None),
        ("saturation", None),
        ("shard_kill", None),
        // shard 0's response writer stalls 30% of frames: slow, not lost
        ("net_stall", Some("net_stall@shard-0:rate=0.3")),
        // shard 0 drops 5% of response frames: the request-timeout reaper
        // redispatches the same id to a replica — zero lost, zero dup
        ("net_drop", Some("net_drop@shard-0:rate=0.05")),
    ];

    // one closed-loop client submission; the callback reports (client,
    // latency, verdict) into the shared completion channel
    fn submit_load(
        router: &Arc<ShardRouter>,
        names: &[String],
        b: &Dense,
        client: usize,
        seq: usize,
        tx: &Sender<(usize, f64, Option<&'static str>)>,
    ) {
        let name = &names[(client + seq) % names.len()];
        let tx = tx.clone();
        let start = Instant::now();
        let priority = if client % 8 == 0 { Priority::High } else { Priority::Normal };
        router.submit(name, b.clone(), priority, 0, move |r| {
            let lat_ms = start.elapsed().as_secs_f64() * 1e3;
            let verdict = match r {
                Ok(_) => None,
                Err(e) => Some(e.kind()),
            };
            let _ = tx.send((client, lat_ms, verdict));
        });
    }

    let mut out = Vec::new();
    for (mode, plan_spec) in modes {
        fault::disable();
        let queue_capacity = if mode == "saturation" { 64 } else { 1024 };
        let router = Arc::new(
            ShardRouter::start(ShardConfig {
                shards: 3,
                replicas: 2,
                workers_per_shard: 2,
                queue_capacity,
                watermark_s: 0.0,
                window: 256,
                batch: BatchPolicy {
                    max_batch_cols: 128,
                    max_batch_reqs: 8,
                    max_delay: Duration::from_micros(500),
                },
                request_timeout: Duration::from_millis(700),
                probe_interval: Duration::from_millis(10),
                probe_timeout: Duration::from_millis(250),
                max_attempts: 4,
            })
            .expect("shard router binds loopback listeners"),
        );

        // register matrices until at least two place off shard 0 (the
        // "clean" set the recovery loop measures in every mode, so the
        // shard_kill recovery figure is comparable against baseline's)
        let mut rng = Rng::new(0x10AD);
        let mut names: Vec<String> = Vec::new();
        let mut clean: Vec<String> = Vec::new();
        while names.len() < 6 || clean.len() < 2 {
            let name = format!("m{}", names.len());
            let coo = Coo::random(64, 96, 0.05, &mut rng);
            let targets = router.register(&name, &coo);
            if !targets.contains(&0) {
                clean.push(name.clone());
            }
            names.push(name);
            if names.len() >= 24 {
                break;
            }
        }
        if clean.is_empty() {
            clean = names.clone(); // deterministic seeds make this unreachable
        }
        let b = Dense::random(96, 8, &mut rng);

        // warm-up: every matrix serves once before any fault is armed
        for name in &names {
            router
                .call(name, b.clone(), Priority::Normal)
                .unwrap_or_else(|e| panic!("{mode}: warm-up call on {name} failed: {e}"));
        }

        // queue-depth sampler: the boundedness proof is the *observed*
        // depth never exceeding the admission capacity under saturation
        let depth_max = Arc::new(AtomicUsize::new(0));
        let sampler_stop = Arc::new(AtomicBool::new(false));
        let sampler = {
            let router = Arc::clone(&router);
            let depth_max = Arc::clone(&depth_max);
            let stop = Arc::clone(&sampler_stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    depth_max.fetch_max(router.max_queue_depth(), Ordering::Relaxed);
                    std::thread::sleep(Duration::from_micros(500));
                }
            })
        };

        if let Some(spec) = plan_spec {
            let plan = fault::FaultPlan::parse(spec, 0x10AD).expect("load plans parse");
            fault::install(&plan);
        }

        // --- main wave: closed loop, all clients in flight at once
        let (tx, rx) = channel();
        let total = (clients * per_client) as u64;
        let mut submitted = 0u64;
        let mut seqs = vec![0usize; clients];
        let t_wall = Instant::now();
        for c in 0..clients {
            submit_load(&router, &names, &b, c, seqs[c], &tx);
            seqs[c] += 1;
            submitted += 1;
        }
        let (mut received, mut acked, mut errors, mut shed) = (0u64, 0u64, 0u64, 0u64);
        let mut lats: Vec<f64> = Vec::new();
        let mut killed = false;
        while received < total {
            let Ok((c, lat_ms, verdict)) = rx.recv_timeout(Duration::from_secs(15)) else {
                break; // stragglers past the deadline count as lost
            };
            received += 1;
            match verdict {
                None => {
                    acked += 1;
                    lats.push(lat_ms);
                }
                Some(kind) => {
                    errors += 1;
                    if kind == "shed" {
                        shed += 1;
                    }
                }
            }
            if mode == "shard_kill" && !killed && received >= total / 2 {
                router.kill_shard(0);
                killed = true;
            }
            if seqs[c] < per_client {
                submit_load(&router, &names, &b, c, seqs[c], &tx);
                seqs[c] += 1;
                submitted += 1;
            }
        }
        let wall_s = t_wall.elapsed().as_secs_f64();
        // a second resolution for an already-counted submission would
        // surface here as an extra message — drain briefly and count
        let mut duplicates = 0u64;
        while rx.recv_timeout(Duration::from_millis(200)).is_ok() {
            duplicates += 1;
        }
        let lost = submitted.saturating_sub(received);
        // fired counters only reset on install — don't read stale counts
        // from a previous plan in the plan-less modes
        let injected = if plan_spec.is_some() { fault::fired_total() } else { 0 };
        fault::disable();

        // --- recovery: closed loop over the off-shard-0 matrices, same
        // shape in every mode so recovered_rps compares against baseline
        let recovery = (clients * per_client / 2).max(32);
        let t_rec = Instant::now();
        let mut recovered = 0usize;
        let mut rec_sent = 0usize;
        while rec_sent < recovery {
            let wave = 64.min(recovery - rec_sent);
            let (wtx, wrx) = channel();
            for i in 0..wave {
                let name = &clean[(rec_sent + i) % clean.len()];
                let wtx = wtx.clone();
                router.submit(name, b.clone(), Priority::Normal, 0, move |r| {
                    let _ = wtx.send(r.is_ok());
                });
            }
            rec_sent += wave;
            for _ in 0..wave {
                if wrx.recv_timeout(Duration::from_secs(15)) == Ok(true) {
                    recovered += 1;
                }
            }
        }
        let recovered_rps = recovered as f64 / t_rec.elapsed().as_secs_f64().max(1e-9);

        sampler_stop.store(true, Ordering::Relaxed);
        let _ = sampler.join();
        let snap = router.counters().snapshot();
        router.shutdown();

        let (p50_ms, p99_ms, p999_ms) = if lats.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN)
        } else {
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (
                stats::percentile_sorted(&lats, 50.0),
                stats::percentile_sorted(&lats, 99.0),
                stats::percentile_sorted(&lats, 99.9),
            )
        };
        out.push(LoadOutcome {
            mode,
            clients,
            requests: submitted,
            acked,
            errors,
            shed,
            lost,
            duplicates,
            failovers: snap.failovers,
            retries: snap.retries,
            suppressed: snap.duplicates_suppressed,
            sustained_rps: acked as f64 / wall_s.max(1e-9),
            p50_ms,
            p99_ms,
            p999_ms,
            max_queue_depth: depth_max.load(Ordering::Relaxed),
            queue_capacity,
            recovered_rps,
            injected,
            wall_s,
        });
    }
    fault::disable();
    out
}

/// Write the machine-readable load record the CI uploads and gates on.
fn write_load_json(outcomes: &[LoadOutcome], kill_gap_pct: f64) -> PathBuf {
    use crate::util::json::Json;
    fn num_or_null(v: f64) -> Json {
        if v.is_finite() { Json::num(v) } else { Json::Null }
    }
    let lost: u64 = outcomes.iter().map(|o| o.lost).sum();
    let dups: u64 = outcomes.iter().map(|o| o.duplicates).sum();
    let sat = outcomes.iter().find(|o| o.mode == "saturation");
    let doc = vec![
        ("bench", Json::str("load")),
        ("pr", Json::num(10.0)),
        ("kill_gap_pct", num_or_null(kill_gap_pct)),
        ("acceptance_kill_gap_pct", Json::num(10.0)),
        ("lost_responses", Json::num(lost as f64)),
        ("duplicate_deliveries", Json::num(dups as f64)),
        (
            "saturation_max_queue_depth",
            sat.map(|o| Json::num(o.max_queue_depth as f64)).unwrap_or(Json::Null),
        ),
        (
            "saturation_queue_capacity",
            sat.map(|o| Json::num(o.queue_capacity as f64)).unwrap_or(Json::Null),
        ),
        (
            "cases",
            Json::arr(outcomes.iter().map(|o| {
                Json::obj(vec![
                    ("mode", Json::str(o.mode)),
                    ("clients", Json::num(o.clients as f64)),
                    ("requests", Json::num(o.requests as f64)),
                    ("acked", Json::num(o.acked as f64)),
                    ("errors", Json::num(o.errors as f64)),
                    ("shed", Json::num(o.shed as f64)),
                    ("lost", Json::num(o.lost as f64)),
                    ("duplicates", Json::num(o.duplicates as f64)),
                    ("failovers", Json::num(o.failovers as f64)),
                    ("retries", Json::num(o.retries as f64)),
                    ("suppressed", Json::num(o.suppressed as f64)),
                    ("sustained_rps", Json::num(o.sustained_rps)),
                    ("p50_ms", num_or_null(o.p50_ms)),
                    ("p99_ms", num_or_null(o.p99_ms)),
                    ("p999_ms", num_or_null(o.p999_ms)),
                    ("max_queue_depth", Json::num(o.max_queue_depth as f64)),
                    ("queue_capacity", Json::num(o.queue_capacity as f64)),
                    ("recovered_rps", Json::num(o.recovered_rps)),
                    ("injected", Json::num(o.injected as f64)),
                    ("wall_s", Json::num(o.wall_s)),
                ])
            })),
        ),
    ];
    let path = results_dir().join("BENCH_PR10.json");
    write_json_or_warn(&path, &Json::obj(doc).to_string());
    path
}

/// Load experiment — concurrent closed-loop clients against the sharded
/// network serving stack (sustained throughput, tail latency, bounded
/// queues, shard-kill failover), emitting `BENCH_PR10.json`.
pub fn load(quick: bool) -> String {
    let outcomes = load_outcomes(quick);
    load_report(&outcomes)
}

/// Render the load experiment (split so tests measure once and reuse).
pub fn load_report(outcomes: &[LoadOutcome]) -> String {
    let mut out = String::from(
        "== load: closed-loop clients vs the shard router — throughput, tails, failover ==\n",
    );
    let baseline_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.recovered_rps)
        .unwrap_or(f64::NAN);
    let mut kill_gap_pct = f64::NAN;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for o in outcomes {
        let gap_pct = 100.0 * (baseline_rps - o.recovered_rps) / baseline_rps.max(1e-9);
        if o.mode == "shard_kill" {
            kill_gap_pct = gap_pct;
        }
        rows.push(vec![
            o.mode.to_string(),
            format!("{}/{}", o.acked, o.requests),
            o.errors.to_string(),
            o.shed.to_string(),
            o.lost.to_string(),
            o.duplicates.to_string(),
            format!("{}+{}", o.failovers, o.retries),
            format!("{:.0}", o.sustained_rps),
            format!("{:.2}", o.p50_ms),
            format!("{:.2}", o.p99_ms),
            format!("{:.2}", o.p999_ms),
            format!("{}/{}", o.max_queue_depth, o.queue_capacity),
            if o.mode == "baseline" { "-".into() } else { format!("{gap_pct:+.1}%") },
        ]);
        csv.push(vec![
            o.mode.to_string(),
            o.clients.to_string(),
            o.requests.to_string(),
            o.acked.to_string(),
            o.errors.to_string(),
            o.shed.to_string(),
            o.lost.to_string(),
            o.duplicates.to_string(),
            o.failovers.to_string(),
            o.retries.to_string(),
            o.suppressed.to_string(),
            format!("{:.2}", o.sustained_rps),
            format!("{:.4}", o.p50_ms),
            format!("{:.4}", o.p99_ms),
            format!("{:.4}", o.p999_ms),
            o.max_queue_depth.to_string(),
            o.queue_capacity.to_string(),
            format!("{:.2}", o.recovered_rps),
            o.injected.to_string(),
            format!("{}", o.wall_s),
        ]);
    }
    out.push_str(&render::table(
        &[
            "mode",
            "acked",
            "err",
            "shed",
            "lost",
            "dup",
            "fo+rt",
            "req/s",
            "p50 ms",
            "p99 ms",
            "p99.9 ms",
            "depth",
            "gap",
        ],
        &rows,
    ));
    let lost: u64 = outcomes.iter().map(|o| o.lost).sum();
    let dups: u64 = outcomes.iter().map(|o| o.duplicates).sum();
    let kill = outcomes.iter().find(|o| o.mode == "shard_kill");
    let sat = outcomes.iter().find(|o| o.mode == "saturation");
    if let Some(k) = kill {
        out.push_str(&format!(
            "\nshard-kill invariant: lost={} duplicated={} — every acked request resolved \
             exactly once at a caller, across an abrupt mid-wave shard kill (both must be 0)\n",
            k.lost, k.duplicates
        ));
    }
    out.push_str(&format!(
        "exactly-once invariant (all modes): {lost} submissions unresolved, {dups} resolved \
         more than once (both must be 0)\n"
    ));
    if let Some(s) = sat {
        out.push_str(&format!(
            "saturation invariant: max sampled queue depth {} <= admission capacity {} — \
             overload sheds with typed errors ({} shed) instead of growing the queue\n",
            s.max_queue_depth, s.queue_capacity, s.shed
        ));
    }
    out.push_str(&format!(
        "shard-kill recovery: live-shard throughput within {kill_gap_pct:+.1}% of baseline \
         after the kill (acceptance: 10%; measured in release `experiment load` — debug \
         runs assert the invariants above, not timing)\n"
    ));
    out.push_str(
        "methodology: per mode, a fresh 3-shard 2-replica router serves a closed-loop wave \
         (every client keeps exactly one request in flight); shard_kill cuts shard 0's \
         sockets mid-wave so unacked requests fail over under their original ids; \
         net_stall/net_drop arm seeded FaultPlans on shard 0's response writer; recovery \
         req/s is a closed loop over matrices placed off shard 0.\n",
    );
    write_csv_or_warn(
        &results_dir().join("load.csv"),
        &[
            "mode",
            "clients",
            "requests",
            "acked",
            "errors",
            "shed",
            "lost",
            "duplicates",
            "failovers",
            "retries",
            "suppressed",
            "sustained_rps",
            "p50_ms",
            "p99_ms",
            "p999_ms",
            "max_queue_depth",
            "queue_capacity",
            "recovered_rps",
            "injected",
            "wall_s",
        ],
        &csv,
    );
    let json_path = write_load_json(outcomes, kill_gap_pct);
    out.push_str(&format!("machine-readable record -> {}\n", json_path.display()));
    out
}

/// Run the corpus once at the scale implied by `quick` for the corpus-wide
/// experiments (fig2/7/9/10, table2).
pub fn corpus_records(quick: bool) -> Vec<Record> {
    let scale = if quick { CorpusScale::Quick } else { CorpusScale::Full };
    corpus_run::run(scale, 42, &[32, 128, 512])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_records() -> Vec<Record> {
        let specs = crate::gen::corpus::specs(CorpusScale::Quick, 42);
        corpus_run::run_specs(&specs[..8.min(specs.len())], &[32, 128, 512])
    }

    #[test]
    fn fig_drivers_render() {
        let recs = tiny_records();
        for report in [fig2(&recs), fig7(&recs), fig9(&recs), fig10(&recs), table2(&recs)] {
            assert!(report.contains("=="), "{report}");
        }
    }

    #[test]
    fn table1_is_static() {
        let t = table1();
        assert!(t.contains("12.5"));
        assert!(t.contains("High"));
    }

    #[test]
    fn ablation_tiles_renders() {
        let t = ablation_tiles();
        assert!(t.contains("TN=32"));
        assert!(t.contains("OI_shmem"));
    }

    /// Acceptance for the exec experiment: every (mode, matrix, N) cell
    /// matches the CSR reference, the acceptance width (N=256) is covered,
    /// and the machine-readable BENCH_PR4.json lands on disk with the
    /// headline geomean. The measurement grid is shrunk to what debug-mode
    /// `cargo test` can afford; the 1.3x ratio itself is printed by the
    /// release-mode `experiment exec` (a perf figure measured on real
    /// hosts, not asserted on loaded CI runners — the prep experiment set
    /// this precedent).
    #[test]
    fn exec_outcomes_are_correct_and_json_lands() {
        let specs = vec![
            MatrixSpec {
                name: "exec-test-fem".into(),
                rows: 768,
                family: Family::Banded { bandwidth: 16, band_fill: 0.6, noise: 0.01 },
                seed: 0xE8EC7,
            },
            MatrixSpec {
                name: "exec-test-rmat".into(),
                rows: 512,
                family: Family::Rmat { edge_factor: 6, skew: 0.57 },
                seed: 0xE8EC8,
            },
        ];
        let widths = [32usize, 256];
        let outcomes = exec_outcomes_for(&specs, &widths, 1);
        assert_eq!(outcomes.len(), specs.len() * widths.len(), "full matrix x width grid");
        for o in &outcomes {
            assert!(
                o.max_rel_err < 1e-5,
                "{} N={}: some exec mode diverged (rel err {})",
                o.matrix,
                o.n,
                o.max_rel_err
            );
            assert!(o.spawn_unblocked_s > 0.0 && o.pooled_blocked_s > 0.0);
            assert!(o.slab_width >= 1 && o.slab_width <= o.n.max(32));
        }
        assert!(outcomes.iter().any(|o| o.n == 256), "the acceptance width is measured");

        let report = exec_report(&outcomes);
        assert!(report.contains("== exec:"), "{report}");
        assert!(report.contains("acceptance floor: 1.3x"), "{report}");
        assert!(report.contains("BENCH_PR4.json"), "{report}");
        let path = results_dir().join("BENCH_PR4.json");
        let text = std::fs::read_to_string(&path).expect("BENCH_PR4.json written");
        let doc = crate::util::json::parse(&text).expect("BENCH_PR4.json parses");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("exec_runtime"));
        assert!(doc.get("geomean_speedup_n256").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(doc.get("cases").unwrap().as_arr().unwrap().len(), outcomes.len());
    }

    /// Acceptance for the trace experiment: all four modes serve the full
    /// workload, the full mode actually records spans, and both
    /// machine-readable artifacts (BENCH_PR6.json, sample.trace.json) land
    /// and parse. The ≤ 2% overhead figure itself is printed by the
    /// release-mode `experiment trace` — a perf figure measured on real
    /// hosts, not asserted in debug-mode CI (the exec experiment set this
    /// precedent).
    #[test]
    fn trace_modes_run_and_emit_valid_json() {
        let outcomes = trace_outcomes_for(96, 32);
        assert_eq!(outcomes.len(), 4);
        for o in &outcomes {
            assert_eq!(o.served + o.shed, o.requests, "{}: every request resolves", o.mode);
            assert!(o.served > 0, "{}: at least some requests served", o.mode);
        }
        let full = outcomes.iter().find(|o| o.mode == "full").expect("full mode present");
        assert!(full.spans > 0, "full tracing records spans");
        assert!(full.exec_span_us > 0, "exec spans carry duration");
        for o in outcomes.iter().filter(|o| o.mode == "baseline" || o.mode == "off") {
            assert_eq!(o.spans, 0, "{}: disabled tracing records nothing", o.mode);
        }

        let report = trace_report(&outcomes);
        assert!(report.contains("== trace:"), "{report}");
        assert!(report.contains("acceptance budget: 2.0%"), "{report}");
        assert!(report.contains("BENCH_PR6.json"), "{report}");
        let text = std::fs::read_to_string(results_dir().join("BENCH_PR6.json"))
            .expect("BENCH_PR6.json written");
        let doc = crate::util::json::parse(&text).expect("BENCH_PR6.json parses");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("trace_overhead"));
        assert_eq!(doc.get("pr").unwrap().as_usize(), Some(6));
        assert!(doc.get("overhead_full_pct").unwrap().as_f64().is_some());
        assert_eq!(doc.get("cases").unwrap().as_arr().unwrap().len(), 4);
        let sample = std::fs::read_to_string(results_dir().join("sample.trace.json"))
            .expect("sample.trace.json written");
        let chrome = crate::util::json::parse(&sample).expect("sample.trace.json parses");
        assert!(
            !chrome.get("traceEvents").unwrap().as_arr().unwrap().is_empty(),
            "the Perfetto sample carries events"
        );
    }

    /// Acceptance for the QoS saturation run: the bounded-queue policy holds
    /// queue depth at or below its configured capacity with zero unbounded
    /// growth, sheds load with typed rejections (reported per lane), and
    /// achieves lower p99 queue wait than the unbounded baseline at the same
    /// offered load.
    #[test]
    fn qos_saturation_bounds_depth_and_tail_latency() {
        let outcomes = qos_saturation_outcomes();
        assert_eq!(outcomes.len(), 3);
        let unbounded = &outcomes[0];
        let reject = &outcomes[1];
        let qos_o = &outcomes[2];

        // the unbounded baseline completes everything but grows without bound
        assert_eq!(unbounded.completed, unbounded.offered);
        assert!(
            unbounded.max_depth > qos_o.capacity,
            "unbounded depth {} should exceed the bounded capacity {}",
            unbounded.max_depth,
            qos_o.capacity
        );

        // both bounded policies hold the configured bound — zero unbounded growth
        assert!(reject.max_depth <= reject.capacity);
        assert!(qos_o.max_depth <= qos_o.capacity);

        // qos sheds with typed rejections, reported per lane and per reason
        let qos_sheds: u64 = qos_o.shed_by_reason.iter().sum();
        assert!(qos_sheds > 0);
        assert_eq!(
            qos_sheds,
            qos_o.shed_lane.iter().sum::<u64>(),
            "per-lane and per-reason counts must agree"
        );
        assert!(
            qos_o.shed_by_reason[RejectReason::Overload.index()] > 0,
            "cost-aware watermark shedding never engaged"
        );
        assert!(
            qos_o.shed_by_reason[RejectReason::DeadlineUnmeetable.index()] > 0,
            "deadline shedding never engaged"
        );
        // the normal lane is shed first under pressure
        assert!(
            qos_o.shed_lane[Priority::Normal.index()]
                > qos_o.shed_lane[Priority::High.index()],
            "normal lane must shed more than the high lane"
        );

        // tail latency: qos beats the unbounded baseline at the same load
        assert!(
            qos_o.p99_wait_ms < unbounded.p99_wait_ms,
            "qos p99 {} vs unbounded p99 {}",
            qos_o.p99_wait_ms,
            unbounded.p99_wait_ms
        );

        let report = qos_saturation();
        assert!(report.contains("QoS saturation"), "{report}");
        assert!(report.contains("unbounded"), "{report}");
        assert!(report.contains("reject-on-full"), "{report}");
    }

    /// Acceptance for the artifact prep run: the warm-start path must
    /// demonstrably skip the rebuild — every warm registration an actual
    /// store hit, parallel build byte-identical to serial on every matrix,
    /// and aggregate warm registration decisively faster than cold. The
    /// experiment report prints the exact speedup against the 5x acceptance
    /// floor; the unit test enforces a 2x margin so a scheduler stall on a
    /// loaded CI runner cannot flake the gate while a broken warm path
    /// (which re-runs the build and lands near 1x) still fails it.
    #[test]
    fn prep_warm_start_skips_rebuild_and_parallel_is_identical() {
        let dir = crate::hrpb::store::test_dir("prep_test");
        let outcomes = prep_outcomes(&dir);
        let _ = std::fs::remove_dir_all(&dir);
        assert!(outcomes.len() >= 3, "prep corpus too small: {}", outcomes.len());

        let (mut cold, mut warm) = (0.0f64, 0.0f64);
        for o in &outcomes {
            assert!(o.parallel_identical, "{}: parallel build diverged from serial", o.matrix);
            assert!(o.warm_hit, "{}: warm registration missed the store", o.matrix);
            assert!(o.artifact_bytes > 0, "{}: artifact not persisted", o.matrix);
            assert!(o.reorder_s > 0.0, "{}: reorder split not measured", o.matrix);
            cold += o.cold_register_s;
            warm += o.warm_register_s;
        }
        let speedup = cold / warm.max(1e-12);
        assert!(
            speedup >= 2.0,
            "warm registration must decisively beat cold (got {speedup:.1}x, \
             cold {cold:.4}s warm {warm:.4}s)"
        );

        // rendering, on the same measured data (no second build suite)
        let report = prep_report(&outcomes);
        assert!(report.contains("== prep:"), "{report}");
        assert!(report.contains("warm registration"), "{report}");
        assert!(report.contains("acceptance floor: 5x"), "{report}");
        assert!(report.contains("identical"), "{report}");
        assert!(report.contains("reorder(ms)"), "{report}");
    }

    /// Acceptance for the reorder A/B: both orders match the CSR reference
    /// on every cell, the shuffled low/medium-synergy families actually
    /// activate with a real α lift, declined cells never report a phantom
    /// speedup, and BENCH_PR5.json lands with the headline geomean.
    /// The 1.2x floor itself is printed by the release-mode `experiment
    /// reorder` (perf figures are measured on real hosts, not asserted on
    /// loaded debug CI runners — the exec/prep experiments set the
    /// precedent).
    #[test]
    fn reorder_outcomes_are_correct_and_json_lands() {
        let specs: Vec<(&'static str, MatrixSpec, bool)> = vec![
            (
                "scattered",
                MatrixSpec {
                    name: "reorder-test-scattered".into(),
                    rows: 512,
                    family: Family::BlockDiag { unit: 16, unit_density: 0.7 },
                    seed: 0x5E0D7,
                },
                true,
            ),
            (
                "community",
                MatrixSpec {
                    name: "reorder-test-community".into(),
                    rows: 512,
                    family: Family::Community {
                        communities: 32,
                        intra_degree: 12,
                        inter_frac: 0.05,
                    },
                    seed: 0x5E0D8,
                },
                true,
            ),
            (
                "rmat",
                MatrixSpec {
                    name: "reorder-test-rmat".into(),
                    rows: 512,
                    family: Family::Rmat { edge_factor: 6, skew: 0.57 },
                    seed: 0x5E0D9,
                },
                false,
            ),
        ];
        let outcomes = reorder_outcomes_for(&specs, 32, 1);
        assert_eq!(outcomes.len(), specs.len());
        for o in &outcomes {
            assert!(
                o.max_rel_err < 1e-5,
                "{}: an order diverged from the CSR reference (rel err {})",
                o.matrix,
                o.max_rel_err
            );
            assert!(o.original_s > 0.0 && o.reordered_s > 0.0);
            assert!(o.reorder_s > 0.0);
            if o.activated {
                assert!(
                    o.alpha_after > o.alpha_before,
                    "{}: activation without α lift ({} -> {})",
                    o.matrix,
                    o.alpha_before,
                    o.alpha_after
                );
            } else {
                assert_eq!(o.reordered_s, o.original_s, "declined cells charge no win");
                assert_eq!(o.alpha_after, o.alpha_before);
            }
        }
        // the shuffled structured families must activate — that is the
        // entire point of the subsystem
        for fam in ["scattered", "community"] {
            assert!(
                outcomes.iter().any(|o| o.family == fam && o.activated),
                "{fam} family failed to activate"
            );
        }

        let report = reorder_report(&outcomes);
        assert!(report.contains("== reorder:"), "{report}");
        assert!(report.contains("acceptance floor: 1.2x"), "{report}");
        assert!(report.contains("BENCH_PR5.json"), "{report}");
        let path = results_dir().join("BENCH_PR5.json");
        let text = std::fs::read_to_string(&path).expect("BENCH_PR5.json written");
        let doc = crate::util::json::parse(&text).expect("BENCH_PR5.json parses");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("reorder"));
        assert!(doc.get("geomean_speedup_lowmed").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(doc.get("cases").unwrap().as_arr().unwrap().len(), outcomes.len());
    }

    /// Acceptance for the geometry A/B: every shape matches the CSR
    /// reference, the scattered family (the existence proof — most 16x4
    /// slots are zero-fill there) picks a non-default shape with a real
    /// predicted slot reduction, the dense-block control stays at 16x4 and
    /// charges no phantom win, and BENCH_PR8.json lands with the headline
    /// geomean. The 1.0x floor itself is printed by the release-mode
    /// `experiment geometry` (perf figures are measured on real hosts, not
    /// asserted on loaded debug CI runners — the reorder experiment sets
    /// the precedent).
    #[test]
    fn geometry_outcomes_are_correct_and_json_lands() {
        let specs: Vec<(&'static str, MatrixSpec)> = vec![
            (
                "scattered",
                MatrixSpec {
                    name: "geometry-test-scattered".into(),
                    rows: 512,
                    family: Family::Random { avg_degree: 2 },
                    seed: 0x6E07,
                },
            ),
            (
                "powerlaw",
                MatrixSpec {
                    name: "geometry-test-powerlaw".into(),
                    rows: 512,
                    family: Family::Rmat { edge_factor: 6, skew: 0.57 },
                    seed: 0x6E08,
                },
            ),
            (
                "blockdense",
                MatrixSpec {
                    name: "geometry-test-blockdense".into(),
                    rows: 512,
                    family: Family::BlockDiag { unit: 16, unit_density: 0.7 },
                    seed: 0x6E09,
                },
            ),
        ];
        let cache = crate::bench::harness::SuiteCache::open("geometry_test")
            .expect("temp dir must be creatable in tests");
        let outcomes = geometry_outcomes_for(&specs, 32, 1, Some(&cache));
        assert_eq!(outcomes.len(), specs.len());
        for o in &outcomes {
            assert!(
                o.max_rel_err < 1e-5,
                "{}: a shape diverged from the CSR reference (rel err {})",
                o.matrix,
                o.max_rel_err
            );
            assert!(o.fixed_s > 0.0 && o.picked_s > 0.0);
            assert!(o.price_s > 0.0);
            assert!(o.slots_default > 0 && o.slots_chosen > 0);
            if o.activated() {
                // the chooser's contract: never deviate from the default
                // without predicted gain
                assert!(
                    o.predicted_gain() >= 1.05,
                    "{}: picked {} on a predicted gain of only {:.3}x",
                    o.matrix,
                    o.chosen,
                    o.predicted_gain()
                );
            } else {
                assert_eq!(o.picked_s, o.fixed_s, "default cells charge no phantom win");
                assert_eq!(o.slots_chosen, o.slots_default);
            }
        }
        let scat = outcomes.iter().find(|o| o.family == "scattered").unwrap();
        assert!(scat.activated(), "scattered family failed to pick a non-default shape");
        let dense = outcomes.iter().find(|o| o.family == "blockdense").unwrap();
        assert!(
            !dense.activated(),
            "blockdense control must stay at 16x4 (picked {})",
            dense.chosen
        );
        // the suite-run cache absorbed every planner-picked cell whose
        // chosen shape coincides with the already-built default
        let not_activated = outcomes.iter().filter(|o| !o.activated()).count() as u64;
        let st = cache.stats();
        assert_eq!(st.hits, not_activated, "default-shape picks must serve from the artifact");
        assert_eq!(st.invalidated, 0);

        let report = geometry_report(&outcomes);
        assert!(report.contains("== geometry:"), "{report}");
        assert!(report.contains("acceptance floor: 1.0x"), "{report}");
        assert!(report.contains("BENCH_PR8.json"), "{report}");
        let path = results_dir().join("BENCH_PR8.json");
        let text = std::fs::read_to_string(&path).expect("BENCH_PR8.json written");
        let doc = crate::util::json::parse(&text).expect("BENCH_PR8.json parses");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("geometry"));
        assert_eq!(doc.get("pr").unwrap().as_f64(), Some(8.0));
        assert!(doc.get("geomean_speedup_unstructured").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(doc.get("cases").unwrap().as_arr().unwrap().len(), outcomes.len());
    }

    /// Acceptance for the chaos suite (debug-mode invariants — the
    /// recovery-gap headline is a release perf figure printed by
    /// `experiment chaos`, not asserted here): every submission gets
    /// exactly one typed reply, faults stay pinned to the victim matrix,
    /// the breaker opens under a primary kernel-panic storm and re-closes
    /// once the fault clears, fallback faults quarantine the matrix,
    /// artifact faults warm-start through retry/invalidation, and
    /// BENCH_PR9.json lands with the headline fields.
    #[test]
    fn chaos_outcomes_contain_isolate_and_recover() {
        let outcomes = chaos_outcomes_for(192, 96);
        assert_eq!(outcomes.len(), 6);
        for o in &outcomes {
            assert_eq!(o.lost, 0, "{}: every submission needs a typed reply", o.mode);
            assert_eq!(o.clean_errors, 0, "{}: faults leaked to the clean matrix", o.mode);
            assert!(o.clean_served > 0, "{}: the clean matrix must keep serving", o.mode);
        }
        let base = outcomes.iter().find(|o| o.mode == "baseline").unwrap();
        assert_eq!(base.engine_faults, 0);
        assert_eq!(base.quarantined, 0);
        assert_eq!(base.served + base.shed, base.requests);

        let kp = outcomes.iter().find(|o| o.mode == "kernel_panic").unwrap();
        assert!(kp.engine_faults > 0, "primary faults surface as typed engine_fault replies");
        assert!(kp.breaker_opens >= 1, "K consecutive faults must open the breaker");
        assert!(kp.fallback_requests >= 1, "the open breaker must reroute the victim to csr");
        assert!(kp.victim_served > 0, "the victim keeps serving on the fallback");
        assert_eq!(kp.breaker_state, "closed", "fault cleared -> a probe must re-close");
        assert!(kp.injected >= crate::coordinator::breaker::FAULT_THRESHOLD as u64);

        let fp = outcomes.iter().find(|o| o.mode == "fallback_panic").unwrap();
        assert!(fp.quarantined >= 1, "fallback faults must become typed quarantine rejections");
        assert_eq!(fp.breaker_state, "quarantined", "quarantine is sticky");

        let ai = outcomes.iter().find(|o| o.mode == "artifact_io").unwrap();
        assert!(ai.injected >= 1, "the artifact injection must have fired");
        assert!(ai.artifact_hits >= 1, "a transient IO error must still warm-start");
        assert_eq!(ai.engine_faults, 0, "artifact faults never reach the serving path");

        let cf = outcomes.iter().find(|o| o.mode == "checksum_flip").unwrap();
        assert!(cf.artifact_invalidated >= 1, "a corrupted artifact invalidates, not crashes");
        assert_eq!(cf.engine_faults, 0);

        let se = outcomes.iter().find(|o| o.mode == "slow_exec").unwrap();
        assert_eq!(se.engine_faults, 0, "stalls are slow, not faulty");
        assert_eq!(se.served + se.shed, se.requests);

        let report = chaos_report(&outcomes);
        assert!(report.contains("== chaos:"), "{report}");
        assert!(report.contains("no-lost-response invariant: 0"), "{report}");
        assert!(report.contains("isolation invariant: 0"), "{report}");
        assert!(report.contains("BENCH_PR9.json"), "{report}");
        let path = results_dir().join("BENCH_PR9.json");
        let text = std::fs::read_to_string(&path).expect("BENCH_PR9.json written");
        let doc = crate::util::json::parse(&text).expect("BENCH_PR9.json parses");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("chaos"));
        assert_eq!(doc.get("pr").unwrap().as_f64(), Some(9.0));
        assert_eq!(doc.get("lost_responses").unwrap().as_usize(), Some(0));
        assert_eq!(doc.get("isolation_violations").unwrap().as_usize(), Some(0));
        assert_eq!(doc.get("acceptance_recovery_gap_pct").unwrap().as_f64(), Some(10.0));
        assert_eq!(doc.get("cases").unwrap().as_arr().unwrap().len(), outcomes.len());
    }

    /// Acceptance for the load suite (debug-mode invariants — sustained
    /// RPS and the kill-recovery gap are release perf figures printed by
    /// `experiment load`, not asserted here): every caller resolves
    /// exactly once in every mode (zero lost, zero duplicated — including
    /// across an abrupt shard kill and dropped response frames), the
    /// saturated queue stays bounded by its admission capacity, and
    /// BENCH_PR10.json lands with the headline fields.
    #[test]
    fn load_outcomes_resolve_exactly_once_with_bounded_queues() {
        let outcomes = load_outcomes_for(24, 2);
        assert_eq!(outcomes.len(), 5);
        for o in &outcomes {
            assert_eq!(o.lost, 0, "{}: every submission must resolve at its caller", o.mode);
            assert_eq!(o.duplicates, 0, "{}: no caller may resolve twice", o.mode);
            assert_eq!(o.acked + o.errors, o.requests, "{}: exactly-once accounting", o.mode);
            assert!(o.acked > 0, "{}: the wave must serve something", o.mode);
            assert!(
                o.max_queue_depth <= o.queue_capacity,
                "{}: sampled depth {} exceeded capacity {}",
                o.mode,
                o.max_queue_depth,
                o.queue_capacity
            );
        }
        let base = outcomes.iter().find(|o| o.mode == "baseline").unwrap();
        assert_eq!(base.errors, base.shed, "baseline errors can only be shed");

        let kill = outcomes.iter().find(|o| o.mode == "shard_kill").unwrap();
        assert_eq!(kill.lost, 0, "killed shard's unacked requests must fail over, not vanish");
        assert_eq!(kill.duplicates, 0, "failover must reuse ids, not double-deliver");

        let stall = outcomes.iter().find(|o| o.mode == "net_stall").unwrap();
        assert!(stall.injected >= 1, "the stall injection must have fired");
        assert_eq!(stall.lost, 0, "stalled responses are slow, not lost");

        let report = load_report(&outcomes);
        assert!(report.contains("== load:"), "{report}");
        assert!(report.contains("lost=0 duplicated=0"), "{report}");
        assert!(report.contains("saturation invariant"), "{report}");
        assert!(report.contains("BENCH_PR10.json"), "{report}");
        let path = results_dir().join("BENCH_PR10.json");
        let text = std::fs::read_to_string(&path).expect("BENCH_PR10.json written");
        let doc = crate::util::json::parse(&text).expect("BENCH_PR10.json parses");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("load"));
        assert_eq!(doc.get("pr").unwrap().as_f64(), Some(10.0));
        assert_eq!(doc.get("lost_responses").unwrap().as_usize(), Some(0));
        assert_eq!(doc.get("duplicate_deliveries").unwrap().as_usize(), Some(0));
        assert_eq!(doc.get("acceptance_kill_gap_pct").unwrap().as_f64(), Some(10.0));
        assert_eq!(doc.get("cases").unwrap().as_arr().unwrap().len(), outcomes.len());
    }

    #[test]
    fn auto_policy_tracks_oracle_within_10_percent() {
        let recs = tiny_records();
        let mut checked = 0;
        for m in MACHINES {
            for n in [32usize, 128, 512] {
                let Some(s) = auto_policy_summary(&recs, m, n) else { continue };
                checked += 1;
                assert!(s.oracle_gflops > 0.0);
                assert!(s.auto_gflops <= s.oracle_gflops * (1.0 + 1e-9));
                // acceptance: Auto within 10% of oracle aggregate throughput
                assert!(
                    s.auto_gflops >= 0.9 * s.oracle_gflops,
                    "[{m}, N={n}] auto {} vs oracle {}",
                    s.auto_gflops,
                    s.oracle_gflops
                );
                assert!(s.routed.iter().sum::<usize>() == s.matrices);
            }
        }
        assert!(checked >= 6, "summaries missing: {checked}");
        let report = auto_policy(&recs);
        assert!(report.contains("auto routing:"), "{report}");
        assert!(report.contains("vs oracle"), "{report}");
    }
}
