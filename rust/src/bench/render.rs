//! ASCII rendering for experiment output: tables, box plots, scatter plots,
//! heatmaps. The paper's figures are regenerated as text so the bench
//! harness works on a terminal and diffs cleanly in EXPERIMENTS.md.

use crate::util::stats::{box_stats, BoxStats};

/// Render an aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:>w$}", cell, w = widths[i]));
        }
        line
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// One labelled box in a box-plot group.
pub struct BoxEntry {
    pub label: String,
    pub stats: BoxStats,
}

/// Render horizontal ASCII box plots on a shared log10 axis.
///
/// ```text
/// label      |----[=====|=====]------|        p50=...
/// ```
pub fn boxplot(entries: &[BoxEntry], axis_label: &str) -> String {
    let finite: Vec<f64> = entries
        .iter()
        .flat_map(|e| [e.stats.min, e.stats.max])
        .filter(|v| v.is_finite() && *v > 0.0)
        .collect();
    if finite.is_empty() {
        return "(no data)\n".into();
    }
    let lo = finite.iter().cloned().fold(f64::INFINITY, f64::min).log10();
    let hi = finite.iter().cloned().fold(0.0f64, f64::max).log10();
    let span = (hi - lo).max(1e-9);
    const W: usize = 56;
    let pos = |v: f64| -> usize {
        if v <= 0.0 {
            return 0;
        }
        (((v.log10() - lo) / span) * (W - 1) as f64).round().clamp(0.0, (W - 1) as f64) as usize
    };
    let label_w = entries.iter().map(|e| e.label.len()).max().unwrap_or(8).max(8);
    let mut out = String::new();
    for e in entries {
        let s = &e.stats;
        if !s.median.is_finite() {
            out.push_str(&format!("{:<label_w$} (no samples)\n", e.label));
            continue;
        }
        let mut line = vec![' '; W];
        let (pmin, p25, p50, p75, pmax) = (pos(s.min), pos(s.q25), pos(s.median), pos(s.q75), pos(s.max));
        for c in line.iter_mut().take(pmax + 1).skip(pmin) {
            *c = '-';
        }
        for c in line.iter_mut().take(p75 + 1).skip(p25) {
            *c = '=';
        }
        line[pmin] = '|';
        line[pmax] = '|';
        line[p50] = '#';
        out.push_str(&format!(
            "{:<label_w$} {}  p50={:.1} [q25={:.1} q75={:.1}]\n",
            e.label,
            line.iter().collect::<String>(),
            s.median,
            s.q25,
            s.q75,
        ));
    }
    out.push_str(&format!(
        "{:<label_w$} {}\n",
        "",
        format!("log10 axis: {:.1} .. {:.1} ({axis_label})", lo, hi)
    ));
    out
}

/// Build a `BoxEntry` from raw samples (empty → NaN stats, rendered blank).
pub fn box_entry(label: impl Into<String>, samples: &[f64]) -> BoxEntry {
    let stats = if samples.is_empty() {
        BoxStats {
            min: f64::NAN,
            q25: f64::NAN,
            median: f64::NAN,
            q75: f64::NAN,
            max: f64::NAN,
            mean: f64::NAN,
            count: 0,
        }
    } else {
        box_stats(samples)
    };
    BoxEntry { label: label.into(), stats }
}

/// Render a dot-density scatter plot of (x, y) points on log-log axes.
pub fn scatter(points: &[(f64, f64)], w: usize, h: usize, xlabel: &str, ylabel: &str) -> String {
    let ok: Vec<(f64, f64)> =
        points.iter().copied().filter(|&(x, y)| x > 0.0 && y > 0.0).collect();
    if ok.is_empty() {
        return "(no data)\n".into();
    }
    let (x0, x1) = ok.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &(x, _)| {
        (a.min(x.log10()), b.max(x.log10()))
    });
    let (y0, y1) = ok.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &(_, y)| {
        (a.min(y.log10()), b.max(y.log10()))
    });
    let (xs, ys) = ((x1 - x0).max(1e-9), (y1 - y0).max(1e-9));
    let mut grid = vec![vec![0u32; w]; h];
    for (x, y) in ok {
        let cx = (((x.log10() - x0) / xs) * (w - 1) as f64) as usize;
        let cy = (((y.log10() - y0) / ys) * (h - 1) as f64) as usize;
        grid[h - 1 - cy][cx] += 1;
    }
    let glyph = |c: u32| match c {
        0 => ' ',
        1 => '.',
        2..=4 => 'o',
        5..=15 => 'O',
        _ => '@',
    };
    let mut out = String::new();
    out.push_str(&format!("{ylabel} (log10 {y0:.1}..{y1:.1})\n"));
    for row in grid {
        out.push('|');
        out.extend(row.into_iter().map(glyph));
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(w));
    out.push('\n');
    out.push_str(&format!(" {xlabel} (log10 {x0:.1}..{x1:.1})\n"));
    out
}

/// Render a heatmap of `values[r][c]` with row/col labels; cell text is the
/// numeric value (e.g. speedup).
pub fn heatmap(row_labels: &[String], col_labels: &[String], values: &[Vec<f64>]) -> String {
    let mut rows = Vec::new();
    for (rl, vals) in row_labels.iter().zip(values) {
        let mut row = vec![rl.clone()];
        for &v in vals {
            row.push(if v.is_finite() { format!("{v:.2}") } else { "-".into() });
        }
        rows.push(row);
    }
    let mut headers: Vec<&str> = vec![""];
    headers.extend(col_labels.iter().map(|s| s.as_str()));
    table(&headers, &rows)
}

/// CSV emission helper.
pub fn write_csv(path: &std::path::Path, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", headers.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns() {
        let out = table(
            &["name", "gflops"],
            &[vec!["a".into(), "1.5".into()], vec!["longer".into(), "20".into()]],
        );
        assert!(out.contains("name"));
        assert!(out.lines().count() == 4);
    }

    #[test]
    fn boxplot_renders_medians() {
        let e = vec![
            box_entry("cute", &[100.0, 200.0, 400.0, 800.0]),
            box_entry("tcgnn", &[10.0, 20.0, 40.0]),
        ];
        let out = boxplot(&e, "GFLOPs");
        assert!(out.contains('#'));
        assert!(out.contains("cute"));
    }

    #[test]
    fn scatter_renders() {
        let pts: Vec<(f64, f64)> = (1..100).map(|i| (i as f64, (i * i) as f64)).collect();
        let out = scatter(&pts, 40, 10, "x", "y");
        assert!(out.contains('.') || out.contains('o'));
    }

    #[test]
    fn heatmap_marks_missing() {
        let out = heatmap(
            &["r0".into()],
            &["c0".into(), "c1".into()],
            &[vec![1.25, f64::NAN]],
        );
        assert!(out.contains("1.25"));
        assert!(out.contains('-'));
    }

    #[test]
    fn csv_roundtrip() {
        let p = std::env::temp_dir().join("cutespmm_test.csv");
        write_csv(&p, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        let _ = std::fs::remove_file(&p);
    }
}
