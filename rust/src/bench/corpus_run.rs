//! Corpus runner: generate → profile → predict for every matrix, in
//! parallel, producing the record set every figure/table experiment consumes.

use crate::gen::corpus::{specs, CorpusScale};
use crate::gen::MatrixSpec;
use crate::gpumodel::{algos, Machine, MatrixProfile};
use crate::spmm::Algo;
use crate::synergy::Synergy;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One prediction cell.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub machine: &'static str,
    pub n: usize,
    pub algo: Algo,
    pub gflops: f64,
    pub time_s: f64,
}

/// One corpus matrix with its structural profile and model predictions.
#[derive(Clone, Debug)]
pub struct Record {
    pub name: String,
    pub family: &'static str,
    pub rows: usize,
    pub nnz: usize,
    pub alpha: f64,
    pub beta: f64,
    pub synergy: Synergy,
    pub cells: Vec<Cell>,
}

impl Record {
    /// Look one cell up.
    pub fn get(&self, machine: &str, n: usize, algo: Algo) -> Option<Cell> {
        self.cells
            .iter()
            .find(|c| c.machine == machine && c.n == n && c.algo == algo)
            .copied()
    }

    /// Best scalar-core GFLOPs at (machine, n) — the paper's Best-SC.
    pub fn best_sc(&self, machine: &str, n: usize) -> Option<Cell> {
        self.cells
            .iter()
            .filter(|c| c.machine == machine && c.n == n && Algo::scalar_core().contains(&c.algo))
            .max_by(|a, b| a.gflops.partial_cmp(&b.gflops).unwrap())
            .copied()
    }
}

/// Algorithms every corpus experiment evaluates (the Fig 2/9/10 set).
pub fn eval_algos() -> Vec<Algo> {
    vec![Algo::Hrpb, Algo::TcGnn, Algo::Csr, Algo::Coo, Algo::Sputnik, Algo::GeSpmm]
}

/// Run the corpus through the analytical models.
///
/// `ns` are the dense widths; both paper machines are always evaluated.
/// Work is spread over all cores; output order matches spec order.
pub fn run(scale: CorpusScale, seed: u64, ns: &[usize]) -> Vec<Record> {
    run_specs(&specs(scale, seed), ns)
}

/// Same, over explicit specs (named matrices, tests).
pub fn run_specs(specs: &[MatrixSpec], ns: &[usize]) -> Vec<Record> {
    let machines = [Machine::a100(), Machine::rtx4090()];
    let algos_v = eval_algos();
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, Record)>> = Mutex::new(Vec::with_capacity(specs.len()));
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    std::thread::scope(|s| {
        for _ in 0..workers.min(specs.len().max(1)) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= specs.len() {
                    break;
                }
                let spec = &specs[i];
                let coo = spec.generate();
                let profile = MatrixProfile::compute(&coo);
                let mut cells = Vec::with_capacity(machines.len() * ns.len() * algos_v.len());
                for m in &machines {
                    for &n in ns {
                        for &algo in &algos_v {
                            let pred = algos::predict(algo, &profile, n, m);
                            cells.push(Cell {
                                machine: m.name,
                                n,
                                algo,
                                gflops: pred.gflops,
                                time_s: pred.time_s,
                            });
                        }
                    }
                }
                let rec = Record {
                    name: spec.name.clone(),
                    family: spec.family_name(),
                    rows: coo.rows,
                    nnz: coo.nnz(),
                    alpha: profile.hrpb.alpha,
                    beta: profile.hrpb.beta,
                    synergy: profile.synergy(),
                    cells,
                };
                results.lock().unwrap().push((i, rec));
            });
        }
    });

    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Table 2: synergy class counts.
pub fn synergy_counts(records: &[Record]) -> [(Synergy, usize); 3] {
    let mut counts = [(Synergy::Low, 0), (Synergy::Medium, 0), (Synergy::High, 0)];
    for r in records {
        for c in counts.iter_mut() {
            if c.0 == r.synergy {
                c.1 += 1;
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_corpus_runs_end_to_end() {
        // tiny slice of the corpus for speed
        let all = specs(CorpusScale::Quick, 42);
        let slice = &all[..6.min(all.len())];
        let recs = run_specs(slice, &[32, 128]);
        assert_eq!(recs.len(), slice.len());
        for r in &recs {
            assert_eq!(r.cells.len(), 2 * 2 * 6); // machines x ns x algos
            assert!(r.get("A100", 128, Algo::Hrpb).unwrap().gflops > 0.0);
            assert!(r.best_sc("A100", 128).is_some());
        }
    }

    #[test]
    fn order_matches_specs() {
        let all = specs(CorpusScale::Quick, 42);
        let slice = &all[..4.min(all.len())];
        let recs = run_specs(slice, &[32]);
        for (s, r) in slice.iter().zip(&recs) {
            assert_eq!(s.name, r.name);
        }
    }

    #[test]
    fn synergy_counts_sum() {
        let all = specs(CorpusScale::Quick, 42);
        let slice = &all[..5.min(all.len())];
        let recs = run_specs(slice, &[32]);
        let counts = synergy_counts(&recs);
        let total: usize = counts.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, recs.len());
    }
}
