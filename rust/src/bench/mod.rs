//! Experiment harness — shared by `benches/*.rs` and the CLI's `experiment`
//! subcommand. `corpus_run` produces the per-matrix prediction records;
//! `experiments` renders each paper table/figure; `render` provides the
//! ASCII tables/box plots/heatmaps and CSV output; `harness` is the perf
//! observatory: declarative suite specs, versioned results history under
//! `results/history/`, and the diff engine behind the CI regression gate.

pub mod corpus_run;
pub mod experiments;
pub mod harness;
pub mod render;

pub use corpus_run::{Cell, Record};
