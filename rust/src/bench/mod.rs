//! Experiment harness — shared by `benches/*.rs` and the CLI's `experiment`
//! subcommand. `corpus_run` produces the per-matrix prediction records;
//! `experiments` renders each paper table/figure; `render` provides the
//! ASCII tables/box plots/heatmaps and CSV output.

pub mod corpus_run;
pub mod experiments;
pub mod render;

pub use corpus_run::{Cell, Record};
