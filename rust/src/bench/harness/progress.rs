//! Per-cell progress lines for long suite runs. The full-scale suites
//! take minutes per cell; without progress, `experiment all` is a silent
//! wall. Lines go to **stderr** — the drivers' stdout reports (and the
//! `BENCH_*.json` side effects) stay byte-identical.

use std::time::Instant;

/// Suite-scoped progress reporter: created when a measurement core
/// starts, announced once per cell as it begins.
pub struct Progress {
    suite: &'static str,
    total: usize,
    t0: Instant,
}

impl Progress {
    pub fn start(suite: &'static str, total: usize) -> Progress {
        Progress { suite, total, t0: Instant::now() }
    }

    /// Announce cell `i` (0-based) as it starts, with the suite's elapsed
    /// wall time so a stalled cell is distinguishable from a slow one.
    pub fn cell(&self, i: usize, key: &str) {
        eprintln!(
            "[{} {}/{}] {key} ({:.1}s elapsed)",
            self.suite,
            i + 1,
            self.total,
            self.t0.elapsed().as_secs_f64()
        );
    }
}
