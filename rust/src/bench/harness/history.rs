//! Append-only run history under `results/history/`, plus the accepted
//! baseline pointer the CI regression gate diffs against.
//!
//! Layout:
//!
//! ```text
//! results/history/
//!   r1754650000-01234.json   one ResultsFile per run (never rewritten)
//!   r1754653600-01240.json
//!   ACCEPTED                 run id of the accepted baseline (one line)
//! ```
//!
//! Run ids sort lexicographically by creation time (zero-padded unix
//! seconds), so "latest" and "previous" are just neighbors in the sorted
//! listing. [`baseline_for`] prefers the explicitly accepted run, falling
//! back to the entry immediately before the current one.

use super::results::{parse_results, ResultsFile};
use crate::bench::experiments;
use std::path::{Path, PathBuf};

/// Name of the accepted-baseline pointer file inside the history dir.
const ACCEPTED_FILE: &str = "ACCEPTED";

/// Where history entries live (under the active results dir, so
/// `--out-dir`/`CUTESPMM_RESULTS_DIR` relocate the history too).
pub fn history_dir() -> PathBuf {
    experiments::results_dir().join("history")
}

/// Sortable run id: zero-padded unix seconds plus the pid as a same-second
/// tiebreaker.
pub fn make_run_id(created_unix: u64) -> String {
    format!("r{created_unix:010}-{:05}", std::process::id() % 100_000)
}

/// Persist a run as a new history entry. Append-only: refuses to overwrite
/// an existing entry for the same run id.
pub fn append(file: &ResultsFile) -> Result<PathBuf, String> {
    let dir = history_dir();
    std::fs::create_dir_all(&dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
    let path = dir.join(format!("{}.json", file.run_id));
    if path.exists() {
        return Err(format!("history entry {} already exists (append-only)", path.display()));
    }
    std::fs::write(&path, file.to_json().to_string())
        .map_err(|e| format!("write {}: {e}", path.display()))?;
    Ok(path)
}

/// All run ids in the history, sorted ascending (oldest first).
pub fn list() -> Vec<String> {
    let mut ids = Vec::new();
    let Ok(entries) = std::fs::read_dir(history_dir()) else {
        return ids;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(id) = name.strip_suffix(".json") {
            ids.push(id.to_string());
        }
    }
    ids.sort();
    ids
}

/// The most recent run id, if any.
pub fn latest() -> Option<String> {
    list().pop()
}

/// Load a run by id.
pub fn load(id: &str) -> Result<ResultsFile, String> {
    load_path(&history_dir().join(format!("{id}.json")))
}

/// Load a results document from an arbitrary path (schema-v1 or a legacy
/// `BENCH_PR*.json` record wrapped as a one-suite run).
pub fn load_path(path: &Path) -> Result<ResultsFile, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    parse_results(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// The accepted baseline's run id, if one was recorded and still exists.
pub fn accepted_id() -> Option<String> {
    let id = std::fs::read_to_string(history_dir().join(ACCEPTED_FILE)).ok()?;
    let id = id.trim().to_string();
    if id.is_empty() {
        return None;
    }
    Some(id)
}

/// Record `id` as the accepted baseline. The entry must exist.
pub fn accept(id: &str) -> Result<PathBuf, String> {
    let entry = history_dir().join(format!("{id}.json"));
    if !entry.exists() {
        return Err(format!("no history entry {}", entry.display()));
    }
    let path = history_dir().join(ACCEPTED_FILE);
    std::fs::write(&path, format!("{id}\n")).map_err(|e| format!("write {}: {e}", path.display()))?;
    Ok(path)
}

/// The baseline to diff `current_id` against: the accepted run when one is
/// recorded (diffing a run against itself is the deterministic clean pass
/// CI relies on), else the history entry immediately before `current_id`,
/// else none (first run ever — nothing to gate against).
pub fn baseline_for(current_id: &str) -> Option<String> {
    if let Some(id) = accepted_id() {
        return Some(id);
    }
    list().into_iter().rev().find(|id| id.as_str() < current_id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_ids_sort_lexicographically_by_creation_time() {
        let early = make_run_id(5);
        let late = make_run_id(1_754_650_000);
        assert!(early < late, "{early} vs {late}");
        assert!(early.starts_with("r0000000005-"));
        // same-second ids from the same process collide by design (one
        // entry per run id is what append-only enforces)
        assert_eq!(make_run_id(7), make_run_id(7));
    }
}
