//! Suite adapters: run each experiment driver's measurement core, render
//! the *existing* report (byte-identical stdout and `BENCH_*.json`
//! side-effects — the drivers keep writing those), and additionally
//! project the outcomes into the harness's [`SuiteResult`] model with a
//! fresh [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot) folded
//! over the cell timings.
//!
//! The headline values recomputed here use the same formulas as the
//! report renderers (geomean over the same subset, same guards), so the
//! number printed in the report and the number the regression gate
//! defends cannot disagree.

use super::results::{CellResult, Direction, Headline, Slip, SuiteResult};
use super::spec::{suite_spec, SuiteSpec};
use crate::bench::corpus_run::Record;
use crate::bench::experiments;
use crate::coordinator::Metrics;
use crate::spmm::Algo;
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// One executed suite: the harness-model result plus the driver's
/// rendered report (printed by the CLI exactly as before).
pub struct SuiteRun {
    pub result: SuiteResult,
    pub report: String,
}

/// Relative slip threshold (percent) for geomean-style headlines — the
/// CI gate's ">10% geomean slip" contract.
pub const DEFAULT_SLIP_PCT: f64 = 10.0;

/// Run one suite by name. `records` feeds the `auto` suite (so
/// `experiment all` shares one corpus run across consumers); the other
/// suites ignore it.
pub fn run_suite(name: &str, quick: bool, records: Option<&[Record]>) -> Result<SuiteRun, String> {
    let spec = suite_spec(name).ok_or_else(|| format!("unknown suite '{name}'"))?;
    match name {
        "exec" => Ok(run_exec(spec, quick)),
        "reorder" => Ok(run_reorder(spec, quick)),
        "geometry" => Ok(run_geometry(spec, quick)),
        "qos" => Ok(run_qos(spec, quick)),
        "trace" => Ok(run_trace(spec, quick)),
        "chaos" => Ok(run_chaos(spec, quick)),
        "load" => Ok(run_load(spec, quick)),
        "prep" => Ok(run_prep(spec, quick)),
        "auto" => {
            let records = records.ok_or("the auto suite needs corpus records")?;
            Ok(run_auto(spec, quick, records))
        }
        other => Err(format!("suite '{other}' has no harness adapter")),
    }
}

/// Geomean with the report renderers' convention: NAN on an empty set
/// (the results model then sanitizes NAN to 0.0 on serialization).
fn geomean_or_nan(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        crate::util::stats::geomean(xs)
    }
}

/// Fold the suite's comparable cell timings through a fresh [`Metrics`]
/// so every history entry carries the same latency/lane snapshot shape
/// the serve path exports. `store` mirrors the suite-run preprocessing
/// cache's hit counters ([`super::cache::SuiteCache`]) into the snapshot's
/// `artifacts` section.
fn fold_metrics(
    cells: &[CellResult],
    route: bool,
    store: Option<crate::hrpb::StoreStats>,
) -> Json {
    let m = Metrics::default();
    for c in cells {
        if !c.time_s.is_finite() || c.time_s <= 0.0 {
            continue;
        }
        let dur = Duration::from_secs_f64(c.time_s);
        m.request_latency.record(dur);
        m.exec_latency.record(dur);
        m.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        m.responses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if route {
            m.record_route(Algo::Hrpb.index(), 1, dur, 0.0);
        }
    }
    if let Some(s) = store {
        m.sync_artifacts(s);
    }
    m.snapshot().to_json()
}

fn make_result(
    spec: &SuiteSpec,
    quick: bool,
    wall_s: f64,
    headlines: Vec<Headline>,
    cells: Vec<CellResult>,
    route: bool,
) -> SuiteResult {
    make_result_with_store(spec, quick, wall_s, headlines, cells, route, None)
}

fn make_result_with_store(
    spec: &SuiteSpec,
    quick: bool,
    wall_s: f64,
    headlines: Vec<Headline>,
    cells: Vec<CellResult>,
    route: bool,
    store: Option<crate::hrpb::StoreStats>,
) -> SuiteResult {
    let metrics = fold_metrics(&cells, route, store);
    SuiteResult {
        suite: spec.name.to_string(),
        title: spec.title.to_string(),
        wall_s,
        spec: spec.to_json(quick),
        headlines,
        cells,
        metrics,
    }
}

fn run_exec(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let specs = experiments::exec_specs(quick);
    let outcomes = experiments::exec_outcomes_for(&specs, spec.widths, spec.reps(quick));
    let report = experiments::exec_report(&outcomes);
    let speedups_256: Vec<f64> =
        outcomes.iter().filter(|o| o.n == 256).map(|o| o.speedup()).collect();
    let headlines = vec![Headline {
        key: "geomean_speedup_n256".to_string(),
        value: geomean_or_nan(&speedups_256),
        unit: "x".to_string(),
        direction: Direction::HigherIsBetter,
        slip: Slip::RelativePct(DEFAULT_SLIP_PCT),
        floor: Some(1.3),
    }];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: format!("{}/N={}", o.matrix, o.n),
            time_s: o.pooled_blocked_s,
            value: o.speedup(),
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, true),
        report,
    }
}

fn run_reorder(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let outcomes = experiments::reorder_outcomes_for(
        &experiments::reorder_specs(quick),
        spec.widths[0],
        spec.reps(quick),
    );
    let report = experiments::reorder_report(&outcomes);
    let lowmed: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.family == "scattered" || o.family == "community")
        .map(|o| o.speedup())
        .collect();
    let headlines = vec![Headline {
        key: "geomean_speedup_lowmed".to_string(),
        value: geomean_or_nan(&lowmed),
        unit: "x".to_string(),
        direction: Direction::HigherIsBetter,
        slip: Slip::RelativePct(DEFAULT_SLIP_PCT),
        floor: Some(1.2),
    }];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: format!("{}/{}", o.family, o.matrix),
            time_s: o.reordered_s,
            value: o.speedup(),
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, true),
        report,
    }
}

fn run_geometry(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    // one preprocessing cache for the whole suite run: cells whose
    // planner-picked shape coincides with the fixed 16x4 build serve the
    // HRPB from the artifact instead of rebuilding
    let cache = super::cache::SuiteCache::open("geometry");
    let outcomes = experiments::geometry_outcomes_for(
        &experiments::geometry_specs(quick),
        spec.widths[0],
        spec.reps(quick),
        cache.as_ref(),
    );
    let report = experiments::geometry_report(&outcomes);
    let unstructured: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.family == "scattered" || o.family == "powerlaw")
        .map(|o| o.speedup())
        .collect();
    let headlines = vec![Headline {
        key: "geomean_speedup_unstructured".to_string(),
        value: geomean_or_nan(&unstructured),
        unit: "x".to_string(),
        direction: Direction::HigherIsBetter,
        slip: Slip::RelativePct(DEFAULT_SLIP_PCT),
        floor: Some(1.0),
    }];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: format!("{}/{}", o.family, o.matrix),
            time_s: o.picked_s,
            value: o.speedup(),
        })
        .collect();
    SuiteRun {
        result: make_result_with_store(
            spec,
            quick,
            t0.elapsed().as_secs_f64(),
            headlines,
            cells,
            true,
            cache.as_ref().map(|c| c.stats()),
        ),
        report,
    }
}

fn run_qos(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let outcomes = experiments::qos_saturation_outcomes();
    let report = experiments::qos_report(&outcomes);
    let qos_p99 = outcomes
        .iter()
        .find(|o| o.policy == "qos")
        .map(|o| o.p99_wait_ms)
        .unwrap_or(f64::NAN);
    let headlines = vec![Headline {
        key: "qos_p99_wait_ms".to_string(),
        value: qos_p99,
        unit: "ms".to_string(),
        direction: Direction::LowerIsBetter,
        slip: Slip::RelativePct(DEFAULT_SLIP_PCT),
        floor: None,
    }];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: o.policy.to_string(),
            time_s: o.p99_wait_ms / 1e3,
            value: o.completed as f64,
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, false),
        report,
    }
}

fn run_trace(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let outcomes = experiments::trace_outcomes(quick);
    let report = experiments::trace_report(&outcomes);
    // Same formulas as trace_report: off-mode overhead vs the untraced
    // baseline, full-mode span-vs-engine-lane reconciliation.
    let baseline_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.req_per_s)
        .unwrap_or(f64::NAN);
    let overhead_off_pct = outcomes
        .iter()
        .find(|o| o.mode == "off")
        .map(|o| 100.0 * (baseline_rps - o.req_per_s) / baseline_rps.max(1e-9))
        .unwrap_or(f64::NAN);
    let reconcile_pct = outcomes
        .iter()
        .find(|o| o.mode == "full" && o.observed_us > 0)
        .map(|o| {
            100.0 * (o.exec_span_us as f64 - o.observed_us as f64).abs() / o.observed_us as f64
        })
        .unwrap_or(0.0);
    let headlines = vec![
        Headline {
            key: "overhead_off_pct".to_string(),
            value: overhead_off_pct,
            unit: "%".to_string(),
            direction: Direction::LowerIsBetter,
            slip: Slip::AbsolutePoints(2.0),
            floor: Some(2.0),
        },
        Headline {
            key: "exec_reconcile_pct".to_string(),
            value: reconcile_pct,
            unit: "%".to_string(),
            direction: Direction::LowerIsBetter,
            slip: Slip::AbsolutePoints(5.0),
            floor: Some(5.0),
        },
    ];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: o.mode.to_string(),
            time_s: o.wall_s,
            value: o.req_per_s,
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, false),
        report,
    }
}

fn run_chaos(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let outcomes = experiments::chaos_outcomes(quick);
    let report = experiments::chaos_report(&outcomes);
    // Same formulas as chaos_report: the kernel-panic mode's post-fault
    // clean-matrix throughput gap vs the baseline mode, and the total
    // no-lost-response count across every mode.
    let baseline_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.recovered_rps)
        .unwrap_or(f64::NAN);
    let recovery_gap_pct = outcomes
        .iter()
        .find(|o| o.mode == "kernel_panic")
        .map(|o| 100.0 * (baseline_rps - o.recovered_rps) / baseline_rps.max(1e-9))
        .unwrap_or(f64::NAN);
    let lost: u64 = outcomes.iter().map(|o| o.lost as u64).sum();
    let headlines = vec![
        Headline {
            key: "recovery_gap_pct".to_string(),
            value: recovery_gap_pct,
            unit: "%".to_string(),
            direction: Direction::LowerIsBetter,
            slip: Slip::AbsolutePoints(5.0),
            floor: Some(10.0),
        },
        Headline {
            key: "lost_responses".to_string(),
            value: lost as f64,
            unit: "".to_string(),
            direction: Direction::LowerIsBetter,
            slip: Slip::AbsolutePoints(0.5),
            floor: Some(0.5),
        },
    ];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: o.mode.to_string(),
            time_s: o.wall_s,
            value: o.recovered_rps,
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, false),
        report,
    }
}

fn run_load(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let outcomes = experiments::load_outcomes(quick);
    let report = experiments::load_report(&outcomes);
    // Same formulas as load_report: baseline sustained throughput, the
    // shard-kill mode's live-shard recovery gap vs the baseline mode, and
    // the exactly-once violation count (lost + duplicated) over all modes.
    let sustained_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.sustained_rps)
        .unwrap_or(f64::NAN);
    let baseline_rps = outcomes
        .iter()
        .find(|o| o.mode == "baseline")
        .map(|o| o.recovered_rps)
        .unwrap_or(f64::NAN);
    let kill_gap_pct = outcomes
        .iter()
        .find(|o| o.mode == "shard_kill")
        .map(|o| 100.0 * (baseline_rps - o.recovered_rps) / baseline_rps.max(1e-9))
        .unwrap_or(f64::NAN);
    let violations: u64 = outcomes.iter().map(|o| o.lost + o.duplicates).sum();
    let headlines = vec![
        Headline {
            key: "sustained_rps".to_string(),
            value: sustained_rps,
            unit: "req/s".to_string(),
            direction: Direction::HigherIsBetter,
            slip: Slip::RelativePct(DEFAULT_SLIP_PCT),
            floor: None,
        },
        Headline {
            key: "kill_gap_pct".to_string(),
            value: kill_gap_pct,
            unit: "%".to_string(),
            direction: Direction::LowerIsBetter,
            slip: Slip::AbsolutePoints(5.0),
            floor: Some(10.0),
        },
        Headline {
            key: "lost_or_duplicated".to_string(),
            value: violations as f64,
            unit: "".to_string(),
            direction: Direction::LowerIsBetter,
            slip: Slip::AbsolutePoints(0.5),
            floor: Some(0.5),
        },
    ];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: o.mode.to_string(),
            time_s: o.wall_s,
            value: o.sustained_rps,
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, false),
        report,
    }
}

fn run_prep(spec: &SuiteSpec, quick: bool) -> SuiteRun {
    let t0 = Instant::now();
    let dir = std::env::temp_dir().join(format!("cutespmm_harness_prep_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let outcomes = experiments::prep_outcomes(&dir);
    let report = experiments::prep_report(&outcomes);
    let _ = std::fs::remove_dir_all(&dir);
    let cold: f64 = outcomes.iter().map(|o| o.cold_register_s).sum();
    let warm: f64 = outcomes.iter().map(|o| o.warm_register_s).sum();
    let headlines = vec![Headline {
        key: "warm_speedup".to_string(),
        value: cold / warm.max(1e-12),
        unit: "x".to_string(),
        direction: Direction::HigherIsBetter,
        // Warm-path timings are tiny (µs scale) and noisy on shared
        // runners; gate with a generous relative band.
        slip: Slip::RelativePct(50.0),
        floor: Some(5.0),
    }];
    let cells = outcomes
        .iter()
        .map(|o| CellResult {
            key: o.matrix.clone(),
            time_s: o.warm_register_s,
            value: o.cold_register_s / o.warm_register_s.max(1e-12),
        })
        .collect();
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, false),
        report,
    }
}

fn run_auto(spec: &SuiteSpec, quick: bool, records: &[Record]) -> SuiteRun {
    let t0 = Instant::now();
    let report = experiments::auto_policy(records);
    let headline_summary = experiments::auto_policy_summary(records, "A100", 128);
    let headlines = vec![Headline {
        key: "auto_vs_oracle".to_string(),
        value: headline_summary
            .map(|s| s.auto_gflops / s.oracle_gflops.max(1e-12))
            .unwrap_or(0.0),
        unit: "x".to_string(),
        direction: Direction::HigherIsBetter,
        slip: Slip::RelativePct(DEFAULT_SLIP_PCT),
        floor: None,
    }];
    let mut cells = Vec::new();
    for machine in spec.families {
        for &n in spec.widths {
            if let Some(s) = experiments::auto_policy_summary(records, machine, n) {
                cells.push(CellResult {
                    key: format!("{machine}/N={n}"),
                    // Modeled throughput, not a wall-clock measurement —
                    // 0.0 keeps it out of the timing geomean.
                    time_s: 0.0,
                    value: s.auto_gflops,
                });
            }
        }
    }
    SuiteRun {
        result: make_result(spec, quick, t0.elapsed().as_secs_f64(), headlines, cells, false),
        report,
    }
}
