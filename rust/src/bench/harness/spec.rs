//! Declarative experiment specs: each suite states its engines, corpus
//! families, dense widths, and repetition counts up front. The runner
//! echoes the spec into every history entry so a result is always
//! reproducible from its own record.
//!
//! The grids are pinned to what the original drivers in
//! [`crate::bench::experiments`] measure — the harness adapters reuse the
//! drivers' measurement cores, so the spec is documentation-with-teeth:
//! it is serialized with the results, not a second source of truth that
//! can drift silently.

use crate::bench::experiments;
use crate::util::json::Json;

/// Static description of one experiment suite's grid.
#[derive(Clone, Copy, Debug)]
pub struct SuiteSpec {
    pub name: &'static str,
    pub title: &'static str,
    /// Engine/policy/mode axis (what each cell's timing compares).
    pub engines: &'static [&'static str],
    /// Corpus family axis (matrix generators, machines for `auto`).
    pub families: &'static [&'static str],
    /// Dense-side width axis (empty where width is not a variable).
    pub widths: &'static [usize],
    pub reps_full: usize,
    pub reps_quick: usize,
}

impl SuiteSpec {
    /// Repetitions (or request count, for the trace suite) at this tier.
    pub fn reps(&self, quick: bool) -> usize {
        if quick {
            self.reps_quick
        } else {
            self.reps_full
        }
    }

    /// Spec echo serialized into the suite's history entry.
    pub fn to_json(&self, quick: bool) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("title", Json::str(self.title)),
            ("engines", Json::arr(self.engines.iter().map(|e| Json::str(*e)))),
            ("families", Json::arr(self.families.iter().map(|f| Json::str(*f)))),
            ("widths", Json::arr(self.widths.iter().map(|w| Json::num(*w as f64)))),
            ("reps", Json::num(self.reps(quick) as f64)),
            ("quick", Json::Bool(quick)),
        ])
    }
}

/// Every suite the harness can run, in `experiment all` execution order.
pub static SUITES: [SuiteSpec; 9] = [
    SuiteSpec {
        name: "exec",
        title: "zero-allocation blocked runtime vs spawn-per-call",
        engines: &["spawn-unblocked", "spawn-blocked", "pooled-unblocked", "pooled-blocked"],
        families: &["exec-fem", "exec-mesh", "exec-rmat"],
        widths: &experiments::EXEC_WIDTHS,
        reps_full: 5,
        reps_quick: 3,
    },
    SuiteSpec {
        name: "reorder",
        title: "similarity-clustered HRPB packing vs arrival order",
        engines: &["original", "reordered"],
        families: &["scattered", "community", "banded", "rmat"],
        widths: &[128],
        reps_full: 5,
        reps_quick: 3,
    },
    SuiteSpec {
        name: "geometry",
        title: "planner-picked brick geometry vs fixed 16x4",
        engines: &["fixed-16x4", "planner-picked"],
        families: &["scattered", "powerlaw", "blockdense"],
        widths: &[128],
        reps_full: 5,
        reps_quick: 3,
    },
    SuiteSpec {
        name: "qos",
        title: "bounded priority admission vs baselines under saturation",
        engines: &["unbounded", "reject-on-full", "qos"],
        families: &["sim-trace"],
        widths: &[],
        reps_full: 4000,
        reps_quick: 4000,
    },
    SuiteSpec {
        name: "trace",
        title: "observability overhead: off / sampled / full vs untraced",
        engines: &["baseline", "off", "sampled", "full"],
        families: &["trace-banded"],
        widths: &[16],
        reps_full: 768,
        reps_quick: 192,
    },
    SuiteSpec {
        name: "chaos",
        title: "fault injection: containment, breakers, quarantine, recovery",
        engines: &[
            "baseline",
            "kernel_panic",
            "fallback_panic",
            "artifact_io",
            "checksum_flip",
            "slow_exec",
        ],
        families: &["chaos-banded"],
        widths: &[16],
        reps_full: 384,
        reps_quick: 160,
    },
    SuiteSpec {
        name: "load",
        title: "closed-loop load vs the shard router: throughput, tails, failover",
        engines: &["baseline", "saturation", "shard_kill", "net_stall", "net_drop"],
        families: &["shard-loop"],
        widths: &[8],
        reps_full: 6144,
        reps_quick: 512,
    },
    SuiteSpec {
        name: "prep",
        title: "persistent HRPB artifacts: cold vs warm registration",
        engines: &["serial", "parallel", "cold", "warm"],
        families: &["prep-fem", "prep-mesh", "prep-rmat", "prep-banded-sparse"],
        widths: &[],
        reps_full: 1,
        reps_quick: 1,
    },
    SuiteSpec {
        name: "auto",
        title: "synergy-driven engine selection vs fixed policies (modeled)",
        engines: &["auto", "oracle", "hrpb-always", "best-sc-always", "tcgnn-always"],
        families: &["A100", "RTX-4090"],
        widths: &[32, 128, 512],
        reps_full: 1,
        reps_quick: 1,
    },
];

/// Look up a suite spec by name.
pub fn suite_spec(name: &str) -> Option<&'static SuiteSpec> {
    SUITES.iter().find(|s| s.name == name)
}
