//! Perf observatory: declarative experiment harness with persistent
//! results history and regression gating.
//!
//! Pieces, in dependency order:
//!
//! - [`spec`] — declarative suite grids (engines × families × widths ×
//!   reps), echoed verbatim into every result so runs are self-describing.
//! - [`results`] — the versioned on-disk model ([`results::ResultsFile`],
//!   schema v1) plus a legacy loader that lifts the pre-harness
//!   `BENCH_PR*.json` records into one-suite runs.
//! - [`suites`] — adapters that run the existing experiment drivers'
//!   measurement cores, keep their reports and `BENCH_*.json` artifacts
//!   byte-identical, and project the outcomes into the model with a
//!   `MetricsSnapshot` per suite.
//! - [`cache`] — per-suite-run artifact cache: cells that revisit a
//!   (matrix, geometry) pair serve the HRPB from the store instead of
//!   rebuilding; hit counters land in the suite's `MetricsSnapshot`.
//! - [`progress`] — per-cell stderr progress lines (suite, cell index,
//!   elapsed), keeping long runs observable without touching stdout.
//! - [`runner`] — stamps executed suites with run id / git rev / flags.
//! - [`history`] — append-only entries under `results/history/` and the
//!   `ACCEPTED` baseline pointer.
//! - [`diff`] — compares a run against a baseline per accepted headline
//!   with configurable slip thresholds; powers `cutespmm experiment
//!   diff` and the CI regression gate (including the `--inject-slip`
//!   gate self-test).

pub mod cache;
pub mod diff;
pub mod history;
pub mod progress;
pub mod results;
pub mod runner;
pub mod spec;
pub mod suites;

pub use cache::SuiteCache;
pub use diff::{diff, inject_slip, DiffReport};
pub use progress::Progress;
pub use results::{parse_results, ResultsFile, SuiteResult};
pub use runner::collect;
pub use spec::{suite_spec, SUITES};
pub use suites::{run_suite, SuiteRun};
