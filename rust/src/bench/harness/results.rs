//! The versioned results model: what one harness run records, and how it
//! round-trips through `util::json`.
//!
//! Schema `v1` is one JSON document per run: run identity (id, creation
//! time, git revision, CLI flags), then one [`SuiteResult`] per suite with
//! its declarative spec echo, headline metrics (each carrying its
//! comparison direction and slip threshold, so the diff engine needs no
//! out-of-band table), per-cell timings, and the suite's
//! [`crate::coordinator::metrics::MetricsSnapshot`] JSON.
//!
//! Pre-harness `BENCH_PR4/5/6/8/9.json` records load through
//! [`suite_from_legacy`], so `experiment diff` can baseline against
//! history written before the observatory existed.

use crate::util::json::{parse, Json};

/// Results-file schema version; bump on incompatible shape changes.
pub const SCHEMA_VERSION: u64 = 1;

/// Document discriminator, so a stray JSON file is rejected with a clear
/// error instead of a missing-key cascade.
pub const KIND: &str = "cutespmm_results";

/// Non-finite timings would serialize as invalid JSON (`NaN` has no JSON
/// spelling); 0.0 is the model's "not comparable" sentinel throughout.
pub fn sanitize(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// Which way a headline metric improves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
}

impl Direction {
    pub fn name(&self) -> &'static str {
        match self {
            Direction::HigherIsBetter => "higher",
            Direction::LowerIsBetter => "lower",
        }
    }

    pub fn parse(s: &str) -> Option<Direction> {
        match s {
            "higher" => Some(Direction::HigherIsBetter),
            "lower" => Some(Direction::LowerIsBetter),
            _ => None,
        }
    }
}

/// How much a headline may move against its direction before the diff
/// engine calls it a regression.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Slip {
    /// Relative threshold in percent (the CI gate's >10% geomean slip).
    RelativePct(f64),
    /// Absolute threshold in the headline's own unit — for metrics that
    /// live near zero (overhead %), where a relative threshold is noise.
    AbsolutePoints(f64),
}

impl Slip {
    pub fn to_json(&self) -> Json {
        let (kind, value) = match self {
            Slip::RelativePct(v) => ("relative_pct", *v),
            Slip::AbsolutePoints(v) => ("absolute_points", *v),
        };
        Json::obj(vec![("kind", Json::str(kind)), ("value", Json::num(sanitize(value)))])
    }

    pub fn from_json(j: &Json) -> Option<Slip> {
        let value = j.get("value")?.as_f64()?;
        match j.get("kind")?.as_str()? {
            "relative_pct" => Some(Slip::RelativePct(value)),
            "absolute_points" => Some(Slip::AbsolutePoints(value)),
            _ => None,
        }
    }
}

/// One accepted headline metric of a suite — the numbers the regression
/// gate defends.
#[derive(Clone, Debug)]
pub struct Headline {
    pub key: String,
    pub value: f64,
    /// Display unit ("x", "%", "ms").
    pub unit: String,
    pub direction: Direction,
    pub slip: Slip,
    /// The driver's printed acceptance bound, when it has one (a floor for
    /// higher-is-better headlines, a ceiling for lower-is-better ones).
    pub floor: Option<f64>,
}

impl Headline {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(self.key.clone())),
            ("value", Json::num(sanitize(self.value))),
            ("unit", Json::str(self.unit.clone())),
            ("direction", Json::str(self.direction.name())),
            ("slip", self.slip.to_json()),
            (
                "floor",
                match self.floor {
                    Some(f) => Json::num(sanitize(f)),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Headline> {
        Some(Headline {
            key: j.get("key")?.as_str()?.to_string(),
            value: j.get("value")?.as_f64()?,
            unit: j.get("unit")?.as_str()?.to_string(),
            direction: Direction::parse(j.get("direction")?.as_str()?)?,
            slip: Slip::from_json(j.get("slip")?)?,
            floor: j.get("floor").and_then(|f| f.as_f64()),
        })
    }
}

/// One cell of a suite's grid: a stable key (matrix/width/mode) plus its
/// primary lower-is-better timing and the driver's headline value for the
/// cell. `time_s == 0.0` marks a cell with no comparable timing (modeled
/// throughput, declined activation) — the diff engine skips it.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub key: String,
    pub time_s: f64,
    pub value: f64,
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(self.key.clone())),
            ("time_s", Json::num(sanitize(self.time_s))),
            ("value", Json::num(sanitize(self.value))),
        ])
    }

    pub fn from_json(j: &Json) -> Option<CellResult> {
        Some(CellResult {
            key: j.get("key")?.as_str()?.to_string(),
            time_s: j.get("time_s")?.as_f64()?,
            value: j.get("value")?.as_f64()?,
        })
    }
}

/// One suite's results inside a run.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub suite: String,
    pub title: String,
    /// Wall time the whole suite took (measure + render).
    pub wall_s: f64,
    /// Echo of the declarative spec the suite ran under.
    pub spec: Json,
    pub headlines: Vec<Headline>,
    pub cells: Vec<CellResult>,
    /// The suite's `MetricsSnapshot` JSON (latency percentiles over the
    /// cell timings, engine lanes, trace counters).
    pub metrics: Json,
}

impl SuiteResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            ("title", Json::str(self.title.clone())),
            ("wall_s", Json::num(sanitize(self.wall_s))),
            ("spec", self.spec.clone()),
            ("headlines", Json::arr(self.headlines.iter().map(|h| h.to_json()))),
            ("cells", Json::arr(self.cells.iter().map(|c| c.to_json()))),
            ("metrics", self.metrics.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<SuiteResult> {
        let headlines =
            j.get("headlines")?.as_arr()?.iter().map(Headline::from_json).collect::<Option<_>>()?;
        let cells =
            j.get("cells")?.as_arr()?.iter().map(CellResult::from_json).collect::<Option<_>>()?;
        Some(SuiteResult {
            suite: j.get("suite")?.as_str()?.to_string(),
            title: j.get("title")?.as_str()?.to_string(),
            wall_s: j.get("wall_s")?.as_f64()?,
            spec: j.get("spec").cloned().unwrap_or(Json::Null),
            headlines,
            cells,
            metrics: j.get("metrics").cloned().unwrap_or(Json::Null),
        })
    }
}

/// One run of the harness: identity plus every suite's results. Persisted
/// append-only under `results/history/<run_id>.json`.
#[derive(Clone, Debug)]
pub struct ResultsFile {
    pub schema: u64,
    pub run_id: String,
    /// Unix seconds at collection time.
    pub created_unix: u64,
    /// `git rev-parse --short HEAD`, or "unknown" outside a checkout.
    pub git_rev: String,
    /// The CLI argv the run was invoked with.
    pub flags: Vec<String>,
    pub quick: bool,
    pub host_threads: usize,
    pub suites: Vec<SuiteResult>,
}

impl ResultsFile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(KIND)),
            ("schema", Json::num(self.schema as f64)),
            ("run_id", Json::str(self.run_id.clone())),
            ("created_unix", Json::num(self.created_unix as f64)),
            ("git_rev", Json::str(self.git_rev.clone())),
            ("flags", Json::arr(self.flags.iter().map(|f| Json::str(f.clone())))),
            ("quick", Json::Bool(self.quick)),
            ("host_threads", Json::num(self.host_threads as f64)),
            ("suites", Json::arr(self.suites.iter().map(|s| s.to_json()))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ResultsFile, String> {
        if j.get("kind").and_then(|k| k.as_str()) != Some(KIND) {
            return Err(format!("not a {KIND} document"));
        }
        let schema = j
            .get("schema")
            .and_then(|s| s.as_f64())
            .ok_or_else(|| "missing schema version".to_string())? as u64;
        if schema > SCHEMA_VERSION {
            return Err(format!("schema v{schema} is newer than this binary (v{SCHEMA_VERSION})"));
        }
        let suites = j
            .get("suites")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| "missing suites".to_string())?
            .iter()
            .map(SuiteResult::from_json)
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| "malformed suite entry".to_string())?;
        Ok(ResultsFile {
            schema,
            run_id: j
                .get("run_id")
                .and_then(|s| s.as_str())
                .ok_or_else(|| "missing run_id".to_string())?
                .to_string(),
            created_unix: j.get("created_unix").and_then(|n| n.as_f64()).unwrap_or(0.0) as u64,
            git_rev: j
                .get("git_rev")
                .and_then(|s| s.as_str())
                .unwrap_or("unknown")
                .to_string(),
            flags: j
                .get("flags")
                .and_then(|f| f.as_arr())
                .map(|a| a.iter().filter_map(|f| f.as_str().map(str::to_string)).collect())
                .unwrap_or_default(),
            quick: j.get("quick").and_then(|b| b.as_bool()).unwrap_or(false),
            host_threads: j.get("host_threads").and_then(|n| n.as_usize()).unwrap_or(0),
            suites,
        })
    }

    /// Find a suite by name.
    pub fn suite(&self, name: &str) -> Option<&SuiteResult> {
        self.suites.iter().find(|s| s.suite == name)
    }
}

/// Parse a results document from text: schema-v1 first, else a single
/// legacy `BENCH_PR*.json` record wrapped as a one-suite run.
pub fn parse_results(text: &str) -> Result<ResultsFile, String> {
    let doc = parse(text)?;
    if doc.get("kind").and_then(|k| k.as_str()) == Some(KIND) {
        return ResultsFile::from_json(&doc);
    }
    let suite = suite_from_legacy(&doc)
        .ok_or_else(|| format!("neither a {KIND} document nor a known BENCH_PR* shape"))?;
    Ok(ResultsFile {
        schema: 0,
        run_id: format!("legacy-{}", suite.suite),
        created_unix: 0,
        git_rev: "unknown".to_string(),
        flags: Vec::new(),
        quick: false,
        host_threads: doc.get("host_threads").and_then(|n| n.as_usize()).unwrap_or(0),
        suites: vec![suite],
    })
}

/// Forward-compat loader for the pre-harness perf-trajectory records:
/// `BENCH_PR4.json` (exec), `BENCH_PR5.json` (reorder), `BENCH_PR6.json`
/// (trace overhead), `BENCH_PR8.json` (geometry), `BENCH_PR9.json`
/// (chaos), `BENCH_PR10.json` (load). Maps each onto the same
/// suite/headline/cell shapes the harness emits, so old records diff
/// against new runs.
pub fn suite_from_legacy(doc: &Json) -> Option<SuiteResult> {
    let bench = doc.get("bench")?.as_str()?;
    let cases = doc.get("cases").and_then(|c| c.as_arr()).unwrap_or(&[]);
    let f = |j: &Json, key: &str| j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let s = |j: &Json, key: &str| -> String {
        j.get(key).and_then(|v| v.as_str()).unwrap_or("?").to_string()
    };
    match bench {
        "exec_runtime" => Some(SuiteResult {
            suite: "exec".to_string(),
            title: "zero-allocation blocked runtime".to_string(),
            wall_s: 0.0,
            spec: Json::Null,
            headlines: vec![Headline {
                key: "geomean_speedup_n256".to_string(),
                value: f(doc, "geomean_speedup_n256"),
                unit: "x".to_string(),
                direction: Direction::HigherIsBetter,
                slip: Slip::RelativePct(10.0),
                floor: doc.get("acceptance_floor_n256").and_then(|v| v.as_f64()),
            }],
            cells: cases
                .iter()
                .map(|c| CellResult {
                    key: format!("{}/N={}", s(c, "matrix"), f(c, "n") as usize),
                    time_s: f(c, "pooled_blocked_s"),
                    value: f(c, "speedup"),
                })
                .collect(),
            metrics: Json::Null,
        }),
        "reorder" => Some(SuiteResult {
            suite: "reorder".to_string(),
            title: "similarity-clustered HRPB packing".to_string(),
            wall_s: 0.0,
            spec: Json::Null,
            headlines: vec![Headline {
                key: "geomean_speedup_lowmed".to_string(),
                value: f(doc, "geomean_speedup_lowmed"),
                unit: "x".to_string(),
                direction: Direction::HigherIsBetter,
                slip: Slip::RelativePct(10.0),
                floor: doc.get("acceptance_floor_lowmed").and_then(|v| v.as_f64()),
            }],
            cells: cases
                .iter()
                .map(|c| CellResult {
                    key: format!("{}/{}", s(c, "family"), s(c, "matrix")),
                    time_s: f(c, "reordered_s"),
                    value: f(c, "speedup"),
                })
                .collect(),
            metrics: Json::Null,
        }),
        "geometry" => Some(SuiteResult {
            suite: "geometry".to_string(),
            title: "planner-picked brick geometry".to_string(),
            wall_s: 0.0,
            spec: Json::Null,
            headlines: vec![Headline {
                key: "geomean_speedup_unstructured".to_string(),
                value: f(doc, "geomean_speedup_unstructured"),
                unit: "x".to_string(),
                direction: Direction::HigherIsBetter,
                slip: Slip::RelativePct(10.0),
                floor: doc.get("acceptance_floor_unstructured").and_then(|v| v.as_f64()),
            }],
            cells: cases
                .iter()
                .map(|c| CellResult {
                    key: format!("{}/{}", s(c, "family"), s(c, "matrix")),
                    time_s: f(c, "picked_s"),
                    value: f(c, "speedup"),
                })
                .collect(),
            metrics: Json::Null,
        }),
        "trace_overhead" => Some(SuiteResult {
            suite: "trace".to_string(),
            title: "observability overhead".to_string(),
            wall_s: 0.0,
            spec: Json::Null,
            headlines: vec![
                Headline {
                    key: "overhead_off_pct".to_string(),
                    value: f(doc, "overhead_off_pct"),
                    unit: "%".to_string(),
                    direction: Direction::LowerIsBetter,
                    slip: Slip::AbsolutePoints(2.0),
                    floor: doc.get("acceptance_overhead_off_pct").and_then(|v| v.as_f64()),
                },
                Headline {
                    key: "exec_reconcile_pct".to_string(),
                    value: f(doc, "exec_reconcile_pct"),
                    unit: "%".to_string(),
                    direction: Direction::LowerIsBetter,
                    slip: Slip::AbsolutePoints(5.0),
                    floor: doc.get("acceptance_reconcile_pct").and_then(|v| v.as_f64()),
                },
            ],
            cells: cases
                .iter()
                .map(|c| CellResult {
                    key: s(c, "mode"),
                    time_s: f(c, "wall_s"),
                    value: f(c, "req_per_s"),
                })
                .collect(),
            metrics: Json::Null,
        }),
        "chaos" => Some(SuiteResult {
            suite: "chaos".to_string(),
            title: "fault injection".to_string(),
            wall_s: 0.0,
            spec: Json::Null,
            headlines: vec![
                Headline {
                    key: "recovery_gap_pct".to_string(),
                    value: f(doc, "recovery_gap_pct"),
                    unit: "%".to_string(),
                    direction: Direction::LowerIsBetter,
                    slip: Slip::AbsolutePoints(5.0),
                    floor: doc.get("acceptance_recovery_gap_pct").and_then(|v| v.as_f64()),
                },
                Headline {
                    key: "lost_responses".to_string(),
                    value: f(doc, "lost_responses"),
                    unit: String::new(),
                    direction: Direction::LowerIsBetter,
                    slip: Slip::AbsolutePoints(0.5),
                    floor: Some(0.5),
                },
            ],
            cells: cases
                .iter()
                .map(|c| CellResult {
                    key: s(c, "mode"),
                    time_s: f(c, "wall_s"),
                    value: f(c, "recovered_rps"),
                })
                .collect(),
            metrics: Json::Null,
        }),
        "load" => Some(SuiteResult {
            suite: "load".to_string(),
            title: "closed-loop shard-router load".to_string(),
            wall_s: 0.0,
            spec: Json::Null,
            headlines: vec![
                Headline {
                    key: "sustained_rps".to_string(),
                    value: cases
                        .iter()
                        .find(|c| s(c, "mode") == "baseline")
                        .map(|c| f(c, "sustained_rps"))
                        .unwrap_or(0.0),
                    unit: "req/s".to_string(),
                    direction: Direction::HigherIsBetter,
                    slip: Slip::RelativePct(10.0),
                    floor: None,
                },
                Headline {
                    key: "kill_gap_pct".to_string(),
                    value: f(doc, "kill_gap_pct"),
                    unit: "%".to_string(),
                    direction: Direction::LowerIsBetter,
                    slip: Slip::AbsolutePoints(5.0),
                    floor: doc.get("acceptance_kill_gap_pct").and_then(|v| v.as_f64()),
                },
                Headline {
                    key: "lost_or_duplicated".to_string(),
                    value: f(doc, "lost_responses") + f(doc, "duplicate_deliveries"),
                    unit: String::new(),
                    direction: Direction::LowerIsBetter,
                    slip: Slip::AbsolutePoints(0.5),
                    floor: Some(0.5),
                },
            ],
            cells: cases
                .iter()
                .map(|c| CellResult {
                    key: s(c, "mode"),
                    time_s: f(c, "wall_s"),
                    value: f(c, "sustained_rps"),
                })
                .collect(),
            metrics: Json::Null,
        }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_run() -> ResultsFile {
        ResultsFile {
            schema: SCHEMA_VERSION,
            run_id: "r0000000042-00007".to_string(),
            created_unix: 42,
            git_rev: "abc1234".to_string(),
            flags: vec!["experiment".to_string(), "all".to_string(), "--quick".to_string()],
            quick: true,
            host_threads: 8,
            suites: vec![
                SuiteResult {
                    suite: "exec".to_string(),
                    title: "zero-allocation blocked runtime".to_string(),
                    wall_s: 1.5,
                    spec: Json::obj(vec![("reps", Json::num(3.0))]),
                    headlines: vec![Headline {
                        key: "geomean_speedup_n256".to_string(),
                        value: 1.62,
                        unit: "x".to_string(),
                        direction: Direction::HigherIsBetter,
                        slip: Slip::RelativePct(10.0),
                        floor: Some(1.3),
                    }],
                    cells: vec![CellResult {
                        key: "exec-fem/N=256".to_string(),
                        time_s: 0.0125,
                        value: 1.7,
                    }],
                    metrics: Json::obj(vec![("requests", Json::num(15.0))]),
                },
                SuiteResult {
                    suite: "trace".to_string(),
                    title: "observability overhead".to_string(),
                    wall_s: 0.4,
                    spec: Json::Null,
                    headlines: vec![Headline {
                        key: "overhead_off_pct".to_string(),
                        value: 0.3,
                        unit: "%".to_string(),
                        direction: Direction::LowerIsBetter,
                        slip: Slip::AbsolutePoints(2.0),
                        floor: None,
                    }],
                    cells: Vec::new(),
                    metrics: Json::Null,
                },
            ],
        }
    }

    #[test]
    fn v1_round_trip_preserves_every_field() {
        let run = sample_run();
        let text = run.to_json().to_string();
        let back = parse_results(&text).expect("own serialization must load");
        assert_eq!(back.schema, run.schema);
        assert_eq!(back.run_id, run.run_id);
        assert_eq!(back.created_unix, run.created_unix);
        assert_eq!(back.git_rev, run.git_rev);
        assert_eq!(back.flags, run.flags);
        assert_eq!(back.quick, run.quick);
        assert_eq!(back.host_threads, run.host_threads);
        assert_eq!(back.suites.len(), run.suites.len());
        // field-exact: re-serializing the loaded document is byte-identical
        assert_eq!(back.to_json().to_string(), text);
        // the lookup helper finds suites by name
        assert_eq!(back.suite("trace").map(|s| s.headlines.len()), Some(1));
        assert!(back.suite("nope").is_none());
    }

    #[test]
    fn rejects_foreign_and_future_documents() {
        assert!(parse_results("{\"hello\": 1}").is_err());
        assert!(parse_results("not json at all").is_err());
        let mut future = sample_run();
        future.schema = SCHEMA_VERSION + 1;
        let err = ResultsFile::from_json(&future.to_json()).unwrap_err();
        assert!(err.contains("newer"), "{err}");
    }

    #[test]
    fn non_finite_values_serialize_as_the_zero_sentinel() {
        let mut run = sample_run();
        run.suites[0].cells[0].time_s = f64::NAN;
        run.suites[0].headlines[0].value = f64::INFINITY;
        let back = parse_results(&run.to_json().to_string()).unwrap();
        assert_eq!(back.suites[0].cells[0].time_s, 0.0);
        assert_eq!(back.suites[0].headlines[0].value, 0.0);
    }

    #[test]
    fn legacy_bench_pr4_loads_as_an_exec_suite() {
        let text = r#"{"bench": "exec_runtime", "pr": 4, "host_threads": 8,
            "widths": [32, 256],
            "geomean_speedup_n256": 1.62, "acceptance_floor_n256": 1.3,
            "cases": [{"matrix": "exec-fem", "nnz": 1000, "n": 256,
                "slab_width": 64, "pooled_blocked_s": 0.01, "speedup": 2.0}]}"#;
        let run = parse_results(text).expect("legacy PR4 record must load");
        assert_eq!(run.schema, 0);
        assert_eq!(run.run_id, "legacy-exec");
        assert_eq!(run.host_threads, 8);
        let suite = run.suite("exec").unwrap();
        assert_eq!(suite.headlines[0].key, "geomean_speedup_n256");
        assert_eq!(suite.headlines[0].value, 1.62);
        assert_eq!(suite.headlines[0].floor, Some(1.3));
        assert_eq!(suite.headlines[0].direction, Direction::HigherIsBetter);
        assert_eq!(suite.cells[0].key, "exec-fem/N=256");
        assert_eq!(suite.cells[0].time_s, 0.01);
        assert_eq!(suite.cells[0].value, 2.0);
    }

    #[test]
    fn legacy_bench_pr5_loads_as_a_reorder_suite() {
        let text = r#"{"bench": "reorder", "pr": 5,
            "geomean_speedup_lowmed": 1.31, "acceptance_floor_lowmed": 1.2,
            "cases": [{"family": "scattered", "matrix": "scattered-0",
                "reordered_s": 0.004, "speedup": 1.4}]}"#;
        let run = parse_results(text).expect("legacy PR5 record must load");
        let suite = run.suite("reorder").unwrap();
        assert_eq!(suite.headlines[0].key, "geomean_speedup_lowmed");
        assert_eq!(suite.headlines[0].floor, Some(1.2));
        assert_eq!(suite.cells[0].key, "scattered/scattered-0");
        assert_eq!(suite.cells[0].time_s, 0.004);
    }

    #[test]
    fn legacy_bench_pr8_loads_as_a_geometry_suite() {
        let text = r#"{"bench": "geometry", "pr": 8,
            "geomean_speedup_unstructured": 1.08, "acceptance_floor_unstructured": 1.0,
            "cases": [{"family": "scattered", "matrix": "geometry-scattered",
                "chosen": "8x1t", "picked_s": 0.003, "speedup": 1.12}]}"#;
        let run = parse_results(text).expect("legacy PR8 record must load");
        let suite = run.suite("geometry").unwrap();
        assert_eq!(suite.headlines[0].key, "geomean_speedup_unstructured");
        assert_eq!(suite.headlines[0].value, 1.08);
        assert_eq!(suite.headlines[0].floor, Some(1.0));
        assert_eq!(suite.cells[0].key, "scattered/geometry-scattered");
        assert_eq!(suite.cells[0].time_s, 0.003);
    }

    #[test]
    fn legacy_bench_pr6_loads_as_a_trace_suite_with_two_headlines() {
        let text = r#"{"bench": "trace_overhead", "pr": 6,
            "overhead_off_pct": 0.4, "overhead_full_pct": 2.1,
            "exec_reconcile_pct": 0.0,
            "acceptance_overhead_off_pct": 2.0, "acceptance_reconcile_pct": 5.0,
            "cases": [{"mode": "baseline", "wall_s": 0.5, "req_per_s": 384.0},
                      {"mode": "full", "wall_s": 0.52, "req_per_s": 369.0}]}"#;
        let run = parse_results(text).expect("legacy PR6 record must load");
        let suite = run.suite("trace").unwrap();
        assert_eq!(suite.headlines.len(), 2);
        assert_eq!(suite.headlines[0].key, "overhead_off_pct");
        assert_eq!(suite.headlines[0].slip, Slip::AbsolutePoints(2.0));
        assert_eq!(suite.headlines[0].direction, Direction::LowerIsBetter);
        assert_eq!(suite.headlines[1].key, "exec_reconcile_pct");
        assert_eq!(suite.headlines[1].floor, Some(5.0));
        assert_eq!(suite.cells[1].key, "full");
        assert_eq!(suite.cells[1].value, 369.0);
    }

    #[test]
    fn legacy_bench_pr9_loads_as_a_chaos_suite() {
        let text = r#"{"bench": "chaos", "pr": 9,
            "recovery_gap_pct": 3.2, "acceptance_recovery_gap_pct": 10.0,
            "lost_responses": 0, "isolation_violations": 0,
            "cases": [{"mode": "baseline", "wall_s": 0.4, "recovered_rps": 512.0},
                      {"mode": "kernel_panic", "wall_s": 0.45, "recovered_rps": 495.0}]}"#;
        let run = parse_results(text).expect("legacy PR9 record must load");
        assert_eq!(run.run_id, "legacy-chaos");
        let suite = run.suite("chaos").unwrap();
        assert_eq!(suite.headlines.len(), 2);
        assert_eq!(suite.headlines[0].key, "recovery_gap_pct");
        assert_eq!(suite.headlines[0].value, 3.2);
        assert_eq!(suite.headlines[0].floor, Some(10.0));
        assert_eq!(suite.headlines[0].direction, Direction::LowerIsBetter);
        assert_eq!(suite.headlines[0].slip, Slip::AbsolutePoints(5.0));
        assert_eq!(suite.headlines[1].key, "lost_responses");
        assert_eq!(suite.headlines[1].value, 0.0);
        assert_eq!(suite.headlines[1].floor, Some(0.5));
        assert_eq!(suite.cells[1].key, "kernel_panic");
        assert_eq!(suite.cells[1].time_s, 0.45);
        assert_eq!(suite.cells[1].value, 495.0);
    }

    #[test]
    fn legacy_bench_pr10_loads_as_a_load_suite() {
        let text = r#"{"bench": "load", "pr": 10,
            "kill_gap_pct": 4.1, "acceptance_kill_gap_pct": 10.0,
            "lost_responses": 0, "duplicate_deliveries": 0,
            "saturation_max_queue_depth": 64, "saturation_queue_capacity": 64,
            "cases": [{"mode": "baseline", "wall_s": 0.8, "sustained_rps": 900.0},
                      {"mode": "shard_kill", "wall_s": 0.9, "sustained_rps": 850.0}]}"#;
        let run = parse_results(text).expect("legacy PR10 record must load");
        assert_eq!(run.run_id, "legacy-load");
        let suite = run.suite("load").unwrap();
        assert_eq!(suite.headlines.len(), 3);
        assert_eq!(suite.headlines[0].key, "sustained_rps");
        assert_eq!(suite.headlines[0].value, 900.0);
        assert_eq!(suite.headlines[0].direction, Direction::HigherIsBetter);
        assert_eq!(suite.headlines[0].slip, Slip::RelativePct(10.0));
        assert_eq!(suite.headlines[1].key, "kill_gap_pct");
        assert_eq!(suite.headlines[1].value, 4.1);
        assert_eq!(suite.headlines[1].floor, Some(10.0));
        assert_eq!(suite.headlines[2].key, "lost_or_duplicated");
        assert_eq!(suite.headlines[2].value, 0.0);
        assert_eq!(suite.headlines[2].floor, Some(0.5));
        assert_eq!(suite.cells[1].key, "shard_kill");
        assert_eq!(suite.cells[1].time_s, 0.9);
        assert_eq!(suite.cells[1].value, 850.0);
    }

    #[test]
    fn unknown_legacy_bench_kind_is_rejected() {
        assert!(parse_results(r#"{"bench": "mystery", "cases": []}"#).is_err());
    }
}
