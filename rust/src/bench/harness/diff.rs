//! The diff engine: compare a run against a baseline per accepted headline
//! (with per-headline slip thresholds) plus an informational per-cell
//! timing geomean.
//!
//! Only headlines gate — they are geomeans/percentiles the drivers already
//! defend with acceptance floors, so a >slip move is signal. Individual
//! cell timings are noisy on shared runners; their geomean ratio is
//! reported but never fails the gate.

use super::results::{CellResult, Direction, ResultsFile, Slip, SuiteResult};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Guard for relative math near zero.
const EPS: f64 = 1e-12;

/// Outcome of one headline comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Within the slip threshold in both directions.
    Pass,
    /// Moved beyond the threshold in the good direction.
    Improved,
    /// Moved beyond the threshold in the bad direction — gates.
    Regressed,
    /// The baseline has no such headline (new suite/metric) — never gates.
    Missing,
    /// A value was non-finite or a relative base was ~zero — never gates,
    /// but is visibly flagged.
    Incomparable,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Improved => "improved",
            Verdict::Regressed => "REGRESSED",
            Verdict::Missing => "missing",
            Verdict::Incomparable => "incomparable",
        }
    }
}

/// Percent change of `cur` vs `base`, when both are finite and the base is
/// meaningfully nonzero. Shared with `cutespmm metrics --diff`.
pub fn pct_change(base: f64, cur: f64) -> Option<f64> {
    if !base.is_finite() || !cur.is_finite() || base.abs() < EPS {
        return None;
    }
    Some(100.0 * (cur - base) / base.abs())
}

/// Judge one headline move against its direction and slip threshold.
/// `slip_override` (the `--slip` flag) replaces relative thresholds only —
/// absolute-points budgets (overhead %) keep their configured width.
pub fn judge(
    direction: Direction,
    slip: Slip,
    base: Option<f64>,
    cur: f64,
    slip_override: Option<f64>,
) -> Verdict {
    let Some(base) = base else {
        return Verdict::Missing;
    };
    if !base.is_finite() || !cur.is_finite() {
        return Verdict::Incomparable;
    }
    // the sanitize() sentinel: 0.0 means "no measurement", not a timing
    match slip {
        Slip::RelativePct(t) => {
            let t = slip_override.unwrap_or(t);
            if base.abs() < EPS {
                return Verdict::Incomparable;
            }
            let slip_frac = match direction {
                Direction::HigherIsBetter => {
                    if base <= 0.0 {
                        return Verdict::Incomparable;
                    }
                    (base - cur) / base
                }
                Direction::LowerIsBetter => (cur - base) / base.abs(),
            };
            if slip_frac > t / 100.0 {
                Verdict::Regressed
            } else if slip_frac < -t / 100.0 {
                Verdict::Improved
            } else {
                Verdict::Pass
            }
        }
        Slip::AbsolutePoints(t) => {
            let delta = match direction {
                Direction::HigherIsBetter => base - cur,
                Direction::LowerIsBetter => cur - base,
            };
            if delta > t {
                Verdict::Regressed
            } else if delta < -t {
                Verdict::Improved
            } else {
                Verdict::Pass
            }
        }
    }
}

/// One headline's comparison.
#[derive(Clone, Debug)]
pub struct HeadlineDiff {
    pub key: String,
    pub unit: String,
    pub base: Option<f64>,
    pub current: f64,
    /// Display-only percent change (None when incomparable/missing).
    pub change_pct: Option<f64>,
    pub verdict: Verdict,
}

/// One suite's comparison: gated headlines plus informational cell stats.
#[derive(Clone, Debug)]
pub struct SuiteDiff {
    pub suite: String,
    pub headlines: Vec<HeadlineDiff>,
    /// Cells present (with a comparable timing) in both runs.
    pub cell_overlap: usize,
    pub cells_only_base: usize,
    pub cells_only_cur: usize,
    /// Geomean of base/current time ratios over the overlap (>1 = current
    /// faster). Informational only — cell noise never gates.
    pub cell_geomean_speedup: Option<f64>,
}

/// The whole run comparison.
#[derive(Clone, Debug)]
pub struct DiffReport {
    pub baseline_id: String,
    pub current_id: String,
    /// Quick and full runs measure different grids; flagged, not fatal.
    pub quick_mismatch: bool,
    pub suites: Vec<SuiteDiff>,
}

/// Geomean of base/current timing ratios over cells matched by key where
/// both timings are finite and positive. Returns (overlap, only_base,
/// only_cur, geomean).
pub fn cell_geomean(base: &[CellResult], cur: &[CellResult]) -> (usize, usize, usize, Option<f64>) {
    let comparable = |c: &&CellResult| c.time_s.is_finite() && c.time_s > 0.0;
    let base_map: BTreeMap<&str, f64> = base
        .iter()
        .filter(comparable)
        .map(|c| (c.key.as_str(), c.time_s))
        .collect();
    let cur_map: BTreeMap<&str, f64> = cur
        .iter()
        .filter(comparable)
        .map(|c| (c.key.as_str(), c.time_s))
        .collect();
    let mut log_sum = 0.0f64;
    let mut overlap = 0usize;
    for (key, b) in &base_map {
        if let Some(c) = cur_map.get(key) {
            log_sum += (b / c).ln();
            overlap += 1;
        }
    }
    let geomean = if overlap > 0 {
        let g = (log_sum / overlap as f64).exp();
        g.is_finite().then_some(g)
    } else {
        None
    };
    (overlap, base_map.len() - overlap, cur_map.len() - overlap, geomean)
}

/// Compare `cur` against `base`, suite by suite (matched by name).
pub fn diff(base: &ResultsFile, cur: &ResultsFile, slip_override: Option<f64>) -> DiffReport {
    let suites = cur
        .suites
        .iter()
        .map(|cs| diff_suite(base.suite(&cs.suite), cs, slip_override))
        .collect();
    DiffReport {
        baseline_id: base.run_id.clone(),
        current_id: cur.run_id.clone(),
        quick_mismatch: base.quick != cur.quick,
        suites,
    }
}

fn diff_suite(
    base: Option<&SuiteResult>,
    cur: &SuiteResult,
    slip_override: Option<f64>,
) -> SuiteDiff {
    let empty: &[CellResult] = &[];
    let base_cells = base.map(|b| b.cells.as_slice()).unwrap_or(empty);
    let (cell_overlap, cells_only_base, cells_only_cur, cell_geomean_speedup) =
        cell_geomean(base_cells, &cur.cells);
    let headlines = cur
        .headlines
        .iter()
        .map(|h| {
            let base_value = base
                .and_then(|b| b.headlines.iter().find(|bh| bh.key == h.key))
                .map(|bh| bh.value);
            let verdict = judge(h.direction, h.slip, base_value, h.value, slip_override);
            HeadlineDiff {
                key: h.key.clone(),
                unit: h.unit.clone(),
                base: base_value,
                current: h.value,
                change_pct: base_value.and_then(|b| pct_change(b, h.value)),
                verdict,
            }
        })
        .collect();
    SuiteDiff {
        suite: cur.suite.clone(),
        headlines,
        cell_overlap,
        cells_only_base,
        cells_only_cur,
        cell_geomean_speedup,
    }
}

impl DiffReport {
    /// Did any accepted headline regress beyond its slip threshold?
    pub fn regressed(&self) -> bool {
        self.suites
            .iter()
            .flat_map(|s| s.headlines.iter())
            .any(|h| h.verdict == Verdict::Regressed)
    }

    /// Human-readable comparison table.
    pub fn render(&self) -> String {
        use crate::bench::render;
        let mut out = format!(
            "== experiment diff: {} (current) vs {} (baseline) ==\n",
            self.current_id, self.baseline_id
        );
        if self.quick_mismatch {
            out.push_str(
                "note: quick/full mismatch between runs — grids differ, compare with care\n",
            );
        }
        let mut rows = Vec::new();
        for s in &self.suites {
            for h in &s.headlines {
                rows.push(vec![
                    s.suite.clone(),
                    h.key.clone(),
                    match h.base {
                        Some(b) => format!("{b:.3}{}", h.unit),
                        None => "-".to_string(),
                    },
                    format!("{:.3}{}", h.current, h.unit),
                    match h.change_pct {
                        Some(p) => format!("{p:+.1}%"),
                        None => "-".to_string(),
                    },
                    h.verdict.name().to_string(),
                ]);
            }
        }
        out.push_str(&render::table(
            &["suite", "headline", "baseline", "current", "change", "verdict"],
            &rows,
        ));
        for s in &self.suites {
            let geo = match s.cell_geomean_speedup {
                Some(g) => format!("{g:.3}x"),
                None => "n/a".to_string(),
            };
            out.push_str(&format!(
                "cells[{}]: overlap={} only_baseline={} only_current={} \
                 timing geomean (baseline/current)={geo} (informational)\n",
                s.suite, s.cell_overlap, s.cells_only_base, s.cells_only_cur
            ));
        }
        out.push_str(if self.regressed() {
            "verdict: REGRESSED — at least one accepted headline slipped beyond its threshold\n"
        } else {
            "verdict: pass — every accepted headline within its slip threshold\n"
        });
        out
    }

    /// Machine-readable comparison (`experiment diff --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("cutespmm_diff")),
            ("baseline_id", Json::str(self.baseline_id.clone())),
            ("current_id", Json::str(self.current_id.clone())),
            ("quick_mismatch", Json::Bool(self.quick_mismatch)),
            ("regressed", Json::Bool(self.regressed())),
            (
                "suites",
                Json::arr(self.suites.iter().map(|s| {
                    Json::obj(vec![
                        ("suite", Json::str(s.suite.clone())),
                        (
                            "headlines",
                            Json::arr(s.headlines.iter().map(|h| {
                                Json::obj(vec![
                                    ("key", Json::str(h.key.clone())),
                                    ("unit", Json::str(h.unit.clone())),
                                    (
                                        "baseline",
                                        match h.base {
                                            Some(b) => Json::num(super::results::sanitize(b)),
                                            None => Json::Null,
                                        },
                                    ),
                                    ("current", Json::num(super::results::sanitize(h.current))),
                                    (
                                        "change_pct",
                                        match h.change_pct {
                                            Some(p) => Json::num(super::results::sanitize(p)),
                                            None => Json::Null,
                                        },
                                    ),
                                    ("verdict", Json::str(h.verdict.name())),
                                ])
                            })),
                        ),
                        ("cell_overlap", Json::num(s.cell_overlap as f64)),
                        ("cells_only_baseline", Json::num(s.cells_only_base as f64)),
                        ("cells_only_current", Json::num(s.cells_only_cur as f64)),
                        (
                            "cell_geomean_speedup",
                            match s.cell_geomean_speedup {
                                Some(g) => Json::num(super::results::sanitize(g)),
                                None => Json::Null,
                            },
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Self-test mode (`experiment diff --inject-slip`): degrade every
/// headline and cell timing of a run by `pct` percent against its
/// direction, so diffing the degraded copy against the original MUST go
/// red — proof the gate can fire.
pub fn inject_slip(run: &ResultsFile, pct: f64) -> ResultsFile {
    let mut out = run.clone();
    out.run_id = format!("{}+slip{}", run.run_id, pct);
    for suite in &mut out.suites {
        for h in &mut suite.headlines {
            match (h.direction, h.slip) {
                (Direction::HigherIsBetter, _) => h.value *= 1.0 - pct / 100.0,
                (Direction::LowerIsBetter, Slip::AbsolutePoints(_)) => h.value += pct,
                (Direction::LowerIsBetter, Slip::RelativePct(_)) => {
                    h.value *= 1.0 + pct / 100.0
                }
            }
        }
        for c in &mut suite.cells {
            c.time_s *= 1.0 + pct / 100.0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::results::{Headline, SCHEMA_VERSION};
    use crate::util::json::Json;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Rng;

    fn headline(key: &str, value: f64, direction: Direction, slip: Slip) -> Headline {
        Headline {
            key: key.to_string(),
            value,
            unit: "x".to_string(),
            direction,
            slip,
            floor: None,
        }
    }

    fn cell(key: &str, time_s: f64) -> CellResult {
        CellResult { key: key.to_string(), time_s, value: 1.0 }
    }

    fn run_with(headlines: Vec<Headline>, cells: Vec<CellResult>) -> ResultsFile {
        ResultsFile {
            schema: SCHEMA_VERSION,
            run_id: "r0000000001-00001".to_string(),
            created_unix: 1,
            git_rev: "test".to_string(),
            flags: Vec::new(),
            quick: true,
            host_threads: 1,
            suites: vec![SuiteResult {
                suite: "exec".to_string(),
                title: "t".to_string(),
                wall_s: 0.0,
                spec: Json::Null,
                headlines,
                cells,
                metrics: Json::Null,
            }],
        }
    }

    #[test]
    fn missing_baseline_never_gates() {
        let v = judge(
            Direction::HigherIsBetter,
            Slip::RelativePct(10.0),
            None,
            1.0,
            None,
        );
        assert_eq!(v, Verdict::Missing);
    }

    #[test]
    fn zero_and_non_finite_inputs_are_incomparable() {
        let rel = Slip::RelativePct(10.0);
        for (dir, base, cur) in [
            (Direction::HigherIsBetter, 0.0, 1.0),
            (Direction::HigherIsBetter, -2.0, 1.0),
            (Direction::HigherIsBetter, f64::NAN, 1.0),
            (Direction::HigherIsBetter, 2.0, f64::NAN),
            (Direction::LowerIsBetter, 0.0, 1.0),
            (Direction::LowerIsBetter, f64::INFINITY, 1.0),
        ] {
            assert_eq!(
                judge(dir, rel, Some(base), cur, None),
                Verdict::Incomparable,
                "dir={dir:?} base={base} cur={cur}"
            );
        }
        // absolute budgets tolerate a zero base (overhead can be ~0%)
        assert_eq!(
            judge(Direction::LowerIsBetter, Slip::AbsolutePoints(2.0), Some(0.0), 1.0, None),
            Verdict::Pass
        );
    }

    #[test]
    fn relative_and_absolute_thresholds_cut_where_configured() {
        let hi = Direction::HigherIsBetter;
        let rel = Slip::RelativePct(10.0);
        assert_eq!(judge(hi, rel, Some(2.0), 1.7, None), Verdict::Regressed); // -15%
        assert_eq!(judge(hi, rel, Some(2.0), 1.9, None), Verdict::Pass); // -5%
        assert_eq!(judge(hi, rel, Some(2.0), 2.5, None), Verdict::Improved); // +25%
        // --slip override tightens the same move into a regression
        assert_eq!(judge(hi, rel, Some(2.0), 1.9, Some(2.0)), Verdict::Regressed);
        let lo = Direction::LowerIsBetter;
        let abs = Slip::AbsolutePoints(2.0);
        assert_eq!(judge(lo, abs, Some(1.0), 3.1, None), Verdict::Regressed); // +2.1 points
        assert_eq!(judge(lo, abs, Some(1.0), 2.9, None), Verdict::Pass); // +1.9 points
        // the override only applies to relative thresholds
        assert_eq!(judge(lo, abs, Some(1.0), 2.9, Some(1.0)), Verdict::Pass);
    }

    #[test]
    fn cell_geomean_uses_only_the_comparable_overlap() {
        let base = vec![cell("k0", 1.0), cell("k1", 1.0), cell("k2", 1.0), cell("bad", 0.0)];
        let cur = vec![cell("k1", 0.5), cell("k2", 0.25), cell("k3", 8.0), cell("bad", 1.0)];
        let (overlap, only_base, only_cur, g) = cell_geomean(&base, &cur);
        assert_eq!((overlap, only_base, only_cur), (2, 1, 2));
        // sqrt((1/0.5) * (1/0.25)) = sqrt(8)
        assert!((g.unwrap() - 8.0f64.sqrt()).abs() < 1e-12);
        let (overlap, _, _, g) = cell_geomean(&base, &[]);
        assert_eq!(overlap, 0);
        assert!(g.is_none());
    }

    #[test]
    fn self_diff_is_clean_and_inject_slip_goes_red() {
        let run = run_with(
            vec![
                headline("geo", 1.62, Direction::HigherIsBetter, Slip::RelativePct(10.0)),
                headline("p99", 4.2, Direction::LowerIsBetter, Slip::RelativePct(10.0)),
                headline("oh", 0.4, Direction::LowerIsBetter, Slip::AbsolutePoints(2.0)),
            ],
            vec![cell("a", 0.01), cell("b", 0.02)],
        );
        let clean = diff(&run, &run, None);
        assert!(!clean.regressed());
        for h in clean.suites.iter().flat_map(|s| s.headlines.iter()) {
            assert_eq!(h.verdict, Verdict::Pass, "{}", h.key);
        }
        assert!((clean.suites[0].cell_geomean_speedup.unwrap() - 1.0).abs() < 1e-12);
        assert!(clean.render().contains("verdict: pass"));

        let slipped = inject_slip(&run, 15.0);
        assert!(slipped.run_id.contains("+slip"));
        let red = diff(&run, &slipped, None);
        assert!(red.regressed());
        for h in red.suites.iter().flat_map(|s| s.headlines.iter()) {
            assert_eq!(h.verdict, Verdict::Regressed, "{}", h.key);
        }
        assert!(red.render().contains("verdict: REGRESSED"));
        assert_eq!(
            red.to_json().get("regressed").and_then(|b| b.as_bool()),
            Some(true)
        );
    }

    #[test]
    fn diff_against_a_baseline_without_the_suite_reports_missing() {
        let base = run_with(vec![], vec![]);
        let mut cur = run_with(
            vec![headline("geo", 1.5, Direction::HigherIsBetter, Slip::RelativePct(10.0))],
            vec![],
        );
        cur.suites[0].suite = "brand-new".to_string();
        let d = diff(&base, &cur, None);
        assert!(!d.regressed());
        assert_eq!(d.suites[0].headlines[0].verdict, Verdict::Missing);
    }

    /// Random runs built only from headline shapes the harness emits, with
    /// strictly positive finite values — the domain where the gate's two
    /// invariants must hold unconditionally.
    struct RunGen;

    impl Gen for RunGen {
        type Value = ResultsFile;
        fn gen(&self, rng: &mut Rng) -> ResultsFile {
            let shapes = [
                (Direction::HigherIsBetter, Slip::RelativePct(10.0)),
                (Direction::LowerIsBetter, Slip::RelativePct(10.0)),
                (Direction::LowerIsBetter, Slip::AbsolutePoints(2.0)),
            ];
            let headlines = (0..rng.range(1, 4))
                .map(|i| {
                    let (d, s) = shapes[rng.below(shapes.len())];
                    headline(&format!("h{i}"), 0.1 + 10.0 * rng.f64(), d, s)
                })
                .collect();
            let cells =
                (0..rng.range(0, 6)).map(|i| cell(&format!("c{i}"), 0.05 + rng.f64())).collect();
            run_with(headlines, cells)
        }
    }

    #[test]
    fn prop_self_diff_always_clean_and_slip_always_flags() {
        check("diff gate invariants", 150, &RunGen, |run| {
            let clean = diff(run, run, None);
            let red = diff(run, &inject_slip(run, 15.0), None);
            !clean.regressed()
                && red.regressed()
                && red
                    .suites
                    .iter()
                    .flat_map(|s| s.headlines.iter())
                    .all(|h| h.verdict == Verdict::Regressed)
        });
    }

    #[test]
    fn prop_missing_baseline_never_regresses() {
        check("missing baseline", 100, &RunGen, |run| {
            run.suites.iter().flat_map(|s| s.headlines.iter()).all(|h| {
                judge(h.direction, h.slip, None, h.value, None) == Verdict::Missing
            })
        });
    }

    /// Cell lists drawn from a shared key universe with random membership,
    /// so overlap / only-base / only-cur all occur.
    struct CellsGen;

    impl Gen for CellsGen {
        type Value = (Vec<CellResult>, Vec<CellResult>);
        fn gen(&self, rng: &mut Rng) -> Self::Value {
            let mut base = Vec::new();
            let mut cur = Vec::new();
            for k in 0..8usize {
                if rng.f64() < 0.5 {
                    base.push(cell(&format!("k{k}"), 0.1 + 9.9 * rng.f64()));
                }
                if rng.f64() < 0.5 {
                    cur.push(cell(&format!("k{k}"), 0.1 + 9.9 * rng.f64()));
                }
            }
            (base, cur)
        }
    }

    #[test]
    fn prop_cell_geomean_over_partial_overlap() {
        check("cell geomean", 200, &CellsGen, |(base, cur)| {
            let expected_overlap = base
                .iter()
                .filter(|b| cur.iter().any(|c| c.key == b.key))
                .count();
            let (overlap, only_base, only_cur, g) = cell_geomean(base, cur);
            overlap == expected_overlap
                && only_base == base.len() - overlap
                && only_cur == cur.len() - overlap
                && match g {
                    Some(g) => overlap > 0 && g.is_finite() && g > 0.0,
                    None => overlap == 0,
                }
        });
    }

    #[test]
    fn prop_cell_geomean_identity_on_unchanged_timings() {
        check("cell geomean identity", 100, &CellsGen, |(base, _)| {
            match cell_geomean(base, base).3 {
                Some(g) => (g - 1.0).abs() < 1e-9,
                None => base.is_empty(),
            }
        });
    }

    #[test]
    fn prop_pct_change_guards_and_sign() {
        check("pct_change", 200, &CellsGen, |(base, _)| {
            base.iter().all(|c| {
                pct_change(f64::NAN, c.time_s).is_none()
                    && pct_change(0.0, c.time_s).is_none()
                    && pct_change(c.time_s, f64::NAN).is_none()
                    && pct_change(c.time_s, c.time_s) == Some(0.0)
                    && pct_change(c.time_s, c.time_s * 2.0).map(|p| p > 0.0) == Some(true)
            })
        });
    }
}
