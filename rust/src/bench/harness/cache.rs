//! Per-suite-run preprocessing cache: one temporary
//! [`ArtifactStore`](crate::hrpb::ArtifactStore) shared by every cell of a
//! single suite run, so a grid that visits the same (matrix, geometry)
//! twice — e.g. the geometry suite's `planner-picked` cell landing on the
//! same shape the `fixed-16x4` cell already built — serves the second
//! visit from the persisted artifact instead of rebuilding the HRPB.
//!
//! The store's hit/miss counters are folded into the suite's
//! `MetricsSnapshot` ([`Metrics::sync_artifacts`]
//! (crate::coordinator::Metrics::sync_artifacts)), so every history entry
//! records how much preprocessing the cache absorbed.

use crate::formats::{Coo, Csr};
use crate::hrpb::{ArtifactStore, StoreStats};
use crate::params::{BrickGeometry, TK, TM};
use crate::spmm::hrpb::HrpbEngine;
use std::path::PathBuf;

/// A suite-run-scoped artifact cache. Dropping it removes the backing
/// directory — the cache deliberately does not outlive the run (cross-run
/// persistence is the registry's job, with its own invalidation story).
pub struct SuiteCache {
    store: ArtifactStore,
    dir: PathBuf,
}

impl SuiteCache {
    /// Open a cache under a unique temp directory; `None` when the
    /// directory cannot be created (cells then build uncached).
    pub fn open(tag: &str) -> Option<SuiteCache> {
        let dir = std::env::temp_dir().join(format!(
            "cutespmm_suite_cache_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ArtifactStore::open(&dir).ok()?;
        Some(SuiteCache { store, dir })
    }

    /// Store key for (matrix, geometry): the planner fingerprint mixed
    /// with the geometry's wire id, so each catalog shape of the same
    /// matrix gets its own artifact.
    fn key(coo: &Coo, geo: BrickGeometry) -> u64 {
        (crate::planner::fingerprint(coo) ^ geo.id() as u64).wrapping_mul(0x100000001b3)
    }

    /// Serve the engine for (matrix, geometry): an artifact hit skips the
    /// HRPB build entirely (and exercises the serialization round-trip on
    /// real suite data); a miss builds at the default tiles and persists
    /// the artifact for the rest of the suite run.
    pub fn engine(&self, coo: &Coo, csr: &Csr, geo: BrickGeometry, threads: usize) -> HrpbEngine {
        let key = Self::key(coo, geo);
        let digest = crate::hrpb::serialize::content_digest(coo);
        if let Some(a) = self.store.load_matching(key, coo.rows, coo.cols, coo.nnz(), digest) {
            // a key collision across geometries is astronomically unlikely
            // but cheap to guard: a wrong-shape artifact is rebuilt
            if a.hrpb.geometry == geo {
                return HrpbEngine::from_shared_with_stats(std::sync::Arc::new(a.hrpb), a.stats);
            }
        }
        let hrpb = crate::hrpb::build_with_geometry_parallel(csr, geo, TM, TK, threads);
        let stats = crate::hrpb::stats::compute(&hrpb);
        let _ = self.store.save(key, &hrpb, &stats, digest, None);
        HrpbEngine::from_shared_with_stats(std::sync::Arc::new(hrpb), stats)
    }

    /// Hit/miss/invalidated counters for the suite's `MetricsSnapshot`.
    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }
}

impl Drop for SuiteCache {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Coo, Csr, Dense};
    use crate::spmm::SpmmEngine;
    use crate::util::rng::Rng;

    #[test]
    fn same_matrix_and_geometry_builds_once_and_serves_identically() {
        let Some(cache) = SuiteCache::open("test_reuse") else {
            panic!("temp dir must be creatable in tests");
        };
        let mut rng = Rng::new(300);
        let coo = Coo::random(96, 80, 0.08, &mut rng);
        let csr = Csr::from_coo(&coo);
        let b = Dense::random(80, 12, &mut rng);

        let first = cache.engine(&coo, &csr, BrickGeometry::DEFAULT, 2);
        assert_eq!(cache.stats(), StoreStats { hits: 0, misses: 1, invalidated: 0 });
        let again = cache.engine(&coo, &csr, BrickGeometry::DEFAULT, 2);
        assert_eq!(cache.stats().hits, 1, "second visit must hit the artifact");
        assert_eq!(
            again.spmm(&b).max_abs_diff(&first.spmm(&b)),
            0.0,
            "artifact-served engine must be bit-identical"
        );

        // a different catalog shape of the same matrix is its own entry
        let other = cache.engine(&coo, &csr, BrickGeometry::CATALOG[3], 2);
        assert_eq!(other.hrpb().geometry, BrickGeometry::CATALOG[3]);
        assert_eq!(cache.stats().misses, 2);
        assert!(other.spmm(&b).rel_fro_error(&first.spmm(&b)) < 1e-6);
        let dir = cache.store.dir().to_path_buf();
        drop(cache);
        assert!(!dir.exists(), "dropping the cache must remove its directory");
    }
}
