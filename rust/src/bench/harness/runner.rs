//! Run assembly: stamp a set of executed suites with the provenance a
//! future reader needs to trust (or rerun) the numbers — schema version,
//! sortable run id, git revision, invoking flags, host parallelism.

use super::history;
use super::results::{ResultsFile, SCHEMA_VERSION};
use super::suites::SuiteRun;
use std::time::{SystemTime, UNIX_EPOCH};

/// `git rev-parse --short HEAD` of the crate checkout, falling back to
/// `CUTESPMM_GIT_REV` (CI tarballs without `.git`), then "unknown".
pub fn git_rev() -> String {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output();
    if let Ok(out) = out {
        if out.status.success() {
            let rev = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !rev.is_empty() {
                return rev;
            }
        }
    }
    std::env::var("CUTESPMM_GIT_REV").unwrap_or_else(|_| "unknown".to_string())
}

/// Assemble executed suites into one versioned [`ResultsFile`], ready for
/// [`history::append`].
pub fn collect(quick: bool, flags: &[String], runs: Vec<SuiteRun>) -> ResultsFile {
    let created_unix = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    ResultsFile {
        schema: SCHEMA_VERSION,
        run_id: history::make_run_id(created_unix),
        created_unix,
        git_rev: git_rev(),
        flags: flags.to_vec(),
        quick,
        host_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        suites: runs.into_iter().map(|r| r.result).collect(),
    }
}
