//! Deterministic fault injection — seeded, named injection points that are
//! zero-cost when disabled.
//!
//! Chaos testing a serving system only works when the faults are
//! reproducible: a flaky injector makes every red run a debugging session
//! about the injector. This module follows the [`crate::trace`] gate
//! discipline — one relaxed atomic load per call site while disabled, a
//! process-global installed plan while enabled — and derives every firing
//! decision from a seed and a per-arm hit ordinal, never from a clock or a
//! global RNG, so the same [`FaultPlan`] against the same workload fires
//! the same faults in the same places every run.
//!
//! Call sites pass a *key* describing where they are (the serving path
//! uses engine-qualified matrix names, `"<engine>@<matrix>"`; the artifact
//! store uses the artifact path). An arm's optional target is a substring
//! match on that key, so a plan can aim at one matrix on one engine
//! (`kernel_panic@cutespmm@victim`), a matrix on any engine
//! (`kernel_panic@@victim`), or everything (`kernel_panic`).

use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Named injection points the serving path exposes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Point {
    /// Panic inside the engine-dispatch boundary (contained by the
    /// coordinator's `catch_unwind` into a typed `EngineFault`).
    KernelPanic,
    /// Transient IO error on an artifact-store read or write (absorbed by
    /// the store's bounded retry).
    ArtifactIo,
    /// Flip one byte of an artifact's bytes in flight (caught by decode
    /// validation and invalidated, never served).
    ChecksumFlip,
    /// Stall the engine execution for a bounded interval (throughput dip,
    /// no error).
    SlowExec,
    /// Drop a network response in flight (the server never writes the
    /// frame; the shard router's idempotent retry must recover it).
    NetDrop,
    /// Stall a network write for a bounded interval (slow-peer pressure on
    /// the connection's in-flight window and deadlines).
    NetStall,
}

impl Point {
    pub const COUNT: usize = 6;

    pub fn index(self) -> usize {
        match self {
            Point::KernelPanic => 0,
            Point::ArtifactIo => 1,
            Point::ChecksumFlip => 2,
            Point::SlowExec => 3,
            Point::NetDrop => 4,
            Point::NetStall => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Point::KernelPanic => "kernel_panic",
            Point::ArtifactIo => "artifact_io",
            Point::ChecksumFlip => "checksum_flip",
            Point::SlowExec => "slow_exec",
            Point::NetDrop => "net_drop",
            Point::NetStall => "net_stall",
        }
    }

    pub fn all() -> [Point; Point::COUNT] {
        [
            Point::KernelPanic,
            Point::ArtifactIo,
            Point::ChecksumFlip,
            Point::SlowExec,
            Point::NetDrop,
            Point::NetStall,
        ]
    }

    pub fn parse(s: &str) -> Option<Point> {
        Point::all().into_iter().find(|p| p.name() == s)
    }
}

/// How an armed injection point decides whether a given hit fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arm {
    /// Fire on a deterministic `rate` fraction of hits: a seeded hash of
    /// the hit ordinal, so the pattern is reproducible, not random.
    Rate(f64),
    /// Fire on exactly the n-th matching hit (1-based), once.
    Nth(u64),
}

/// One armed injection point of a [`FaultPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct Injection {
    pub point: Point,
    /// Substring the call site's key must contain; `None` matches every
    /// key at this point.
    pub target: Option<String>,
    pub arm: Arm,
}

/// A parsed, seeded fault plan — inert data until [`install`]ed.
///
/// Spec grammar (the `--fault-plan` flag): semicolon-separated arms, each
/// `point[@target][:rate=R|:nth=N]`. The arm clause defaults to
/// `rate=1.0` (fire on every matching hit). Parsing is all-or-nothing: a
/// bad arm rejects the whole spec and nothing is armed.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub injections: Vec<Injection>,
}

impl FaultPlan {
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut injections = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            injections.push(parse_injection(part)?);
        }
        if injections.is_empty() {
            return Err(
                "empty fault plan: expected point[@target][:rate=R|:nth=N][;...]".to_string()
            );
        }
        Ok(FaultPlan { seed, injections })
    }
}

fn parse_injection(part: &str) -> Result<Injection, String> {
    let (head, arm) = match part.split_once(':') {
        Some((h, clause)) => (h, parse_arm(part, clause)?),
        None => (part, Arm::Rate(1.0)),
    };
    let (point_s, target) = match head.split_once('@') {
        Some((p, t)) if !t.is_empty() => (p, Some(t.to_string())),
        Some(_) => return Err(format!("empty @target in '{part}'")),
        None => (head, None),
    };
    let point = Point::parse(point_s).ok_or_else(|| {
        let known: Vec<&str> = Point::all().iter().map(|p| p.name()).collect();
        format!("unknown injection point '{point_s}' in '{part}' (known: {})", known.join(", "))
    })?;
    Ok(Injection { point, target, arm })
}

fn parse_arm(part: &str, clause: &str) -> Result<Arm, String> {
    let (k, v) = clause
        .split_once('=')
        .ok_or_else(|| format!("bad arm clause '{clause}' in '{part}': expected rate=R or nth=N"))?;
    match k {
        "rate" => {
            let r: f64 =
                v.parse().map_err(|_| format!("bad rate '{v}' in '{part}': not a number"))?;
            if !(0.0..=1.0).contains(&r) {
                return Err(format!("rate {r} in '{part}' outside [0, 1]"));
            }
            Ok(Arm::Rate(r))
        }
        "nth" => {
            let n: u64 =
                v.parse().map_err(|_| format!("bad nth '{v}' in '{part}': not an integer"))?;
            if n == 0 {
                return Err(format!("nth=0 in '{part}': hit ordinals are 1-based"));
            }
            Ok(Arm::Nth(n))
        }
        other => Err(format!("unknown arm '{other}' in '{part}' (expected rate or nth)")),
    }
}

/// One relaxed load — the entire cost of every injection point while no
/// plan is installed.
static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<ArmedPlan>> = Mutex::new(None);
static FIRED: [AtomicU64; Point::COUNT] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];
static SESSION: Mutex<()> = Mutex::new(());

struct ArmedState {
    inj: Injection,
    hits: u64,
}

struct ArmedPlan {
    seed: u64,
    arms: Vec<ArmedState>,
}

/// Is any fault plan armed? One relaxed load; every injection helper
/// checks this before touching the plan lock.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm `plan`. The gate goes down before the plan swap and up after it,
/// so no call site ever observes a half-installed plan. Fired counters
/// and per-arm hit ordinals reset.
pub fn install(plan: &FaultPlan) {
    ENABLED.store(false, Ordering::SeqCst);
    {
        let mut g = PLAN.lock().unwrap_or_else(|p| p.into_inner());
        *g = Some(ArmedPlan {
            seed: plan.seed,
            arms: plan
                .injections
                .iter()
                .map(|inj| ArmedState { inj: inj.clone(), hits: 0 })
                .collect(),
        });
    }
    for c in &FIRED {
        c.store(0, Ordering::SeqCst);
    }
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disarm everything (the "fault clears" transition in chaos runs).
/// Fired counters survive until the next [`install`] so callers can still
/// read how many faults the cleared plan fired.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
    *PLAN.lock().unwrap_or_else(|p| p.into_inner()) = None;
}

/// Faults fired at `point` since the last [`install`].
pub fn fired(point: Point) -> u64 {
    FIRED[point.index()].load(Ordering::Relaxed)
}

/// Total faults fired since the last [`install`].
pub fn fired_total() -> u64 {
    FIRED.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Injection state is process-global: anything that installs a plan
/// (tests, the chaos driver, the CLI) holds this guard for the session so
/// concurrent users serialize instead of trampling each other's plans.
pub fn session_guard() -> MutexGuard<'static, ()> {
    SESSION.lock().unwrap_or_else(|p| p.into_inner())
}

/// splitmix64 finalizer mapping (seed, point, hit ordinal) to [0, 1) —
/// the same determinism discipline as the trace sampler.
fn unit_hash(seed: u64, point: Point, hit: u64) -> f64 {
    let mut z = seed
        .wrapping_add(hit.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(point.index() as u64);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// Should a hit at `point` with this `key` fire? Counts the hit against
/// every matching arm; any matching arm firing fires the point.
fn should_fire(point: Point, key: &str) -> bool {
    if !enabled() {
        return false;
    }
    let mut g = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    let Some(plan) = g.as_mut() else { return false };
    let seed = plan.seed;
    let mut fire = false;
    for arm in plan.arms.iter_mut() {
        if arm.inj.point != point {
            continue;
        }
        if let Some(t) = &arm.inj.target {
            if !key.contains(t.as_str()) {
                continue;
            }
        }
        arm.hits += 1;
        fire |= match arm.inj.arm {
            Arm::Nth(n) => arm.hits == n,
            Arm::Rate(r) => unit_hash(seed, point, arm.hits) < r,
        };
    }
    if fire {
        FIRED[point.index()].fetch_add(1, Ordering::Relaxed);
    }
    fire
}

/// How long [`slow_exec`] stalls when it fires — bounded by construction.
pub const STALL: Duration = Duration::from_millis(2);

/// Kernel-panic injection point: panics when armed for this key. Sited
/// inside the coordinator's `catch_unwind` boundary, so firing exercises
/// the real containment path, not a simulation of it.
#[inline]
pub fn kernel_panic(key: &str) {
    if enabled() && should_fire(Point::KernelPanic, key) {
        panic!("injected kernel fault at {key}");
    }
}

/// Slow-exec stall: sleeps [`STALL`] when armed for this key.
#[inline]
pub fn slow_exec(key: &str) {
    if enabled() && should_fire(Point::SlowExec, key) {
        std::thread::sleep(STALL);
    }
}

/// Artifact-IO injection point: a synthetic transient error when armed,
/// `None` otherwise. The store's retry loop treats the returned error
/// exactly like a real one.
#[inline]
pub fn artifact_io(key: &str) -> Option<io::Error> {
    if enabled() && should_fire(Point::ArtifactIo, key) {
        Some(io::Error::other(format!("injected artifact IO fault at {key}")))
    } else {
        None
    }
}

/// Checksum-flip injection point: corrupts one byte in flight when armed,
/// so decode-side validation must catch it.
#[inline]
pub fn checksum_flip(key: &str, bytes: &mut [u8]) {
    if enabled() && should_fire(Point::ChecksumFlip, key) && !bytes.is_empty() {
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
    }
}

/// Net-drop injection point: `true` when a network response should be
/// dropped in flight. Pure decision — the siting (skipping the frame
/// write) lives in [`crate::net::server`], so firing exercises the shard
/// router's real timeout-and-retry path, not a simulation of it.
#[inline]
pub fn net_drop(key: &str) -> bool {
    enabled() && should_fire(Point::NetDrop, key)
}

/// Net-stall injection point: sleeps [`STALL`] before a network write when
/// armed for this key (slow-peer pressure, no error).
#[inline]
pub fn net_stall(key: &str) {
    if enabled() && should_fire(Point::NetStall, key) {
        std::thread::sleep(STALL);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RAII: tests that arm the global plan must leave it disarmed even
    /// when an assertion unwinds mid-test.
    struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            disable();
        }
    }

    #[test]
    fn parse_accepts_the_documented_grammar() {
        let p = FaultPlan::parse("kernel_panic", 1).unwrap();
        assert_eq!(p.injections.len(), 1);
        assert_eq!(p.injections[0].point, Point::KernelPanic);
        assert_eq!(p.injections[0].target, None);
        assert_eq!(p.injections[0].arm, Arm::Rate(1.0));

        let p = FaultPlan::parse("artifact_io@hrpb-:nth=2; slow_exec:rate=0.25", 7).unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.injections.len(), 2);
        assert_eq!(p.injections[0].point, Point::ArtifactIo);
        assert_eq!(p.injections[0].target.as_deref(), Some("hrpb-"));
        assert_eq!(p.injections[0].arm, Arm::Nth(2));
        assert_eq!(p.injections[1].arm, Arm::Rate(0.25));

        // a target may itself contain '@' (engine-qualified keys)
        let p = FaultPlan::parse("kernel_panic@csr@victim", 1).unwrap();
        assert_eq!(p.injections[0].target.as_deref(), Some("csr@victim"));

        // PR 10 network points ride the same grammar: targets are shard
        // names, arms are unchanged
        let p = FaultPlan::parse("net_drop@shard-0:rate=0.05; net_stall@shard-1:nth=3", 5).unwrap();
        assert_eq!(p.injections.len(), 2);
        assert_eq!(p.injections[0].point, Point::NetDrop);
        assert_eq!(p.injections[0].target.as_deref(), Some("shard-0"));
        assert_eq!(p.injections[0].arm, Arm::Rate(0.05));
        assert_eq!(p.injections[1].point, Point::NetStall);
        assert_eq!(p.injections[1].arm, Arm::Nth(3));
    }

    #[test]
    fn net_point_parse_stays_all_or_nothing() {
        // one bad arm in a spec that also names the new points rejects the
        // whole plan — nothing is armed
        for bad in ["net_drop:rate=2.0", "net_stall:nth=0; kernel_panic", "net_drop@"] {
            assert!(FaultPlan::parse(bad, 1).is_err(), "'{bad}' must be rejected");
        }
        assert!(!enabled());
    }

    #[test]
    fn parse_rejects_bad_specs_without_arming_anything() {
        for bad in [
            "",
            " ; ",
            "mystery_point",
            "kernel_panic:rate=2.0",
            "kernel_panic:rate=x",
            "kernel_panic:nth=0",
            "kernel_panic:nth=1.5",
            "kernel_panic:every=3",
            "kernel_panic:rate",
            "kernel_panic@",
        ] {
            let err = FaultPlan::parse(bad, 1);
            assert!(err.is_err(), "'{bad}' must be rejected, got {err:?}");
        }
        // parsing never touches the global gate — no partial arming
        assert!(!enabled());
    }

    #[test]
    fn disabled_points_are_inert() {
        let _s = session_guard();
        disable();
        assert!(!enabled());
        kernel_panic("any"); // must not panic
        slow_exec("any");
        assert!(artifact_io("any").is_none());
        let mut bytes = [1u8, 2, 3];
        checksum_flip("any", &mut bytes);
        assert_eq!(bytes, [1, 2, 3]);
        assert!(!net_drop("any"));
        net_stall("any"); // must not stall
    }

    #[test]
    fn net_points_fire_and_count_like_the_rest() {
        let _s = session_guard();
        let _d = Disarm;
        install(&FaultPlan::parse("net_drop@shard-0:nth=2", 3).unwrap());
        assert!(!net_drop("net@shard-0"));
        assert!(net_drop("net@shard-0"), "second targeted hit fires");
        assert!(!net_drop("net@shard-1"), "untargeted shard never fires");
        assert_eq!(fired(Point::NetDrop), 1);

        install(&FaultPlan::parse("net_stall:nth=1", 3).unwrap());
        let t0 = std::time::Instant::now();
        net_stall("net@shard-0");
        assert!(t0.elapsed() >= STALL, "armed net_stall must stall at least STALL");
        assert_eq!(fired(Point::NetStall), 1);
    }

    #[test]
    fn nth_arming_fires_exactly_once_on_the_named_hit() {
        let _s = session_guard();
        let _d = Disarm;
        install(&FaultPlan::parse("artifact_io:nth=3", 9).unwrap());
        assert!(artifact_io("k").is_none());
        assert!(artifact_io("k").is_none());
        assert!(artifact_io("k").is_some(), "third hit fires");
        assert!(artifact_io("k").is_none(), "nth fires once, not from the nth on");
        assert_eq!(fired(Point::ArtifactIo), 1);
        assert_eq!(fired_total(), 1);
    }

    #[test]
    fn targets_filter_by_substring_and_rates_are_deterministic() {
        let _s = session_guard();
        let _d = Disarm;
        install(&FaultPlan::parse("checksum_flip@victim:rate=1.0", 3).unwrap());
        let mut hit = [0u8; 4];
        let mut missed = [0u8; 4];
        checksum_flip("hrpb@victim", &mut hit);
        checksum_flip("hrpb@clean", &mut missed);
        assert_ne!(hit, [0u8; 4], "targeted key must be corrupted");
        assert_eq!(missed, [0u8; 4], "untargeted key must pass through");

        // rate=0 never fires; the same seed reproduces the same pattern
        install(&FaultPlan::parse("artifact_io:rate=0.0", 3).unwrap());
        for _ in 0..64 {
            assert!(artifact_io("k").is_none());
        }
        let pattern = |seed: u64| -> Vec<bool> {
            install(&FaultPlan::parse("artifact_io:rate=0.5", seed).unwrap());
            (0..64).map(|_| artifact_io("k").is_some()).collect()
        };
        let a = pattern(11);
        let b = pattern(11);
        assert_eq!(a, b, "same seed, same firing pattern");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f), "rate=0.5 mixes hits and misses");
    }

    #[test]
    fn install_resets_fired_counters_and_disable_clears_the_gate() {
        let _s = session_guard();
        let _d = Disarm;
        install(&FaultPlan::parse("artifact_io:nth=1", 1).unwrap());
        assert!(artifact_io("k").is_some());
        assert_eq!(fired_total(), 1);
        disable();
        assert!(!enabled());
        // counters survive the clear (chaos reads them post-phase) ...
        assert_eq!(fired_total(), 1);
        // ... and reset on the next install
        install(&FaultPlan::parse("artifact_io:nth=1", 1).unwrap());
        assert_eq!(fired_total(), 0);
    }

    #[test]
    fn point_names_round_trip() {
        let mut seen = [false; Point::COUNT];
        for p in Point::all() {
            seen[p.index()] = true;
            assert_eq!(Point::parse(p.name()), Some(p));
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(Point::parse("nope"), None);
    }
}
