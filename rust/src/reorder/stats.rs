//! Exact pre-build HRPB brick statistics, computed from the CSR and a
//! candidate row order / brick geometry without building the HRPB.
//!
//! The builder compacts each panel's active columns to the left, so the
//! panel's brick columns are exactly the `brick_k`-wide groups of the sorted
//! column union, and every such group holds at least one nonzero. That
//! makes the brick counts a pure function of per-panel column unions: no
//! pattern encoding or value packing is needed to price a permutation — or
//! a candidate [`BrickGeometry`]. [`panel_stats_geo`] is equivalence-tested
//! against [`crate::hrpb::stats::compute`] on built instances for every
//! catalog geometry — it is *exact*, not an approximation, which is what
//! lets the planner gate reorder activation AND pick the brick geometry
//! from the CSR without ever paying for a speculative build.

use crate::formats::Csr;
use crate::params::BrickGeometry;
use crate::reorder::RowPermutation;
use crate::util::bits::ceil_div;

/// Brick statistics of an HRPB that *would be built* from a given row
/// order and geometry (field meanings match [`crate::hrpb::HrpbStats`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PanelStats {
    pub nnz: usize,
    pub num_blocks: usize,
    pub num_bricks: usize,
    pub num_brick_cols: usize,
    /// Brick density `nnz / (num_bricks · brick_m · brick_k)`.
    pub alpha: f64,
    /// Active bricks per occupied brick column (1.0 identically when
    /// TM = brick_m).
    pub beta: f64,
}

impl PanelStats {
    /// The MMA-slot work proxy the geometry chooser minimizes: total
    /// pattern slots fed to the (modeled) tensor units, `num_bricks ·
    /// brick_m · brick_k`. Equal nnz across geometries, so minimizing
    /// slots maximizes α.
    pub fn brick_slots(&self, geo: BrickGeometry) -> usize {
        self.num_bricks * geo.bits()
    }
}

/// Compute the brick statistics of building `csr` at `(tm, tk)` under
/// `perm` (`None` = arrival order) with the default geometry.
pub fn panel_stats(
    csr: &Csr,
    perm: Option<&RowPermutation>,
    tm: usize,
    tk: usize,
) -> PanelStats {
    panel_stats_geo(csr, perm, BrickGeometry::DEFAULT, tm, tk)
}

/// Compute the brick statistics of building `csr` at `(tm, tk)` under
/// `perm` with brick geometry `geo`.
pub fn panel_stats_geo(
    csr: &Csr,
    perm: Option<&RowPermutation>,
    geo: BrickGeometry,
    tm: usize,
    tk: usize,
) -> PanelStats {
    assert!(tm % geo.brick_m == 0 && tm > 0 && tm <= 256, "invalid TM {tm}");
    assert!(tk % geo.brick_k == 0 && tk > 0, "invalid TK {tk}");
    if let Some(p) = perm {
        assert_eq!(p.len(), csr.rows, "permutation rows != matrix rows");
    }
    let rows = csr.rows;
    let num_panels = ceil_div(rows.max(1), tm);
    let bricks_per_col = tm / geo.brick_m;
    let mut nnz = 0usize;
    let mut num_blocks = 0usize;
    let mut num_bricks = 0usize;
    let mut num_brick_cols = 0usize;
    // scratch reused across panels
    let mut union: Vec<u32> = Vec::new();
    let mut group: Vec<usize> = Vec::new();
    for p in 0..num_panels {
        let r0 = p * tm;
        let r1 = ((p + 1) * tm).min(rows);
        union.clear();
        for n in r0..r1 {
            let old = perm.map_or(n, |pm| pm.new_to_old[n] as usize);
            union.extend_from_slice(&csr.col_idx[csr.row_range(old)]);
        }
        if union.is_empty() {
            continue;
        }
        nnz += union.len();
        union.sort_unstable();
        union.dedup();
        let l = union.len();
        num_blocks += ceil_div(l, tk);
        // compaction packs active columns left, so every brick_k-wide group
        // of the union is an occupied brick column
        num_brick_cols += ceil_div(l, geo.brick_k);
        if bricks_per_col == 1 {
            // TM = brick_m: one brick row per panel — every occupied brick
            // column holds exactly one brick
            num_bricks += ceil_div(l, geo.brick_k);
        } else {
            // taller panels: a brick is active iff its brick_m-row group
            // touches its brick column; map each row's columns to compacted
            // slots and count distinct (group, slot/brick_k) pairs per group
            for g in 0..bricks_per_col {
                group.clear();
                let g0 = r0 + g * geo.brick_m;
                let g1 = (g0 + geo.brick_m).min(r1);
                for n in g0..g1 {
                    let old = perm.map_or(n, |pm| pm.new_to_old[n] as usize);
                    for &c in &csr.col_idx[csr.row_range(old)] {
                        let slot =
                            union.binary_search(&c).expect("column is in the panel union");
                        group.push(slot / geo.brick_k);
                    }
                }
                group.sort_unstable();
                group.dedup();
                num_bricks += group.len();
            }
        }
    }
    let brick_slots = (num_bricks * geo.bits()) as f64;
    let alpha = if num_bricks == 0 { 0.0 } else { nnz as f64 / brick_slots };
    let beta = if num_brick_cols == 0 {
        0.0
    } else {
        num_bricks as f64 / num_brick_cols as f64
    };
    PanelStats { nnz, num_blocks, num_bricks, num_brick_cols, alpha, beta }
}

/// Price every catalog geometry from the CSR under `perm` (`None` = arrival
/// order) — one exact [`PanelStats`] per [`BrickGeometry::CATALOG`] entry,
/// in catalog order. This is what the planner's geometry chooser ranks: the
/// registry prices under the row order it is about to build, and no build
/// happens until the winner is known.
pub fn price_catalog(
    csr: &Csr,
    perm: Option<&RowPermutation>,
    tm: usize,
    tk: usize,
) -> Vec<(BrickGeometry, PanelStats)> {
    BrickGeometry::CATALOG
        .iter()
        .map(|&geo| (geo, panel_stats_geo(csr, perm, geo, tm, tk)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::{builder, stats as hstats};
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    fn assert_matches_built_geo(
        csr: &Csr,
        perm: Option<&RowPermutation>,
        geo: BrickGeometry,
        tm: usize,
        tk: usize,
    ) {
        let predicted = panel_stats_geo(csr, perm, geo, tm, tk);
        let built = match perm {
            Some(p) => builder::build_with_geometry(&p.apply_csr(csr), geo, tm, tk),
            None => builder::build_with_geometry(csr, geo, tm, tk),
        };
        let s = hstats::compute_serial(&built);
        assert_eq!(predicted.nnz, s.nnz);
        assert_eq!(predicted.num_blocks, s.num_blocks, "blocks at {geo} tm={tm} tk={tk}");
        assert_eq!(predicted.num_bricks, s.num_bricks, "bricks at {geo} tm={tm} tk={tk}");
        assert_eq!(predicted.num_brick_cols, s.num_brick_cols, "brick cols at {geo}");
        assert!((predicted.alpha - s.alpha).abs() < 1e-12);
        assert!((predicted.beta - s.beta).abs() < 1e-12);
    }

    fn assert_matches_built(csr: &Csr, perm: Option<&RowPermutation>, tm: usize, tk: usize) {
        assert_matches_built_geo(csr, perm, BrickGeometry::DEFAULT, tm, tk);
    }

    #[test]
    fn exact_against_built_stats_at_default_tiles() {
        let mut rng = Rng::new(60);
        for density in [0.01, 0.05, 0.2] {
            let coo = Coo::random(130, 170, density, &mut rng);
            let csr = Csr::from_coo(&coo);
            assert_matches_built(&csr, None, 16, 16);
        }
    }

    #[test]
    fn exact_for_taller_panels_and_other_tk() {
        let mut rng = Rng::new(61);
        let coo = Coo::random(200, 150, 0.08, &mut rng);
        let csr = Csr::from_coo(&coo);
        assert_matches_built(&csr, None, 32, 16);
        assert_matches_built(&csr, None, 16, 32);
        assert_matches_built(&csr, None, 48, 8);
    }

    #[test]
    fn exact_under_a_permutation() {
        let mut rng = Rng::new(62);
        let coo = Coo::random(96, 96, 0.1, &mut rng);
        let csr = Csr::from_coo(&coo);
        let perm = RowPermutation::random(96, &mut rng);
        assert_matches_built(&csr, Some(&perm), 16, 16);
        assert_matches_built(&csr, Some(&perm), 32, 16);
    }

    #[test]
    fn exact_for_every_catalog_geometry() {
        let mut rng = Rng::new(63);
        for density in [0.02, 0.08, 0.2] {
            let coo = Coo::random(160, 140, density, &mut rng);
            let csr = Csr::from_coo(&coo);
            for geo in BrickGeometry::CATALOG {
                assert_matches_built_geo(&csr, None, geo, 16, 16);
                assert_matches_built_geo(&csr, None, geo, 32, 16);
            }
        }
    }

    #[test]
    fn exact_for_catalog_geometries_under_a_permutation() {
        let mut rng = Rng::new(64);
        let coo = Coo::random(128, 96, 0.1, &mut rng);
        let csr = Csr::from_coo(&coo);
        let perm = RowPermutation::random(128, &mut rng);
        for geo in BrickGeometry::CATALOG {
            assert_matches_built_geo(&csr, Some(&perm), geo, 16, 16);
        }
    }

    #[test]
    fn price_catalog_covers_the_catalog_and_agrees_with_direct_pricing() {
        let mut rng = Rng::new(65);
        let coo = Coo::random(96, 128, 0.07, &mut rng);
        let csr = Csr::from_coo(&coo);
        let priced = price_catalog(&csr, None, 16, 16);
        assert_eq!(priced.len(), BrickGeometry::CATALOG.len());
        for (i, (geo, s)) in priced.iter().enumerate() {
            assert_eq!(*geo, BrickGeometry::CATALOG[i]);
            assert_eq!(*s, panel_stats_geo(&csr, None, *geo, 16, 16));
            assert_eq!(s.brick_slots(*geo), s.num_bricks * geo.bits());
            // all geometries price the same matrix: identical nnz
            assert_eq!(s.nnz, priced[0].1.nnz);
        }
        // pricing under a permutation matches per-geometry direct pricing
        let perm = RowPermutation::random(96, &mut rng);
        for (geo, s) in price_catalog(&csr, Some(&perm), 16, 16) {
            assert_eq!(s, panel_stats_geo(&csr, Some(&perm), geo, 16, 16));
        }
    }

    #[test]
    fn prop_exactness_over_sparse_corpus() {
        let g = SparseGen { max_m: 70, max_k: 90, max_density: 0.25 };
        check("panel_stats == built stats", 25, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let csr = Csr::from_coo(&coo);
            let predicted = panel_stats(&csr, None, 16, 16);
            let s = hstats::compute_serial(&builder::build_with(&csr, 16, 16));
            predicted.num_bricks == s.num_bricks
                && predicted.num_brick_cols == s.num_brick_cols
                && predicted.num_blocks == s.num_blocks
                && predicted.nnz == s.nnz
        });
    }

    #[test]
    fn empty_matrix_is_all_zero() {
        let csr = Csr::from_coo(&Coo::new(32, 32));
        let s = panel_stats(&csr, None, 16, 16);
        assert_eq!(s.num_bricks, 0);
        assert_eq!(s.alpha, 0.0);
        assert_eq!(s.beta, 0.0);
    }
}
