//! Row reordering — similarity-clustered HRPB packing.
//!
//! The TCU-Synergy model says HRPB throughput is governed by brick density
//! `α` and brick-column reuse `β` ([`crate::synergy`]), and both are fixed
//! by whatever row order the input arrives in: a matrix whose similar rows
//! are scattered across row panels lands in the low-synergy regime even
//! when the latent structure is dense. This module recovers that structure
//! *before* the kernel runs (the Acc-SpMM / FlashSparse data-affinity
//! argument): rows with overlapping column supports are permuted into the
//! same `TM`-row panel, so their nonzeros share bricks and `α` rises.
//!
//! Pipeline:
//!
//! 1. [`signature`] — a minhash signature per row over its column-block
//!    (brick-column) support; estimated Jaccard similarity is the fraction
//!    of agreeing components.
//! 2. [`cluster`] — greedy packing over the LSH (lexicographic-signature)
//!    ordering: each panel seeds with the next unassigned row and greedily
//!    pulls the most similar rows from a bounded lookahead window. Empty
//!    rows carry the max signature and sink to the tail, compacting all
//!    real work into leading panels.
//! 3. [`stats`] — exact post-permutation brick statistics straight from the
//!    CSR + permutation (no HRPB build), pricing a proposal before anything
//!    is rebuilt. The planner gates activation on the predicted α gain
//!    ([`crate::planner::Planner::gate_reorder`]).
//!
//! An activated [`RowPermutation`] is attached to the built
//! [`Hrpb`](crate::hrpb::Hrpb); the native engine fuses the inverse scatter
//! into its kernel epilogue so `spmm` output always comes back in original
//! row order with no extra pass over C, and artifacts persist the
//! permutation (format v3, [`crate::hrpb::serialize`]).

pub mod cluster;
pub mod signature;
pub mod stats;

pub use cluster::pack;
pub use signature::{row_signatures, Signature, SIG_HASHES};
pub use stats::{panel_stats, panel_stats_geo, price_catalog, PanelStats};

use crate::formats::{Coo, Csr};
use crate::util::rng::Rng;

/// A row permutation in both directions. Position `n` of the reordered
/// matrix holds original row `new_to_old[n]`; original row `o` moved to
/// position `old_to_new[o]`. The two maps are mutual inverses
/// (`forward ∘ inverse = id`, enforced by [`RowPermutation::validate`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowPermutation {
    /// `new_to_old[n]` = original index of the row placed at position `n`.
    pub new_to_old: Vec<u32>,
    /// `old_to_new[o]` = position original row `o` was moved to.
    pub old_to_new: Vec<u32>,
}

impl RowPermutation {
    /// The identity permutation on `rows` rows.
    pub fn identity(rows: usize) -> RowPermutation {
        let id: Vec<u32> = (0..rows as u32).collect();
        RowPermutation { new_to_old: id.clone(), old_to_new: id }
    }

    /// Build from the forward map, validating it is a bijection and
    /// deriving the inverse.
    pub fn from_new_to_old(new_to_old: Vec<u32>) -> Result<RowPermutation, String> {
        let rows = new_to_old.len();
        let mut old_to_new = vec![u32::MAX; rows];
        for (n, &o) in new_to_old.iter().enumerate() {
            let slot = old_to_new
                .get_mut(o as usize)
                .ok_or_else(|| format!("permutation target {o} out of range ({rows} rows)"))?;
            if *slot != u32::MAX {
                return Err(format!("permutation maps row {o} twice"));
            }
            *slot = n as u32;
        }
        Ok(RowPermutation { new_to_old, old_to_new })
    }

    /// A uniformly random permutation (deterministic per seed) — the bench
    /// corpus uses this to *hide* structure that reordering then recovers.
    pub fn random(rows: usize, rng: &mut Rng) -> RowPermutation {
        let mut order: Vec<u32> = (0..rows as u32).collect();
        rng.shuffle(&mut order);
        RowPermutation::from_new_to_old(order).expect("shuffle emits a bijection")
    }

    /// Number of rows the permutation spans.
    pub fn len(&self) -> usize {
        self.new_to_old.len()
    }

    pub fn is_empty(&self) -> bool {
        self.new_to_old.is_empty()
    }

    /// `true` when the permutation moves nothing.
    pub fn is_identity(&self) -> bool {
        self.new_to_old.iter().enumerate().all(|(n, &o)| n as u32 == o)
    }

    /// Check the bijection invariants (artifact decode, property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.old_to_new.len() != self.new_to_old.len() {
            return Err("permutation maps differ in length".into());
        }
        for (n, &o) in self.new_to_old.iter().enumerate() {
            match self.old_to_new.get(o as usize) {
                Some(&back) if back as usize == n => {}
                Some(_) => return Err(format!("inverse disagrees at position {n}")),
                None => return Err(format!("permutation target {o} out of range")),
            }
        }
        Ok(())
    }

    /// The row-permuted CSR: new row `n` holds original row
    /// `new_to_old[n]`'s entries (per-row column order is preserved).
    pub fn apply_csr(&self, csr: &Csr) -> Csr {
        assert_eq!(self.len(), csr.rows, "permutation rows != matrix rows");
        let mut row_ptr = Vec::with_capacity(csr.rows + 1);
        row_ptr.push(0u32);
        let mut col_idx = Vec::with_capacity(csr.nnz());
        let mut values = Vec::with_capacity(csr.nnz());
        for &old in &self.new_to_old {
            let r = csr.row_range(old as usize);
            col_idx.extend_from_slice(&csr.col_idx[r.clone()]);
            values.extend_from_slice(&csr.values[r]);
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { rows: csr.rows, cols: csr.cols, row_ptr, col_idx, values }
    }

    /// The row-permuted COO (normalized by construction).
    pub fn apply_coo(&self, coo: &Coo) -> Coo {
        self.apply_csr(&Csr::from_coo(coo)).to_coo()
    }
}

/// Reorder outcome summary, threaded through plans, registry entries and
/// the metrics report (`reorder=[...]`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Gains {
    /// Brick density in the arrival row order.
    pub alpha_before: f64,
    /// Brick density after similarity-clustered packing.
    pub alpha_after: f64,
    /// Brick-column reuse before (1.0 identically at TM = brick_m).
    pub beta_before: f64,
    /// Brick-column reuse after.
    pub beta_after: f64,
    /// One-time cost of the signature + clustering + pricing pass
    /// (seconds). Zero when the permutation was warm-loaded from an
    /// artifact.
    pub seconds: f64,
}

/// A priced reorder candidate: the permutation plus exact pre/post brick
/// statistics. Produced by [`propose`], gated by the planner.
#[derive(Clone, Debug)]
pub struct Proposal {
    pub perm: RowPermutation,
    /// Brick statistics in the arrival row order.
    pub before: PanelStats,
    /// Brick statistics under `perm`.
    pub after: PanelStats,
}

impl Proposal {
    /// Rows the proposal spans.
    pub fn rows(&self) -> usize {
        self.perm.len()
    }

    /// Predicted α improvement factor (1.0 when the matrix is empty).
    pub fn alpha_gain(&self) -> f64 {
        if self.before.alpha > 0.0 {
            self.after.alpha / self.before.alpha
        } else {
            1.0
        }
    }

    /// The reportable gains of activating this proposal, with `seconds`
    /// recording the measured one-time cost.
    pub fn gains(&self, seconds: f64) -> Gains {
        Gains {
            alpha_before: self.before.alpha,
            alpha_after: self.after.alpha,
            beta_before: self.before.beta,
            beta_after: self.after.beta,
            seconds,
        }
    }
}

/// Compute a reorder proposal for `csr` at tile sizes `(tm, tk)`:
/// signatures, greedy clustering, and the exact before/after pricing the
/// activation gate consumes.
pub fn propose(csr: &Csr, tm: usize, tk: usize) -> Proposal {
    let sigs = signature::row_signatures(csr);
    let perm = cluster::pack(csr.rows, &sigs, tm);
    let before = stats::panel_stats(csr, None, tm, tk);
    let after = stats::panel_stats(csr, Some(&perm), tm, tk);
    Proposal { perm, before, after }
}

/// Build the HRPB of `csr` under `perm` and attach the permutation: the
/// registry's activation path. The native engine reads the attached
/// permutation and scatters its output back to original row order.
pub fn build_reordered(
    csr: &Csr,
    perm: RowPermutation,
    tm: usize,
    tk: usize,
    threads: usize,
) -> crate::hrpb::Hrpb {
    build_reordered_geo(csr, perm, crate::params::BrickGeometry::DEFAULT, tm, tk, threads)
}

/// [`build_reordered`] at an explicit brick geometry — the registry's path
/// when the geometry chooser and the reorder gate both activate.
pub fn build_reordered_geo(
    csr: &Csr,
    perm: RowPermutation,
    geo: crate::params::BrickGeometry,
    tm: usize,
    tk: usize,
    threads: usize,
) -> crate::hrpb::Hrpb {
    let permuted = perm.apply_csr(csr);
    let mut hrpb =
        crate::hrpb::builder::build_with_geometry_parallel(&permuted, geo, tm, tk, threads);
    hrpb.perm = Some(std::sync::Arc::new(perm));
    hrpb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{TK, TM};
    use crate::util::proptest::{check, SparseGen};

    #[test]
    fn identity_roundtrip_and_properties() {
        let p = RowPermutation::identity(8);
        assert!(p.is_identity());
        assert_eq!(p.len(), 8);
        p.validate().unwrap();
        let coo = Coo::from_triplets(8, 8, &[(0, 1, 1.0), (7, 3, 2.0)]);
        assert_eq!(p.apply_coo(&coo).to_dense(), coo.to_dense());
    }

    #[test]
    fn prop_forward_compose_inverse_is_identity() {
        let g = crate::util::proptest::UsizeGen { lo: 0, hi: 300 };
        check("perm forward∘inverse = id", 60, &g, |&rows| {
            let mut rng = Rng::new(rows as u64 * 7 + 1);
            let p = RowPermutation::random(rows, &mut rng);
            p.validate().is_ok()
                && (0..rows).all(|o| p.new_to_old[p.old_to_new[o] as usize] as usize == o)
                && (0..rows).all(|n| p.old_to_new[p.new_to_old[n] as usize] as usize == n)
        });
    }

    #[test]
    fn from_new_to_old_rejects_non_bijections() {
        assert!(RowPermutation::from_new_to_old(vec![0, 0]).is_err(), "duplicate target");
        assert!(RowPermutation::from_new_to_old(vec![2, 0]).is_err(), "out of range");
        assert!(RowPermutation::from_new_to_old(vec![1, 0]).is_ok());
    }

    #[test]
    fn apply_csr_permutes_rows_exactly() {
        let coo = Coo::from_triplets(3, 4, &[(0, 1, 1.0), (1, 0, 2.0), (1, 3, 3.0), (2, 2, 4.0)]);
        let csr = Csr::from_coo(&coo);
        let p = RowPermutation::from_new_to_old(vec![2, 0, 1]).unwrap();
        let r = p.apply_csr(&csr);
        r.validate().unwrap();
        let rows: Vec<Vec<(u32, f32)>> =
            (0..3).map(|i| r.row_entries(i).collect()).collect();
        assert_eq!(rows[0], vec![(2, 4.0)]);
        assert_eq!(rows[1], vec![(1, 1.0)]);
        assert_eq!(rows[2], vec![(0, 2.0), (3, 3.0)]);
    }

    #[test]
    fn degenerate_single_row_and_all_empty() {
        // single row: only one possible packing
        let one = Csr::from_coo(&Coo::from_triplets(1, 8, &[(0, 3, 1.0)]));
        let prop = propose(&one, TM, TK);
        assert!(prop.perm.is_identity());
        assert_eq!(prop.before, prop.after);

        // all-empty rows: nothing to cluster, stats all zero
        let empty = Csr::from_coo(&Coo::new(64, 32));
        let prop = propose(&empty, TM, TK);
        prop.perm.validate().unwrap();
        assert_eq!(prop.after.nnz, 0);
        assert_eq!(prop.after.num_bricks, 0);
        assert_eq!(prop.after.alpha, 0.0);
        assert_eq!(prop.alpha_gain(), 1.0);
    }

    #[test]
    fn propose_recovers_shuffled_block_structure() {
        // 16 dense 16-row units, rows shuffled: arrival order scatters every
        // panel across ~16 units; clustering must reassemble them
        let spec = crate::gen::MatrixSpec {
            name: "t".into(),
            rows: 256,
            family: crate::gen::Family::BlockDiag { unit: 16, unit_density: 0.8 },
            seed: 31,
        };
        let coo = spec.generate();
        let shuffled = RowPermutation::random(coo.rows, &mut Rng::new(99)).apply_coo(&coo);
        let csr = Csr::from_coo(&shuffled);
        let prop = propose(&csr, TM, TK);
        assert!(
            prop.alpha_gain() > 2.0,
            "clustering must recover the hidden units: α {} -> {}",
            prop.before.alpha,
            prop.after.alpha
        );
        assert!(prop.after.num_bricks < prop.before.num_bricks);
    }

    #[test]
    fn prop_proposal_permutations_are_valid_and_priced() {
        let g = SparseGen { max_m: 80, max_k: 100, max_density: 0.2 };
        check("propose emits valid priced permutations", 30, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let csr = Csr::from_coo(&coo);
            let prop = propose(&csr, TM, TK);
            prop.perm.validate().is_ok()
                && prop.perm.len() == case.m
                && prop.before.nnz == coo.nnz()
                && prop.after.nnz == coo.nnz()
                && prop.after.alpha <= 1.0 + 1e-12
        });
    }

    #[test]
    fn build_reordered_attaches_the_permutation_and_decodes_back() {
        let spec = crate::gen::MatrixSpec {
            name: "t".into(),
            rows: 128,
            family: crate::gen::Family::Community {
                communities: 8,
                intra_degree: 10,
                inter_frac: 0.05,
            },
            seed: 5,
        };
        let coo = spec.generate();
        let shuffled = RowPermutation::random(coo.rows, &mut Rng::new(17)).apply_coo(&coo);
        let csr = Csr::from_coo(&shuffled);
        let prop = propose(&csr, TM, TK);
        let hrpb = build_reordered(&csr, prop.perm.clone(), TM, TK, 3);
        hrpb.validate().unwrap();
        assert_eq!(hrpb.perm.as_deref(), Some(&prop.perm));
        // decode scatters rows back: the dense form is the ORIGINAL matrix
        assert_eq!(
            crate::hrpb::decode::to_dense(&hrpb).max_abs_diff(&shuffled.to_dense()),
            0.0,
            "decode must honor the permutation"
        );
    }
}
