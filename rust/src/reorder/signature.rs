//! Minhash column-block signatures — the similarity proxy behind the
//! clustering pass.
//!
//! Two rows share a brick only when their nonzeros fall into the same
//! brick-width column block after panel compaction, so the natural
//! similarity measure is the Jaccard overlap of their *column-block*
//! supports (`col / brick_k`, at the default geometry's width — the
//! clustering is a similarity ordering, not a per-geometry exact count, so
//! one block width serves the whole catalog). A minhash signature
//! estimates that overlap in O(1)
//! per pair: component `i` is the minimum of hash `h_i` over the row's
//! block ids, and `P[sig_a[i] == sig_b[i]] = J(a, b)` — so the fraction of
//! agreeing components estimates the Jaccard similarity, and sorting rows
//! lexicographically by signature is a multi-band LSH ordering that puts
//! high-overlap rows next to each other.

use crate::formats::Csr;
use crate::params::BrickGeometry;

/// Signature width. 8 components estimate Jaccard at ±1/8 granularity —
/// enough to separate "same support" from "disjoint support", which is
/// what panel packing needs — at 32 bytes per row.
pub const SIG_HASHES: usize = 8;

/// A row's minhash signature over its column-block support.
pub type Signature = [u32; SIG_HASHES];

/// Signature of a row with no nonzeros: all-max, so empty rows sort after
/// every real row and sink to the tail panels.
pub const EMPTY_SIG: Signature = [u32::MAX; SIG_HASHES];

/// Per-component hash seeds (distinct odd 64-bit constants; the SplitMix64
/// increment spaced by multiplication keeps the streams independent).
const SEEDS: [u64; SIG_HASHES] = [
    0x9E3779B97F4A7C15,
    0xBF58476D1CE4E5B9,
    0x94D049BB133111EB,
    0xD6E8FEB86659FD93,
    0xA5A5A5A5A5A5A5A5 | 1,
    0xC2B2AE3D27D4EB4F,
    0x165667B19E3779F9,
    0x27D4EB2F165667C5,
];

/// SplitMix64-style finalizer of `(block, seed)` truncated to 32 bits.
#[inline]
fn mix(block: u32, seed: u64) -> u32 {
    let mut z = (block as u64).wrapping_add(seed);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    (z ^ (z >> 31)) as u32
}

/// Signature of one row given its (sorted) column ids.
pub fn row_signature(cols: &[u32]) -> Signature {
    if cols.is_empty() {
        return EMPTY_SIG;
    }
    let mut sig = [u32::MAX; SIG_HASHES];
    let mut last_block = u32::MAX;
    for &c in cols {
        let block = c / BrickGeometry::DEFAULT.brick_k as u32;
        if block == last_block {
            continue; // cols are sorted: consecutive duplicates collapse
        }
        last_block = block;
        for (s, seed) in sig.iter_mut().zip(SEEDS) {
            *s = (*s).min(mix(block, seed));
        }
    }
    sig
}

/// Signatures for every row of `csr`.
pub fn row_signatures(csr: &Csr) -> Vec<Signature> {
    (0..csr.rows)
        .map(|r| row_signature(&csr.col_idx[csr.row_range(r)]))
        .collect()
}

/// Number of agreeing components — `overlap / SIG_HASHES` estimates the
/// Jaccard similarity of the two rows' column-block supports.
#[inline]
pub fn overlap(a: &Signature, b: &Signature) -> usize {
    a.iter().zip(b).filter(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;

    #[test]
    fn identical_supports_share_the_full_signature() {
        let a = row_signature(&[0, 5, 9, 40]);
        let b = row_signature(&[1, 4, 8, 41]); // same blocks {0, 1, 2, 10}
        assert_eq!(a, b, "block-identical supports must collide exactly");
        assert_eq!(overlap(&a, &b), SIG_HASHES);
    }

    #[test]
    fn disjoint_supports_rarely_agree() {
        let a = row_signature(&[0, 4, 8]);
        let b = row_signature(&[400, 404, 408]);
        assert!(overlap(&a, &b) <= 2, "disjoint blocks should almost never collide");
    }

    #[test]
    fn empty_rows_sort_last() {
        let real = row_signature(&[3]);
        assert!(real < EMPTY_SIG);
        assert_eq!(row_signature(&[]), EMPTY_SIG);
    }

    #[test]
    fn partial_overlap_is_between() {
        // half the blocks shared: expected overlap ~ SIG_HASHES/2
        let a = row_signature(&[0, 4, 8, 12]);
        let b = row_signature(&[0, 4, 100, 104]);
        let o = overlap(&a, &b);
        assert!(o >= 1 && o < SIG_HASHES, "overlap {o}");
    }

    #[test]
    fn signatures_cover_every_row() {
        let coo = Coo::from_triplets(4, 16, &[(0, 1, 1.0), (2, 8, 2.0), (2, 9, 3.0)]);
        let sigs = row_signatures(&Csr::from_coo(&coo));
        assert_eq!(sigs.len(), 4);
        assert_eq!(sigs[1], EMPTY_SIG);
        assert_eq!(sigs[3], EMPTY_SIG);
        assert_ne!(sigs[0], EMPTY_SIG);
        // cols 8 and 9 share block 2 -> single-block signature
        assert_eq!(sigs[2], row_signature(&[8]));
    }
}
