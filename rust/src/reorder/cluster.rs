//! Greedy similarity clustering: LSH ordering + windowed greedy panel
//! packing.
//!
//! Sorting rows lexicographically by minhash signature is a multi-band LSH
//! pass: rows with identical column-block support become adjacent, and
//! partially-overlapping rows land near each other (their low signature
//! components agree with high probability). The greedy packer then walks
//! that ordering panel by panel: each panel seeds with the next unassigned
//! row and pulls the `TM - 1` most similar rows (estimated Jaccard =
//! agreeing signature components) from a bounded lookahead window, so rows
//! the sort left *near* but not *next to* their cluster still pack
//! together. Empty rows carry the max signature, sink to the tail, and
//! leave the leading panels densely packed.
//!
//! Deterministic: ties break on the LSH position, never on iteration
//! order, so the same matrix always produces the same permutation (the
//! property artifact caching and the plan cache rely on).

use crate::reorder::signature::{overlap, Signature};
use crate::reorder::RowPermutation;

/// Lookahead window, in panels, the greedy packer scans past each seed.
/// Larger windows recover clusters the sort separated further at O(window)
/// extra work per row; 4 panels covers the band-boundary splits seen in
/// practice.
const WINDOW_PANELS: usize = 4;

/// Pack rows into `tm`-row panels by support similarity; returns the
/// permutation (position `n` of the packed order holds original row
/// `new_to_old[n]`).
pub fn pack(rows: usize, sigs: &[Signature], tm: usize) -> RowPermutation {
    assert_eq!(sigs.len(), rows, "one signature per row");
    if rows == 0 {
        return RowPermutation::identity(0);
    }
    // 1. LSH ordering: lexicographic over the signature, original index as
    // the deterministic tiebreak (keeps equal-support runs in arrival
    // order, which also preserves any cache-friendly locality they had)
    let mut order: Vec<u32> = (0..rows as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        sigs[a as usize].cmp(&sigs[b as usize]).then(a.cmp(&b))
    });
    if tm <= 1 || rows <= tm {
        // a single panel (or degenerate height): the ordering IS the packing
        return RowPermutation::from_new_to_old(order).expect("sort emits a bijection");
    }

    // 2. greedy packing over the ordering
    let window = tm * WINDOW_PANELS;
    let mut taken = vec![false; rows];
    let mut packed: Vec<u32> = Vec::with_capacity(rows);
    let mut head = 0usize; // first position in `order` that may be untaken
    let mut cand: Vec<(usize, usize)> = Vec::with_capacity(window); // (position, overlap)
    while packed.len() < rows {
        while head < rows && taken[order[head] as usize] {
            head += 1;
        }
        if head >= rows {
            break;
        }
        let seed = order[head] as usize;
        taken[seed] = true;
        packed.push(seed as u32);
        // the seed's companions: the most similar untaken rows in the window
        cand.clear();
        let mut pos = head + 1;
        while pos < rows && cand.len() < window {
            let r = order[pos] as usize;
            if !taken[r] {
                cand.push((pos, overlap(&sigs[seed], &sigs[r])));
            }
            pos += 1;
        }
        cand.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for &(p, _) in cand.iter().take(tm - 1) {
            let r = order[p] as usize;
            taken[r] = true;
            packed.push(r as u32);
        }
    }
    RowPermutation::from_new_to_old(packed).expect("greedy packing emits a bijection")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::signature::{row_signature, EMPTY_SIG, SIG_HASHES};

    #[test]
    fn packs_identical_supports_into_one_panel() {
        // 8 rows of support A interleaved with 8 of support B: packing must
        // separate them into two clean 8-row groups
        let a = row_signature(&[0, 4, 8]);
        let b = row_signature(&[100, 104, 108]);
        let sigs: Vec<Signature> =
            (0..16).map(|i| if i % 2 == 0 { a } else { b }).collect();
        let perm = pack(16, &sigs, 8);
        perm.validate().unwrap();
        for panel in 0..2 {
            let members = &perm.new_to_old[panel * 8..(panel + 1) * 8];
            let first = sigs[members[0] as usize];
            assert!(
                members.iter().all(|&r| sigs[r as usize] == first),
                "panel {panel} mixes supports: {members:?}"
            );
        }
    }

    #[test]
    fn empty_rows_sink_to_the_tail() {
        let real = row_signature(&[0]);
        let mut sigs = vec![EMPTY_SIG; 12];
        sigs[3] = real;
        sigs[9] = real;
        let perm = pack(12, &sigs, 4);
        assert_eq!(&perm.new_to_old[..2], &[3, 9], "real rows lead");
        assert!(perm.new_to_old[2..].iter().all(|&r| sigs[r as usize] == EMPTY_SIG));
    }

    #[test]
    fn window_recovers_separated_cluster_members() {
        // three clusters of 4 whose members the signature sort interleaves
        // only when signatures collide exactly — force near-misses by using
        // identical signatures (sort handles it) plus one stray row whose
        // signature differs in the last component only
        let base = row_signature(&[0, 4]);
        let mut stray = base;
        stray[SIG_HASHES - 1] = stray[SIG_HASHES - 1].wrapping_add(1);
        let mut sigs = vec![base; 7];
        sigs.push(stray);
        let perm = pack(8, &sigs, 4);
        perm.validate().unwrap();
        // the stray row sorts right after the identical run and the greedy
        // pass still packs full panels
        assert_eq!(perm.len(), 8);
    }

    #[test]
    fn deterministic_across_calls() {
        let sigs: Vec<Signature> = (0..64u32)
            .map(|i| row_signature(&[i % 7 * 4, i % 5 * 8 + 1]))
            .collect();
        let a = pack(64, &sigs, 16);
        let b = pack(64, &sigs, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(pack(0, &[], 16).len(), 0);
        let one = pack(1, &[row_signature(&[3])], 16);
        assert!(one.is_identity());
        // rows < tm: single-panel path
        let sigs = vec![row_signature(&[0]); 5];
        let p = pack(5, &sigs, 16);
        p.validate().unwrap();
        assert!(p.is_identity(), "equal signatures keep arrival order");
    }
}
