//! Analytical GPU cost models — the testbed substitution for the paper's
//! A100 / RTX-4090 measurements (DESIGN.md §2).
//!
//! The paper's own §4 analysis is a transaction-count model; this module
//! implements that model (Eqs 1-5) plus roofline limits (TCU/scalar compute,
//! shared-memory bandwidth, DRAM with an L2 estimate), wave-quantized grid
//! utilization and the §5 imbalance treatment, for all six algorithms of the
//! evaluation. The figures/tables benches drive these models over the
//! synthetic corpus.

pub mod algos;
pub mod machine;
pub mod profile;

pub use algos::{predict, predict_best_sc, Bound, Prediction};
pub use machine::Machine;
pub use profile::MatrixProfile;

use crate::formats::Coo;
use crate::spmm::Algo;

/// Convenience: profile + predict over a set of algorithms in one pass.
pub fn predict_all(coo: &Coo, n: usize, m: &Machine, algos: &[Algo]) -> Vec<(Algo, Prediction)> {
    let p = MatrixProfile::compute(coo);
    algos.iter().map(|&a| (a, predict(a, &p, n, m))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn predict_all_covers_requested_algos() {
        let coo = Coo::random(512, 512, 0.02, &mut Rng::new(1));
        let out = predict_all(&coo, 128, &Machine::a100(), &Algo::all());
        assert_eq!(out.len(), 7);
        for (a, pr) in out {
            assert!(pr.gflops > 0.0, "{}", a.name());
        }
    }
}
