//! Per-algorithm analytical cost models.
//!
//! Each model composes the same primitive terms — launch latency, compute
//! roofline (TCU or scalar), shared-memory transactions (the paper's Eqs
//! 1-3), DRAM traffic with an L2 reuse estimate, decode work, wave-quantized
//! grid utilization, and a §5-style load-imbalance factor — with the
//! *structural* differences between the algorithms. Nothing is fitted to
//! measured numbers; the who-wins shape must come from structure (DESIGN.md
//! §2). Absolute numbers are calibrated only by public hardware peaks.

use crate::gpumodel::machine::Machine;
use crate::gpumodel::profile::MatrixProfile;
use crate::params::{BrickGeometry, TK, TM, TN};
use crate::spmm::Algo;
use crate::synergy;

/// What limited the kernel in the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Launch,
    TcuCompute,
    ScalarCompute,
    Shmem,
    Dram,
    Decode,
}

impl Bound {
    pub fn name(&self) -> &'static str {
        match self {
            Bound::Launch => "launch",
            Bound::TcuCompute => "tcu",
            Bound::ScalarCompute => "scalar",
            Bound::Shmem => "shmem",
            Bound::Dram => "dram",
            Bound::Decode => "decode",
        }
    }

    /// Every bound, in stable order (the HRPB artifact format serializes a
    /// bound as its position in this array).
    pub fn all() -> [Bound; 6] {
        [
            Bound::Launch,
            Bound::TcuCompute,
            Bound::ScalarCompute,
            Bound::Shmem,
            Bound::Dram,
            Bound::Decode,
        ]
    }
}

/// Model output for one (algorithm, matrix, N, machine) point.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub time_s: f64,
    /// Useful throughput `2·nnz·N / time`.
    pub gflops: f64,
    pub bound: Bound,
    /// Component times (s): compute, shmem, dram, decode (pre-imbalance).
    pub t_compute: f64,
    pub t_shmem: f64,
    pub t_dram: f64,
    pub t_decode: f64,
    /// Load-imbalance multiplier applied to the binding term.
    pub imbalance: f64,
}

/// Effective fraction of B-gather DRAM traffic that misses L2: once the hot
/// B rows fit in L2 only compulsory traffic remains.
fn l2_miss(b_bytes_resident: f64, m: &Machine) -> f64 {
    if b_bytes_resident <= m.l2_bytes as f64 {
        0.0
    } else {
        1.0 - m.l2_bytes as f64 / b_bytes_resident
    }
}

fn finish(p: &MatrixProfile, n: usize, m: &Machine, grid: usize, shmem_per_block: usize,
          t_compute: f64, t_shmem: f64, t_dram: f64, t_decode: f64, imbalance: f64,
          compute_bound: Bound) -> Prediction {
    let util = m.grid_utilization(grid, shmem_per_block).max(1e-3);
    let launch = m.launch_overhead_us * 1e-6;
    let (mut tmax, mut bound) = (t_compute, compute_bound);
    for (t, b) in [(t_shmem, Bound::Shmem), (t_dram, Bound::Dram), (t_decode, Bound::Decode)] {
        if t > tmax {
            tmax = t;
            bound = b;
        }
    }
    // compute/decode scale with tail utilization; bandwidth terms do too
    // (fewer resident blocks can't saturate DRAM either)
    let mut time = launch + tmax * imbalance / util;
    if launch > tmax * imbalance / util {
        bound = Bound::Launch;
    }
    if time <= 0.0 {
        time = launch.max(1e-9);
    }
    let flops = p.flops(n);
    Prediction {
        time_s: time,
        gflops: flops / time / 1e9,
        bound,
        t_compute,
        t_shmem,
        t_dram,
        t_decode,
        imbalance,
    }
}

/// cuTeSpMM (this paper): HRPB + Algorithm 1 with §5 wave-aware balancing,
/// at the default brick geometry.
pub fn predict_cutespmm(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    predict_cutespmm_geo(p, n, m, BrickGeometry::DEFAULT)
}

/// cuTeSpMM with an explicit brick geometry: the zero-filled MMA volume
/// (bits per brick) and the shared-memory ledger both follow the geometry.
/// `p.hrpb` must describe an HRPB built (or priced) at that geometry —
/// brick counts are not transferable between shapes.
pub fn predict_cutespmm_geo(
    p: &MatrixProfile,
    n: usize,
    m: &Machine,
    geo: BrickGeometry,
) -> Prediction {
    let s = &p.hrpb;
    let nf = n as f64;
    let grid = p.hrpb_grid(n);
    let shmem = p.hrpb_shmem_per_block(n);

    // TCU compute: full zero-filled brick MMAs. Double-buffered shared
    // staging keeps the MMA pipe ~60% fed (the practical ceiling of
    // register-sourced m16n8k4 issue).
    let executed = 2.0 * s.num_bricks as f64 * geo.bits() as f64 * nf;
    let t_compute = executed / (m.tcu_tf32_tflops * 1e12 * 0.6);

    // Shared-memory transactions (Eqs 1-3 via the synergy model), 128 B each.
    let oi = synergy::model_with_geometry(s, n, TN, geo);
    let t_shmem = (oi.shmem_trans_a + oi.shmem_trans_b) * 128.0 / m.shmem_bw();

    // DRAM: packed A once; B gathered per block (TK coalesced row loads —
    // full-bandwidth, L2-filtered); C written once.
    let b_resident = p.cols as f64 * nf * 4.0;
    let b_gather = s.num_blocks as f64 * TK as f64 * nf * 4.0;
    let b_bytes = b_resident.min(b_gather) + (b_gather - b_resident).max(0.0) * l2_miss(b_resident, m);
    let c_bytes = p.rows as f64 * nf * 4.0;
    let t_dram = (p.hrpb_a_bytes() * (nf / 128.0).max(1.0) + b_bytes + c_bytes)
        / (m.dram_gbps * 1e9);

    // Decode: prefix popcounts on scalar cores, overlapped with MMAs but
    // bounded by scalar issue: ~8 int ops per lane per brick per TN pass.
    let passes = (nf / crate::params::TN as f64).max(1.0);
    let decode_ops = s.num_bricks as f64 * 32.0 * 8.0 * passes;
    let t_decode = decode_ops / (m.fp32_tflops * 1e12 * 0.5);

    // §5 wave-aware balancing: waves absorb imbalance; residual is the part
    // a single wave cannot hide, and splitting caps it near 1.
    let waves = m.num_waves(grid, shmem) as f64;
    let imbalance = (p.panel_imbalance / waves).max(1.0).min(1.15);

    finish(p, n, m, grid, shmem, t_compute, t_shmem, t_dram, t_decode, imbalance,
           Bound::TcuCompute)
}

/// TC-GNN SGT: single-level 16×8 TC blocks, B gathered from global memory
/// per block (no shared staging), dense tiles built by scalar cores.
pub fn predict_tcgnn(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    let nf = n as f64;
    let grid = p.tcgnn_grid();
    let shmem = 8 * 1024; // fixed SGT staging buffers

    // MMA issue stalls on un-double-buffered global fragment loads and the
    // serialized decode→load→MMA phase structure: the pipe runs a few
    // percent fed (§1's "not able to exploit the 8x"; the paper's Tables 3/4
    // put TC-GNN's *executed* throughput at ~1-4% of TCU peak).
    let executed = 2.0 * p.tcgnn_blocks as f64 * (TM * 8) as f64 * nf;
    let t_compute = executed / (m.tcu_tf32_tflops * 1e12 * 0.04);

    // B fetched per TC block: 8 rows × N via per-element gathers — each
    // 4-byte element drags a full 32-byte sector (8x waste, no staging).
    let b_resident = p.cols as f64 * nf * 4.0;
    let b_gather = p.tcgnn_blocks as f64 * 8.0 * nf * 32.0;
    let b_bytes = b_resident.min(b_gather) + (b_gather - b_resident).max(0.0) * l2_miss(b_resident, m);
    let a_bytes = p.nnz as f64 * 8.0;
    let c_bytes = p.rows as f64 * nf * 4.0;
    let t_dram = (a_bytes + c_bytes + b_bytes) / (m.dram_gbps * 1e9);

    // SGT decode: every dense tile element is placed by a scalar thread
    // (128 ops per block), *serialized before* the MMA (not overlapped).
    let decode_ops = p.tcgnn_blocks as f64 * 128.0 * 4.0;
    let t_decode = decode_ops / (m.fp32_tflops * 1e12 * 0.25);

    // no shared-memory staging: charge the register-path equivalent of the
    // Eq. 2 B term without the TN coarsening (TN = 8, one MMA tile)
    let s = &p.hrpb;
    let oi = synergy::model_with(s, n, 8);
    let t_shmem = (oi.shmem_trans_a + oi.shmem_trans_b) * 128.0 / m.shmem_bw();

    // row windows are natural units; no balancing pass at all
    let waves = m.num_waves(grid, shmem) as f64;
    let imbalance = (p.panel_imbalance / waves).max(1.0).min(2.0);

    finish(p, n, m, grid, shmem, t_compute, t_shmem, t_dram, t_decode, imbalance,
           Bound::TcuCompute)
}

/// Shared scaffolding for the scalar-core engines.
///
/// Scalar SpMM inner loops perform one gathered B load per FMA, so they are
/// load-store-unit bound: the LSU issues at 1/4 of the FP32 FMA rate. The
/// effective compute peak is therefore `fp32 × 0.25 × issue_eff`, with
/// `issue_eff` capturing each kernel's pipeline quality on top of that
/// structural ceiling.
const LSU_RATIO: f64 = 0.25;

struct ScalarCfg {
    /// Fraction of the LSU-bound ceiling the inner loop sustains.
    issue_eff: f64,
    /// Multiplier on gathered-B DRAM traffic (1 = every nnz×N load goes to
    /// DRAM post-L2; engines with shared-memory staging shrink it).
    b_gather_factor: f64,
    /// Extra C traffic multiplier (atomics for COO).
    c_factor: f64,
    /// Row-imbalance exposure (1 = fully exposed, 0 = immune).
    imbalance_exposure: f64,
}

fn predict_scalar(p: &MatrixProfile, n: usize, m: &Machine, cfg: ScalarCfg) -> Prediction {
    let nf = n as f64;
    // one warp per (32-row, 32-col) output tile: scalar kernels fill the
    // machine far more easily than the blocked TCU kernels
    let grid = p.rows.div_ceil(32).max(1) * n.div_ceil(32).max(1);
    let shmem = 16 * 1024;

    let t_compute = p.flops(n) / (m.fp32_tflops * 1e12 * LSU_RATIO * cfg.issue_eff);

    let b_resident = p.cols as f64 * nf * 4.0;
    let b_gather = p.nnz as f64 * nf * 4.0 * cfg.b_gather_factor;
    let b_bytes = b_resident.min(b_gather) + (b_gather - b_resident).max(0.0) * l2_miss(b_resident, m);
    let c_bytes = p.rows as f64 * nf * 4.0 * cfg.c_factor;
    let t_dram = (p.csr_bytes() + b_bytes + c_bytes) / (m.dram_gbps * 1e9);

    let row_imb = 1.0 + (p.row_cv * cfg.imbalance_exposure).min(1.5);

    finish(p, n, m, grid, shmem, t_compute, 0.0, t_dram, 0.0, row_imb, Bound::ScalarCompute)
}

/// cuSparse CSR: solid row-split kernel, L2-reliant B gather.
pub fn predict_csr(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    predict_scalar(p, n, m, ScalarCfg {
        issue_eff: 0.40,
        b_gather_factor: 0.5, // warp-level reuse of row slabs
        c_factor: 1.0,
        imbalance_exposure: 0.35,
    })
}

/// cuSparse COO: segmented reduction with atomic C updates.
pub fn predict_coo(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    predict_scalar(p, n, m, ScalarCfg {
        issue_eff: 0.25,
        b_gather_factor: 0.5,
        c_factor: 2.0, // atomic read-modify-write
        imbalance_exposure: 0.0, // nnz-split is immune to row skew
    })
}

/// Sputnik: row swizzle + residue-free vector loads.
pub fn predict_sputnik(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    predict_scalar(p, n, m, ScalarCfg {
        issue_eff: 0.50,
        b_gather_factor: 0.5,
        c_factor: 1.0,
        imbalance_exposure: 0.05, // swizzle flattens skew
    })
}

/// GE-SpMM: coalesced sparse-row caching in shared memory.
pub fn predict_gespmm(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    predict_scalar(p, n, m, ScalarCfg {
        issue_eff: 0.45,
        b_gather_factor: 0.35, // staged col indices -> coalesced B rows
        c_factor: 1.0,
        imbalance_exposure: 0.35,
    })
}

/// Dense oracle on TCUs (the no-compression strawman for ablation).
pub fn predict_dense(p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    let nf = n as f64;
    let executed = 2.0 * p.rows as f64 * p.cols as f64 * nf;
    let t_compute = executed / (m.tcu_tf32_tflops * 1e12);
    let bytes = p.rows as f64 * p.cols as f64 * 4.0
        + p.cols as f64 * nf * 4.0
        + p.rows as f64 * nf * 4.0;
    let t_dram = bytes / (m.dram_gbps * 1e9);
    let grid = (p.rows.div_ceil(128) * n.div_ceil(128)).max(1);
    finish(p, n, m, grid, 32 * 1024, t_compute, 0.0, t_dram, 0.0, 1.0, Bound::TcuCompute)
}

/// Dispatch one algorithm.
pub fn predict(algo: Algo, p: &MatrixProfile, n: usize, m: &Machine) -> Prediction {
    match algo {
        Algo::Hrpb => predict_cutespmm_geo(p, n, m, p.geometry),
        Algo::TcGnn => predict_tcgnn(p, n, m),
        Algo::Csr => predict_csr(p, n, m),
        Algo::Coo => predict_coo(p, n, m),
        Algo::Sputnik => predict_sputnik(p, n, m),
        Algo::GeSpmm => predict_gespmm(p, n, m),
        Algo::Dense => predict_dense(p, n, m),
    }
}

/// The paper's Best-SC envelope: fastest scalar-core prediction.
pub fn predict_best_sc(p: &MatrixProfile, n: usize, m: &Machine) -> (Algo, Prediction) {
    Algo::scalar_core()
        .into_iter()
        .map(|a| (a, predict(a, p, n, m)))
        .min_by(|a, b| a.1.time_s.partial_cmp(&b.1.time_s).unwrap())
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::gen::{Family, MatrixSpec};
    use crate::util::rng::Rng;

    fn profile(coo: &Coo) -> MatrixProfile {
        MatrixProfile::compute(coo)
    }

    /// A dense-clustered (Emilia-like, high synergy) test matrix.
    fn clustered(rows: usize) -> Coo {
        MatrixSpec {
            name: "banded-test".into(),
            family: Family::Banded { bandwidth: 24, band_fill: 0.65, noise: 0.0 },
            rows,
            seed: 7,
        }
        .generate()
    }

    /// A scattered (NotreDame-like, low synergy) test matrix.
    fn scattered(rows: usize) -> Coo {
        Coo::random(rows, rows, 8.0 / rows as f64, &mut Rng::new(8))
    }

    #[test]
    fn all_predictions_positive_and_finite() {
        let coo = scattered(4096);
        let p = profile(&coo);
        for m in [Machine::a100(), Machine::rtx4090()] {
            for algo in Algo::all() {
                for n in [32usize, 128, 512] {
                    let pr = predict(algo, &p, n, &m);
                    assert!(pr.time_s.is_finite() && pr.time_s > 0.0, "{} {}", algo.name(), n);
                    assert!(pr.gflops.is_finite() && pr.gflops > 0.0);
                }
            }
        }
    }

    #[test]
    fn high_synergy_cutespmm_beats_best_sc_on_a100() {
        // the paper's headline: high-synergy matrices win on TCUs
        let coo = clustered(8192);
        let p = profile(&coo);
        assert!(p.hrpb.alpha >= 0.25, "test matrix must be high synergy, alpha={}", p.hrpb.alpha);
        let m = Machine::a100();
        let cute = predict_cutespmm(&p, 128, &m);
        let (_, best) = predict_best_sc(&p, 128, &m);
        assert!(cute.gflops > best.gflops, "cute {} vs best-sc {}", cute.gflops, best.gflops);
    }

    #[test]
    fn tcgnn_slower_than_best_sc_everywhere_sampled() {
        // Fig. 2: TC-GNN never beats Best-SC on the A100
        let m = Machine::a100();
        for coo in [scattered(2048), scattered(8192), clustered(4096)] {
            let p = profile(&coo);
            let tc = predict_tcgnn(&p, 128, &m);
            let (_, best) = predict_best_sc(&p, 128, &m);
            assert!(tc.gflops < best.gflops, "tcgnn {} best {}", tc.gflops, best.gflops);
        }
    }

    #[test]
    fn cutespmm_beats_tcgnn_everywhere_sampled() {
        for m in [Machine::a100(), Machine::rtx4090()] {
            for coo in [scattered(2048), clustered(4096)] {
                let p = profile(&coo);
                for n in [32usize, 128, 512] {
                    let cute = predict_cutespmm(&p, n, &m);
                    let tc = predict_tcgnn(&p, n, &m);
                    assert!(cute.gflops > tc.gflops, "{} n={n}", m.name);
                }
            }
        }
    }

    #[test]
    fn oi_correlates_with_predicted_throughput() {
        // Fig. 7's correlation, over a density sweep
        let m = Machine::a100();
        let mut rng = Rng::new(9);
        let mut ois = Vec::new();
        let mut gf = Vec::new();
        for i in 0..12 {
            let d = 0.002 * (i + 1) as f64;
            let coo = Coo::random(4096, 4096, d, &mut rng);
            let p = profile(&coo);
            ois.push(512.0 * p.hrpb.alpha);
            gf.push(predict_cutespmm(&p, 128, &m).gflops);
        }
        let r = crate::util::stats::pearson(&ois, &gf);
        assert!(r > 0.7, "OI-vs-GFLOPs correlation too weak: {r}");
    }

    #[test]
    fn small_matrices_are_launch_or_tail_bound() {
        let coo = Coo::random(256, 256, 0.05, &mut Rng::new(10));
        let p = profile(&coo);
        let m = Machine::a100();
        let pr = predict_cutespmm(&p, 32, &m);
        // a 256-row matrix can't fill 108 SMs: time must sit well above the
        // raw component terms
        let raw = pr.t_compute.max(pr.t_dram).max(pr.t_shmem).max(pr.t_decode);
        assert!(pr.time_s > raw * 2.0);
    }

    #[test]
    fn wider_n_improves_cutespmm_gflops() {
        // Tables 3/4 trend: GFLOPs grow with N (better amortization)
        let coo = scattered(8192);
        let p = profile(&coo);
        let m = Machine::a100();
        let g32 = predict_cutespmm(&p, 32, &m).gflops;
        let g128 = predict_cutespmm(&p, 128, &m).gflops;
        assert!(g128 > g32);
    }

    #[test]
    fn a100_tcu_advantage_over_4090_for_high_synergy() {
        // A100's 8x TCU/SC ratio should show a bigger cuTeSpMM/Best-SC gap
        let coo = clustered(8192);
        let p = profile(&coo);
        let a = Machine::a100();
        let r = Machine::rtx4090();
        let speedup_a = predict_cutespmm(&p, 128, &a).gflops / predict_best_sc(&p, 128, &a).1.gflops;
        let speedup_r = predict_cutespmm(&p, 128, &r).gflops / predict_best_sc(&p, 128, &r).1.gflops;
        assert!(speedup_a > speedup_r * 0.8, "a100 {speedup_a} vs 4090 {speedup_r}");
    }
}
