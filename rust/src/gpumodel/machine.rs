//! Machine descriptions for the paper's two testbeds and the occupancy /
//! wave calculators the §5 scheme depends on.
//!
//! All peak numbers are the ones the paper itself quotes (§1: A100 FP32
//! 19.2 TF, TF32 TCU 156 TF; RTX 4090 82.6 TF for both) plus public
//! datasheet memory figures. The *model* never fits to measured data — who
//! wins and by what factor must fall out of the structure (DESIGN.md §2).

/// A GPU machine description.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub name: &'static str,
    pub num_sms: usize,
    /// Boost clock the throughput numbers are quoted at (GHz).
    pub clock_ghz: f64,
    /// Peak scalar FP32 throughput (TFLOP/s).
    pub fp32_tflops: f64,
    /// Peak tensor-core TF32 throughput (TFLOP/s).
    pub tcu_tf32_tflops: f64,
    /// DRAM bandwidth (GB/s).
    pub dram_gbps: f64,
    /// Shared-memory capacity per SM (bytes) usable by one kernel.
    pub shmem_per_sm: usize,
    /// Shared-memory bytes per clock per SM (128 = 32 banks × 4 B).
    pub shmem_bytes_per_clk_sm: f64,
    /// Hardware cap on resident thread blocks per SM.
    pub max_blocks_per_sm: usize,
    /// L2 capacity (bytes) — drives the B-matrix reuse model of the scalar
    /// engines.
    pub l2_bytes: usize,
    /// Fixed kernel-launch + tail latency charged once per kernel (µs);
    /// dominates the small GNN matrices of Tables 3/4.
    pub launch_overhead_us: f64,
}

impl Machine {
    /// Nvidia Ampere A100-80GB (§6.1: 108 SMs, the paper's main testbed).
    pub fn a100() -> Machine {
        Machine {
            name: "A100",
            num_sms: 108,
            clock_ghz: 1.41,
            fp32_tflops: 19.2,
            tcu_tf32_tflops: 156.0,
            dram_gbps: 1935.0,
            shmem_per_sm: 164 * 1024,
            shmem_bytes_per_clk_sm: 128.0,
            max_blocks_per_sm: 32,
            l2_bytes: 40 * 1024 * 1024,
            launch_overhead_us: 4.0,
        }
    }

    /// Nvidia Ada RTX 4090 (§6.1: 128 SMs, 2.2 GHz base).
    pub fn rtx4090() -> Machine {
        Machine {
            name: "RTX-4090",
            num_sms: 128,
            clock_ghz: 2.2,
            fp32_tflops: 82.6,
            tcu_tf32_tflops: 82.6,
            dram_gbps: 1008.0,
            shmem_per_sm: 100 * 1024,
            shmem_bytes_per_clk_sm: 128.0,
            max_blocks_per_sm: 24,
            l2_bytes: 72 * 1024 * 1024,
            launch_overhead_us: 3.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Machine> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(Machine::a100()),
            "4090" | "rtx4090" | "rtx-4090" => Some(Machine::rtx4090()),
            _ => None,
        }
    }

    /// Aggregate shared-memory bandwidth (bytes/s).
    pub fn shmem_bw(&self) -> f64 {
        self.shmem_bytes_per_clk_sm * self.clock_ghz * 1e9 * self.num_sms as f64
    }

    /// Resident thread blocks per SM given a kernel's shared-memory usage
    /// (register pressure folded into `max_blocks_per_sm`).
    pub fn blocks_per_sm(&self, shmem_per_block: usize) -> usize {
        if shmem_per_block == 0 {
            return self.max_blocks_per_sm;
        }
        (self.shmem_per_sm / shmem_per_block).clamp(1, self.max_blocks_per_sm)
    }

    /// §5 wave count for a grid of `total_blocks` with the given per-block
    /// shared-memory footprint.
    pub fn num_waves(&self, total_blocks: usize, shmem_per_block: usize) -> usize {
        let concurrent = self.num_sms * self.blocks_per_sm(shmem_per_block);
        total_blocks.div_ceil(concurrent.max(1)).max(1)
    }

    /// Fraction of SMs actually busy in the last (partial) wave — the
    /// tail-utilization factor of small grids.
    pub fn grid_utilization(&self, total_blocks: usize, shmem_per_block: usize) -> f64 {
        if total_blocks == 0 {
            return 0.0;
        }
        let concurrent = (self.num_sms * self.blocks_per_sm(shmem_per_block)).max(1);
        let waves = total_blocks.div_ceil(concurrent);
        total_blocks as f64 / (waves * concurrent) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_numbers() {
        let a = Machine::a100();
        assert_eq!(a.fp32_tflops, 19.2);
        assert_eq!(a.tcu_tf32_tflops, 156.0);
        assert!((a.tcu_tf32_tflops / a.fp32_tflops - 8.125).abs() < 0.01, "the 8x of §1");
        let r = Machine::rtx4090();
        assert_eq!(r.fp32_tflops, r.tcu_tf32_tflops, "4090: TCU peak == SC peak (§1)");
    }

    #[test]
    fn occupancy_clamps() {
        let a = Machine::a100();
        assert_eq!(a.blocks_per_sm(0), a.max_blocks_per_sm);
        assert_eq!(a.blocks_per_sm(200 * 1024), 1); // oversubscribed
        assert_eq!(a.blocks_per_sm(10 * 1024), 16);
    }

    #[test]
    fn wave_math_matches_section5_example() {
        // §5's worked example: 991 blocks, 100 SMs x 1 block -> 10 waves
        let m = Machine {
            name: "toy",
            num_sms: 100,
            clock_ghz: 1.0,
            fp32_tflops: 1.0,
            tcu_tf32_tflops: 1.0,
            dram_gbps: 1.0,
            shmem_per_sm: 1024,
            shmem_bytes_per_clk_sm: 128.0,
            max_blocks_per_sm: 1,
            l2_bytes: 1,
            launch_overhead_us: 0.0,
        };
        assert_eq!(m.num_waves(991, 1024), 10);
    }

    #[test]
    fn tail_utilization() {
        let a = Machine::a100();
        // one block on the whole machine: terrible utilization
        assert!(a.grid_utilization(1, 0) < 0.001);
        // exactly one full wave: perfect
        let full = a.num_sms * a.max_blocks_per_sm;
        assert_eq!(a.grid_utilization(full, 0), 1.0);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(Machine::by_name("a100").unwrap().name, "A100");
        assert_eq!(Machine::by_name("RTX4090").unwrap().name, "RTX-4090");
        assert!(Machine::by_name("h100").is_none());
    }
}
