//! Matrix profile — every structural quantity the cost models consume,
//! computed once per matrix (mirrors the preprocessing the real kernels do).

use crate::formats::Coo;
use crate::hrpb::{self, HrpbStats};
use crate::loadbalance;
use crate::params::{BrickGeometry, TK, TM};
use crate::spmm::tcgnn::TcGnnEngine;
use crate::synergy::Synergy;

/// Structural profile of one sparse matrix.
#[derive(Clone, Debug)]
pub struct MatrixProfile {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// HRPB stats at the paper's TM=16, TK=16.
    pub hrpb: HrpbStats,
    /// Brick geometry the profiled HRPB was built with — `hrpb` brick counts
    /// (and hence α and the zero-fill volume) are only meaningful at this
    /// shape, so the cost models must price against it.
    pub geometry: BrickGeometry,
    /// TC-GNN SGT 16×8 block count (its zero-fill denominator).
    pub tcgnn_blocks: usize,
    /// Row-length distribution: mean, coefficient of variation, max.
    pub row_mean: f64,
    pub row_cv: f64,
    pub row_max: usize,
    /// Per-panel block-count imbalance: max panel load over mean (drives the
    /// §5 load-balance factor).
    pub panel_imbalance: f64,
    /// Number of HRPB row panels with at least one block.
    pub active_panels: usize,
    /// Row-reorder gains when the profiled HRPB was built under a
    /// similarity-clustered permutation ([`crate::reorder`]); the registry
    /// annotates this before planning so the plan records the knob. `None`
    /// everywhere else — the profile then describes the arrival order.
    pub reorder: Option<crate::reorder::Gains>,
}

impl MatrixProfile {
    pub fn compute(coo: &Coo) -> MatrixProfile {
        let hrpb_mat = hrpb::build_from_coo(coo);
        Self::with_hrpb(coo, &hrpb_mat)
    }

    /// Profile against an already-built HRPB instance (the registry and the
    /// planner build HRPB once and share it; rebuilding here would double
    /// the §6.3 preprocessing cost).
    pub fn with_hrpb(coo: &Coo, hrpb_mat: &hrpb::Hrpb) -> MatrixProfile {
        let stats = hrpb::stats::compute(hrpb_mat);
        let loads = loadbalance::panel_loads(hrpb_mat);
        let active: Vec<usize> = loads.iter().copied().filter(|&l| l > 0).collect();
        let mean_load = if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<usize>() as f64 / active.len() as f64
        };
        let max_load = active.iter().copied().max().unwrap_or(0);
        let panel_imbalance = if mean_load > 0.0 { max_load as f64 / mean_load } else { 1.0 };

        let counts = coo.row_counts();
        let nz_rows: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let (row_mean, row_std) = crate::util::stats::mean_std(&nz_rows);
        let row_cv = if row_mean > 0.0 { row_std / row_mean } else { 0.0 };
        let row_max = counts.iter().copied().max().unwrap_or(0) as usize;

        let tcgnn_blocks = TcGnnEngine::prepare(coo).num_tc_blocks();

        MatrixProfile {
            rows: coo.rows,
            cols: coo.cols,
            nnz: coo.nnz(),
            hrpb: stats,
            geometry: hrpb_mat.geometry,
            tcgnn_blocks,
            row_mean,
            row_cv,
            row_max,
            panel_imbalance,
            active_panels: stats.active_panels,
            reorder: None,
        }
    }

    /// Minimal profile sufficient for the HRPB (cuTeSpMM) cost model only —
    /// the serving registry prices unplanned matrices for QoS admission with
    /// this, skipping the TC-GNN SGT build and row-statistics passes of
    /// [`MatrixProfile::with_hrpb`]. Fields only the other engine models
    /// consume (`tcgnn_blocks`, row stats) are left at neutral defaults, so
    /// only `Algo::Hrpb` predictions are meaningful against it.
    pub fn hrpb_only(
        rows: usize,
        cols: usize,
        nnz: usize,
        stats: HrpbStats,
        hrpb_mat: &hrpb::Hrpb,
    ) -> MatrixProfile {
        let loads = loadbalance::panel_loads(hrpb_mat);
        let active: Vec<usize> = loads.iter().copied().filter(|&l| l > 0).collect();
        let mean_load = if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<usize>() as f64 / active.len() as f64
        };
        let max_load = active.iter().copied().max().unwrap_or(0);
        let panel_imbalance = if mean_load > 0.0 { max_load as f64 / mean_load } else { 1.0 };
        MatrixProfile {
            rows,
            cols,
            nnz,
            hrpb: stats,
            geometry: hrpb_mat.geometry,
            tcgnn_blocks: 0,
            row_mean: if rows > 0 { nnz as f64 / rows as f64 } else { 0.0 },
            row_cv: 0.0,
            row_max: 0,
            panel_imbalance,
            active_panels: stats.active_panels,
            reorder: None,
        }
    }

    /// Synergy class (Table 1) of the HRPB α.
    pub fn synergy(&self) -> Synergy {
        Synergy::from_alpha(self.hrpb.alpha)
    }

    /// Useful FLOPs at width `n`.
    pub fn flops(&self, n: usize) -> f64 {
        2.0 * self.nnz as f64 * n as f64
    }

    /// HRPB grid size at width `n` (kernel §3.3: (M/TM) × (N/128) blocks).
    pub fn hrpb_grid(&self, n: usize) -> usize {
        self.active_panels.max(1) * n.div_ceil(128).max(1)
    }

    /// TC-GNN grid size: one thread block per row window.
    pub fn tcgnn_grid(&self) -> usize {
        self.rows.div_ceil(TM).max(1)
    }

    /// Bytes of the packed HRPB stream (A-traffic from DRAM).
    pub fn hrpb_a_bytes(&self) -> f64 {
        (self.hrpb.packed_bytes + self.hrpb.meta_bytes) as f64
    }

    /// CSR byte footprint (scalar engines' A-traffic): `f32` value + `u32`
    /// column id per nonzero plus the `u32` row pointer (the crate-wide
    /// 4-byte index assumption, see [`HrpbStats::csr_bytes`]).
    pub fn csr_bytes(&self) -> f64 {
        use std::mem::size_of;
        (self.nnz * (size_of::<f32>() + size_of::<u32>())
            + (self.rows + 1) * size_of::<u32>()) as f64
    }

    /// Shared memory per HRPB thread block at width `n` (Algorithm 1 line 3:
    /// `TM*TK` A values + metadata + `TK × min(n,128)` B panel, f32).
    pub fn hrpb_shmem_per_block(&self, n: usize) -> usize {
        let a = TM * TK * 4 + 512; // values + metadata upper bound
        let b = TK * n.min(128) * 4;
        a + b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn profile_of_random_matrix() {
        let mut rng = Rng::new(100);
        let coo = Coo::random(256, 512, 0.02, &mut rng);
        let p = MatrixProfile::compute(&coo);
        assert_eq!(p.nnz, coo.nnz());
        assert!(p.hrpb.alpha > 0.0 && p.hrpb.alpha <= 1.0);
        assert!(p.tcgnn_blocks > 0);
        assert!(p.row_mean > 0.0);
        assert!(p.panel_imbalance >= 1.0);
    }

    #[test]
    fn banded_profile_has_higher_alpha_than_random() {
        // clustered nonzeros (Emilia-like) vs scattered (NotreDame-like)
        let mut rng = Rng::new(101);
        let mut t = Vec::new();
        for r in 0..512usize {
            for d in 0..8usize {
                let c = (r + d).min(511);
                t.push((r, c, 1.0f32));
            }
        }
        let banded = Coo::from_triplets(512, 512, &t);
        let random = Coo::random(512, 512, banded.nnz() as f64 / (512.0 * 512.0), &mut rng);
        let pb = MatrixProfile::compute(&banded);
        let pr = MatrixProfile::compute(&random);
        assert!(pb.hrpb.alpha > pr.hrpb.alpha);
    }

    #[test]
    fn hrpb_only_profile_matches_full_profile_for_hrpb_prediction() {
        use crate::gpumodel::{algos, Machine};
        use crate::spmm::Algo;
        let coo = Coo::random(512, 384, 0.02, &mut Rng::new(104));
        let hrpb_mat = crate::hrpb::build_from_coo(&coo);
        let stats = crate::hrpb::stats::compute(&hrpb_mat);
        let full = MatrixProfile::with_hrpb(&coo, &hrpb_mat);
        let cheap =
            MatrixProfile::hrpb_only(coo.rows, coo.cols, coo.nnz(), stats, &hrpb_mat);
        let m = Machine::a100();
        let a = algos::predict(Algo::Hrpb, &full, 128, &m).time_s;
        let b = algos::predict(Algo::Hrpb, &cheap, 128, &m).time_s;
        assert!(
            (a - b).abs() <= a * 1e-9,
            "hrpb_only diverged from the full profile: {a} vs {b}"
        );
    }

    #[test]
    fn grid_scales_with_n() {
        let coo = Coo::random(512, 512, 0.01, &mut Rng::new(102));
        let p = MatrixProfile::compute(&coo);
        assert!(p.hrpb_grid(512) >= p.hrpb_grid(128));
        assert_eq!(p.hrpb_grid(128), p.hrpb_grid(32)); // both one N-slab
    }

    #[test]
    fn shmem_grows_with_n_until_128() {
        let coo = Coo::random(64, 64, 0.1, &mut Rng::new(103));
        let p = MatrixProfile::compute(&coo);
        assert!(p.hrpb_shmem_per_block(128) > p.hrpb_shmem_per_block(32));
        assert_eq!(p.hrpb_shmem_per_block(128), p.hrpb_shmem_per_block(512));
    }
}
