//! # cutespmm — a reproduction of *cuTeSpMM: Accelerating Sparse-Dense Matrix
//! Multiplication using GPU Tensor Cores* as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! The crate is organised bottom-up (see `DESIGN.md` for the full inventory
//! and the paper-experiment index):
//!
//! * [`util`] — RNG, bit ops, statistics, a minimal JSON writer and an
//!   in-repo property-testing harness (the offline image has no proptest).
//! * [`formats`] — COO/CSR/CSC sparse formats, dense matrices, MatrixMarket IO.
//! * [`gen`] — synthetic SuiteSparse-like corpus + named GNN matrix recipes
//!   (the testbed substitution documented in DESIGN.md §2).
//! * [`hrpb`] — the paper's Hierarchical Row-Panel-Blocking structure:
//!   row-panel compaction, 64-bit brick patterns, BlkCSC packing (Figs 3-5),
//!   a panel-parallel builder, and the persistent artifact layer
//!   ([`hrpb::serialize`] + [`hrpb::store`]) that makes §6.3's preprocessing
//!   amortization survive process restarts: versioned, checksummed on-disk
//!   artifacts keyed by matrix fingerprint, warm-starting registration.
//! * [`reorder`] — synergy-raising row reordering: minhash/LSH column-block
//!   signatures, greedy similarity clustering that packs overlapping rows
//!   into the same `TM` panel, and exact pre-build pricing of the
//!   candidate permutation. The planner gates activation on predicted α
//!   gain; the native engine scatters output back to original row order in
//!   its kernel epilogue; artifacts persist the permutation (format v3).
//! * [`synergy`] — brick density α, `OI_shmem = 512·α` (Eq. 4) and the
//!   Low/Medium/High TCU-Synergy classes (Table 1).
//! * [`loadbalance`] — wave-aware virtual row-panel partitioning (§5).
//! * [`spmm`] — executable engines: the native HRPB hot path (Algorithm 1 on
//!   CPU) plus the scalar-core and TC-GNN-style baselines, all running on
//!   the zero-allocation execution runtime ([`spmm::exec`]): a persistent
//!   worker pool shared across calls, `spmm_into` with a reusable
//!   output-buffer arena, and TN column-slab micro-kernels that keep the C
//!   tile and hoisted B-row slices L1-resident at serving-scale widths.
//! * [`gpumodel`] — analytical A100 / RTX-4090 cost models for all six
//!   algorithms (regenerates the paper's figures and tables).
//! * [`planner`] — synergy-driven adaptive engine selection: ranks every
//!   executable engine per matrix (Table 1 classes + `gpumodel` runtimes),
//!   caches plans by matrix fingerprint, optionally calibrates the model to
//!   this host with a micro-benchmark pass, and demotes engines whose
//!   observed serving latency drifts from the prediction. Surfaces as
//!   `EnginePolicy::Auto` in the coordinator and `cutespmm plan` in the CLI.
//! * [`qos`] — the serving-path QoS admission layer: a bounded
//!   dual-priority queue in front of the batcher, cost-aware load shedding
//!   driven by the planner's per-matrix predicted time (low-synergy =
//!   expensive, shed first), and deadline-driven scheduling that rejects
//!   requests whose estimated wait already exceeds their deadline with a
//!   typed `Rejected{est_wait}` error. Surfaces as `Config::qos`,
//!   `serve --qos` and `experiment qos`.
//! * [`runtime`] — PJRT artifact registry + executor (the AOT path).
//! * [`trace`] — runtime-gated observability: per-thread span ring buffers
//!   recording a span tree per request (admit → queue_wait → batch → exec →
//!   scatter) plus kernel profiling spans (pool workers, HRPB work units),
//!   exported as Chrome `trace_event` JSON for Perfetto. Surfaces as
//!   `Config::trace`, `serve --trace-out` and `experiment trace`.
//! * [`coordinator`] — the L3 serving layer: matrix registry, router,
//!   dynamic batcher, worker pool, metrics (with a structured
//!   `MetricsSnapshot` JSON export behind `cutespmm metrics`).
//! * [`bench`] — the experiment harness behind `benches/` and the CLI,
//!   including the perf observatory (`bench::harness`): declarative suite
//!   specs, a versioned results history under `results/history/`, and the
//!   `experiment diff` regression gate CI runs on every push.

pub mod bench;
pub mod coordinator;
pub mod formats;
pub mod gen;
pub mod gpumodel;
pub mod hrpb;
pub mod loadbalance;
pub mod planner;
pub mod qos;
pub mod reorder;
pub mod runtime;
pub mod spmm;
pub mod synergy;
pub mod trace;
pub mod util;

/// Paper-fixed tile constants (§3.1, §4): row-panel height `TM`, block width
/// `TK`, WMMA brick shape `(BRICK_M, BRICK_K, BRICK_N)` and warp-coarsened
/// output width `TN`.
pub mod params {
    /// Row-panel height (paper evaluates TM = 16 = brick_m).
    pub const TM: usize = 16;
    /// Block width along K (paper: empirically 16).
    pub const TK: usize = 16;
    /// WMMA A-fragment rows (Ampere TF32 m16n8k4).
    pub const BRICK_M: usize = 16;
    /// WMMA A-fragment cols / B-fragment rows.
    pub const BRICK_K: usize = 4;
    /// WMMA B-fragment cols.
    pub const BRICK_N: usize = 8;
    /// Warp-coarsened output width (paper §4 chooses 32 to balance A/B
    /// shared-memory traffic).
    pub const TN: usize = 32;
    /// Bits in a brick nonzero pattern (BRICK_M * BRICK_K).
    pub const BRICK_BITS: usize = BRICK_M * BRICK_K;
}
