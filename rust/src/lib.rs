//! # cutespmm — a reproduction of *cuTeSpMM: Accelerating Sparse-Dense Matrix
//! Multiplication using GPU Tensor Cores* as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! The crate is organised bottom-up (see `DESIGN.md` for the full inventory
//! and the paper-experiment index):
//!
//! * [`util`] — RNG, bit ops, statistics, a minimal JSON writer and an
//!   in-repo property-testing harness (the offline image has no proptest).
//! * [`formats`] — COO/CSR/CSC sparse formats, dense matrices, MatrixMarket IO.
//! * [`gen`] — synthetic SuiteSparse-like corpus + named GNN matrix recipes
//!   (the testbed substitution documented in DESIGN.md §2).
//! * [`hrpb`] — the paper's Hierarchical Row-Panel-Blocking structure:
//!   row-panel compaction, 64-bit brick patterns, BlkCSC packing (Figs 3-5),
//!   a panel-parallel builder, and the persistent artifact layer
//!   ([`hrpb::serialize`] + [`hrpb::store`]) that makes §6.3's preprocessing
//!   amortization survive process restarts: versioned, checksummed on-disk
//!   artifacts keyed by matrix fingerprint, warm-starting registration.
//! * [`reorder`] — synergy-raising row reordering: minhash/LSH column-block
//!   signatures, greedy similarity clustering that packs overlapping rows
//!   into the same `TM` panel, and exact pre-build pricing of the
//!   candidate permutation. The planner gates activation on predicted α
//!   gain; the native engine scatters output back to original row order in
//!   its kernel epilogue; artifacts persist the permutation (format v3).
//! * [`synergy`] — brick density α, `OI_shmem = 512·α` (Eq. 4) and the
//!   Low/Medium/High TCU-Synergy classes (Table 1).
//! * [`loadbalance`] — wave-aware virtual row-panel partitioning (§5).
//! * [`spmm`] — executable engines: the native HRPB hot path (Algorithm 1 on
//!   CPU) plus the scalar-core and TC-GNN-style baselines, all running on
//!   the zero-allocation execution runtime ([`spmm::exec`]): a persistent
//!   worker pool shared across calls, `spmm_into` with a reusable
//!   output-buffer arena, and TN column-slab micro-kernels that keep the C
//!   tile and hoisted B-row slices L1-resident at serving-scale widths.
//! * [`gpumodel`] — analytical A100 / RTX-4090 cost models for all six
//!   algorithms (regenerates the paper's figures and tables).
//! * [`planner`] — synergy-driven adaptive engine selection: ranks every
//!   executable engine per matrix (Table 1 classes + `gpumodel` runtimes),
//!   caches plans by matrix fingerprint, optionally calibrates the model to
//!   this host with a micro-benchmark pass, and demotes engines whose
//!   observed serving latency drifts from the prediction. Surfaces as
//!   `EnginePolicy::Auto` in the coordinator and `cutespmm plan` in the CLI.
//! * [`qos`] — the serving-path QoS admission layer: a bounded
//!   dual-priority queue in front of the batcher, cost-aware load shedding
//!   driven by the planner's per-matrix predicted time (low-synergy =
//!   expensive, shed first), and deadline-driven scheduling that rejects
//!   requests whose estimated wait already exceeds their deadline with a
//!   typed `Rejected{est_wait}` error. Surfaces as `Config::qos`,
//!   `serve --qos` and `experiment qos`.
//! * [`runtime`] — PJRT artifact registry + executor (the AOT path).
//! * [`trace`] — runtime-gated observability: per-thread span ring buffers
//!   recording a span tree per request (admit → queue_wait → batch → exec →
//!   scatter) plus kernel profiling spans (pool workers, HRPB work units),
//!   exported as Chrome `trace_event` JSON for Perfetto. Surfaces as
//!   `Config::trace`, `serve --trace-out` and `experiment trace`.
//! * [`coordinator`] — the L3 serving layer: matrix registry, router,
//!   dynamic batcher, worker pool, metrics (with a structured
//!   `MetricsSnapshot` JSON export behind `cutespmm metrics`), plus the
//!   PR 9 fault-tolerance layer: a typed `ServeError` taxonomy on every
//!   reply channel, panic containment at the engine-dispatch boundary,
//!   and per-matrix circuit breakers with CSR fallback and quarantine.
//! * [`fault`] — deterministic, seeded fault injection (kernel panic,
//!   artifact IO error, checksum flip, slow-exec stall, network response
//!   drop/stall), zero-cost when disabled. Surfaces as `--fault-plan` on
//!   `serve`/`experiment` and drives `experiment chaos` / `experiment load`.
//! * [`net`] — the network serving layer: a length-prefixed binary wire
//!   protocol (versioned, checksummed frames; hostile bytes are typed
//!   errors, never panics), a TCP server per coordinator with bounded
//!   per-connection in-flight windows and read/write deadlines, and a
//!   multiplexed client whose dead-connection semantics (fail pending
//!   exactly once, suppress late duplicates) the shard router builds on.
//! * [`shard`] — a consistent-hash router over N served coordinators:
//!   fingerprint-placed replication, breaker-probed shard health,
//!   idempotent request ids with replica failover (zero lost, zero
//!   duplicated), abrupt kill for chaos and ordered graceful drain
//!   through the QoS shutdown path. Surfaces as `experiment load`.
//! * [`bench`] — the experiment harness behind `benches/` and the CLI,
//!   including the perf observatory (`bench::harness`): declarative suite
//!   specs, a versioned results history under `results/history/`, and the
//!   `experiment diff` regression gate CI runs on every push.

pub mod bench;
pub mod coordinator;
pub mod fault;
pub mod formats;
pub mod gen;
pub mod gpumodel;
pub mod hrpb;
pub mod loadbalance;
pub mod net;
pub mod planner;
pub mod qos;
pub mod reorder;
pub mod runtime;
pub mod shard;
pub mod spmm;
pub mod synergy;
pub mod trace;
pub mod util;

/// Paper-fixed tile constants (§3.1, §4) and the brick-geometry catalog.
///
/// The raw `BRICK_*` constants survive only as the catalog's default entry
/// ([`BrickGeometry::DEFAULT`]); every consumer outside this module goes
/// through a [`BrickGeometry`] value instead of the constants.
pub mod params {
    /// Row-panel height (paper evaluates TM = 16 = brick_m).
    pub const TM: usize = 16;
    /// Block width along K (paper: empirically 16).
    pub const TK: usize = 16;
    /// WMMA A-fragment rows (Ampere TF32 m16n8k4) — default geometry only.
    pub const BRICK_M: usize = 16;
    /// WMMA A-fragment cols / B-fragment rows — default geometry only.
    pub const BRICK_K: usize = 4;
    /// WMMA B-fragment cols.
    pub const BRICK_N: usize = 8;
    /// Warp-coarsened output width (paper §4 chooses 32 to balance A/B
    /// shared-memory traffic).
    pub const TN: usize = 32;
    /// Bits in a brick nonzero pattern (BRICK_M * BRICK_K) — default
    /// geometry only.
    pub const BRICK_BITS: usize = BRICK_M * BRICK_K;

    /// One WMMA brick shape the HRPB format, pricer, kernel and planner can
    /// all be instantiated over.
    ///
    /// `transposed_b` marks the FlashSparse-style swapped-operand variant
    /// (PAPERS.md, arXiv 2412.11007): operand roles swap so the sparse
    /// fragment is consumed at `brick_m × 1` granularity, which minimizes
    /// redundant zero-fill on unstructured matrices. On this CPU re-host it
    /// changes the format granularity and the cost model, not the kernel
    /// semantics (bricks stay row-major with `brick_k = 1`).
    ///
    /// Invariant: `brick_m * brick_k <= 64` — a brick's nonzero pattern must
    /// fit one `u64` word (this is why 16×8 is not in the catalog).
    #[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
    pub struct BrickGeometry {
        /// Brick rows (A-fragment rows). Must divide `TM`.
        pub brick_m: usize,
        /// Brick cols (A-fragment cols / B-fragment rows). Must divide `TK`.
        pub brick_k: usize,
        /// FlashSparse-style swapped-operand variant.
        pub transposed_b: bool,
    }

    impl BrickGeometry {
        /// The paper's fixed shape — the catalog's default entry, and what
        /// every pre-catalog artifact (format v2/v3) decodes as.
        pub const DEFAULT: BrickGeometry =
            BrickGeometry { brick_m: BRICK_M, brick_k: BRICK_K, transposed_b: false };

        /// The fixed candidate catalog the pricer prices and the planner
        /// selects from. 16×8 is excluded: 128 pattern bits don't fit the
        /// u64 pattern word.
        pub const CATALOG: [BrickGeometry; 4] = [
            BrickGeometry::DEFAULT,
            BrickGeometry { brick_m: 8, brick_k: 8, transposed_b: false },
            BrickGeometry { brick_m: 8, brick_k: 4, transposed_b: false },
            BrickGeometry { brick_m: 8, brick_k: 1, transposed_b: true },
        ];

        /// Pattern bits per brick (`brick_m * brick_k`).
        #[inline]
        pub const fn bits(self) -> usize {
            self.brick_m * self.brick_k
        }

        /// Is this the catalog's default entry?
        #[inline]
        pub fn is_default(self) -> bool {
            self == BrickGeometry::DEFAULT
        }

        /// Position in [`Self::CATALOG`], if this geometry is catalogued.
        pub fn catalog_index(self) -> Option<usize> {
            BrickGeometry::CATALOG.iter().position(|&g| g == self)
        }

        /// Stable wire id (independent of catalog order) for artifact v4 and
        /// calibration JSON: `brick_m | brick_k << 8 | transposed << 16`.
        pub fn id(self) -> u32 {
            debug_assert!(self.brick_m <= 255 && self.brick_k <= 255);
            self.brick_m as u32 | (self.brick_k as u32) << 8 | (self.transposed_b as u32) << 16
        }

        /// Decode a wire id; rejects shapes that violate the invariants.
        pub fn from_id(id: u32) -> Option<BrickGeometry> {
            let g = BrickGeometry {
                brick_m: (id & 0xFF) as usize,
                brick_k: (id >> 8 & 0xFF) as usize,
                transposed_b: id >> 16 & 1 == 1,
            };
            let known = id >> 17 == 0;
            let valid = g.brick_m >= 1 && g.brick_k >= 1 && g.bits() <= 64;
            (known && valid).then_some(g)
        }

        /// Human/CLI name: `"16x4"`, `"8x1t"` (trailing `t` = transposed).
        pub fn name(self) -> String {
            let t = if self.transposed_b { "t" } else { "" };
            format!("{}x{}{}", self.brick_m, self.brick_k, t)
        }

        /// Parse [`Self::name`] output (used by `plan --geometry` and JSON).
        pub fn parse(s: &str) -> Option<BrickGeometry> {
            let (body, transposed_b) = match s.strip_suffix('t') {
                Some(b) => (b, true),
                None => (s, false),
            };
            let (m, k) = body.split_once('x')?;
            let g = BrickGeometry {
                brick_m: m.parse().ok()?,
                brick_k: k.parse().ok()?,
                transposed_b,
            };
            (g.brick_m >= 1 && g.brick_k >= 1 && g.bits() <= 64).then_some(g)
        }
    }

    impl Default for BrickGeometry {
        fn default() -> BrickGeometry {
            BrickGeometry::DEFAULT
        }
    }

    impl std::fmt::Display for BrickGeometry {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "{}", self.name())
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn catalog_entries_are_valid_and_distinct() {
            for g in BrickGeometry::CATALOG {
                assert!(g.bits() <= 64, "{g}: pattern must fit u64");
                assert_eq!(TM % g.brick_m, 0, "{g}: brick_m must divide TM");
                assert_eq!(TK % g.brick_k, 0, "{g}: brick_k must divide TK");
            }
            assert_eq!(BrickGeometry::CATALOG[0], BrickGeometry::DEFAULT);
            for (i, a) in BrickGeometry::CATALOG.iter().enumerate() {
                for b in &BrickGeometry::CATALOG[i + 1..] {
                    assert_ne!(a, b);
                }
            }
        }

        #[test]
        fn id_roundtrips_and_rejects_garbage() {
            for g in BrickGeometry::CATALOG {
                assert_eq!(BrickGeometry::from_id(g.id()), Some(g));
            }
            assert_eq!(BrickGeometry::from_id(0), None, "0x0 bricks are invalid");
            assert_eq!(BrickGeometry::from_id(16 | 8 << 8), None, "16x8 exceeds 64 bits");
            assert_eq!(BrickGeometry::from_id(1 << 20), None, "unknown flag bits");
        }

        #[test]
        fn name_parse_roundtrips() {
            for g in BrickGeometry::CATALOG {
                assert_eq!(BrickGeometry::parse(&g.name()), Some(g));
            }
            assert_eq!(BrickGeometry::parse("16x4").unwrap(), BrickGeometry::DEFAULT);
            assert!(BrickGeometry::parse("16x8").is_none());
            assert!(BrickGeometry::parse("x4").is_none());
            assert!(BrickGeometry::parse("banana").is_none());
        }

        #[test]
        fn default_matches_the_legacy_constants() {
            let d = BrickGeometry::DEFAULT;
            assert_eq!(d.brick_m, BRICK_M);
            assert_eq!(d.brick_k, BRICK_K);
            assert_eq!(d.bits(), BRICK_BITS);
            assert!(!d.transposed_b);
            assert!(d.is_default());
            assert_eq!(d.catalog_index(), Some(0));
        }
    }
}
