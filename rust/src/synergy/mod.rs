//! TCU-Synergy — the paper's §6.4 metric and §4 operational-intensity model.
//!
//! A matrix's *synergy* with tensor-core SpMM is driven by the packed brick
//! density `α` (HRPB stats): each B element loaded from shared memory feeds
//! `16·α` MACs per brick column, so `OI_shmem = 512·α` at the paper's TN=32
//! (Eq. 4). Table 1 cuts α into Low / Medium / High classes that predict
//! whether cuTeSpMM beats the best scalar-core SpMM.

use crate::hrpb::HrpbStats;
use crate::params::{BrickGeometry, TN};

/// The paper's Table 1 synergy classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Synergy {
    /// α ∈ [0, 12.5%): ≤ 1 B-reuse per shared-memory load; scalar cores
    /// usually win.
    Low,
    /// α ∈ [12.5%, 25%): OI_shmem between 32 and 64.
    Medium,
    /// α ∈ [25%, 100%]: OI_shmem > 64; TCUs win decisively.
    High,
}

impl Synergy {
    /// Classify by packed brick density α (Table 1 ranges).
    pub fn from_alpha(alpha: f64) -> Synergy {
        if alpha < 0.125 {
            Synergy::Low
        } else if alpha < 0.25 {
            Synergy::Medium
        } else {
            Synergy::High
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Synergy::Low => "Low",
            Synergy::Medium => "Medium",
            Synergy::High => "High",
        }
    }

    pub fn all() -> [Synergy; 3] {
        [Synergy::Low, Synergy::Medium, Synergy::High]
    }

    /// Table 1 α range of this class, `[lo, hi)` (`hi` inclusive for High).
    pub fn alpha_range(&self) -> (f64, f64) {
        match self {
            Synergy::Low => (0.0, 0.125),
            Synergy::Medium => (0.125, 0.25),
            Synergy::High => (0.25, 1.0),
        }
    }
}

/// The paper's modeled operational intensity and shared-memory traffic for
/// cuTeSpMM on a given matrix (§4, Eqs 1-5).
#[derive(Clone, Copy, Debug)]
pub struct OiModel {
    /// Packed brick density (from HRPB stats).
    pub alpha: f64,
    /// Brick-column stacking factor (Eq. 5; 1 when TM = brick_m).
    pub beta: f64,
    /// Modeled shared-memory transactions for A at width N (Eq. 1/3).
    pub shmem_trans_a: f64,
    /// Modeled shared-memory transactions for B (Eq. 2/3, with β of Eq. 5).
    pub shmem_trans_b: f64,
    /// FLOPs of the sparse product: `2 · nnz · N`.
    pub flops: f64,
    /// Operational intensity w.r.t. shared memory, FLOPs per 32-wide
    /// transaction. With TN=32 and β=1 this reduces to the paper's
    /// `OI_shmem = 512 · α` (Eq. 4).
    pub oi_shmem: f64,
    /// Synergy class of α.
    pub synergy: Synergy,
}

/// Eq. 4's closed form: `OI_shmem = 512 · α` (valid at TN=32, β=1).
pub fn oi_shmem_closed_form(alpha: f64) -> f64 {
    512.0 * alpha
}

/// Build the §4 model for a matrix with the given HRPB stats and dense width
/// `n`, using the paper's default tile parameters.
pub fn model(stats: &HrpbStats, n: usize) -> OiModel {
    model_with(stats, n, TN)
}

/// Build the model with an explicit TN (the §4 TN sweep / ablation).
pub fn model_with(stats: &HrpbStats, n: usize, tn: usize) -> OiModel {
    model_with_geometry(stats, n, tn, BrickGeometry::DEFAULT)
}

/// Build the model for an explicit brick geometry: the per-brick slot count
/// (Eq. 1's value words) and the brick height (Eq. 2's row amortization)
/// both follow the geometry, so the same α prices differently under
/// different brick shapes — exactly what the planner's geometry chooser
/// compares. The transposed variant swaps which operand streams through
/// shared memory; on the traffic ledger that swaps nothing (A's masks and
/// values still stream, B rows are still amortized over `brick_m · β`), so
/// it shares the formula.
pub fn model_with_geometry(
    stats: &HrpbStats,
    n: usize,
    tn: usize,
    geo: BrickGeometry,
) -> OiModel {
    let nnz = stats.nnz as f64;
    let (alpha, beta) = (stats.alpha, stats.beta.max(1.0));
    let nf = n as f64;
    if nnz == 0.0 || alpha == 0.0 {
        return OiModel {
            alpha,
            beta,
            shmem_trans_a: 0.0,
            shmem_trans_b: 0.0,
            flops: 0.0,
            oi_shmem: 0.0,
            synergy: Synergy::Low,
        };
    }
    let brick = geo.bits() as f64;
    // Eq. 1: per brick, each lane reads the 8-byte mask (2 transactions)
    // plus the warp collectively reads the ⌈α·bits/32⌉ value words; one
    // pass per TN slice of N.
    let bricks = nnz / (alpha * brick);
    let per_brick = ((alpha * brick) / 32.0).ceil() + 2.0;
    let shmem_trans_a = per_brick * (nf / tn as f64).max(1.0) * bricks;
    // Eq. 2 with Eq. 5's β reuse: one N-wide row load per brick column,
    // amortized over the brick_m rows it feeds.
    let shmem_trans_b = nf * nnz / (32.0 * alpha * geo.brick_m as f64 * beta);
    let flops = 2.0 * nnz * nf;
    OiModel {
        alpha,
        beta,
        shmem_trans_a,
        shmem_trans_b,
        flops,
        oi_shmem: flops / (shmem_trans_a + shmem_trans_b),
        synergy: Synergy::from_alpha(alpha),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::{build_from_coo, stats};
    use crate::util::rng::Rng;

    #[test]
    fn table1_class_boundaries() {
        assert_eq!(Synergy::from_alpha(0.0), Synergy::Low);
        assert_eq!(Synergy::from_alpha(0.124), Synergy::Low);
        assert_eq!(Synergy::from_alpha(0.125), Synergy::Medium);
        assert_eq!(Synergy::from_alpha(0.249), Synergy::Medium);
        assert_eq!(Synergy::from_alpha(0.25), Synergy::High);
        assert_eq!(Synergy::from_alpha(1.0), Synergy::High);
    }

    #[test]
    fn prop_alpha_classes_partition_the_range() {
        use crate::util::proptest::{check, UsizeGen};
        let claims = |alpha: f64| {
            Synergy::all()
                .iter()
                .filter(|c| {
                    let (lo, hi) = c.alpha_range();
                    alpha >= lo && (alpha < hi || (**c == Synergy::High && alpha <= hi))
                })
                .count()
        };
        check("alpha classes partition [0,1]", 400, &UsizeGen { lo: 0, hi: 100_000 }, |&v| {
            let alpha = v as f64 / 100_000.0;
            let s = Synergy::from_alpha(alpha);
            let (lo, hi) = s.alpha_range();
            let inside = alpha >= lo && (alpha < hi || (s == Synergy::High && alpha <= hi));
            inside && claims(alpha) == 1
        });
        // the Table 1 boundaries route upward, exactly
        assert_eq!(claims(0.125), 1);
        assert_eq!(Synergy::from_alpha(0.125), Synergy::Medium);
        assert_eq!(claims(0.25), 1);
        assert_eq!(Synergy::from_alpha(0.25), Synergy::High);
    }

    #[test]
    fn boundary_alpha_from_real_matrices() {
        // α exactly at the Table 1 cuts, built structurally: k of 64 slots
        // occupied in a single brick.
        let brick_with = |k: usize| {
            let t: Vec<(usize, usize, f32)> = (0..k).map(|r| (r, 0usize, 1.0f32)).collect();
            let coo = Coo::from_triplets(16, 16, &t);
            stats::compute(&build_from_coo(&coo))
        };
        let s8 = brick_with(8);
        assert_eq!(s8.alpha, 0.125, "8/64 slots");
        assert_eq!(Synergy::from_alpha(s8.alpha), Synergy::Medium);
        let s16 = brick_with(16);
        assert_eq!(s16.alpha, 0.25, "16/64 slots");
        assert_eq!(Synergy::from_alpha(s16.alpha), Synergy::High);
        let s7 = brick_with(7);
        assert_eq!(Synergy::from_alpha(s7.alpha), Synergy::Low);
    }

    #[test]
    fn eq4_closed_form_at_tn32_beta1() {
        // a matrix whose bricks land exactly: α = 0.25 (16 of 64 slots)
        let mut t = Vec::new();
        for r in 0..16 {
            t.push((r, r % 4, 1.0f32)); // 16 nnz in one brick => α = 0.25
        }
        let coo = Coo::from_triplets(16, 16, &t);
        let hrpb = build_from_coo(&coo);
        let s = stats::compute(&hrpb);
        assert_eq!(s.alpha, 0.25);
        let m = model(&s, 128);
        // Eq. 4: OI = 512 α = 128; the Eq. 1 ceil() makes the A term slightly
        // coarser than the paper's asymptotic form, so allow 20%.
        let closed = oi_shmem_closed_form(s.alpha);
        assert!(
            (m.oi_shmem - closed).abs() / closed < 0.2,
            "modeled {} vs closed-form {closed}",
            m.oi_shmem
        );
    }

    #[test]
    fn oi_increases_with_alpha() {
        let mut rng = Rng::new(30);
        let sparse = Coo::random(256, 256, 0.01, &mut rng);
        let dense = Coo::random(256, 256, 0.30, &mut rng);
        let ms = model(&stats::compute(&build_from_coo(&sparse)), 128);
        let md = model(&stats::compute(&build_from_coo(&dense)), 128);
        assert!(md.alpha > ms.alpha);
        assert!(md.oi_shmem > ms.oi_shmem);
    }

    #[test]
    fn beta_reuse_raises_oi() {
        // same stats but doubled beta must not lower OI (Eq. 5)
        let mut rng = Rng::new(31);
        let coo = Coo::random(128, 128, 0.05, &mut rng);
        let s = stats::compute(&build_from_coo(&coo));
        let mut s2 = s;
        s2.beta = s.beta * 2.0;
        assert!(model(&s2, 128).oi_shmem >= model(&s, 128).oi_shmem);
    }

    #[test]
    fn tn_balances_a_and_b_traffic() {
        // §4: TN=32 roughly equalizes A and B shared-memory transactions
        // when β=1 (the Eq. 1 ceil() and mask term skew it slightly).
        let mut t = Vec::new();
        for r in 0..16 {
            for c in 0..4 {
                if (r + c) % 2 == 0 {
                    t.push((r, c, 1.0f32)); // α = 0.5
                }
            }
        }
        let coo = Coo::from_triplets(16, 16, &t);
        let s = stats::compute(&build_from_coo(&coo));
        let m = model_with(&s, 512, 32);
        let ratio = m.shmem_trans_a / m.shmem_trans_b;
        assert!(ratio > 0.5 && ratio < 4.0, "A/B traffic ratio {ratio}");
    }

    #[test]
    fn geometry_parameterization_prices_the_brick_shape() {
        let mut rng = Rng::new(32);
        let coo = Coo::random(128, 128, 0.08, &mut rng);
        let s = stats::compute(&build_from_coo(&coo));
        // default geometry reproduces the unparameterized model exactly
        let base = model_with(&s, 128, TN);
        let geo = model_with_geometry(&s, 128, TN, BrickGeometry::DEFAULT);
        assert_eq!(base.oi_shmem, geo.oi_shmem);
        assert_eq!(base.shmem_trans_a, geo.shmem_trans_a);
        // shorter bricks (8x8, same 64 slots) halve the B-row amortization
        // height: B traffic doubles, OI drops at identical stats
        let short = model_with_geometry(
            &s,
            128,
            TN,
            BrickGeometry { brick_m: 8, brick_k: 8, transposed_b: false },
        );
        assert!(short.shmem_trans_b > base.shmem_trans_b);
        assert!(short.oi_shmem < base.oi_shmem);
        // the 8-slot transposed brick pays more mask overhead per value:
        // A traffic rises
        let thin = model_with_geometry(
            &s,
            128,
            TN,
            BrickGeometry { brick_m: 8, brick_k: 1, transposed_b: true },
        );
        assert!(thin.shmem_trans_a > base.shmem_trans_a);
    }

    #[test]
    fn empty_matrix_is_low_synergy_zero_oi() {
        let coo = Coo::new(32, 32);
        let m = model(&stats::compute(&build_from_coo(&coo)), 128);
        assert_eq!(m.synergy, Synergy::Low);
        assert_eq!(m.oi_shmem, 0.0);
    }
}
