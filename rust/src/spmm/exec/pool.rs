//! Persistent worker pool — one set of threads for every SpMM call.
//!
//! The paper's kernel launches one grid and keeps operands resident; our CPU
//! re-host used to pay a fresh `std::thread::scope` spawn (≈ tens of µs per
//! worker) on *every* `spmm` call in every parallel engine. This pool is the
//! launch-once analogue: threads are spawned lazily on first use and then
//! shared across all engines and all calls for the life of the process.
//!
//! Dispatch model: a call submits one *job* with `parts` participants.
//! Participant indices are claimed from a shared atomic counter (the same
//! self-scheduling the HRPB engine uses for work units), so however many
//! pool threads actually wake up, the work is covered — the caller itself
//! participates, which also makes a zero-thread pool (single-core host)
//! correct with no special casing. The caller blocks until every claimed
//! part has finished, which is what makes lending stack-borrowed closures to
//! the pool sound.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One submitted job: a lifetime-erased task plus claim/completion state.
struct Job {
    /// The caller's `&(dyn Fn(usize) + Sync)` with its lifetime erased.
    /// SAFETY invariant: [`WorkerPool::run`] does not return until
    /// `completed == parts`, so the borrow outlives every dereference.
    task: *const (dyn Fn(usize) + Sync),
    parts: usize,
    /// Next participant index to claim.
    next: AtomicUsize,
    /// Participant indices fully executed.
    completed: AtomicUsize,
    /// First caught panic payload; re-raised on the caller once the job
    /// drains, preserving the original message.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: the raw task pointer is only dereferenced while the submitting
// caller is blocked inside `run` (see the invariant on `task`), and the
// pointee is `Sync`, so concurrent calls from pool threads are sound.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run participant indices until none remain. Runs on pool
    /// threads *and* on the submitting caller.
    fn execute(&self) {
        loop {
            let p = self.next.fetch_add(1, Ordering::Relaxed);
            if p >= self.parts {
                break;
            }
            let t0 = crate::trace::kernel_enabled().then(std::time::Instant::now);
            // SAFETY: see the invariant on `task`.
            let task = unsafe { &*self.task };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| task(p))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if let Some(t0) = t0 {
                // per-part worker span: which thread claimed which part,
                // for how long — pool utilization and self-scheduling
                // imbalance become visible in the trace
                crate::trace::record(
                    crate::trace::Kind::Kernel,
                    "worker",
                    t0,
                    crate::trace::NO_TOKEN,
                    crate::trace::SpanArgs::new()
                        .with("part", p as u64)
                        .with("parts", self.parts as u64),
                );
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.parts {
                // take the latch lock so the notify cannot race a caller
                // between its re-check and its wait
                let _g = self.done.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }
}

/// Queue entries: work, or an exit marker consumed by exactly one worker
/// (pushed by `Drop`, after all pending work).
enum Ticket {
    Work(Arc<Job>),
    Exit,
}

struct Shared {
    queue: Mutex<VecDeque<Ticket>>,
    available: Condvar,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let ticket = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match ticket {
            Ticket::Work(job) => job.execute(),
            Ticket::Exit => break,
        }
    }
}

/// A lazily-spawned, persistent worker pool. Engines share one process-wide
/// instance via [`WorkerPool::global`]; tests may embed private instances
/// (dropping a pool exits and joins its threads).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    jobs: AtomicU64,
}

impl WorkerPool {
    /// A pool with exactly `threads` worker threads (0 is valid: every job
    /// runs entirely on its caller).
    pub fn with_threads(threads: usize) -> WorkerPool {
        let shared =
            Arc::new(Shared { queue: Mutex::new(VecDeque::new()), available: Condvar::new() });
        let handles = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cutespmm-exec-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn exec pool worker")
            })
            .collect();
        WorkerPool { shared, handles, jobs: AtomicU64::new(0) }
    }

    /// The process-wide pool, spawned on first use with
    /// `available_parallelism - 1` threads (the calling thread is the final
    /// participant, so caller + pool together saturate the machine without
    /// oversubscribing it).
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
            WorkerPool::with_threads(hw.saturating_sub(1))
        })
    }

    /// Worker threads owned by this pool (excludes callers).
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Jobs submitted over the pool's lifetime (test/report hook: serving
    /// steady state grows this while `threads` stays constant — no per-call
    /// spawning).
    pub fn jobs_run(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Run `task(p)` for every `p in 0..parts`, in parallel across the pool
    /// threads and the calling thread. Returns once every part completed; a
    /// panicking part is re-raised on the caller with its original payload.
    pub fn run(&self, parts: usize, task: &(dyn Fn(usize) + Sync)) {
        if parts == 0 {
            return;
        }
        self.jobs.fetch_add(1, Ordering::Relaxed);
        if parts == 1 || self.handles.is_empty() {
            for p in 0..parts {
                let t0 = crate::trace::kernel_enabled().then(std::time::Instant::now);
                task(p);
                if let Some(t0) = t0 {
                    crate::trace::record(
                        crate::trace::Kind::Kernel,
                        "worker",
                        t0,
                        crate::trace::NO_TOKEN,
                        crate::trace::SpanArgs::new()
                            .with("part", p as u64)
                            .with("parts", parts as u64),
                    );
                }
            }
            return;
        }
        // erase the borrow's lifetime; sound because this frame blocks on
        // the completion latch below before the borrow can expire
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        let job = Arc::new(Job {
            task: erased as *const (dyn Fn(usize) + Sync),
            parts,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panic: Mutex::new(None),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            // one ticket per helper; the caller covers the final part slot
            let tickets = (parts - 1).min(self.handles.len());
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..tickets {
                q.push_back(Ticket::Work(job.clone()));
            }
        }
        self.shared.available.notify_all();
        job.execute();
        let mut g = job.done.lock().unwrap();
        while job.completed.load(Ordering::Acquire) < parts {
            g = job.done_cv.wait(g).unwrap();
        }
        drop(g);
        if let Some(payload) = job.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // exit markers queue *behind* any pending work tickets, so dropped
        // pools drain gracefully; then join so no thread outlives the pool
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..self.handles.len() {
                q.push_back(Ticket::Exit);
            }
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_part_exactly_once() {
        let pool = WorkerPool::with_threads(3);
        for parts in [1usize, 2, 7, 64] {
            let counts: Vec<AtomicUsize> = (0..parts).map(|_| AtomicUsize::new(0)).collect();
            pool.run(parts, &|p| {
                counts[p].fetch_add(1, Ordering::Relaxed);
            });
            assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1), "parts={parts}");
        }
        assert_eq!(pool.jobs_run(), 4);
        assert_eq!(pool.threads(), 3);
    }

    #[test]
    fn zero_thread_pool_runs_on_caller() {
        let pool = WorkerPool::with_threads(0);
        let hits = AtomicUsize::new(0);
        pool.run(5, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn reused_across_repeated_concurrent_calls() {
        // the pool-reuse property the runtime exists for: many concurrent
        // callers over many iterations, one thread set, correct sums
        let pool = Arc::new(WorkerPool::with_threads(2));
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                let total = total.clone();
                s.spawn(move || {
                    for _ in 0..25 {
                        pool.run(8, &|p| {
                            total.fetch_add(p + 1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        // 4 threads x 25 jobs x sum(1..=8)
        assert_eq!(total.load(Ordering::Relaxed), 4 * 25 * 36);
        assert_eq!(pool.jobs_run(), 100);
    }

    #[test]
    fn panicking_part_propagates_payload_and_pool_survives() {
        let pool = WorkerPool::with_threads(1);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|p| {
                if p == 2 {
                    panic!("boom-42");
                }
            });
        }));
        let payload = caught.expect_err("panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom-42", "the original payload survives the pool boundary");
        // the pool is still usable afterwards
        let ok = AtomicUsize::new(0);
        pool.run(3, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn every_part_panicking_raises_exactly_one_payload_and_runs_all_parts() {
        // the panic slot keeps the *first* payload and drops the rest; the
        // completion latch still counts every part, so the caller neither
        // hangs nor double-panics
        let pool = WorkerPool::with_threads(3);
        let executed = Arc::new(AtomicUsize::new(0));
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let executed = executed.clone();
            pool.run(16, &move |p| {
                executed.fetch_add(1, Ordering::Relaxed);
                panic!("part-{p} down");
            });
        }));
        let payload = caught.expect_err("at least one panic must reach the caller");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.starts_with("part-") && msg.ends_with(" down"), "one original payload: {msg}");
        assert_eq!(executed.load(Ordering::Relaxed), 16, "the job drains before re-raising");
        // the slot was taken, not left poisoned: a clean job runs fine
        let ok = AtomicUsize::new(0);
        pool.run(16, &|p| {
            ok.fetch_add(p + 1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 136, "sum 1..=16 on the reused pool");
    }

    #[test]
    fn concurrent_jobs_with_panicking_parts_stay_isolated() {
        // several callers share the pool while some of their jobs panic in
        // multiple parts at once: each caller sees its *own* job's payload
        // (or success), never a neighbor's, and the pool survives it all
        let pool = Arc::new(WorkerPool::with_threads(4));
        std::thread::scope(|s| {
            for caller in 0..4 {
                let pool = pool.clone();
                s.spawn(move || {
                    for round in 0..10 {
                        let poisoned = caller % 2 == 0;
                        let tag = caller * 1000 + round;
                        let ran = Arc::new(AtomicUsize::new(0));
                        let caught = {
                            let ran = ran.clone();
                            catch_unwind(AssertUnwindSafe(|| {
                                pool.run(8, &move |p| {
                                    ran.fetch_add(1, Ordering::Relaxed);
                                    if poisoned && p % 2 == 0 {
                                        panic!("job-{tag}");
                                    }
                                });
                            }))
                        };
                        assert_eq!(ran.load(Ordering::Relaxed), 8, "all parts ran");
                        match caught {
                            Ok(()) => assert!(!poisoned, "poisoned job must re-raise"),
                            Err(payload) => {
                                let msg = payload
                                    .downcast_ref::<String>()
                                    .cloned()
                                    .unwrap_or_default();
                                assert_eq!(
                                    msg,
                                    format!("job-{tag}"),
                                    "payload crossed between concurrent jobs"
                                );
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(pool.jobs_run(), 40);
        // and the shared pool still computes correctly afterwards
        let total = AtomicUsize::new(0);
        pool.run(8, &|p| {
            total.fetch_add(p + 1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 36);
    }

    #[test]
    fn drop_joins_worker_threads() {
        let pool = WorkerPool::with_threads(2);
        let hits = AtomicUsize::new(0);
        pool.run(6, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 6);
        drop(pool); // must not hang: exit tickets wake and join both workers
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
    }
}
