//! TN column-slab selection — operand-residency blocking over the dense
//! width.
//!
//! The GPU kernel's warp-coarsened `TN` keeps the C fragment in registers
//! and the B fragment in shared memory for the whole panel. The CPU analogue:
//! at serving-scale `N` a panel's `TM × N` C tile (and the B rows it re-reads
//! per brick column) no longer fit L1, so every brick row streams C and B
//! from L2. Processing C in column slabs restores residency: the slab of the
//! C tile stays L1-hot across *all* blocks of a work unit while the packed
//! A-side stream and B row slabs stream through.

use crate::params::{BrickGeometry, TM};
use crate::spmm::exec::microkernel::LANES;

/// L1 data budget the slab model targets (bytes): half of a typical 32 KiB
/// L1d, leaving the rest for the packed block stream, metadata and B-row
/// lookahead.
const L1_TARGET_BYTES: usize = 16 * 1024;

/// Narrowest slab worth the per-slab decode re-walk.
pub const MIN_SLAB: usize = 32;

/// Widest slab the model will pick (beyond this the C tile alone overflows
/// the target on every cache geometry we care about).
pub const MAX_SLAB: usize = 512;

/// Choose a slab width for dense width `n` from the cache model: the
/// resident working set per slab pass is the `TM`-row C tile plus the
/// brick-column B rows in flight (and one brick column of lookahead), all
/// `f32`. Sized for the default geometry — the catalog's brick_k range
/// (1-8) moves the resident set by at most a few rows out of ~24, within
/// the model's own slack, so one width serves all geometries. Result is
/// `LANES`-aligned, clamped to `[MIN_SLAB, MAX_SLAB]`, and collapses to a
/// single slab when `n` already fits.
pub fn choose(n: usize) -> usize {
    if n == 0 {
        return LANES;
    }
    let resident_rows = TM + 2 * BrickGeometry::DEFAULT.brick_k;
    let budget_cols = L1_TARGET_BYTES / (4 * resident_rows);
    let ts = (budget_cols / LANES * LANES).clamp(MIN_SLAB, MAX_SLAB);
    if ts >= n {
        n
    } else {
        ts
    }
}

/// Effective slab width for an engine-level override: `0` means "auto"
/// (the cache model chooses per call); anything else is clamped to `[1, n]`.
pub fn effective(requested: usize, n: usize) -> usize {
    match requested {
        0 => choose(n),
        w if w >= n => n.max(1),
        w => w,
    }
}

/// The column slabs `[s0, s1)` covering `0..n` at width `ts`.
pub fn slabs(n: usize, ts: usize) -> impl Iterator<Item = std::ops::Range<usize>> {
    let ts = ts.max(1);
    (0..n).step_by(ts).map(move |s0| s0..(s0 + ts).min(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_is_bounded_and_aligned() {
        for n in [1usize, 8, 31, 32, 64, 128, 256, 512, 4096] {
            let ts = choose(n);
            assert!(ts >= 1 && ts <= n.max(MIN_SLAB), "n={n} ts={ts}");
            if ts < n {
                assert_eq!(ts % LANES, 0, "multi-slab widths are lane-aligned (n={n})");
                assert!((MIN_SLAB..=MAX_SLAB).contains(&ts));
            }
        }
        // small n collapses to one slab
        assert_eq!(choose(32), 32);
        assert_eq!(choose(1), 1);
    }

    #[test]
    fn effective_handles_override_and_auto() {
        assert_eq!(effective(0, 256), choose(256));
        assert_eq!(effective(64, 256), 64);
        assert_eq!(effective(usize::MAX, 256), 256, "MAX = unblocked single slab");
        assert_eq!(effective(64, 16), 16, "override clamps to n");
    }

    #[test]
    fn slabs_tile_exactly() {
        for (n, ts) in [(0usize, 8usize), (7, 8), (8, 8), (100, 32), (256, 168), (512, 168)] {
            let ranges: Vec<_> = slabs(n, ts).collect();
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} ts={ts}");
            for pair in ranges.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
            }
            if n > 0 {
                assert_eq!(ranges[0].start, 0);
                assert_eq!(ranges.last().unwrap().end, n);
            }
        }
    }
}
