//! Fixed-width FMA micro-kernels for the brick-row contraction.
//!
//! One brick row contributes 1–4 products `a_i · B[k_i, :]` to a single C
//! row. The HRPB kernel fuses those into one pass over the C slab (the CPU
//! analogue of the MMA's 4-deep contraction); these helpers run that pass
//! over `chunks_exact(LANES)` bodies so the compiler sees a fixed trip
//! count with no tail check and auto-vectorizes the 1–4-term FMA stream,
//! with a short scalar loop for the slab remainder.
//!
//! Every `b` slice must be at least as long as `c` (the current slab width).
//!
//! Association contract: every body folds its terms **left-to-right
//! starting from the current C value** — `((c + a0·b0) + a1·b1) + …` — so
//! splitting one ascending term sequence into consecutive fmaN calls
//! produces bit-identical results. The row-reorder path relies on this: a
//! permuted build regroups a row's (column-ordered) terms into different
//! brick boundaries, and the left fold makes that regrouping numerically
//! invisible (`spmm` on a reordered HRPB is bit-identical to unreordered).

/// Vector lane granularity: 8 f32s = one 256-bit register.
pub const LANES: usize = 8;

/// `c += a · b` (1-term brick row).
#[inline]
pub fn fma1(c: &mut [f32], a: f32, b: &[f32]) {
    let n = c.len();
    let main = n - n % LANES;
    let (cm, ct) = c.split_at_mut(main);
    let (bm, bt) = b[..n].split_at(main);
    for (cv, v0) in cm.chunks_exact_mut(LANES).zip(bm.chunks_exact(LANES)) {
        for (cl, v) in cv.iter_mut().zip(v0) {
            *cl += a * v;
        }
    }
    for (cl, v) in ct.iter_mut().zip(bt) {
        *cl += a * v;
    }
}

/// `c += a0·b0 + a1·b1` (2-term brick row).
#[inline]
pub fn fma2(c: &mut [f32], a: [f32; 2], b: [&[f32]; 2]) {
    let n = c.len();
    let main = n - n % LANES;
    let (cm, ct) = c.split_at_mut(main);
    let (b0m, b0t) = b[0][..n].split_at(main);
    let (b1m, b1t) = b[1][..n].split_at(main);
    for ((cv, v0), v1) in cm
        .chunks_exact_mut(LANES)
        .zip(b0m.chunks_exact(LANES))
        .zip(b1m.chunks_exact(LANES))
    {
        for ((cl, v0), v1) in cv.iter_mut().zip(v0).zip(v1) {
            *cl = (*cl + a[0] * v0) + a[1] * v1;
        }
    }
    for ((cl, v0), v1) in ct.iter_mut().zip(b0t).zip(b1t) {
        *cl = (*cl + a[0] * v0) + a[1] * v1;
    }
}

/// `c += a0·b0 + a1·b1 + a2·b2` (3-term brick row).
#[inline]
pub fn fma3(c: &mut [f32], a: [f32; 3], b: [&[f32]; 3]) {
    let n = c.len();
    let main = n - n % LANES;
    let (cm, ct) = c.split_at_mut(main);
    let (b0m, b0t) = b[0][..n].split_at(main);
    let (b1m, b1t) = b[1][..n].split_at(main);
    let (b2m, b2t) = b[2][..n].split_at(main);
    for (((cv, v0), v1), v2) in cm
        .chunks_exact_mut(LANES)
        .zip(b0m.chunks_exact(LANES))
        .zip(b1m.chunks_exact(LANES))
        .zip(b2m.chunks_exact(LANES))
    {
        for (((cl, v0), v1), v2) in cv.iter_mut().zip(v0).zip(v1).zip(v2) {
            *cl = ((*cl + a[0] * v0) + a[1] * v1) + a[2] * v2;
        }
    }
    for (((cl, v0), v1), v2) in ct.iter_mut().zip(b0t).zip(b1t).zip(b2t) {
        *cl = ((*cl + a[0] * v0) + a[1] * v1) + a[2] * v2;
    }
}

/// `c += a0·b0 + a1·b1 + a2·b2 + a3·b3` (the full 4-deep MMA contraction).
#[inline]
pub fn fma4(c: &mut [f32], a: [f32; 4], b: [&[f32]; 4]) {
    let n = c.len();
    let main = n - n % LANES;
    let (cm, ct) = c.split_at_mut(main);
    let (b0m, b0t) = b[0][..n].split_at(main);
    let (b1m, b1t) = b[1][..n].split_at(main);
    let (b2m, b2t) = b[2][..n].split_at(main);
    let (b3m, b3t) = b[3][..n].split_at(main);
    for ((((cv, v0), v1), v2), v3) in cm
        .chunks_exact_mut(LANES)
        .zip(b0m.chunks_exact(LANES))
        .zip(b1m.chunks_exact(LANES))
        .zip(b2m.chunks_exact(LANES))
        .zip(b3m.chunks_exact(LANES))
    {
        for ((((cl, v0), v1), v2), v3) in cv.iter_mut().zip(v0).zip(v1).zip(v2).zip(v3) {
            *cl = (((*cl + a[0] * v0) + a[1] * v1) + a[2] * v2) + a[3] * v3;
        }
    }
    for ((((cl, v0), v1), v2), v3) in ct.iter_mut().zip(b0t).zip(b1t).zip(b2t).zip(b3t) {
        *cl = (((*cl + a[0] * v0) + a[1] * v1) + a[2] * v2) + a[3] * v3;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(c: &mut [f32], a: &[f32], b: &[Vec<f32>]) {
        for (i, cv) in c.iter_mut().enumerate() {
            for (av, bv) in a.iter().zip(b) {
                *cv += av * bv[i];
            }
        }
    }

    #[test]
    fn all_term_counts_match_naive_across_lengths() {
        let mut rng = Rng::new(0xF11A);
        // lengths straddle the LANES boundary: empty, sub-lane, exact
        // multiples, and ragged tails
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 24, 31, 33, 160, 161] {
            for terms in 1..=4usize {
                let a: Vec<f32> = (0..terms).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let b: Vec<Vec<f32>> = (0..terms)
                    .map(|_| (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect())
                    .collect();
                let mut want: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                let mut got = want.clone();
                naive(&mut want, &a, &b);
                match terms {
                    1 => fma1(&mut got, a[0], &b[0]),
                    2 => fma2(&mut got, [a[0], a[1]], [&b[0], &b[1]]),
                    3 => fma3(&mut got, [a[0], a[1], a[2]], [&b[0], &b[1], &b[2]]),
                    _ => fma4(&mut got, [a[0], a[1], a[2], a[3]], [&b[0], &b[1], &b[2], &b[3]]),
                }
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() <= 1e-5, "n={n} terms={terms}: {g} vs {w}");
                }
            }
        }
    }

    /// The association contract behind reorder bit-identity: any split of
    /// one term sequence into consecutive fmaN calls is bit-identical.
    #[test]
    fn consecutive_splits_are_bit_identical() {
        let mut rng = Rng::new(0xF11B);
        for n in [1usize, 7, 8, 9, 33] {
            let a: Vec<f32> = (0..4).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let b: Vec<Vec<f32>> =
                (0..4).map(|_| (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.f32()).collect();

            let mut fused = base.clone();
            fma4(&mut fused, [a[0], a[1], a[2], a[3]], [&b[0], &b[1], &b[2], &b[3]]);

            // 1+3, 2+2, 3+1, 1+1+1+1 — all must match the fused pass exactly
            let mut split13 = base.clone();
            fma1(&mut split13, a[0], &b[0]);
            fma3(&mut split13, [a[1], a[2], a[3]], [&b[1], &b[2], &b[3]]);
            assert_eq!(fused, split13, "n={n} 1+3");

            let mut split22 = base.clone();
            fma2(&mut split22, [a[0], a[1]], [&b[0], &b[1]]);
            fma2(&mut split22, [a[2], a[3]], [&b[2], &b[3]]);
            assert_eq!(fused, split22, "n={n} 2+2");

            let mut split31 = base.clone();
            fma3(&mut split31, [a[0], a[1], a[2]], [&b[0], &b[1], &b[2]]);
            fma1(&mut split31, a[3], &b[3]);
            assert_eq!(fused, split31, "n={n} 3+1");

            let mut ones = base.clone();
            for t in 0..4 {
                fma1(&mut ones, a[t], &b[t]);
            }
            assert_eq!(fused, ones, "n={n} 1x4");
        }
    }

    #[test]
    fn longer_b_than_c_is_allowed() {
        // the kernel contract: b slices may exceed the slab (hoisted full
        // rows); only the first c.len() entries participate
        let b: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut c = vec![1.0f32; 5];
        fma1(&mut c, 2.0, &b);
        assert_eq!(c, vec![1.0, 3.0, 5.0, 7.0, 9.0]);
    }
}
