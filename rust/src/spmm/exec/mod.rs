//! Zero-allocation blocked execution runtime for the CPU engines.
//!
//! The paper's kernel owes its throughput to launching once and keeping
//! operands resident; this module gives the CPU re-hosts the same shape:
//!
//! * [`pool`] — a lazily-initialized persistent worker pool shared by every
//!   parallel engine, replacing the per-call `std::thread::scope` spawn.
//! * [`slab`] — TN column-slab selection: process C in cache-sized column
//!   slabs so the C tile and the hoisted B-row slices stay L1-resident
//!   across a work unit's blocks.
//! * [`microkernel`] — fixed-width (`chunks_exact`) 1–4-term FMA bodies the
//!   slab kernel dispatches to; auto-vectorized.
//! * [`OutputArena`] — a reusable output-buffer pool behind
//!   `SpmmEngine::spmm_into`, so steady-state serving performs zero output
//!   allocations (the coordinator asserts this via the hit counter).

pub mod microkernel;
pub mod pool;
pub mod slab;

pub use pool::WorkerPool;

use crate::formats::Dense;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A `Send + Sync` raw output pointer for handing disjoint C regions to
/// pool workers (each engine documents its disjointness argument at the
/// `from_raw_parts_mut` site).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);

// SAFETY: carriers only ever materialize disjoint subslices per worker.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Accessor so closures capture the whole `SendPtr` (Send + Sync) rather
    /// than disjointly capturing the raw pointer field (2021 capture rules).
    #[inline]
    pub(crate) fn get(self) -> *mut f32 {
        self.0
    }
}

/// Reusable output-buffer pool for `spmm_into` callers.
///
/// `acquire` hands back a `rows × cols` [`Dense`] with **unspecified
/// contents**, reusing a released buffer whose capacity fits (a *hit* — no
/// allocation, and no redundant zero fill: `spmm_into` overwrites C anyway,
/// and the coordinator overwrites every fused-B column it reads back);
/// otherwise it allocates fresh (a *miss*). In steady state a serving
/// worker cycles the same buffers batch after batch, so the miss counter
/// stops moving after warmup — the zero-allocation property the coordinator
/// tests assert.
pub struct OutputArena {
    free: Mutex<Vec<Vec<f32>>>,
    max_buffers: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for OutputArena {
    fn default() -> Self {
        OutputArena::new()
    }
}

impl OutputArena {
    /// An arena retaining up to 8 buffers (2 per worker on the default
    /// 4-worker coordinator: fused B + C).
    pub fn new() -> OutputArena {
        OutputArena::with_capacity(8)
    }

    /// An arena retaining up to `max_buffers` released buffers.
    pub fn with_capacity(max_buffers: usize) -> OutputArena {
        OutputArena {
            free: Mutex::new(Vec::new()),
            max_buffers: max_buffers.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A `rows × cols` matrix with unspecified contents (callers overwrite
    /// — see the type docs), reusing a retained buffer when one is big
    /// enough.
    pub fn acquire(&self, rows: usize, cols: usize) -> Dense {
        let need = rows * cols;
        let reused = {
            let mut free = self.free.lock().unwrap();
            free.iter()
                .position(|b| b.capacity() >= need)
                .map(|i| free.swap_remove(i))
        };
        match reused {
            Some(mut data) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // resize writes only the grown tail (len..need); the kept
                // prefix stays dirty — the hot path's saved memset
                data.truncate(need);
                data.resize(need, 0.0);
                Dense { rows, cols, data }
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Dense::zeros(rows, cols)
            }
        }
    }

    /// Return a buffer for reuse. Past the retention cap the smallest
    /// retained buffer is displaced if this one is bigger (so the arena
    /// converges on the largest shapes in play), otherwise the buffer is
    /// dropped.
    pub fn release(&self, d: Dense) {
        if d.data.capacity() == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_buffers {
            free.push(d.data);
            return;
        }
        if let Some((i, smallest)) = free
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, b)| (i, b.capacity()))
        {
            if smallest < d.data.capacity() {
                free[i] = d.data;
            }
        }
    }

    /// Acquires served from a retained buffer (no allocation).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Acquires that had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Buffers currently retained.
    pub fn retained(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_reuses_dirty_buffers_at_the_right_shape() {
        // the contract is "unspecified contents" — consumers (spmm_into,
        // the coordinator's fused-B writer) overwrite, so acquire skips the
        // memset; only shape and length are guaranteed
        let arena = OutputArena::new();
        let mut d = arena.acquire(4, 4);
        d.data.iter_mut().for_each(|v| *v = f32::NAN);
        arena.release(d);
        let d = arena.acquire(2, 3);
        assert_eq!(arena.hits(), 1);
        assert_eq!((d.rows, d.cols), (2, 3));
        assert_eq!(d.data.len(), 6);
        // growing past the old length zero-fills only the new tail, so the
        // buffer is still fully initialized memory
        arena.release(d);
        let d = arena.acquire(4, 4);
        assert_eq!(d.data.len(), 16);
    }

    #[test]
    fn steady_state_stops_allocating() {
        let arena = OutputArena::new();
        for _ in 0..16 {
            let c = arena.acquire(32, 8);
            arena.release(c);
        }
        assert_eq!(arena.misses(), 1, "only the first acquire allocates");
        assert_eq!(arena.hits(), 15);
    }

    #[test]
    fn smaller_request_reuses_bigger_buffer() {
        let arena = OutputArena::new();
        arena.release(Dense::zeros(100, 10));
        let d = arena.acquire(5, 5);
        assert_eq!(arena.hits(), 1);
        assert_eq!(d.data.len(), 25);
    }

    #[test]
    fn retention_cap_keeps_the_biggest() {
        let arena = OutputArena::with_capacity(2);
        arena.release(Dense::zeros(1, 8));
        arena.release(Dense::zeros(1, 16));
        arena.release(Dense::zeros(1, 64)); // displaces the 8-slot buffer
        assert_eq!(arena.retained(), 2);
        let d = arena.acquire(1, 64);
        assert_eq!(arena.hits(), 1, "the big buffer survived the cap");
        arena.release(d);
        arena.release(Dense::zeros(1, 4)); // smaller than both: dropped
        assert_eq!(arena.retained(), 2);
        assert!(arena.acquire(1, 64).data.len() == 64);
        assert_eq!(arena.hits(), 2);
    }

    #[test]
    fn zero_width_buffers_are_not_retained() {
        let arena = OutputArena::new();
        arena.release(Dense::zeros(8, 0));
        assert_eq!(arena.retained(), 0);
    }
}
