//! Executable SpMM engines (CPU).
//!
//! These are the *algorithms* of the paper's evaluation, re-hosted on CPU so
//! every comparison runs end-to-end on this testbed (DESIGN.md §2): the
//! native HRPB hot path mirrors cuTeSpMM's Algorithm 1, and the baselines
//! mirror the scalar-core kernels (cuSparse CSR/COO, Sputnik, GE-SpMM) and
//! the TC-GNN SGT scheme. Emulated tensor-core engines perform the *full*
//! zero-filled dense brick products so their operation counts match what the
//! TCU would execute; scalar engines touch only stored nonzeros.
//!
//! Preprocessing (format construction) is deliberately separated from
//! execution — §6.3 measures it.

pub mod coo;
pub mod csr;
pub mod dense;
pub mod exec;
pub mod gespmm;
pub mod hrpb;
pub mod sputnik;
pub mod tcgnn;

use crate::formats::{Coo, Dense};

/// A prepared SpMM engine: the sparse matrix has been converted to the
/// algorithm's native format; `spmm` may be invoked many times (the
/// amortization argument of §6.3).
pub trait SpmmEngine: Send + Sync {
    /// Algorithm name (stable, used in reports).
    fn name(&self) -> &'static str;
    /// `C = A · B`; `B.rows` must equal the sparse matrix's column count.
    fn spmm(&self, b: &Dense) -> Dense;
    /// `C = A · B` into a caller-owned output (the zero-allocation serving
    /// path; pair with [`exec::OutputArena`]). `c` must already be shaped
    /// `rows × b.cols`; its prior contents are overwritten, so a reused
    /// dirty buffer is fine. The parallel engines override this to write in
    /// place; the default delegates to [`SpmmEngine::spmm`] and copies.
    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        let out = self.spmm(b);
        assert_eq!(
            (c.rows, c.cols),
            (out.rows, out.cols),
            "C shape must be rows x B cols"
        );
        c.data.copy_from_slice(&out.data);
    }
    /// Useful FLOPs per invocation at width `n`: `2 · nnz · n`.
    fn flops(&self, n: usize) -> f64;
    /// FLOPs the hardware would *execute* per invocation, including
    /// zero-fill (equals `flops` for scalar engines).
    fn executed_flops(&self, n: usize) -> f64 {
        self.flops(n)
    }
    /// Sparse operand shape `(rows, cols)`.
    fn shape(&self) -> (usize, usize);
}

/// Algorithm selector (CLI / bench wiring).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Dense oracle (zero-filled full matmul).
    Dense,
    /// cuSparse-CSR-like row-split scalar kernel.
    Csr,
    /// cuSparse-COO-like segmented scalar kernel.
    Coo,
    /// Sputnik-like: row swizzle + 1-D tiling.
    Sputnik,
    /// GE-SpMM-like: CSR with coalesced sparse-row caching.
    GeSpmm,
    /// TC-GNN SGT: row-window column condensing into 16×8 TC blocks.
    TcGnn,
    /// cuTeSpMM: HRPB + Algorithm 1 (this paper).
    Hrpb,
}

impl Algo {
    /// Number of algorithm variants (dense array sizing: planner scale
    /// tables, metrics routing lanes).
    pub const COUNT: usize = 7;

    /// Stable dense index in `0..Algo::COUNT`, matching `Algo::all()` order.
    pub fn index(&self) -> usize {
        match self {
            Algo::Dense => 0,
            Algo::Csr => 1,
            Algo::Coo => 2,
            Algo::Sputnik => 3,
            Algo::GeSpmm => 4,
            Algo::TcGnn => 5,
            Algo::Hrpb => 6,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Dense => "dense",
            Algo::Csr => "csr",
            Algo::Coo => "coo",
            Algo::Sputnik => "sputnik",
            Algo::GeSpmm => "gespmm",
            Algo::TcGnn => "tcgnn",
            Algo::Hrpb => "cutespmm",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "dense" => Algo::Dense,
            "csr" => Algo::Csr,
            "coo" => Algo::Coo,
            "sputnik" => Algo::Sputnik,
            "gespmm" => Algo::GeSpmm,
            "tcgnn" => Algo::TcGnn,
            "cutespmm" | "hrpb" => Algo::Hrpb,
            _ => return None,
        })
    }

    /// All executable algorithms.
    pub fn all() -> [Algo; 7] {
        [Algo::Dense, Algo::Csr, Algo::Coo, Algo::Sputnik, Algo::GeSpmm, Algo::TcGnn, Algo::Hrpb]
    }

    /// The scalar-core baselines forming the paper's `Best-SC` envelope.
    pub fn scalar_core() -> [Algo; 4] {
        [Algo::Csr, Algo::Coo, Algo::Sputnik, Algo::GeSpmm]
    }

    /// Prepare an engine for this algorithm (the preprocessing step).
    pub fn prepare(&self, coo: &Coo) -> Box<dyn SpmmEngine> {
        match self {
            Algo::Dense => Box::new(dense::DenseEngine::prepare(coo)),
            Algo::Csr => Box::new(csr::CsrEngine::prepare(coo)),
            Algo::Coo => Box::new(coo::CooEngine::prepare(coo)),
            Algo::Sputnik => Box::new(sputnik::SputnikEngine::prepare(coo)),
            Algo::GeSpmm => Box::new(gespmm::GeSpmmEngine::prepare(coo)),
            Algo::TcGnn => Box::new(tcgnn::TcGnnEngine::prepare(coo)),
            Algo::Hrpb => Box::new(hrpb::HrpbEngine::prepare(coo)),
        }
    }
}

/// Shared `spmm_into` precondition: B matches the sparse shape and C is
/// already `rows × B.cols` (the panic strings match the `spmm` asserts).
pub(crate) fn check_into_shapes(engine: &dyn SpmmEngine, b: &Dense, c: &Dense) {
    let (rows, cols) = engine.shape();
    assert_eq!(b.rows, cols, "B rows must equal A cols");
    assert_eq!((c.rows, c.cols), (rows, b.cols), "C shape must be rows x B cols");
}

/// Worker count for the parallel engines (capped so test machines with many
/// cores don't oversubscribe tiny matrices).
pub(crate) fn num_workers(rows: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    hw.min(rows.div_ceil(64)).max(1)
}

/// Split `n` items into per-worker contiguous ranges.
pub(crate) fn chunks(n: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    let workers = workers.min(n.max(1)).max(1);
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut pos = 0;
    for i in 0..workers {
        let len = base + usize::from(i < extra);
        out.push(pos..pos + len);
        pos += len;
    }
    out
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::rng::Rng;

    /// Every engine must match the dense oracle on a batch of random cases.
    pub fn engine_matches_oracle(algo: Algo) {
        let mut rng = Rng::new(0xC0FFEE);
        for (m, k, n, d) in [
            (1, 1, 1, 1.0),
            (16, 16, 8, 0.3),
            (33, 70, 32, 0.12),
            (128, 256, 64, 0.03),
            (100, 64, 17, 0.08),
            (257, 300, 33, 0.015),
        ] {
            let coo = Coo::random(m, k, d, &mut rng);
            let b = Dense::random(k, n, &mut rng);
            let want = coo.to_dense().matmul(&b);
            let engine = algo.prepare(&coo);
            let got = engine.spmm(&b);
            assert_eq!((got.rows, got.cols), (m, n), "{} shape", algo.name());
            let err = got.rel_fro_error(&want);
            assert!(err < 1e-5, "{} ({m}x{k}, n={n}, d={d}): rel err {err}", algo.name());
        }
    }

    /// Engines must handle an empty matrix.
    pub fn engine_handles_empty(algo: Algo) {
        let coo = Coo::new(32, 48);
        let b = Dense::random(48, 8, &mut Rng::new(1));
        let got = algo.prepare(&coo).spmm(&b);
        assert_eq!(got.data.iter().filter(|&&v| v != 0.0).count(), 0);
    }

    /// `spmm_into` must agree with `spmm` — including into a dirty (NaN)
    /// reused buffer, which catches any path that forgets to overwrite C.
    pub fn spmm_into_matches_spmm(engine: &dyn SpmmEngine, b: &Dense) {
        let want = engine.spmm(b);
        let (rows, _) = engine.shape();
        let mut c = Dense::from_vec(rows, b.cols, vec![f32::NAN; rows * b.cols]);
        engine.spmm_into(b, &mut c);
        let err = c.rel_fro_error(&want);
        assert!(err < 1e-6, "{}: spmm_into diverged from spmm (rel err {err})", engine.name());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_roundtrip() {
        for algo in Algo::all() {
            assert_eq!(Algo::parse(algo.name()), Some(algo));
        }
        assert_eq!(Algo::parse("hrpb"), Some(Algo::Hrpb));
        assert_eq!(Algo::parse("nope"), None);
    }

    #[test]
    fn algo_index_is_a_dense_bijection() {
        let mut seen = [false; Algo::COUNT];
        for (i, algo) in Algo::all().into_iter().enumerate() {
            assert_eq!(algo.index(), i, "{}", algo.name());
            assert!(!seen[algo.index()]);
            seen[algo.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn prop_spmm_into_matches_spmm_for_every_algo() {
        use crate::util::proptest::{check, SparseGen};
        use crate::util::rng::Rng;
        let g = SparseGen { max_m: 64, max_k: 96, max_density: 0.2 };
        check("spmm_into == spmm (all engines)", 10, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            // n = 33: odd width, exercises the micro-kernel lane remainder
            let b = Dense::random(case.k, 33, &mut Rng::new(case.m as u64 * 7 + 1));
            for algo in Algo::all() {
                testutil::spmm_into_matches_spmm(algo.prepare(&coo).as_ref(), &b);
            }
            true
        });
    }

    #[test]
    fn spmm_into_matches_on_large_parallel_shapes() {
        use crate::util::rng::Rng;
        // rows large enough that every engine takes its parallel (pooled)
        // path, plus a serving-scale width that spans multiple slabs
        let mut rng = Rng::new(0xEC0);
        let coo = Coo::random(1024, 512, 0.01, &mut rng);
        let b = Dense::random(512, 256, &mut rng);
        for algo in Algo::all() {
            testutil::spmm_into_matches_spmm(algo.prepare(&coo).as_ref(), &b);
        }
    }

    #[test]
    fn chunks_cover_exactly() {
        for n in [0usize, 1, 7, 64, 100] {
            for w in [1usize, 3, 8] {
                let cs = chunks(n, w);
                let total: usize = cs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                for pair in cs.windows(2) {
                    assert_eq!(pair[0].end, pair[1].start);
                }
            }
        }
    }
}
