//! Sputnik-like scalar engine (Gale et al., SC'20): 1-D row decomposition
//! with *row swizzle* — rows are sorted by nonzero count and dealt round-robin
//! to workers so each worker gets a balanced nnz share — plus residue-free
//! vector-width inner loops. The strongest of the paper's scalar baselines on
//! irregular matrices.

use crate::formats::{Coo, Csr, Dense};
use crate::spmm::exec::{self, SendPtr};
use crate::spmm::{num_workers, SpmmEngine};

pub struct SputnikEngine {
    csr: Csr,
    /// Row processing order after the swizzle (heaviest rows first).
    swizzle: Vec<u32>,
}

impl SputnikEngine {
    pub fn prepare(coo: &Coo) -> Self {
        let csr = Csr::from_coo(coo);
        let mut swizzle: Vec<u32> = (0..csr.rows as u32).collect();
        // sort by descending row length; stable so equal rows keep locality
        swizzle.sort_by_key(|&r| std::cmp::Reverse(csr.row_nnz(r as usize)));
        SputnikEngine { csr, swizzle }
    }

    /// nnz assigned to each of `w` workers under the swizzle (test hook: the
    /// balance property the swizzle exists for).
    pub fn worker_nnz(&self, w: usize) -> Vec<usize> {
        let mut loads = vec![0usize; w];
        for (i, &r) in self.swizzle.iter().enumerate() {
            loads[i % w] += self.csr.row_nnz(r as usize);
        }
        loads
    }
}

impl SpmmEngine for SputnikEngine {
    fn name(&self) -> &'static str {
        "sputnik"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        let mut c = Dense::zeros(self.csr.rows, b.cols);
        self.spmm_into(b, &mut c);
        c
    }

    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        crate::spmm::check_into_shapes(self, b, c);
        let n = b.cols;
        c.data.fill(0.0);
        let workers = num_workers(self.csr.rows);
        if workers <= 1 || self.csr.rows < 128 {
            for &r in &self.swizzle {
                row_kernel(&self.csr, b, r as usize, c.row_mut(r as usize));
            }
            return;
        }
        // round-robin deal of the swizzled order: worker w takes rows
        // swizzle[w], swizzle[w + workers], ... — balanced nnz by
        // construction. Output rows are disjoint; hand out raw row pointers.
        let cptr = SendPtr(c.data.as_mut_ptr());
        let swizzle = &self.swizzle;
        let csr = &self.csr;
        exec::WorkerPool::global().run(workers, &|w| {
            let mut i = w;
            while i < swizzle.len() {
                let r = swizzle[i] as usize;
                // SAFETY: each row index appears exactly once in the
                // swizzle, so row slices are disjoint across workers.
                let crow = unsafe { std::slice::from_raw_parts_mut(cptr.get().add(r * n), n) };
                row_kernel(csr, b, r, crow);
                i += workers;
            }
        });
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.csr.nnz() as f64 * n as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.csr.rows, self.csr.cols)
    }
}

#[inline]
fn row_kernel(csr: &Csr, b: &Dense, r: usize, crow: &mut [f32]) {
    for (col, v) in csr.row_entries(r) {
        let brow = b.row(col as usize);
        for (cv, bv) in crow.iter_mut().zip(brow) {
            *cv += v * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::{testutil, Algo};
    use crate::util::rng::Rng;

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::Sputnik);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::Sputnik);
    }

    #[test]
    fn spmm_into_reuses_a_dirty_buffer() {
        let mut rng = Rng::new(62);
        let coo = Coo::random(700, 300, 0.02, &mut rng);
        let engine = SputnikEngine::prepare(&coo);
        let b = Dense::random(300, 20, &mut rng);
        testutil::spmm_into_matches_spmm(&engine, &b);
    }

    #[test]
    fn swizzle_balances_worker_nnz() {
        // power-law row lengths: without swizzle, contiguous split is wildly
        // unbalanced; with it, worker loads stay within 2x of each other
        let mut rng = Rng::new(60);
        let mut t = Vec::new();
        for r in 0..512usize {
            let len = if r < 8 { 200 } else { 2 };
            for j in 0..len {
                t.push((r, (j * 7 + r) % 1024, rng.nz_value()));
            }
        }
        let coo = Coo::from_triplets(512, 1024, &t);
        let engine = SputnikEngine::prepare(&coo);
        let loads = engine.worker_nnz(4);
        let (mn, mx) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(*mx as f64 / (*mn).max(1) as f64 <= 2.0, "loads {loads:?}");
    }

    #[test]
    fn swizzle_is_a_permutation() {
        let coo = Coo::random(200, 100, 0.05, &mut Rng::new(61));
        let engine = SputnikEngine::prepare(&coo);
        let mut seen = vec![false; 200];
        for &r in &engine.swizzle {
            assert!(!seen[r as usize]);
            seen[r as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
