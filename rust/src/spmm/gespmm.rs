//! GE-SpMM-like scalar engine (Huang et al., SC'20): CSR with *coalesced
//! sparse-row caching* — the row's (col, val) pairs are staged once into a
//! local buffer and reused across the N dimension in cache-sized tiles, the
//! CPU analogue of GE-SpMM staging them in shared memory for all warps
//! covering the feature dimension.

use crate::formats::{Coo, Csr, Dense};
use crate::spmm::csr::parallel_row_split_into;
use crate::spmm::SpmmEngine;

/// N-tile width: one row of B per tile fits comfortably in L1 alongside the
/// staged sparse row (mirrors GE-SpMM's 32-thread coalesced tile).
const N_TILE: usize = 64;

pub struct GeSpmmEngine {
    csr: Csr,
}

impl GeSpmmEngine {
    pub fn prepare(coo: &Coo) -> Self {
        GeSpmmEngine { csr: Csr::from_coo(coo) }
    }
}

impl SpmmEngine for GeSpmmEngine {
    fn name(&self) -> &'static str {
        "gespmm"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        let mut c = Dense::zeros(self.csr.rows, b.cols);
        self.spmm_into(b, &mut c);
        c
    }

    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        crate::spmm::check_into_shapes(self, b, c);
        parallel_row_split_into(&self.csr, b, c, |csr, b, range, out| {
            let n = b.cols;
            // staged sparse row (the "shared memory" buffer)
            let mut cols: Vec<u32> = Vec::new();
            let mut vals: Vec<f32> = Vec::new();
            for (i, r) in range.clone().enumerate() {
                cols.clear();
                vals.clear();
                for (c, v) in csr.row_entries(r) {
                    cols.push(c);
                    vals.push(v);
                }
                let crow = &mut out[i * n..(i + 1) * n];
                // walk N in tiles, reusing the staged row per tile
                let mut n0 = 0;
                while n0 < n {
                    let n1 = (n0 + N_TILE).min(n);
                    for (&c, &v) in cols.iter().zip(&vals) {
                        let brow = &b.row(c as usize)[n0..n1];
                        let ctile = &mut crow[n0..n1];
                        for (cv, bv) in ctile.iter_mut().zip(brow) {
                            *cv += v * bv;
                        }
                    }
                    n0 = n1;
                }
            }
        })
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.csr.nnz() as f64 * n as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.csr.rows, self.csr.cols)
    }
}

#[cfg(test)]
mod tests {
    use crate::spmm::{testutil, Algo};

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::GeSpmm);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::GeSpmm);
    }

    #[test]
    fn wide_n_crosses_tiles() {
        use crate::formats::{Coo, Dense};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(70);
        let coo = Coo::random(64, 128, 0.05, &mut rng);
        let b = Dense::random(128, 200, &mut rng); // 200 > 3 tiles
        let want = coo.to_dense().matmul(&b);
        let got = Algo::GeSpmm.prepare(&coo).spmm(&b);
        assert!(got.rel_fro_error(&want) < 1e-5);
    }
}
