//! cuSparse-COO-like scalar engine: the nonzero stream is split into equal
//! segments; each worker accumulates into a private C and segments are merged
//! row-block-wise — mirroring the atomic/segmented-reduction structure of a
//! GPU COO SpMM without its fine-grained atomics.

use crate::formats::{Coo, Dense};
use crate::spmm::exec::{self, SendPtr};
use crate::spmm::{chunks, num_workers, SpmmEngine};
use std::sync::Mutex;

pub struct CooEngine {
    coo: Coo,
}

impl CooEngine {
    pub fn prepare(coo: &Coo) -> Self {
        let mut c = coo.clone();
        if !c.is_normalized() {
            c.normalize();
        }
        CooEngine { coo: c }
    }
}

impl SpmmEngine for CooEngine {
    fn name(&self) -> &'static str {
        "coo"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        let mut c = Dense::zeros(self.coo.rows, b.cols);
        self.spmm_into(b, &mut c);
        c
    }

    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        crate::spmm::check_into_shapes(self, b, c);
        let n = b.cols;
        c.data.fill(0.0);
        let nnz = self.coo.nnz();
        let workers = num_workers(nnz / 64 + 1);
        if workers <= 1 || nnz < 4096 {
            scatter(&self.coo, b, 0..nnz, &mut c.data, n);
            return;
        }
        // each worker owns a nonzero segment; segment 0 scatters straight
        // into C (it is zeroed and no other part touches it during the
        // run), the rest accumulate into private outputs summed afterwards
        // (the "consolidation" cost the paper's §5 discussion attributes to
        // K-split schemes, made explicit here)
        let segs = chunks(nnz, workers);
        let partials: Mutex<Vec<Dense>> = Mutex::new(Vec::new());
        let cptr = SendPtr(c.data.as_mut_ptr());
        let clen = c.data.len();
        exec::WorkerPool::global().run(segs.len(), &|w| {
            if w == 0 {
                // SAFETY: part 0 is C's only writer until `run` returns;
                // the merge below happens strictly afterwards.
                let out = unsafe { std::slice::from_raw_parts_mut(cptr.get(), clen) };
                scatter(&self.coo, b, segs[0].clone(), out, n);
            } else {
                let mut part = Dense::zeros(self.coo.rows, n);
                scatter(&self.coo, b, segs[w].clone(), &mut part.data, n);
                partials.lock().unwrap().push(part);
            }
        });
        for part in partials.into_inner().unwrap() {
            for (cv, pv) in c.data.iter_mut().zip(&part.data) {
                *cv += pv;
            }
        }
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.coo.nnz() as f64 * n as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.coo.rows, self.coo.cols)
    }
}

fn scatter(coo: &Coo, b: &Dense, seg: std::ops::Range<usize>, c: &mut [f32], n: usize) {
    for i in seg {
        let r = coo.row_idx[i] as usize;
        let col = coo.col_idx[i] as usize;
        let v = coo.values[i];
        let brow = b.row(col);
        let crow = &mut c[r * n..(r + 1) * n];
        for (cv, bv) in crow.iter_mut().zip(brow) {
            *cv += v * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::spmm::{testutil, Algo};

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::Coo);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::Coo);
    }

    #[test]
    fn parallel_segments_match_oracle_into_dirty_buffer() {
        use crate::formats::{Coo, Dense};
        use crate::util::rng::Rng;
        // dense enough that nnz >= 4096: the segmented parallel path runs
        let mut rng = Rng::new(63);
        let coo = Coo::random(400, 200, 0.1, &mut rng);
        assert!(coo.nnz() >= 4096, "test needs the parallel path");
        let engine = Algo::Coo.prepare(&coo);
        let b = Dense::random(200, 18, &mut rng);
        testutil::spmm_into_matches_spmm(engine.as_ref(), &b);
    }
}
