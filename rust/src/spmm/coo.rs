//! cuSparse-COO-like scalar engine: the nonzero stream is split into equal
//! segments; each worker accumulates into a private C and segments are merged
//! row-block-wise — mirroring the atomic/segmented-reduction structure of a
//! GPU COO SpMM without its fine-grained atomics.

use crate::formats::{Coo, Dense};
use crate::spmm::{chunks, num_workers, SpmmEngine};

pub struct CooEngine {
    coo: Coo,
}

impl CooEngine {
    pub fn prepare(coo: &Coo) -> Self {
        let mut c = coo.clone();
        if !c.is_normalized() {
            c.normalize();
        }
        CooEngine { coo: c }
    }
}

impl SpmmEngine for CooEngine {
    fn name(&self) -> &'static str {
        "coo"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        assert_eq!(b.rows, self.coo.cols, "B rows must equal A cols");
        let n = b.cols;
        let nnz = self.coo.nnz();
        let workers = num_workers(nnz / 64 + 1);
        if workers <= 1 || nnz < 4096 {
            let mut c = Dense::zeros(self.coo.rows, n);
            scatter(&self.coo, b, 0..nnz, &mut c);
            return c;
        }
        // each worker owns a nonzero segment and a private output; private
        // outputs are summed (the "consolidation" cost the paper's §5
        // discussion attributes to K-split schemes, made explicit here)
        let segs = chunks(nnz, workers);
        let partials: Vec<Dense> = std::thread::scope(|s| {
            let handles: Vec<_> = segs
                .into_iter()
                .map(|seg| {
                    s.spawn(move || {
                        let mut part = Dense::zeros(self.coo.rows, n);
                        scatter(&self.coo, b, seg, &mut part);
                        part
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut c = Dense::zeros(self.coo.rows, n);
        for part in partials {
            for (cv, pv) in c.data.iter_mut().zip(&part.data) {
                *cv += pv;
            }
        }
        c
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.coo.nnz() as f64 * n as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.coo.rows, self.coo.cols)
    }
}

fn scatter(coo: &Coo, b: &Dense, seg: std::ops::Range<usize>, c: &mut Dense) {
    for i in seg {
        let r = coo.row_idx[i] as usize;
        let col = coo.col_idx[i] as usize;
        let v = coo.values[i];
        let brow = b.row(col);
        let crow = c.row_mut(r);
        for (cv, bv) in crow.iter_mut().zip(brow) {
            *cv += v * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::spmm::{testutil, Algo};

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::Coo);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::Coo);
    }
}
