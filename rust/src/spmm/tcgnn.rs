//! TC-GNN-like engine (Wang et al., ATC'23): SGT — Sparse Graph Translation.
//!
//! Rows are cut into 16-high *row windows*; the unique columns of each window
//! are condensed (deduplicated, order of first appearance) and grouped into
//! 16×8 TC blocks that a tensor core consumes after zero-filling. Unlike
//! HRPB there is **no brick-level pattern compression**: every TC block is
//! materialized densely (zeros included) and the MMA executes the full
//! 16×8×N product. That single-level blocking — and the dense decode traffic
//! it implies — is exactly the inefficiency the paper's Fig. 2/9/10 measure
//! against cuTeSpMM.

use crate::formats::{Coo, Dense};
use crate::spmm::exec::{self, SendPtr};
use crate::spmm::{chunks, num_workers, SpmmEngine};

const WIN_H: usize = 16; // row-window height = TC block rows
const WIN_W: usize = 8; // TC block columns (condensed)

/// One 16×8 TC block, stored dense (the zero-filled operand the TCU sees).
struct TcBlock {
    /// Original B-row index of each of the 8 condensed column slots
    /// (`u32::MAX` for padding slots).
    cols: [u32; WIN_W],
    /// Dense 16×8 values, row-major.
    vals: [f32; WIN_H * WIN_W],
}

pub struct TcGnnEngine {
    rows: usize,
    cols: usize,
    nnz: usize,
    /// TC blocks of window `w`: `win_ptr[w]..win_ptr[w+1]`.
    win_ptr: Vec<u32>,
    blocks: Vec<TcBlock>,
}

impl TcGnnEngine {
    pub fn prepare(coo: &Coo) -> Self {
        let mut c = coo.clone();
        if !c.is_normalized() {
            c.normalize();
        }
        let num_windows = c.rows.div_ceil(WIN_H).max(1);
        let mut win_ptr = Vec::with_capacity(num_windows + 1);
        win_ptr.push(0u32);
        let mut blocks = Vec::new();

        // entries are row-major sorted; walk windows
        let mut i = 0usize;
        for w in 0..num_windows {
            let r_end = ((w + 1) * WIN_H) as u32;
            let start = i;
            while i < c.nnz() && c.row_idx[i] < r_end {
                i += 1;
            }
            let entries = start..i;

            // condense: unique columns sorted ascending (SGT orders by
            // column id), then group into blocks of 8
            let mut uniq: Vec<u32> = entries.clone().map(|j| c.col_idx[j]).collect();
            uniq.sort_unstable();
            uniq.dedup();
            let nblk = uniq.len().div_ceil(WIN_W);
            let blk_base = blocks.len();
            for b in 0..nblk {
                let slot_cols = &uniq[b * WIN_W..((b + 1) * WIN_W).min(uniq.len())];
                let mut cols = [u32::MAX; WIN_W];
                cols[..slot_cols.len()].copy_from_slice(slot_cols);
                blocks.push(TcBlock { cols, vals: [0.0; WIN_H * WIN_W] });
            }
            // scatter values into their block slots
            for j in entries {
                let col = c.col_idx[j];
                let slot = uniq.binary_search(&col).unwrap();
                let (b, s) = (slot / WIN_W, slot % WIN_W);
                let r = (c.row_idx[j] as usize) % WIN_H;
                blocks[blk_base + b].vals[r * WIN_W + s] = c.values[j];
            }
            win_ptr.push(blocks.len() as u32);
        }

        TcGnnEngine { rows: c.rows, cols: c.cols, nnz: c.nnz(), win_ptr, blocks }
    }

    /// Number of TC blocks (the SGT compression metric).
    pub fn num_tc_blocks(&self) -> usize {
        self.blocks.len()
    }
}

impl SpmmEngine for TcGnnEngine {
    fn name(&self) -> &'static str {
        "tcgnn"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        let mut c = Dense::zeros(self.rows, b.cols);
        self.spmm_into(b, &mut c);
        c
    }

    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        crate::spmm::check_into_shapes(self, b, c);
        let n = b.cols;
        let num_windows = self.win_ptr.len() - 1;
        c.data.fill(0.0);

        let run = |win_range: std::ops::Range<usize>, out: &mut [f32]| {
            let base_row = win_range.start * WIN_H;
            for w in win_range {
                let (bs, be) = (self.win_ptr[w] as usize, self.win_ptr[w + 1] as usize);
                for blk in &self.blocks[bs..be] {
                    // dense 16x8 @ 8xN MMA, zero-fill included: the inner
                    // loops do NOT skip zeros — that is the TCU's execution
                    // model and TC-GNN's cost structure.
                    for (s, &col) in blk.cols.iter().enumerate() {
                        if col == u32::MAX {
                            continue; // padding slot: no B row exists
                        }
                        let brow = b.row(col as usize);
                        for r in 0..WIN_H {
                            let row = w * WIN_H + r;
                            if row >= self.rows {
                                break;
                            }
                            let a = blk.vals[r * WIN_W + s];
                            let off = (row - base_row) * n;
                            let crow = &mut out[off..off + n];
                            for (cv, bv) in crow.iter_mut().zip(brow) {
                                *cv += a * bv;
                            }
                        }
                    }
                }
            }
        };

        let workers = num_workers(self.rows);
        if workers <= 1 || num_windows < 8 {
            run(0..num_windows, &mut c.data);
            return;
        }
        let ranges = chunks(num_windows, workers);
        let cptr = SendPtr(c.data.as_mut_ptr());
        exec::WorkerPool::global().run(ranges.len(), &|w| {
            let rg = ranges[w].clone();
            let row_start = (rg.start * WIN_H).min(self.rows);
            let row_end = (rg.end * WIN_H).min(self.rows);
            // SAFETY: window ranges are disjoint and contiguous, so the
            // per-part row slices never alias.
            let out = unsafe {
                std::slice::from_raw_parts_mut(
                    cptr.get().add(row_start * n),
                    (row_end - row_start) * n,
                )
            };
            run(rg, out);
        });
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.nnz as f64 * n as f64
    }

    fn executed_flops(&self, n: usize) -> f64 {
        // every TC block runs the full dense 16x8xN product
        2.0 * (self.blocks.len() * WIN_H * WIN_W * n) as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::{testutil, Algo};
    use crate::util::rng::Rng;

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::TcGnn);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::TcGnn);
    }

    #[test]
    fn condensing_dedups_columns() {
        // 16 rows all hitting columns {0, 500}: one window, 2 unique cols,
        // one TC block
        let mut t = Vec::new();
        for r in 0..16 {
            t.push((r, 0usize, 1.0f32));
            t.push((r, 500usize, 2.0f32));
        }
        let coo = Coo::from_triplets(16, 1000, &t);
        let e = TcGnnEngine::prepare(&coo);
        assert_eq!(e.num_tc_blocks(), 1);
    }

    #[test]
    fn executed_flops_exceed_useful_on_sparse_input() {
        let coo = Coo::random(128, 512, 0.005, &mut Rng::new(80));
        let e = TcGnnEngine::prepare(&coo);
        assert!(e.executed_flops(32) > e.flops(32));
    }

    #[test]
    fn tc_blocks_at_least_hrpb_bricks_worth() {
        // SGT has no 16x4 brick packing; its 16x8 blocks over the same
        // matrix can't beat HRPB's active-column compaction by more than the
        // width ratio — sanity relation used by the cost models.
        let coo = Coo::random(256, 256, 0.02, &mut Rng::new(81));
        let e = TcGnnEngine::prepare(&coo);
        let hrpb = crate::hrpb::build_from_coo(&coo);
        let s = crate::hrpb::stats::compute(&hrpb);
        // 2 brick columns (4 wide) per TC block (8 wide)
        assert!(e.num_tc_blocks() * 2 >= s.num_brick_cols / 2);
    }
}
