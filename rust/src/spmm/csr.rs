//! cuSparse-CSR-like scalar engine: row-split parallelism, each worker owns a
//! contiguous row range and streams `C[r, :] += a · B[col, :]` over the row's
//! nonzeros — the canonical scalar-core SpMM the paper's Best-SC includes.

use crate::formats::{Coo, Csr, Dense};
use crate::spmm::exec::{self, SendPtr};
use crate::spmm::{chunks, num_workers, SpmmEngine};

pub struct CsrEngine {
    csr: Csr,
}

impl CsrEngine {
    pub fn prepare(coo: &Coo) -> Self {
        CsrEngine { csr: Csr::from_coo(coo) }
    }

    pub fn csr(&self) -> &Csr {
        &self.csr
    }
}

/// Row-range kernel shared with the other CSR-based baselines: compute rows
/// `range` of C into `out` (a zeroed `range.len() * n` slice).
pub(crate) fn csr_rows_kernel(csr: &Csr, b: &Dense, range: std::ops::Range<usize>, out: &mut [f32]) {
    let n = b.cols;
    for (i, r) in range.clone().enumerate() {
        let crow = &mut out[i * n..(i + 1) * n];
        for (c, v) in csr.row_entries(r) {
            let brow = b.row(c as usize);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += v * bv;
            }
        }
    }
}

/// Parallel row-split driver shared by CSR-family engines: zero `c`, then
/// run `kernel` over contiguous row ranges on the persistent worker pool
/// (no per-call thread spawn, no per-call output allocation).
pub(crate) fn parallel_row_split_into(
    csr: &Csr,
    b: &Dense,
    c: &mut Dense,
    kernel: impl Fn(&Csr, &Dense, std::ops::Range<usize>, &mut [f32]) + Sync,
) {
    let n = b.cols;
    c.data.fill(0.0);
    let workers = num_workers(csr.rows);
    if workers <= 1 || csr.rows < 128 {
        kernel(csr, b, 0..csr.rows, &mut c.data);
        return;
    }
    let ranges = chunks(csr.rows, workers);
    let base = SendPtr(c.data.as_mut_ptr());
    exec::WorkerPool::global().run(ranges.len(), &|w| {
        let range = ranges[w].clone();
        // SAFETY: `chunks` yields disjoint contiguous row ranges, so the
        // per-part output slices never alias.
        let out = unsafe {
            std::slice::from_raw_parts_mut(base.get().add(range.start * n), range.len() * n)
        };
        kernel(csr, b, range, out);
    });
}

impl SpmmEngine for CsrEngine {
    fn name(&self) -> &'static str {
        "csr"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        let mut c = Dense::zeros(self.csr.rows, b.cols);
        self.spmm_into(b, &mut c);
        c
    }

    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        crate::spmm::check_into_shapes(self, b, c);
        parallel_row_split_into(&self.csr, b, c, csr_rows_kernel);
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.csr.nnz() as f64 * n as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.csr.rows, self.csr.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::{testutil, Algo};
    use crate::util::rng::Rng;

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::Csr);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::Csr);
    }

    #[test]
    fn large_parallel_path_consistent_with_serial() {
        let mut rng = Rng::new(50);
        let coo = Coo::random(1000, 300, 0.01, &mut rng);
        let b = Dense::random(300, 40, &mut rng);
        let engine = CsrEngine::prepare(&coo);
        let par = engine.spmm(&b);
        // serial reference through the same kernel
        let mut ser = Dense::zeros(1000, 40);
        csr_rows_kernel(&engine.csr, &b, 0..1000, &mut ser.data);
        assert_eq!(par.max_abs_diff(&ser), 0.0);
    }

    #[test]
    fn spmm_into_reuses_a_dirty_buffer() {
        let mut rng = Rng::new(52);
        let coo = Coo::random(600, 200, 0.02, &mut rng);
        let engine = CsrEngine::prepare(&coo);
        let b = Dense::random(200, 24, &mut rng);
        testutil::spmm_into_matches_spmm(&engine, &b);
    }

    #[test]
    #[should_panic(expected = "B rows must equal A cols")]
    fn shape_mismatch_panics() {
        let coo = Coo::random(10, 20, 0.2, &mut Rng::new(51));
        let b = Dense::zeros(19, 4);
        CsrEngine::prepare(&coo).spmm(&b);
    }
}
