//! Dense oracle engine: zero-fill the sparse matrix and run a full matmul.
//! Correctness reference for every other engine; also the "what if the TCU
//! did *no* compression" strawman in the ablation bench.

use crate::formats::{Coo, Dense};
use crate::spmm::SpmmEngine;

pub struct DenseEngine {
    a: Dense,
}

impl DenseEngine {
    pub fn prepare(coo: &Coo) -> Self {
        DenseEngine { a: coo.to_dense() }
    }
}

impl SpmmEngine for DenseEngine {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        self.a.matmul(b)
    }

    fn flops(&self, n: usize) -> f64 {
        let nnz = self.a.data.iter().filter(|&&v| v != 0.0).count();
        2.0 * nnz as f64 * n as f64
    }

    fn executed_flops(&self, n: usize) -> f64 {
        2.0 * (self.a.rows * self.a.cols * n) as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.a.rows, self.a.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::testutil;
    use crate::spmm::Algo;
    use crate::util::rng::Rng;

    #[test]
    fn matches_oracle_by_construction() {
        testutil::engine_matches_oracle(Algo::Dense);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::Dense);
    }

    #[test]
    fn executed_flops_counts_zeros() {
        let coo = Coo::random(32, 32, 0.1, &mut Rng::new(2));
        let e = DenseEngine::prepare(&coo);
        assert!(e.executed_flops(16) > e.flops(16));
    }
}
