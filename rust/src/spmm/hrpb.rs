//! cuTeSpMM native engine — the paper's Algorithm 1 re-hosted on CPU.
//!
//! Faithful structural mirror of the GPU kernel:
//! * one *work unit* (= GPU thread block) per row panel, or per virtual
//!   panel after §5 wave-aware splitting;
//! * per block: the packed byte run is read in place (the shared-memory
//!   staging of line 17), the needed B rows are addressed through
//!   `active_cols` (lines 19-22), brick patterns are decoded with prefix
//!   popcounts (lines 33-38, `util::bits`), and a dense `TM × N` accumulator
//!   tile stays register/L1-stationary until the panel completes (c_frag,
//!   line 46);
//! * C is processed in TN-style **column slabs** ([`exec::slab`]): the slab
//!   of the C tile stays L1-resident across all blocks of a unit while the
//!   hoisted B-row slab slices stream — the warp-coarsened `TN` loop of §4,
//!   re-hosted against a cache model instead of a register file. The
//!   1-4-term brick-row FMAs dispatch to the fixed-width
//!   [`exec::microkernel`] bodies;
//! * units run on the persistent [`exec::WorkerPool`] in natural panel
//!   order (consecutive panels share B rows — §5's cache argument); split
//!   panels accumulate into private tiles merged once at the end — the CPU
//!   analogue of the atomic consolidation §5 prices in.
//!
//! The scalar FMA here skips the zero-fill the real TCU would execute;
//! [`SpmmEngine::executed_flops`] reports the TCU count (bricks ×
//! pattern-bits × N) so the cost models and benches can charge it.
//!
//! The kernel is instantiated per [`BrickGeometry`]: the brick-row mask
//! width, the B-row fragment count and the FMA chaining all follow the
//! HRPB's geometry. The default 16×4 shape takes exactly the pre-catalog
//! path (one 1-4-term micro-kernel per brick row); wider bricks (8×8)
//! chain a 4-term pass with a 1-4-term remainder — bit-identical under the
//! micro-kernels' strict left-fold contract.

use crate::formats::{Coo, Dense};
use crate::hrpb::{self, pack, Hrpb};
use crate::loadbalance::{self, Device, Schedule, WorkUnit};
use crate::params::BrickGeometry;
use crate::spmm::exec::{self, microkernel, slab, SendPtr};
use crate::spmm::SpmmEngine;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Execution controls, exposed for the `experiment exec` A/B measurement.
/// Serving paths use [`ExecOpts::default`] (pooled dispatch, auto slab or
/// the engine's planner-provided override).
#[derive(Clone, Copy, Debug)]
pub struct ExecOpts {
    /// Dispatch units on the persistent worker pool; `false` spawns scoped
    /// threads per call (the pre-runtime behavior, kept for the A/B).
    pub pooled: bool,
    /// Column-slab width: `0` = auto (cache model), `usize::MAX` =
    /// unblocked (one slab spanning all of N).
    pub slab_width: usize,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts { pooled: true, slab_width: 0 }
    }
}

/// Widest brick column the kernel's fixed fragment arrays accommodate
/// (the catalog maximum; see [`BrickGeometry::CATALOG`]).
const MAX_BK: usize = 8;

pub struct HrpbEngine {
    /// Shared with the registry entry under serving — the engine never
    /// mutates the HRPB, so preparation avoids a deep clone of the whole
    /// structure (blocks + packed stream).
    hrpb: std::sync::Arc<Hrpb>,
    schedule: Schedule,
    /// Unit processing order, longest first (LPT dispatch).
    order: Vec<u32>,
    stats: hrpb::HrpbStats,
    /// Column-slab width override; 0 = auto (the planner records a swept
    /// width in its plan, the registry installs it here).
    slab_width: usize,
}

impl HrpbEngine {
    /// Prepare with the paper's default tiles and wave-aware balancing for
    /// this host's worker count.
    pub fn prepare(coo: &Coo) -> Self {
        let hrpb = hrpb::build_from_coo(coo);
        Self::from_hrpb(hrpb)
    }

    /// Prepare with an explicit brick geometry at the default tiles.
    pub fn prepare_with_geometry(coo: &Coo, geo: BrickGeometry) -> Self {
        use crate::params::{TK, TM};
        let csr = crate::formats::Csr::from_coo(coo);
        Self::from_hrpb(hrpb::build_with_geometry(&csr, geo, TM, TK))
    }

    /// Wrap an already-built HRPB (preprocessing measured separately).
    pub fn from_hrpb(hrpb: Hrpb) -> Self {
        Self::from_shared(std::sync::Arc::new(hrpb))
    }

    /// Wrap a shared HRPB without cloning it (the registry's build path).
    pub fn from_shared(hrpb: std::sync::Arc<Hrpb>) -> Self {
        let stats = hrpb::stats::compute(&hrpb);
        Self::from_shared_with_stats(hrpb, stats)
    }

    /// Wrap a shared HRPB reusing already-computed stats (the registry's
    /// warm-start path — the artifact carries the stats, recomputing them
    /// would touch every block again).
    pub fn from_shared_with_stats(hrpb: std::sync::Arc<Hrpb>, stats: hrpb::HrpbStats) -> Self {
        let workers = crate::spmm::num_workers(hrpb.rows);
        // CPU "device": `workers` SMs × 1 resident block
        let dev = Device { num_sms: workers, blocks_per_sm: 1 };
        let schedule = loadbalance::schedule_wave_aware(&hrpb, dev);
        Self::with_shared_schedule(hrpb, schedule, stats)
    }

    /// Explicit schedule (the §5 ablation entry point).
    pub fn with_schedule(hrpb: Hrpb, schedule: Schedule) -> Self {
        let stats = hrpb::stats::compute(&hrpb);
        Self::with_shared_schedule(std::sync::Arc::new(hrpb), schedule, stats)
    }

    fn with_shared_schedule(
        hrpb: std::sync::Arc<Hrpb>,
        schedule: Schedule,
        stats: hrpb::HrpbStats,
    ) -> Self {
        debug_assert!(schedule.validate(&hrpb).is_ok());
        // run_unit's fragment arrays are sized for the catalog's widest
        // brick; every catalog entry satisfies this
        assert!(
            hrpb.geometry.brick_k <= MAX_BK,
            "engine supports brick_k <= {MAX_BK}, got {}",
            hrpb.geometry
        );
        // Natural (panel) order: §5's observation — consecutive panels share
        // active columns, so processing them in order keeps B rows hot in
        // cache; the work-stealing dispatch already absorbs imbalance the
        // way GPU waves do (heaviest-first LPT measured 10-20% slower on
        // banded matrices — EXPERIMENTS.md §Perf step 3).
        let order: Vec<u32> = (0..schedule.units.len() as u32).collect();
        HrpbEngine { hrpb, schedule, order, stats, slab_width: 0 }
    }

    pub fn hrpb(&self) -> &Hrpb {
        &self.hrpb
    }

    pub fn stats(&self) -> &hrpb::HrpbStats {
        &self.stats
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// The column-slab width override (0 = auto per call).
    pub fn slab_width(&self) -> usize {
        self.slab_width
    }

    /// Install a column-slab width (the planner knob; 0 restores auto).
    pub fn set_slab_width(&mut self, width: usize) {
        self.slab_width = width;
    }

    /// `C = A · B` with explicit execution controls (`experiment exec`).
    pub fn spmm_opts(&self, b: &Dense, opts: ExecOpts) -> Dense {
        let mut c = Dense::zeros(self.hrpb.rows, b.cols);
        self.spmm_into_opts(b, &mut c, opts);
        c
    }

    /// `spmm_into` with explicit execution controls.
    pub fn spmm_into_opts(&self, b: &Dense, c: &mut Dense, opts: ExecOpts) {
        crate::spmm::check_into_shapes(self, b, c);
        let n = b.cols;
        let tm = self.hrpb.tm;
        c.data.fill(0.0);
        let units = &self.schedule.units;
        if units.is_empty() || n == 0 {
            return;
        }
        let ts = slab::effective(opts.slab_width, n);
        let workers = crate::spmm::num_workers(self.hrpb.rows).min(units.len());
        let next = AtomicUsize::new(0);
        // partial tiles from atomic (split-panel) units, merged afterwards
        let partials: Mutex<Vec<(u32, Vec<f32>)>> = Mutex::new(Vec::new());
        let cptr = SendPtr(c.data.as_mut_ptr());
        let rows = self.hrpb.rows;
        // inverse scatter ([`crate::reorder`]): with a build-time row
        // permutation, unit-local row r of panel p lands in C row
        // new_to_old[p·tm + r], so output comes back in original row order
        // with no extra pass over C
        let scatter: Option<&[u32]> = self.hrpb.perm.as_deref().map(|p| p.new_to_old.as_slice());

        let worker = |_: usize| {
            // private tile for atomic units only, reused across them
            let mut tile: Vec<f32> = Vec::new();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= self.order.len() {
                    break;
                }
                let unit = &units[self.order[i] as usize];
                let r0 = unit.panel as usize * tm;
                let rows_here = tm.min(rows - r0);
                if unit.atomic {
                    tile.clear();
                    tile.resize(rows_here * n, 0.0);
                    let tptr = SendPtr(tile.as_mut_ptr());
                    // SAFETY: local rows index this worker's private
                    // rows_here × n tile, zeroed just above.
                    self.run_unit(unit, b, &|r| unsafe { tptr.get().add(r * n) }, n, ts);
                    // the copy covers only the ragged panel's real rows and
                    // is built *before* taking the partials lock
                    let copy = tile.clone();
                    partials.lock().unwrap().push((unit.panel, copy));
                } else {
                    // exclusive writer of this panel's rows: accumulate
                    // straight into C (the tile buffer + copy would double
                    // the per-panel traffic — EXPERIMENTS.md §Perf step 2).
                    // SAFETY: non-atomic units own their panel exclusively
                    // (Schedule::validate guarantees exact tiling), the
                    // scatter map is a bijection so target rows stay
                    // disjoint across units, and C was zeroed above,
                    // matching run_unit's contract.
                    self.run_unit(
                        unit,
                        b,
                        &|r| {
                            let row = match scatter {
                                Some(s) => s[r0 + r] as usize,
                                None => r0 + r,
                            };
                            unsafe { cptr.get().add(row * n) }
                        },
                        n,
                        ts,
                    );
                }
            }
        };

        if workers <= 1 {
            worker(0);
        } else if opts.pooled {
            exec::WorkerPool::global().run(workers, &worker);
        } else {
            std::thread::scope(|s| {
                for w in 0..workers {
                    let worker = &worker;
                    s.spawn(move || worker(w));
                }
            });
        }

        // consolidation of split panels (the atomic cost of §5), routed
        // through the same inverse scatter as the direct path
        for (panel, tile) in partials.into_inner().unwrap() {
            let r0 = panel as usize * tm;
            let rows_here = tile.len() / n;
            for r in 0..rows_here {
                let row = match scatter {
                    Some(s) => s[r0 + r] as usize,
                    None => r0 + r,
                };
                let out = &mut c.data[row * n..row * n + n];
                for (cv, tv) in out.iter_mut().zip(&tile[r * n..(r + 1) * n]) {
                    *cv += tv;
                }
            }
        }
    }

    /// Process one work unit. `row_ptr(r)` resolves unit-local row `r`
    /// (0-based within the panel) to the start of its length-`n` output
    /// row — a private tile row, or a (possibly permutation-scattered) row
    /// of C itself. The caller guarantees every resolved row starts zeroed
    /// and is owned exclusively by this unit; `ts` is the column-slab
    /// width.
    #[inline]
    fn run_unit<F: Fn(usize) -> *mut f32>(
        &self,
        unit: &WorkUnit,
        b: &Dense,
        row_ptr: &F,
        n: usize,
        ts: usize,
    ) {
        let tk = self.hrpb.tk;
        let geo = self.hrpb.geometry;
        let (bm, bk) = (geo.brick_m, geo.brick_k);
        // per-brick-row nonzero mask: the low bk bits of the pattern shifted
        // to the row (bk <= 8 < 64, so the shift never overflows)
        let row_mask = (1u64 << bk) - 1;
        let brick_cols = tk / bk;
        let panel_base = self.hrpb.blocked_row_ptr[unit.panel as usize] as usize;
        let blocks = (panel_base + unit.start as usize)..(panel_base + unit.end as usize);
        // unit-granularity profiling span (the GPU analogue: one thread
        // block). Gated on one relaxed load; the clock and the brick-count
        // walk below run only while kernel tracing is on.
        let trace_t0 = crate::trace::kernel_enabled().then(std::time::Instant::now);

        // TN loop (§4): one cache-sized column slab of the C tile at a time,
        // held L1-resident across every block of the unit. The packed
        // stream is re-decoded per slab — index arithmetic, cheap next to
        // the slab's FMA volume.
        for cols in slab::slabs(n, ts) {
            let (s0, s1) = (cols.start, cols.end);
            for blk_idx in blocks.clone() {
                // line 17-18: the packed block, read in place
                let blk = pack::view(&self.hrpb, blk_idx);
                let active = self.hrpb.block_active_cols(blk_idx);
                debug_assert_eq!(active.len(), tk);

                // lines 25-41: walk brick columns, decode patterns, FMA
                let mut vi = 0usize;
                for bc in 0..brick_cols {
                    let (s, e) = (blk.col_ptr[bc] as usize, blk.col_ptr[bc + 1] as usize);
                    if s == e {
                        continue;
                    }
                    // b_frag: the brick_k B-row *slab slices* of this brick
                    // column, hoisted once per slab (lines 26-28)
                    let empty: &[f32] = &[];
                    let mut brows = [empty; MAX_BK];
                    for (c, brow) in brows.iter_mut().enumerate().take(bk) {
                        *brow = &b.row(active[bc * bk + c] as usize)[s0..s1];
                    }
                    for j in s..e {
                        let br = blk.rows[j] as usize * bm;
                        let pattern = blk.patterns[j];
                        // walk brick rows; each row's bk-wide window of the
                        // pattern is its nonzero mask (row-major bit order,
                        // Fig 3b — a nibble at the default geometry)
                        let mut rest = pattern;
                        while rest != 0 {
                            let r = rest.trailing_zeros() as usize / bk;
                            let row_bits = (pattern >> (r * bk)) & row_mask;
                            rest &= !(row_mask << (r * bk));
                            // SAFETY: the caller owns local row `br + r`
                            // exclusively (see the method contract), and
                            // distinct local rows never alias.
                            let crow = unsafe {
                                std::slice::from_raw_parts_mut(row_ptr(br + r).add(s0), s1 - s0)
                            };
                            // the MMA (line 41), zero-skipped on CPU. The
                            // brick row's 1-brick_k products fuse into 1-2
                            // passes over the C slab — the CPU analogue of
                            // the MMA's brick_k-deep contraction.
                            let mut av = [0f32; MAX_BK];
                            let mut bs = [empty; MAX_BK];
                            let mut cnt = 0usize;
                            let mut bits = row_bits;
                            while bits != 0 {
                                let ci = bits.trailing_zeros() as usize;
                                bits &= bits - 1;
                                av[cnt] = blk.values[vi];
                                bs[cnt] = brows[ci];
                                vi += 1;
                                cnt += 1;
                            }
                            // >4 terms (8-wide bricks) chain a full 4-term
                            // pass with a 1-4-term remainder; the strict
                            // left-fold micro-kernel contract makes the
                            // split bit-identical to one 5-8-term fold
                            let mut lo = 0usize;
                            if cnt > 4 {
                                microkernel::fma4(
                                    &mut *crow,
                                    [av[0], av[1], av[2], av[3]],
                                    [bs[0], bs[1], bs[2], bs[3]],
                                );
                                lo = 4;
                            }
                            match cnt - lo {
                                1 => microkernel::fma1(crow, av[lo], bs[lo]),
                                2 => microkernel::fma2(
                                    crow,
                                    [av[lo], av[lo + 1]],
                                    [bs[lo], bs[lo + 1]],
                                ),
                                3 => microkernel::fma3(
                                    crow,
                                    [av[lo], av[lo + 1], av[lo + 2]],
                                    [bs[lo], bs[lo + 1], bs[lo + 2]],
                                ),
                                _ => microkernel::fma4(
                                    crow,
                                    [av[lo], av[lo + 1], av[lo + 2], av[lo + 3]],
                                    [bs[lo], bs[lo + 1], bs[lo + 2], bs[lo + 3]],
                                ),
                            }
                        }
                    }
                }
            }
        }
        if let Some(t0) = trace_t0 {
            // brick volume of this unit: one col_ptr tail load per block
            // (num_bricks = col_ptr[brick_cols], see hrpb::pack)
            let bricks: u64 = blocks
                .clone()
                .map(|blk_idx| pack::view(&self.hrpb, blk_idx).col_ptr[brick_cols] as u64)
                .sum();
            crate::trace::record(
                crate::trace::Kind::Kernel,
                "unit",
                t0,
                crate::trace::NO_TOKEN,
                crate::trace::SpanArgs::new()
                    .with("panel", unit.panel as u64)
                    .with("bricks", bricks)
                    .with("slab", ts as u64),
            );
        }
    }
}

impl SpmmEngine for HrpbEngine {
    fn name(&self) -> &'static str {
        "cutespmm"
    }

    fn spmm(&self, b: &Dense) -> Dense {
        self.spmm_opts(b, ExecOpts { pooled: true, slab_width: self.slab_width })
    }

    fn spmm_into(&self, b: &Dense, c: &mut Dense) {
        self.spmm_into_opts(b, c, ExecOpts { pooled: true, slab_width: self.slab_width });
    }

    fn flops(&self, n: usize) -> f64 {
        2.0 * self.hrpb.nnz as f64 * n as f64
    }

    fn executed_flops(&self, n: usize) -> f64 {
        // each active brick costs a full dense brick_m×brick_k x brick_k×N
        // MMA pass — bits() slots per brick regardless of fill
        2.0 * (self.stats.num_bricks * self.hrpb.geometry.bits() * n) as f64
    }

    fn shape(&self) -> (usize, usize) {
        (self.hrpb.rows, self.hrpb.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::{testutil, Algo};
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    #[test]
    fn matches_oracle() {
        testutil::engine_matches_oracle(Algo::Hrpb);
    }

    #[test]
    fn empty_ok() {
        testutil::engine_handles_empty(Algo::Hrpb);
    }

    #[test]
    fn split_schedule_matches_unsplit() {
        // force maximal splitting (avg-split strawman) and verify the atomic
        // consolidation path produces identical results
        let mut rng = Rng::new(90);
        let mut t = Vec::new();
        for c in 0..200usize {
            t.push((c % 16, c * 3, rng.nz_value()));
        }
        for r in (16..160).step_by(16) {
            t.push((r, 0, rng.nz_value()));
        }
        let coo = crate::formats::Coo::from_triplets(160, 640, &t);
        let b = Dense::random(640, 48, &mut rng);

        let h1 = crate::hrpb::build_from_coo(&coo);
        let none = HrpbEngine::with_schedule(h1.clone(), loadbalance::schedule_none(&h1));
        let split = HrpbEngine::with_schedule(h1.clone(), loadbalance::schedule_avg_split(&h1));
        assert!(split.schedule().atomic_units > 0, "test needs real splitting");
        let c1 = none.spmm(&b);
        let c2 = split.spmm(&b);
        assert!(c1.rel_fro_error(&c2) < 1e-6);
    }

    #[test]
    fn prop_hrpb_engine_equals_csr_engine() {
        let g = SparseGen { max_m: 80, max_k: 120, max_density: 0.2 };
        let mut rng = Rng::new(91);
        check("hrpb == csr engine", 30, &g, |case| {
            let coo = crate::formats::Coo::from_triplets(case.m, case.k, &case.triplets);
            let b = Dense::random(case.k, 24, &mut Rng::new(case.m as u64 * 31 + case.k as u64));
            let want = Algo::Csr.prepare(&coo).spmm(&b);
            let got = Algo::Hrpb.prepare(&coo).spmm(&b);
            got.rel_fro_error(&want) < 1e-5
        });
        let _ = &mut rng;
    }

    #[test]
    fn executed_flops_charge_brick_zero_fill() {
        let coo = crate::formats::Coo::random(128, 512, 0.004, &mut Rng::new(92));
        let e = HrpbEngine::prepare(&coo);
        assert!(e.executed_flops(32) >= e.flops(32));
        // fill ratio consistency: executed / useful == 1/alpha
        let ratio = e.executed_flops(32) / e.flops(32);
        assert!((ratio - 1.0 / e.stats().alpha).abs() / ratio < 1e-9);
    }

    #[test]
    fn tall_matrix_last_panel_partial() {
        // rows not a multiple of TM: last panel is ragged
        let mut rng = Rng::new(93);
        let coo = crate::formats::Coo::random(37, 64, 0.15, &mut rng);
        let b = Dense::random(64, 16, &mut rng);
        let want = coo.to_dense().matmul(&b);
        let got = HrpbEngine::prepare(&coo).spmm(&b);
        assert!(got.rel_fro_error(&want) < 1e-5);
    }

    /// Every (pooled, slab) combination must agree with the dense oracle,
    /// including slab widths that do not divide N, exceed N, or force
    /// remainder-only micro-kernel passes.
    #[test]
    fn slab_boundaries_match_oracle() {
        let mut rng = Rng::new(94);
        let coo = crate::formats::Coo::random(200, 160, 0.06, &mut rng);
        let engine = HrpbEngine::prepare(&coo);
        for n in [1usize, 7, 33, 40, 256] {
            let b = Dense::random(160, n, &mut rng);
            let want = coo.to_dense().matmul(&b);
            for pooled in [true, false] {
                for slab_width in [0usize, 1, 3, 16, 24, n, n + 13, usize::MAX] {
                    let got = engine.spmm_opts(&b, ExecOpts { pooled, slab_width });
                    let err = got.rel_fro_error(&want);
                    assert!(err < 1e-5, "n={n} pooled={pooled} slab={slab_width}: err {err}");
                }
            }
        }
    }

    /// Installed slab overrides survive and change nothing numerically.
    #[test]
    fn slab_width_knob_is_numerically_inert() {
        let mut rng = Rng::new(95);
        let coo = crate::formats::Coo::random(300, 256, 0.03, &mut rng);
        let b = Dense::random(256, 200, &mut rng);
        let auto = HrpbEngine::prepare(&coo);
        assert_eq!(auto.slab_width(), 0);
        let want = auto.spmm(&b);
        let mut pinned = HrpbEngine::prepare(&coo);
        pinned.set_slab_width(48);
        assert_eq!(pinned.slab_width(), 48);
        assert!(pinned.spmm(&b).rel_fro_error(&want) < 1e-6);
    }

    /// Build the (unreordered, reordered) HRPB pair for a matrix; the
    /// reordered side always gets a non-trivial permutation.
    fn reorder_pair(coo: &crate::formats::Coo) -> (crate::hrpb::Hrpb, crate::hrpb::Hrpb) {
        use crate::params::{TK, TM};
        let csr = crate::formats::Csr::from_coo(coo);
        let orig = crate::hrpb::builder::build_with(&csr, TM, TK);
        let prop = crate::reorder::propose(&csr, TM, TK);
        let reord = crate::reorder::build_reordered(&csr, prop.perm, TM, TK, 3);
        (orig, reord)
    }

    /// The reorder contract: output rows come back in original order, and
    /// (on split-free schedules) the result is BIT-identical to the
    /// unreordered engine — a row permutation does not change per-row
    /// accumulation order, and the micro-kernels fold terms left-to-right
    /// so regrouped brick boundaries are numerically invisible.
    #[test]
    fn reordered_spmm_is_bit_identical_to_unreordered() {
        let spec = crate::gen::MatrixSpec {
            name: "t".into(),
            rows: 384,
            family: crate::gen::Family::Community {
                communities: 24,
                intra_degree: 10,
                inter_frac: 0.08,
            },
            seed: 0x5EED,
        };
        let coo = crate::reorder::RowPermutation::random(384, &mut Rng::new(7))
            .apply_coo(&spec.generate());
        let (orig, reord) = reorder_pair(&coo);
        assert!(reord.perm.as_ref().is_some_and(|p| !p.is_identity()), "needs a real perm");
        let e_orig = HrpbEngine::with_schedule(orig.clone(), loadbalance::schedule_none(&orig));
        let e_reord = HrpbEngine::with_schedule(reord.clone(), loadbalance::schedule_none(&reord));
        let b = Dense::random(coo.cols, 40, &mut Rng::new(8));
        let want = e_orig.spmm(&b);
        assert_eq!(e_reord.spmm(&b).max_abs_diff(&want), 0.0, "spmm must be bit-identical");
        // spmm_into into a NaN-dirty buffer: the scatter epilogue must
        // overwrite every row
        let mut dirty = Dense::from_vec(coo.rows, 40, vec![f32::NAN; coo.rows * 40]);
        e_reord.spmm_into(&b, &mut dirty);
        assert_eq!(dirty.max_abs_diff(&want), 0.0, "spmm_into must be bit-identical");
    }

    #[test]
    fn prop_reordered_engine_is_bit_identical_on_random_sparse() {
        let g = SparseGen { max_m: 90, max_k: 110, max_density: 0.2 };
        check("reordered == unreordered (bit exact)", 25, &g, |case| {
            let coo = crate::formats::Coo::from_triplets(case.m, case.k, &case.triplets);
            let (orig, reord) = reorder_pair(&coo);
            let e_o = HrpbEngine::with_schedule(orig.clone(), loadbalance::schedule_none(&orig));
            let e_r =
                HrpbEngine::with_schedule(reord.clone(), loadbalance::schedule_none(&reord));
            let b = Dense::random(case.k, 17, &mut Rng::new(case.m as u64 * 13 + 5));
            let want = e_o.spmm(&b);
            let mut dirty =
                Dense::from_vec(case.m, 17, vec![f32::NAN; case.m * 17]);
            e_r.spmm_into(&b, &mut dirty);
            e_r.spmm(&b).max_abs_diff(&want) == 0.0 && dirty.max_abs_diff(&want) == 0.0
        });
    }

    /// The default (wave-aware, pooled) path on a reordered build still
    /// matches the dense oracle — covering scattered direct writes, the
    /// atomic-unit merge epilogue, and ragged last panels.
    #[test]
    fn reordered_default_engine_matches_oracle_including_ragged_rows() {
        let mut rng = Rng::new(97);
        let coo = crate::formats::Coo::random(275, 160, 0.07, &mut rng);
        let (_, reord) = reorder_pair(&coo);
        let engine = HrpbEngine::from_hrpb(reord);
        let b = Dense::random(160, 33, &mut rng);
        let want = coo.to_dense().matmul(&b);
        assert!(engine.spmm(&b).rel_fro_error(&want) < 1e-5, "rows scatter to original order");
        let mut c = Dense::from_vec(275, 33, vec![f32::NAN; 275 * 33]);
        engine.spmm_into(&b, &mut c);
        assert!(c.rel_fro_error(&want) < 1e-5);
    }

    /// Split (atomic) schedules on a reordered build merge partial tiles
    /// through the scatter map.
    #[test]
    fn reordered_split_schedule_merges_through_the_scatter() {
        let mut rng = Rng::new(98);
        let mut t = Vec::new();
        for c in 0..220usize {
            t.push((c % 16, c * 2, rng.nz_value()));
        }
        for r in 16..128 {
            t.push((r, (r * 7) % 440, rng.nz_value()));
        }
        let coo = crate::formats::Coo::from_triplets(128, 440, &t);
        let (_, reord) = reorder_pair(&coo);
        let split = HrpbEngine::with_schedule(
            reord.clone(),
            loadbalance::schedule_avg_split(&reord),
        );
        assert!(split.schedule().atomic_units > 0, "test needs real splitting");
        let b = Dense::random(440, 24, &mut rng);
        let want = coo.to_dense().matmul(&b);
        assert!(split.spmm(&b).rel_fro_error(&want) < 1e-5);
    }

    /// The tentpole geometry contract: every catalog geometry serves
    /// BIT-identically to the default-geometry engine. Per C row the
    /// product stream is the panel's columns in compacted order whatever
    /// the brick shape, and the micro-kernels fold strictly left-to-right
    /// (chained for 8-wide bricks), so regrouped brick boundaries are
    /// numerically invisible. Covers ragged panels (rows % 16 != 0), the
    /// transposed 8x1 variant, and NaN-dirty `spmm_into` buffers.
    #[test]
    fn catalog_geometries_are_bit_identical_to_the_default_engine() {
        let mut rng = Rng::new(200);
        let coo = crate::formats::Coo::random(203, 157, 0.08, &mut rng);
        let b = Dense::random(157, 33, &mut rng);
        let oracle = coo.to_dense().matmul(&b);
        let want = HrpbEngine::prepare(&coo).spmm(&b);
        assert!(want.rel_fro_error(&oracle) < 1e-5);
        for geo in BrickGeometry::CATALOG {
            let e = HrpbEngine::prepare_with_geometry(&coo, geo);
            assert_eq!(e.hrpb().geometry, geo);
            assert_eq!(e.spmm(&b).max_abs_diff(&want), 0.0, "{geo}: spmm");
            let mut dirty = Dense::from_vec(203, 33, vec![f32::NAN; 203 * 33]);
            e.spmm_into(&b, &mut dirty);
            assert_eq!(dirty.max_abs_diff(&want), 0.0, "{geo}: spmm_into");
            assert!(e.executed_flops(33) >= e.flops(33), "{geo}: zero-fill charge");
        }
    }

    #[test]
    fn prop_catalog_geometries_match_the_csr_oracle_bit_identically() {
        let g = SparseGen { max_m: 70, max_k: 90, max_density: 0.2 };
        check("catalog geometries bit-identical", 12, &g, |case| {
            let coo = crate::formats::Coo::from_triplets(case.m, case.k, &case.triplets);
            let b = Dense::random(case.k, 9, &mut Rng::new(case.m as u64 * 7 + 3));
            let want = Algo::Csr.prepare(&coo).spmm(&b);
            let base = HrpbEngine::prepare(&coo).spmm(&b);
            base.rel_fro_error(&want) < 1e-5
                && BrickGeometry::CATALOG.iter().all(|&geo| {
                    let e = HrpbEngine::prepare_with_geometry(&coo, geo);
                    let mut dirty =
                        Dense::from_vec(case.m, 9, vec![f32::NAN; case.m * 9]);
                    e.spmm_into(&b, &mut dirty);
                    e.spmm(&b).max_abs_diff(&base) == 0.0
                        && dirty.max_abs_diff(&base) == 0.0
                })
        });
    }

    /// Split (atomic) schedules stay correct for every geometry — the
    /// partial-tile merge epilogue is geometry-agnostic.
    #[test]
    fn split_schedules_match_unsplit_for_every_geometry() {
        let mut rng = Rng::new(201);
        let mut t = Vec::new();
        for c in 0..220usize {
            t.push((c % 16, c * 2, rng.nz_value()));
        }
        for r in 16..128 {
            t.push((r, (r * 7) % 440, rng.nz_value()));
        }
        let coo = crate::formats::Coo::from_triplets(128, 440, &t);
        let csr = crate::formats::Csr::from_coo(&coo);
        let b = Dense::random(440, 24, &mut rng);
        let want = coo.to_dense().matmul(&b);
        for geo in BrickGeometry::CATALOG {
            use crate::params::{TK, TM};
            let h = crate::hrpb::build_with_geometry(&csr, geo, TM, TK);
            let split = HrpbEngine::with_schedule(h.clone(), loadbalance::schedule_avg_split(&h));
            assert!(split.schedule().atomic_units > 0, "{geo}: test needs real splitting");
            let none = HrpbEngine::with_schedule(h.clone(), loadbalance::schedule_none(&h));
            assert!(split.spmm(&b).rel_fro_error(&want) < 1e-5, "{geo}: split vs oracle");
            assert!(none.spmm(&b).rel_fro_error(&want) < 1e-5, "{geo}: unsplit vs oracle");
        }
    }

    /// The pool-reuse property: many threads issuing many calls against
    /// shared engines stay correct and never spawn per call (the global
    /// pool's thread count is fixed; its job counter grows).
    #[test]
    fn pooled_execution_is_correct_across_repeated_concurrent_calls() {
        let mut rng = Rng::new(96);
        let coo = crate::formats::Coo::random(512, 256, 0.04, &mut rng);
        let engine = std::sync::Arc::new(HrpbEngine::prepare(&coo));
        let dense = std::sync::Arc::new(coo.to_dense());
        let jobs_before = exec::WorkerPool::global().jobs_run();
        let threads_before = exec::WorkerPool::global().threads();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let engine = engine.clone();
                let dense = dense.clone();
                s.spawn(move || {
                    let mut c = Dense::zeros(512, 16);
                    for i in 0..6 {
                        let b = Dense::random(256, 16, &mut Rng::new(t * 100 + i));
                        let want = dense.matmul(&b);
                        engine.spmm_into(&b, &mut c);
                        assert!(c.rel_fro_error(&want) < 1e-5, "thread {t} iter {i}");
                    }
                });
            }
        });
        assert_eq!(
            exec::WorkerPool::global().threads(),
            threads_before,
            "no per-call thread creation"
        );
        // single-core hosts run the workers<=1 fast path and skip the pool
        if crate::spmm::num_workers(512) > 1 {
            assert!(exec::WorkerPool::global().jobs_run() >= jobs_before + 24);
        }
    }
}
