//! Sparse and dense matrix formats + MatrixMarket IO.
//!
//! `Coo` is the interchange format every generator produces; `Csr`/`Csc` are
//! the baselines' native formats; `Dense` backs correctness oracles and the
//! B/C operands of SpMM.

pub mod coo;
pub mod csc;
pub mod csr;
pub mod dense;
pub mod mtx;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use dense::Dense;
