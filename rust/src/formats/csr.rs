//! Compressed Sparse Row — the native format of the scalar-core baselines
//! (cuSparse-CSR-like, GE-SpMM-like, Sputnik-like engines).

use crate::formats::coo::Coo;
use crate::formats::dense::Dense;

/// CSR sparse matrix. `row_ptr.len() == rows + 1`; column indices within each
/// row are sorted ascending.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Build from normalized COO (sorted, deduplicated).
    pub fn from_coo(coo: &Coo) -> Self {
        debug_assert!(coo.is_normalized(), "from_coo requires normalized COO");
        let mut row_ptr = vec![0u32; coo.rows + 1];
        for &r in &coo.row_idx {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..coo.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Csr {
            rows: coo.rows,
            cols: coo.cols,
            row_ptr,
            col_idx: coo.col_idx.clone(),
            values: coo.values.clone(),
        }
    }

    /// Back to COO (normalized by construction).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_range(r) {
                coo.push(r, self.col_idx[i] as usize, self.values[i]);
            }
        }
        coo
    }

    /// Index range of row `r`'s entries.
    #[inline]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize
    }

    /// Entries `(col, value)` of row `r`.
    #[inline]
    pub fn row_entries(&self, r: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.row_range(r).map(move |i| (self.col_idx[i], self.values[i]))
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        (self.row_ptr[r + 1] - self.row_ptr[r]) as usize
    }

    pub fn to_dense(&self) -> Dense {
        self.to_coo().to_dense()
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err("row_ptr length".into());
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() as usize != self.nnz() {
            return Err("row_ptr endpoints".into());
        }
        for r in 0..self.rows {
            if self.row_ptr[r] > self.row_ptr[r + 1] {
                return Err(format!("row_ptr not monotone at {r}"));
            }
            let rng = self.row_range(r);
            for i in rng.clone() {
                if self.col_idx[i] as usize >= self.cols {
                    return Err(format!("col index out of range in row {r}"));
                }
                if i > rng.start && self.col_idx[i - 1] >= self.col_idx[i] {
                    return Err(format!("cols not sorted in row {r}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    #[test]
    fn from_coo_round_trip() {
        let mut rng = Rng::new(7);
        let coo = Coo::random(40, 25, 0.15, &mut rng);
        let csr = Csr::from_coo(&coo);
        csr.validate().unwrap();
        assert_eq!(csr.to_coo(), coo);
    }

    #[test]
    fn row_access() {
        let coo = Coo::from_triplets(3, 5, &[(0, 1, 1.0), (0, 4, 2.0), (2, 0, 3.0)]);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.row_nnz(0), 2);
        assert_eq!(csr.row_nnz(1), 0);
        assert_eq!(csr.row_nnz(2), 1);
        let row0: Vec<_> = csr.row_entries(0).collect();
        assert_eq!(row0, vec![(1, 1.0), (4, 2.0)]);
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::new(4, 4);
        let csr = Csr::from_coo(&coo);
        csr.validate().unwrap();
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn prop_csr_coo_round_trip() {
        let g = SparseGen { max_m: 48, max_k: 48, max_density: 0.3 };
        check("csr<->coo round trip", 60, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let csr = Csr::from_coo(&coo);
            csr.validate().is_ok() && csr.to_coo() == coo
        });
    }
}
