//! MatrixMarket (`.mtx`) IO — the on-disk format of the SuiteSparse
//! collection the paper's corpus comes from. Supports the `matrix coordinate
//! {real,integer,pattern} {general,symmetric,skew-symmetric}` subset that
//! covers SuiteSparse SpMM use, plus writing for corpus export.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::formats::coo::Coo;

/// MatrixMarket IO error (a message; the offline image has no `anyhow`).
#[derive(Debug)]
pub struct MtxError(pub String);

impl std::fmt::Display for MtxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for MtxError {}

impl From<std::io::Error> for MtxError {
    fn from(e: std::io::Error) -> Self {
        MtxError(e.to_string())
    }
}

impl From<std::num::ParseIntError> for MtxError {
    fn from(e: std::num::ParseIntError) -> Self {
        MtxError(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for MtxError {
    fn from(e: std::num::ParseFloatError) -> Self {
        MtxError(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, MtxError>;

macro_rules! bail {
    ($($arg:tt)*) => {
        return Err(MtxError(format!($($arg)*)))
    };
}

/// `anyhow::Context`-shaped helpers for the two wrapping styles used below.
trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| MtxError(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| MtxError(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| MtxError(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| MtxError(f()))
    }
}

/// Symmetry classes we understand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a MatrixMarket coordinate file into (normalized) COO, expanding
/// symmetric storage.
pub fn read_mtx(path: &Path) -> Result<Coo> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_mtx_from(std::io::BufReader::new(file))
}

/// Read from any buffered reader (tests use in-memory strings).
pub fn read_mtx_from<R: BufRead>(reader: R) -> Result<Coo> {
    let mut lines = reader.lines();

    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("empty mtx file"),
        }
    };
    let head: Vec<String> = header.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    if head.len() < 4 || head[0] != "%%matrixmarket" || head[1] != "matrix" {
        bail!("not a MatrixMarket matrix header: {header}");
    }
    if head[2] != "coordinate" {
        bail!("only coordinate (sparse) mtx supported, got {}", head[2]);
    }
    let field = head[3].as_str();
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field type {field}");
    }
    let symmetry = match head.get(4).map(|s| s.as_str()).unwrap_or("general") {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => bail!("unsupported symmetry {other}"),
    };

    // size line (skip comments)
    let size_line = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if t.is_empty() || t.starts_with('%') {
                    continue;
                }
                break l;
            }
            None => bail!("missing size line"),
        }
    };
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad size line: {size_line}"))?;
    if dims.len() != 3 {
        bail!("size line needs 'rows cols nnz', got: {size_line}");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::new(rows, cols);
    let mut read = 0usize;
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("missing row")?.parse()?;
        let c: usize = it.next().context("missing col")?.parse()?;
        let v: f32 = match field {
            "pattern" => 1.0,
            _ => it.next().context("missing value")?.parse()?,
        };
        if r == 0 || c == 0 || r > rows || c > cols {
            bail!("index ({r},{c}) out of 1-based range {rows}x{cols}");
        }
        let (r, c) = (r - 1, c - 1);
        if v != 0.0 {
            coo.push(r, c, v);
            match symmetry {
                Symmetry::General => {}
                Symmetry::Symmetric if r != c => coo.push(c, r, v),
                Symmetry::SkewSymmetric if r != c => coo.push(c, r, -v),
                _ => {}
            }
        }
        read += 1;
    }
    if read != nnz {
        bail!("expected {nnz} entries, found {read}");
    }
    coo.normalize();
    Ok(coo)
}

/// Write COO as a `general real` coordinate file.
pub fn write_mtx(path: &Path, coo: &Coo, comment: Option<&str>) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    if let Some(c) = comment {
        for line in c.lines() {
            writeln!(w, "% {line}")?;
        }
    }
    writeln!(w, "{} {} {}", coo.rows, coo.cols, coo.nnz())?;
    for i in 0..coo.nnz() {
        writeln!(w, "{} {} {}", coo.row_idx[i] + 1, coo.col_idx[i] + 1, coo.values[i])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    fn parse(s: &str) -> Result<Coo> {
        read_mtx_from(Cursor::new(s.as_bytes()))
    }

    #[test]
    fn general_real() {
        let coo = parse(
            "%%MatrixMarket matrix coordinate real general\n\
             % a comment\n\
             3 4 2\n\
             1 2 1.5\n\
             3 4 -2\n",
        )
        .unwrap();
        assert_eq!((coo.rows, coo.cols, coo.nnz()), (3, 4, 2));
        assert_eq!(coo.to_dense()[(0, 1)], 1.5);
        assert_eq!(coo.to_dense()[(2, 3)], -2.0);
    }

    #[test]
    fn symmetric_expansion() {
        let coo = parse(
            "%%MatrixMarket matrix coordinate real symmetric\n\
             3 3 2\n\
             2 1 5\n\
             3 3 7\n",
        )
        .unwrap();
        assert_eq!(coo.nnz(), 3); // (1,0), (0,1), (2,2)
        let d = coo.to_dense();
        assert_eq!(d[(1, 0)], 5.0);
        assert_eq!(d[(0, 1)], 5.0);
        assert_eq!(d[(2, 2)], 7.0);
    }

    #[test]
    fn skew_symmetric() {
        let coo = parse(
            "%%MatrixMarket matrix coordinate real skew-symmetric\n\
             2 2 1\n\
             2 1 3\n",
        )
        .unwrap();
        let d = coo.to_dense();
        assert_eq!(d[(1, 0)], 3.0);
        assert_eq!(d[(0, 1)], -3.0);
    }

    #[test]
    fn pattern_field() {
        let coo = parse(
            "%%MatrixMarket matrix coordinate pattern general\n\
             2 2 2\n\
             1 1\n\
             2 2\n",
        )
        .unwrap();
        assert_eq!(coo.to_dense()[(0, 0)], 1.0);
        assert_eq!(coo.to_dense()[(1, 1)], 1.0);
    }

    #[test]
    fn rejects_bad_headers_and_counts() {
        assert!(parse("%%MatrixMarket matrix array real general\n2 2\n").is_err());
        assert!(parse("garbage\n1 1 0\n").is_err());
        assert!(parse("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1\n").is_err());
        assert!(parse("%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n").is_err());
    }

    #[test]
    fn write_read_round_trip() {
        let mut rng = Rng::new(9);
        let coo = Coo::random(25, 18, 0.1, &mut rng);
        let dir = std::env::temp_dir().join("cutespmm_mtx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.mtx");
        write_mtx(&path, &coo, Some("round trip\ntwo lines")).unwrap();
        let back = read_mtx(&path).unwrap();
        assert_eq!(back.rows, coo.rows);
        assert_eq!(back.nnz(), coo.nnz());
        assert!(back.to_dense().max_abs_diff(&coo.to_dense()) < 1e-6);
    }
}
