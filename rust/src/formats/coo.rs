//! Coordinate (triplet) sparse format — the interchange representation every
//! generator emits and every converter consumes.

use crate::formats::dense::Dense;
use crate::util::rng::Rng;

/// COO sparse matrix. Invariant after `normalize`: entries sorted
/// row-major, no duplicates, all indices in range, no explicit zeros.
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row_idx: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, row_idx: Vec::new(), col_idx: Vec::new(), values: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.row_idx.push(r as u32);
        self.col_idx.push(c as u32);
        self.values.push(v);
    }

    /// From `(row, col, value)` triplets.
    pub fn from_triplets(rows: usize, cols: usize, t: &[(usize, usize, f32)]) -> Self {
        let mut coo = Coo::new(rows, cols);
        for &(r, c, v) in t {
            coo.push(r, c, v);
        }
        coo.normalize();
        coo
    }

    /// Sort row-major, sum duplicates, drop explicit zeros.
    pub fn normalize(&mut self) {
        let n = self.nnz();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by_key(|&i| (self.row_idx[i], self.col_idx[i]));
        let mut row = Vec::with_capacity(n);
        let mut col = Vec::with_capacity(n);
        let mut val: Vec<f32> = Vec::with_capacity(n);
        for &i in &order {
            let (r, c, v) = (self.row_idx[i], self.col_idx[i], self.values[i]);
            if let (Some(&lr), Some(&lc)) = (row.last(), col.last()) {
                if lr == r && lc == c {
                    *val.last_mut().unwrap() += v;
                    continue;
                }
            }
            row.push(r);
            col.push(c);
            val.push(v);
        }
        // drop zeros created by cancellation or pushed explicitly
        let mut keep_row = Vec::with_capacity(val.len());
        let mut keep_col = Vec::with_capacity(val.len());
        let mut keep_val = Vec::with_capacity(val.len());
        for i in 0..val.len() {
            if val[i] != 0.0 {
                keep_row.push(row[i]);
                keep_col.push(col[i]);
                keep_val.push(val[i]);
            }
        }
        self.row_idx = keep_row;
        self.col_idx = keep_col;
        self.values = keep_val;
    }

    /// Is the triplet list sorted row-major with no duplicates?
    pub fn is_normalized(&self) -> bool {
        (1..self.nnz()).all(|i| {
            (self.row_idx[i - 1], self.col_idx[i - 1]) < (self.row_idx[i], self.col_idx[i])
        })
    }

    /// Uniform random sparse matrix with ~`density` fill.
    pub fn random(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Self {
        let mut coo = Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density).round() as usize;
        let mut seen = std::collections::HashSet::with_capacity(target * 2);
        while coo.nnz() < target {
            let r = rng.below(rows);
            let c = rng.below(cols);
            if seen.insert((r, c)) {
                coo.push(r, c, rng.nz_value());
            }
        }
        coo.normalize();
        coo
    }

    /// Materialize dense (oracle use only; asserts a sane size).
    pub fn to_dense(&self) -> Dense {
        assert!(self.rows * self.cols <= 64 << 20, "to_dense on a huge matrix");
        let mut d = Dense::zeros(self.rows, self.cols);
        for i in 0..self.nnz() {
            d[(self.row_idx[i] as usize, self.col_idx[i] as usize)] += self.values[i];
        }
        d
    }

    /// Build from a dense matrix (tests).
    pub fn from_dense(d: &Dense) -> Self {
        let mut coo = Coo::new(d.rows, d.cols);
        for r in 0..d.rows {
            for c in 0..d.cols {
                let v = d[(r, c)];
                if v != 0.0 {
                    coo.push(r, c, v);
                }
            }
        }
        coo
    }

    /// Number of nonzeros per row.
    pub fn row_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.rows];
        for &r in &self.row_idx {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Check all internal invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.row_idx.len() != self.values.len() || self.col_idx.len() != self.values.len() {
            return Err("array length mismatch".into());
        }
        for i in 0..self.nnz() {
            if self.row_idx[i] as usize >= self.rows {
                return Err(format!("row index {} out of range", self.row_idx[i]));
            }
            if self.col_idx[i] as usize >= self.cols {
                return Err(format!("col index {} out of range", self.col_idx[i]));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, SparseGen};

    #[test]
    fn normalize_sorts_and_merges() {
        let mut coo = Coo::new(4, 4);
        coo.push(2, 1, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(2, 1, 3.0); // duplicate -> summed
        coo.push(1, 1, -1.0);
        coo.normalize();
        assert!(coo.is_normalized());
        assert_eq!(coo.nnz(), 3);
        let d = coo.to_dense();
        assert_eq!(d[(2, 1)], 4.0);
        assert_eq!(d[(0, 3)], 2.0);
    }

    #[test]
    fn normalize_drops_cancelled_zeros() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, -1.0);
        coo.normalize();
        assert_eq!(coo.nnz(), 0);
    }

    #[test]
    fn random_density_close() {
        let mut rng = Rng::new(4);
        let coo = Coo::random(100, 200, 0.05, &mut rng);
        let want = (100.0 * 200.0 * 0.05) as usize;
        assert_eq!(coo.nnz(), want);
        coo.validate().unwrap();
        assert!(coo.is_normalized());
    }

    #[test]
    fn dense_round_trip() {
        let mut rng = Rng::new(5);
        let coo = Coo::random(30, 17, 0.2, &mut rng);
        let back = Coo::from_dense(&coo.to_dense());
        assert_eq!(back.nnz(), coo.nnz());
        assert_eq!(back.to_dense(), coo.to_dense());
    }

    #[test]
    fn prop_from_triplets_matches_dense_scatter() {
        let g = SparseGen { max_m: 32, max_k: 32, max_density: 0.4 };
        check("coo triplets == dense scatter", 60, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            if coo.validate().is_err() || !coo.is_normalized() {
                return false;
            }
            // scatter triplets into dense independently (duplicates summed)
            let mut d = Dense::zeros(case.m, case.k);
            for &(r, c, v) in &case.triplets {
                d[(r, c)] += v;
            }
            coo.to_dense().max_abs_diff(&d) < 1e-5
        });
    }

    #[test]
    fn row_counts_sum_to_nnz() {
        let mut rng = Rng::new(6);
        let coo = Coo::random(50, 50, 0.1, &mut rng);
        assert_eq!(coo.row_counts().iter().sum::<u32>() as usize, coo.nnz());
    }
}
