//! Compressed Sparse Column — used by the cuSparse-COO/CSC-style baseline and
//! by the HRPB builder's per-panel active-column scan.

use crate::formats::coo::Coo;
use crate::formats::dense::Dense;

/// CSC sparse matrix. `col_ptr.len() == cols + 1`; row indices within each
/// column sorted ascending.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub col_ptr: Vec<u32>,
    pub row_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csc {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Build from normalized COO.
    pub fn from_coo(coo: &Coo) -> Self {
        debug_assert!(coo.is_normalized());
        let nnz = coo.nnz();
        let mut col_ptr = vec![0u32; coo.cols + 1];
        for &c in &coo.col_idx {
            col_ptr[c as usize + 1] += 1;
        }
        for i in 0..coo.cols {
            col_ptr[i + 1] += col_ptr[i];
        }
        let mut next = col_ptr.clone();
        let mut row_idx = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        // COO is row-major sorted, so writing in order keeps rows sorted
        // within each column.
        for i in 0..nnz {
            let c = coo.col_idx[i] as usize;
            let dst = next[c] as usize;
            row_idx[dst] = coo.row_idx[i];
            values[dst] = coo.values[i];
            next[c] += 1;
        }
        Csc { rows: coo.rows, cols: coo.cols, col_ptr, row_idx, values }
    }

    #[inline]
    pub fn col_range(&self, c: usize) -> std::ops::Range<usize> {
        self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize
    }

    pub fn col_nnz(&self, c: usize) -> usize {
        (self.col_ptr[c + 1] - self.col_ptr[c]) as usize
    }

    /// Entries `(row, value)` of column `c`.
    pub fn col_entries(&self, c: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.col_range(c).map(move |i| (self.row_idx[i], self.values[i]))
    }

    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for c in 0..self.cols {
            for i in self.col_range(c) {
                coo.push(self.row_idx[i] as usize, c, self.values[i]);
            }
        }
        coo.normalize();
        coo
    }

    pub fn to_dense(&self) -> Dense {
        self.to_coo().to_dense()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.col_ptr.len() != self.cols + 1 {
            return Err("col_ptr length".into());
        }
        if self.col_ptr[0] != 0 || *self.col_ptr.last().unwrap() as usize != self.nnz() {
            return Err("col_ptr endpoints".into());
        }
        for c in 0..self.cols {
            let rng = self.col_range(c);
            for i in rng.clone() {
                if self.row_idx[i] as usize >= self.rows {
                    return Err(format!("row index out of range in col {c}"));
                }
                if i > rng.start && self.row_idx[i - 1] >= self.row_idx[i] {
                    return Err(format!("rows not sorted in col {c}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, SparseGen};
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_random() {
        let mut rng = Rng::new(8);
        let coo = Coo::random(33, 21, 0.2, &mut rng);
        let csc = Csc::from_coo(&coo);
        csc.validate().unwrap();
        assert_eq!(csc.to_coo(), coo);
    }

    #[test]
    fn col_access() {
        let coo = Coo::from_triplets(5, 3, &[(1, 0, 1.0), (4, 0, 2.0), (0, 2, 3.0)]);
        let csc = Csc::from_coo(&coo);
        assert_eq!(csc.col_nnz(0), 2);
        assert_eq!(csc.col_nnz(1), 0);
        let col0: Vec<_> = csc.col_entries(0).collect();
        assert_eq!(col0, vec![(1, 1.0), (4, 2.0)]);
    }

    #[test]
    fn prop_round_trip() {
        let g = SparseGen { max_m: 40, max_k: 40, max_density: 0.3 };
        check("csc<->coo round trip", 60, &g, |case| {
            let coo = Coo::from_triplets(case.m, case.k, &case.triplets);
            let csc = Csc::from_coo(&coo);
            csc.validate().is_ok() && csc.to_coo() == coo
        });
    }
}
