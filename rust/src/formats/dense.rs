//! Row-major dense matrix. Backs the B and C operands of SpMM, the
//! correctness oracles, and the dense feature matrices of the GNN examples.

use crate::util::rng::Rng;

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Dense {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Dense { rows, cols, data }
    }

    /// Identity-like (1s on the main diagonal).
    pub fn eye(n: usize) -> Self {
        let mut d = Dense::zeros(n, n);
        for i in 0..n {
            d[(i, i)] = 1.0;
        }
        d
    }

    /// Uniform random values in [-1, 1).
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.f32() * 2.0 - 1.0).collect();
        Dense { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Dense matmul (blocked; oracle for examples — not a hot path).
    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.cols, other.rows, "inner dims");
        let mut out = Dense::zeros(self.rows, other.cols);
        const BK: usize = 64;
        for k0 in (0..self.cols).step_by(BK) {
            let k1 = (k0 + BK).min(self.cols);
            for i in 0..self.rows {
                let arow = self.row(i);
                for k in k0..k1 {
                    let a = arow[k];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = other.row(k);
                    let crow = out.row_mut(i);
                    for (c, b) in crow.iter_mut().zip(brow) {
                        *c += a * b;
                    }
                }
            }
        }
        out
    }

    /// Max absolute difference against another matrix of the same shape.
    pub fn max_abs_diff(&self, other: &Dense) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius-norm error ||self - other|| / ||other||.
    pub fn rel_fro_error(&self, other: &Dense) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f64::INFINITY };
        }
        (num / den).sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Dense {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Dense {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_round_trip() {
        let mut d = Dense::zeros(3, 4);
        d[(2, 3)] = 7.5;
        assert_eq!(d[(2, 3)], 7.5);
        assert_eq!(d.row(2)[3], 7.5);
    }

    #[test]
    fn eye_matmul_is_identity() {
        let mut rng = Rng::new(1);
        let a = Dense::random(5, 5, &mut rng);
        let c = Dense::eye(5).matmul(&a);
        assert!(c.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn matmul_small_known() {
        let a = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Dense::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Rng::new(2);
        let a = Dense::random(17, 33, &mut rng);
        let b = Dense::random(33, 9, &mut rng);
        let c = a.matmul(&b);
        for i in 0..17 {
            for j in 0..9 {
                let mut s = 0.0f32;
                for k in 0..33 {
                    s += a[(i, k)] * b[(k, j)];
                }
                assert!((c[(i, j)] - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn rel_fro_error_zero_for_equal() {
        let mut rng = Rng::new(3);
        let a = Dense::random(4, 4, &mut rng);
        assert_eq!(a.rel_fro_error(&a), 0.0);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_shape_mismatch_panics() {
        let a = Dense::zeros(2, 3);
        let b = Dense::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
