//! Length-prefixed binary frame codec — the wire protocol's bottom layer.
//!
//! Every frame is a fixed 12-byte header followed by the payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic "cT"
//! 2       1     protocol version (1)
//! 3       1     frame kind (request / response / ping / pong)
//! 4       4     payload length, u32 LE (<= MAX_FRAME)
//! 8       4     FNV-1a checksum of the payload, u32 LE
//! ```
//!
//! Robustness-first decode contract (SNIPPETS.md #1 catalogs front-ends
//! that wedge or crash on hostile input): a bad frame is a typed
//! [`FrameError`], never a panic, and every *recoverable* error consumes
//! exactly the offending frame's bytes so the next frame starts clean —
//! an unknown kind, a future version, an oversized length, or a checksum
//! mismatch each skip their (length-known) payload and leave the stream
//! usable. Only errors that lose framing (bad magic — resync is
//! impossible without a length) or lose the stream (truncation, IO) are
//! terminal. The proptests at the bottom pin this contract.

use std::io::{self, Read, Write};

/// Frame magic: rejects peers speaking a different protocol with a typed
/// error on the first two bytes.
pub const MAGIC: [u8; 2] = *b"cT";

/// Current protocol version. Decoders accept exactly this version and
/// skip-with-typed-error anything newer (forward compatibility: a newer
/// peer's frames don't wedge an older server).
pub const VERSION: u8 = 1;

/// Hard payload cap. A hostile length field beyond this is an
/// [`FrameError::Oversized`], and the decoder never allocates more than
/// this many bytes no matter what the header claims.
pub const MAX_FRAME: usize = 16 << 20;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// A [`super::wire::WireRequest`] payload.
    Request,
    /// A [`super::wire::WireResponse`] payload.
    Response,
    /// Health probe (empty payload) — the shard router's probe loop.
    Ping,
    /// Health probe reply (empty payload).
    Pong,
}

impl FrameKind {
    pub fn to_u8(self) -> u8 {
        match self {
            FrameKind::Request => 1,
            FrameKind::Response => 2,
            FrameKind::Ping => 3,
            FrameKind::Pong => 4,
        }
    }

    pub fn from_u8(v: u8) -> Option<FrameKind> {
        match v {
            1 => Some(FrameKind::Request),
            2 => Some(FrameKind::Response),
            3 => Some(FrameKind::Ping),
            4 => Some(FrameKind::Pong),
            _ => None,
        }
    }
}

/// Every way a frame can fail to decode, as data. `recoverable()` says
/// whether the connection is still usable for the next frame.
#[derive(Clone, Debug, PartialEq)]
pub enum FrameError {
    /// Clean end of stream at a frame boundary — the peer closed. Not an
    /// error condition, but decode has to say *something* typed.
    Closed,
    /// The stream ended inside a frame (header or payload cut short).
    Truncated { needed: usize, got: usize },
    /// The first two bytes are not [`MAGIC`] — framing is lost and resync
    /// is impossible (there is no trustworthy length to skip by).
    BadMagic([u8; 2]),
    /// A version newer than [`VERSION`]. The header layout is part of the
    /// version-independent contract, so the payload is skipped and the
    /// connection survives.
    FutureVersion(u8),
    /// An unrecognized frame kind (payload skipped, connection survives).
    UnknownKind(u8),
    /// The length field exceeds [`MAX_FRAME`] (payload skipped in bounded
    /// chunks without ever buffering it, connection survives).
    Oversized { len: usize, max: usize },
    /// Payload checksum mismatch — bit-flip in flight. The payload was
    /// already consumed, so the connection survives.
    BadChecksum { want: u32, got: u32 },
    /// Underlying IO failure (timeouts surface here with their kind).
    Io { kind: io::ErrorKind, detail: String },
}

impl FrameError {
    /// Stable snake_case name (log/metric keys and the CLI error tally).
    pub fn kind(&self) -> &'static str {
        match self {
            FrameError::Closed => "closed",
            FrameError::Truncated { .. } => "truncated",
            FrameError::BadMagic(_) => "bad_magic",
            FrameError::FutureVersion(_) => "future_version",
            FrameError::UnknownKind(_) => "unknown_kind",
            FrameError::Oversized { .. } => "oversized",
            FrameError::BadChecksum { .. } => "bad_checksum",
            FrameError::Io { .. } => "io",
        }
    }

    /// May the caller keep reading frames from this connection? True
    /// exactly when decode consumed the whole offending frame.
    pub fn recoverable(&self) -> bool {
        matches!(
            self,
            FrameError::FutureVersion(_)
                | FrameError::UnknownKind(_)
                | FrameError::Oversized { .. }
                | FrameError::BadChecksum { .. }
        )
    }

    /// Is this a read timeout (deadline expired with no frame)? The
    /// server's reader loop uses this to poll its stop flag instead of
    /// tearing the connection down.
    pub fn timed_out(&self) -> bool {
        matches!(
            self,
            FrameError::Io { kind: io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut, .. }
        )
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::FutureVersion(v) => {
                write!(f, "future protocol version {v} (this peer speaks {VERSION})")
            }
            FrameError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::BadChecksum { want, got } => {
                write!(f, "payload checksum mismatch: header says {want:#010x}, got {got:#010x}")
            }
            FrameError::Io { kind, detail } => write!(f, "io error ({kind:?}): {detail}"),
        }
    }
}

/// FNV-1a over the payload — cheap, order-sensitive, catches the
/// single-bit flips the chaos harness injects.
pub fn checksum(payload: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in payload {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Encode one frame. Panics only on a payload over [`MAX_FRAME`] — a
/// caller bug (the wire layer sizes payloads), not a peer-controlled path.
pub fn encode(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME, "frame payload exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind.to_u8());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Write one frame to a stream (single write call — header and payload in
/// one buffer, so a well-behaved kernel sends one segment for small
/// frames).
pub fn write_frame(w: &mut impl Write, kind: FrameKind, payload: &[u8]) -> io::Result<()> {
    w.write_all(&encode(kind, payload))?;
    w.flush()
}

/// Read exactly `buf.len()` bytes; `Ok(n)` with `n < buf.len()` means the
/// stream ended early (n bytes read).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// Consume and discard `len` payload bytes in bounded chunks (never
/// buffering the claimed length), so recoverable errors leave the stream
/// positioned at the next frame.
fn skip_payload(r: &mut impl Read, len: usize) -> Result<(), FrameError> {
    let mut remaining = len;
    let mut chunk = [0u8; 4096];
    while remaining > 0 {
        let take = remaining.min(chunk.len());
        let got = read_full(r, &mut chunk[..take]).map_err(io_error)?;
        if got < take {
            return Err(FrameError::Truncated { needed: len, got: len - remaining + got });
        }
        remaining -= take;
    }
    Ok(())
}

fn io_error(e: io::Error) -> FrameError {
    FrameError::Io { kind: e.kind(), detail: e.to_string() }
}

/// Decode one frame. On `Ok`, exactly one frame was consumed. On a
/// [recoverable](FrameError::recoverable) error, the offending frame was
/// still fully consumed — call decode again for the next frame. On a
/// terminal error the stream is unusable.
pub fn decode(r: &mut impl Read) -> Result<(FrameKind, Vec<u8>), FrameError> {
    decode_with_max(r, MAX_FRAME)
}

/// [`decode`] with an explicit payload cap (tests use a small cap to
/// exercise the oversized-skip path without 16 MiB streams).
pub fn decode_with_max(
    r: &mut impl Read,
    max_frame: usize,
) -> Result<(FrameKind, Vec<u8>), FrameError> {
    let mut header = [0u8; HEADER_LEN];
    let got = read_full(r, &mut header).map_err(io_error)?;
    if got == 0 {
        return Err(FrameError::Closed);
    }
    if got < HEADER_LEN {
        return Err(FrameError::Truncated { needed: HEADER_LEN, got });
    }
    if header[0..2] != MAGIC {
        return Err(FrameError::BadMagic([header[0], header[1]]));
    }
    let version = header[2];
    let kind_byte = header[3];
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    let want_sum = u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
    // length sanity comes first: a hostile length must never drive an
    // allocation, whatever else is wrong with the frame
    if len > max_frame {
        skip_payload(r, len)?;
        return Err(FrameError::Oversized { len, max: max_frame });
    }
    if version > VERSION {
        skip_payload(r, len)?;
        return Err(FrameError::FutureVersion(version));
    }
    let Some(kind) = FrameKind::from_u8(kind_byte) else {
        skip_payload(r, len)?;
        return Err(FrameError::UnknownKind(kind_byte));
    };
    let mut payload = vec![0u8; len];
    let got = read_full(r, &mut payload).map_err(io_error)?;
    if got < len {
        return Err(FrameError::Truncated { needed: len, got });
    }
    let got_sum = checksum(&payload);
    if got_sum != want_sum {
        return Err(FrameError::BadChecksum { want: want_sum, got: got_sum });
    }
    Ok((kind, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen, UsizeGen};
    use crate::util::rng::Rng;
    use std::io::Cursor;

    fn roundtrip(kind: FrameKind, payload: &[u8]) -> (FrameKind, Vec<u8>) {
        let bytes = encode(kind, payload);
        decode(&mut Cursor::new(bytes)).expect("well-formed frames decode")
    }

    #[test]
    fn well_formed_frames_round_trip() {
        for (kind, payload) in [
            (FrameKind::Request, b"hello".to_vec()),
            (FrameKind::Response, vec![0u8; 1024]),
            (FrameKind::Ping, Vec::new()),
            (FrameKind::Pong, Vec::new()),
        ] {
            let (k, p) = roundtrip(kind, &payload);
            assert_eq!(k, kind);
            assert_eq!(p, payload);
        }
    }

    #[test]
    fn back_to_back_frames_decode_in_order() {
        let mut stream = encode(FrameKind::Request, b"first");
        stream.extend(encode(FrameKind::Ping, b""));
        stream.extend(encode(FrameKind::Response, b"third"));
        let mut cur = Cursor::new(stream);
        assert_eq!(decode(&mut cur).unwrap(), (FrameKind::Request, b"first".to_vec()));
        assert_eq!(decode(&mut cur).unwrap(), (FrameKind::Ping, Vec::new()));
        assert_eq!(decode(&mut cur).unwrap(), (FrameKind::Response, b"third".to_vec()));
        assert_eq!(decode(&mut cur).unwrap_err(), FrameError::Closed);
    }

    /// A generated frame byte-stream with a hostile mutation applied to
    /// the first frame and a clean frame appended after it.
    #[derive(Clone, Debug)]
    struct Mutated {
        bytes: Vec<u8>,
        /// Byte index the mutation touched (for truncation: the cut).
        at: usize,
        mode: u8,
    }

    struct MutatedGen;

    impl Gen for MutatedGen {
        type Value = Mutated;
        fn gen(&self, rng: &mut Rng) -> Mutated {
            let len = rng.range(0, 256);
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let first = encode(FrameKind::Request, &payload);
            let mode = rng.below(3) as u8;
            let mut bytes = first;
            let at;
            match mode {
                // truncated: cut the frame mid-header or mid-payload
                0 => {
                    at = rng.range(1, bytes.len());
                    bytes.truncate(at);
                }
                // bit-flipped payload: checksum must catch it
                1 => {
                    // empty payloads can't flip; force one byte
                    if bytes.len() == HEADER_LEN {
                        bytes = encode(FrameKind::Request, &[7u8]);
                    }
                    at = rng.range(HEADER_LEN, bytes.len());
                    let bit = 1u8 << rng.below(8);
                    bytes[at] ^= bit;
                }
                // future version
                _ => {
                    at = 2;
                    bytes[2] = VERSION + 1 + rng.below(16) as u8;
                }
            }
            Mutated { bytes, at, mode }
        }
        fn shrink(&self, v: &Mutated) -> Vec<Mutated> {
            // shrink toward the smallest stream exhibiting the failure:
            // re-encode with a shorter payload where possible
            let mut out = Vec::new();
            if v.bytes.len() > HEADER_LEN + 1 {
                let mut smaller = v.clone();
                smaller.bytes.truncate(v.bytes.len() - 1);
                out.push(smaller);
            }
            out
        }
    }

    /// Satellite proptest: every hostile mutation yields the *right* typed
    /// error, never a panic — and for the recoverable classes the very
    /// next frame on the stream still decodes.
    #[test]
    fn proptest_hostile_frames_yield_typed_errors_and_recover() {
        let clean_tail = encode(FrameKind::Pong, b"tail");
        check("hostile frames", 300, &MutatedGen, |m| {
            let mut stream = m.bytes.clone();
            stream.extend_from_slice(&clean_tail);
            let mut cur = Cursor::new(stream);
            let err = match decode(&mut cur) {
                Err(e) => e,
                // a payload bit-flip can collide back to a valid checksum
                // only if it didn't change anything — impossible for xor
                // with a nonzero bit — so Ok here means the mutation hit
                // bytes the codec legitimately ignores; skip the case
                Ok(_) => return m.mode == 0 && m.at >= m.bytes.len(),
            };
            let right_type = match m.mode {
                0 => {
                    // the cut splices the clean tail's bytes into the
                    // first frame, so depending on where it fell the
                    // decoder sees a short stream, a garbled header, or a
                    // mismatched payload — any typed error is correct,
                    // a panic or hang is the only failure
                    matches!(
                        err,
                        FrameError::BadChecksum { .. }
                            | FrameError::Truncated { .. }
                            | FrameError::BadMagic(_)
                            | FrameError::UnknownKind(_)
                            | FrameError::Oversized { .. }
                            | FrameError::FutureVersion(_)
                    )
                }
                1 => matches!(err, FrameError::BadChecksum { .. }),
                _ => matches!(err, FrameError::FutureVersion(_)),
            };
            if !right_type {
                return false;
            }
            // recoverable errors must leave the clean tail decodable
            if err.recoverable() && m.mode != 0 {
                match decode(&mut cur) {
                    Ok((k, p)) => k == FrameKind::Pong && p == b"tail",
                    Err(_) => false,
                }
            } else {
                true
            }
        });
    }

    /// Satellite proptest (oversized arm): any frame whose payload
    /// exceeds the cap yields `Oversized` and leaves the stream usable.
    /// Uses a small cap via `decode_with_max` so each case stays tiny;
    /// the 16 MiB production cap is covered by the deterministic test
    /// below.
    #[test]
    fn proptest_oversized_frames_recover() {
        const CAP: usize = 256;
        let clean_tail = encode(FrameKind::Pong, b"tail");
        check("oversized frames", 200, &UsizeGen { lo: CAP + 1, hi: CAP * 4 }, |&len| {
            let payload = vec![0x3Cu8; len];
            let mut stream = encode(FrameKind::Request, &payload);
            stream.extend_from_slice(&clean_tail);
            let mut cur = Cursor::new(stream);
            let err = match decode_with_max(&mut cur, CAP) {
                Err(e) => e,
                Ok(_) => return false,
            };
            if err != (FrameError::Oversized { len, max: CAP }) || !err.recoverable() {
                return false;
            }
            matches!(decode_with_max(&mut cur, CAP), Ok((FrameKind::Pong, ref p)) if p == b"tail")
        });
    }

    #[test]
    fn oversized_frame_is_skipped_without_allocation_and_stream_recovers() {
        // hand-craft a frame whose header claims MAX_FRAME + 3 bytes but
        // whose on-stream payload is small — after the typed error the
        // next frame decodes
        let claimed = MAX_FRAME + 3;
        let body = vec![0xAAu8; 64];
        let mut stream = Vec::new();
        stream.extend_from_slice(&MAGIC);
        stream.push(VERSION);
        stream.push(FrameKind::Request.to_u8());
        stream.extend_from_slice(&(claimed as u32).to_le_bytes());
        stream.extend_from_slice(&checksum(&body).to_le_bytes());
        // on-stream payload: exactly `claimed` bytes so the skip succeeds
        stream.extend(std::iter::repeat(0u8).take(claimed));
        stream.extend(encode(FrameKind::Ping, b""));
        let mut cur = Cursor::new(stream);
        let err = decode(&mut cur).unwrap_err();
        assert_eq!(err, FrameError::Oversized { len: claimed, max: MAX_FRAME });
        assert!(err.recoverable());
        assert_eq!(decode(&mut cur).unwrap(), (FrameKind::Ping, Vec::new()));
    }

    #[test]
    fn future_version_and_unknown_kind_skip_and_recover() {
        let payload = b"from-the-future".to_vec();
        // future version
        let mut f = encode(FrameKind::Request, &payload);
        f[2] = VERSION + 5;
        f.extend(encode(FrameKind::Ping, b""));
        let mut cur = Cursor::new(f);
        assert_eq!(decode(&mut cur).unwrap_err(), FrameError::FutureVersion(VERSION + 5));
        assert_eq!(decode(&mut cur).unwrap(), (FrameKind::Ping, Vec::new()));
        // unknown kind
        let mut f = encode(FrameKind::Request, &payload);
        f[3] = 200;
        f.extend(encode(FrameKind::Pong, b""));
        let mut cur = Cursor::new(f);
        assert_eq!(decode(&mut cur).unwrap_err(), FrameError::UnknownKind(200));
        assert_eq!(decode(&mut cur).unwrap(), (FrameKind::Pong, Vec::new()));
    }

    #[test]
    fn bad_magic_is_terminal() {
        let mut f = encode(FrameKind::Request, b"x");
        f[0] = b'X';
        let err = decode(&mut Cursor::new(f)).unwrap_err();
        assert!(matches!(err, FrameError::BadMagic(_)));
        assert!(!err.recoverable());
    }

    #[test]
    fn truncation_points_are_all_typed() {
        // cut a valid frame at every possible byte offset: each prefix
        // must produce a typed error, never a panic
        let full = encode(FrameKind::Response, b"payload-bytes");
        for cut in 0..full.len() {
            let mut cur = Cursor::new(full[..cut].to_vec());
            let err = decode(&mut cur).unwrap_err();
            if cut == 0 {
                assert_eq!(err, FrameError::Closed);
            } else {
                assert!(
                    matches!(err, FrameError::Truncated { .. }),
                    "cut at {cut}: got {err:?}"
                );
            }
        }
    }

    #[test]
    fn checksum_is_order_sensitive() {
        assert_ne!(checksum(b"ab"), checksum(b"ba"));
        assert_ne!(checksum(b""), checksum(b"\0"));
        // proptest: any single-bit flip changes the checksum
        check("checksum bit sensitivity", 200, &UsizeGen { lo: 0, hi: 1023 }, |&i| {
            let mut data = vec![0x5Au8; 128];
            let byte = i / 8 % 128;
            let bit = 1u8 << (i % 8);
            let before = checksum(&data);
            data[byte] ^= bit;
            checksum(&data) != before
        });
    }
}
