//! TCP front-end for one [`Coordinator`]: per-connection handlers with a
//! bounded in-flight window and read/write deadlines.
//!
//! Robustness contract (the SNIPPETS.md #1 failure catalog, inverted):
//!
//! - **Bounded everything.** Each connection's in-flight window is a
//!   `sync_channel(window)` between its reader and writer half — when the
//!   window is full the reader stops pulling frames, which backs pressure
//!   up the TCP receive buffer to the client. No unbounded queue exists on
//!   the request path.
//! - **Hostile bytes are typed errors.** A recoverable frame error
//!   (checksum flip, future version, oversized, unknown kind) produces a
//!   `ServeError::Protocol` *response* and the connection keeps serving;
//!   an unsyncable or dead stream (bad magic, truncation, IO) closes only
//!   that connection. Nothing panics the process.
//! - **Deadlines.** Idle connections are polled with a non-consuming
//!   `peek` under the read timeout (so the reader notices a stop request);
//!   a peer that stalls *mid-frame* past the read deadline is
//!   disconnected, and slow readers are bounded by the write deadline.
//! - **Two shutdown shapes.** [`Server::drain`] funnels through the
//!   coordinator's QoS shutdown path — every admitted request completes or
//!   is typed-rejected, and every produced response is written before the
//!   listener closes. [`Server::kill`] is the chaos path: sockets are cut
//!   first so unwritten responses are genuinely lost, which is what the
//!   shard router's failover has to survive.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::frame::{self, FrameKind};
use super::wire::{self, WireOk, WireResponse};
use crate::coordinator::{Coordinator, Response, ServeError};
use crate::fault;
use crate::qos::Priority;

/// Per-server tuning. `name` keys the `net_drop@name` / `net_stall@name`
/// fault points, so chaos specs can target one shard.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Fault-injection key; shards use "shard-N".
    pub name: String,
    /// Max responses in flight per connection before the reader stops
    /// pulling frames (TCP backpressure).
    pub window: usize,
    /// Idle-poll tick *and* mid-frame stall bound for the reader half.
    pub read_timeout: Duration,
    /// Bound on a blocked write to a slow or dead peer.
    pub write_timeout: Duration,
    /// Deadline applied to requests that arrive with `deadline_us == 0`.
    pub default_deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            name: "server".into(),
            window: 64,
            read_timeout: Duration::from_millis(50),
            write_timeout: Duration::from_secs(2),
            default_deadline: None,
        }
    }
}

/// Wire-visible counters (drained by the load experiment's report).
#[derive(Default)]
pub struct NetCounters {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub protocol_errors: AtomicU64,
    /// Responses deliberately not written by the `net_drop` fault point.
    pub dropped_writes: AtomicU64,
}

/// One writer-queue item. FIFO through the window: pongs and protocol
/// errors share the response path, so a saturated window honestly shows
/// up in probe latency.
enum ConnItem {
    /// An admitted request: id + the coordinator's reply channel.
    Done(u64, Receiver<Result<Response, ServeError>>),
    /// An immediately-known response (protocol error, unknown matrix...).
    Reply(WireResponse),
    Pong(Vec<u8>),
}

/// A listening server bound to one coordinator.
pub struct Server {
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    /// Dup handles of every live connection socket, for abrupt kill.
    conns: Arc<Mutex<Vec<TcpStream>>>,
    threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind a loopback listener on an ephemeral port and start accepting.
    pub fn start(coord: Arc<Coordinator>, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(NetCounters::default());
        let conns = Arc::new(Mutex::new(Vec::new()));
        let threads = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let coord = Arc::clone(&coord);
            let cfg = cfg.clone();
            let stop = Arc::clone(&stop);
            let counters = Arc::clone(&counters);
            let conns = Arc::clone(&conns);
            let threads = Arc::clone(&threads);
            std::thread::spawn(move || {
                for incoming in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = incoming else { continue };
                    counters.connections.fetch_add(1, Ordering::Relaxed);
                    if let Ok(dup) = stream.try_clone() {
                        conns.lock().unwrap_or_else(|p| p.into_inner()).push(dup);
                    }
                    let handles = spawn_connection(
                        stream,
                        Arc::clone(&coord),
                        cfg.clone(),
                        Arc::clone(&stop),
                        Arc::clone(&counters),
                    );
                    threads.lock().unwrap_or_else(|p| p.into_inner()).extend(handles);
                }
            })
        };
        Ok(Server { coord, cfg, addr, stop, counters, conns, threads, accept: Some(accept) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coord
    }

    pub fn counters(&self) -> &NetCounters {
        &self.counters
    }

    /// Fault-injection key for this server's network points.
    pub fn fault_key(&self) -> String {
        format!("net@{}", self.cfg.name)
    }

    /// Stop accepting and join the accept thread + all connection threads.
    /// Readers notice `stop` within one read-timeout tick; writers flush
    /// whatever their reader enqueued before exiting.
    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handles: Vec<_> =
            self.threads.lock().unwrap_or_else(|p| p.into_inner()).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        self.conns.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// Graceful drain: complete or typed-reject everything in flight via
    /// the coordinator's QoS shutdown path, write every produced response,
    /// then close the listener. Zero accepted-then-unanswered requests.
    pub fn drain(mut self) {
        // 1. all admitted work resolves (responses or typed shutdown
        //    rejections land on the per-request reply channels)
        self.coord.drain();
        // 2. readers exit on the next idle tick; writers drain their
        //    windows — every resolved response crosses the wire
        self.stop_and_join();
        // Server drops here, closing the listener last.
    }

    /// Abrupt chaos kill: cut every connection socket *first*, so
    /// responses that were computed but not yet written are genuinely
    /// lost, then reap threads. This is the failure the shard router's
    /// idempotent failover must absorb.
    pub fn kill(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap_or_else(|p| p.into_inner()).iter() {
            let _ = c.shutdown(Shutdown::Both);
        }
        self.stop_and_join();
        // reap coordinator threads only after the sockets are dead —
        // nothing it finishes now can reach a client
        self.coord.drain();
    }
}

/// Spawn the reader + writer halves for one accepted connection.
fn spawn_connection(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
) -> Vec<std::thread::JoinHandle<()>> {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return Vec::new(),
    };
    let _ = write_half.set_write_timeout(Some(cfg.write_timeout));
    let (tx, rx) = sync_channel::<ConnItem>(cfg.window.max(1));
    let fault_key = format!("net@{}", cfg.name);
    let reader = {
        let counters = Arc::clone(&counters);
        std::thread::spawn(move || reader_loop(stream, coord, cfg, stop, counters, tx))
    };
    let writer = std::thread::spawn(move || writer_loop(write_half, rx, counters, fault_key));
    vec![reader, writer]
}

fn reader_loop(
    mut stream: TcpStream,
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    tx: SyncSender<ConnItem>,
) {
    let mut probe = [0u8; 1];
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // non-consuming idle poll: a timeout here means "no frame yet",
        // with the stream still aligned on a frame boundary
        match stream.peek(&mut probe) {
            Ok(0) => break, // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
        // a frame has started: from here the read deadline bounds a
        // mid-frame stall (frame::decode surfaces it as a fatal Io error)
        match frame::decode(&mut stream) {
            Ok((FrameKind::Ping, payload)) => {
                if tx.send(ConnItem::Pong(payload)).is_err() {
                    break;
                }
            }
            Ok((FrameKind::Request, payload)) => {
                counters.requests.fetch_add(1, Ordering::Relaxed);
                let item = handle_request(&coord, &cfg, &counters, &payload);
                if tx.send(item).is_err() {
                    break;
                }
            }
            // a client has no business sending Response/Pong frames; a
            // typed complaint keeps the connection diagnosable
            Ok((kind, _)) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let err = ServeError::Protocol {
                    detail: format!("unexpected {kind:?} frame from client"),
                };
                let reply = WireResponse { request_id: 0, body: Err(err) };
                if tx.send(ConnItem::Reply(reply)).is_err() {
                    break;
                }
            }
            Err(e) if e.recoverable() => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let err = ServeError::Protocol { detail: e.to_string() };
                let reply = WireResponse { request_id: 0, body: Err(err) };
                if tx.send(ConnItem::Reply(reply)).is_err() {
                    break;
                }
            }
            // Closed / Truncated / BadMagic / Io: the stream is
            // unsyncable or dead — close this connection only
            Err(_) => break,
        }
    }
    // dropping tx lets the writer flush the remaining window, then exit
}

/// Decode one request payload and route it into the coordinator. Always
/// returns an item — hostile payloads become typed protocol errors.
fn handle_request(
    coord: &Arc<Coordinator>,
    cfg: &ServerConfig,
    counters: &Arc<NetCounters>,
    payload: &[u8],
) -> ConnItem {
    let req = match wire::decode_request(payload) {
        Ok(r) => r,
        Err(e) => {
            counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            // best-effort id echo so the client can fail the right call:
            // the id is the first 8 bytes and most wire errors are
            // downstream of it
            let id = payload
                .get(..8)
                .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                .unwrap_or(0);
            let err = ServeError::Protocol { detail: format!("bad request payload: {e}") };
            return ConnItem::Reply(WireResponse { request_id: id, body: Err(err) });
        }
    };
    let Some(entry) = coord.registry().by_name(&req.matrix) else {
        // name-keyed miss: the numeric-id space has no entry to point at,
        // so the sentinel id marks "unknown by name"
        let err = ServeError::UnknownMatrix(crate::coordinator::MatrixId(u64::MAX));
        return ConnItem::Reply(WireResponse { request_id: req.request_id, body: Err(err) });
    };
    let deadline = if req.deadline_us == 0 {
        cfg.default_deadline
    } else {
        Some(Duration::from_micros(req.deadline_us))
    };
    // submit_with folds admission rejections into the reply channel, so
    // the writer half sees exactly one resolution per admitted request
    let rx = coord.submit_with(entry.id, req.b, req.priority, deadline);
    ConnItem::Done(req.request_id, rx)
}

fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<ConnItem>,
    counters: Arc<NetCounters>,
    fault_key: String,
) {
    for item in rx {
        let (kind, payload) = match item {
            ConnItem::Pong(body) => (FrameKind::Pong, body),
            ConnItem::Reply(resp) => (FrameKind::Response, wire::encode_response(&resp)),
            ConnItem::Done(id, reply) => {
                let body = match reply.recv() {
                    Ok(Ok(resp)) => {
                        Ok(WireOk { engine: resp.engine.to_string(), c: resp.c })
                    }
                    Ok(Err(e)) => Err(e),
                    // reply sender dropped without a verdict: shutdown
                    // raced the request
                    Err(_) => Err(ServeError::Shutdown),
                };
                let resp = WireResponse { request_id: id, body };
                (FrameKind::Response, wire::encode_response(&resp))
            }
        };
        // chaos hooks: a stalled or dropped *response* — exactly the
        // partition shapes the shard router must absorb
        fault::net_stall(&fault_key);
        if kind == FrameKind::Response && fault::net_drop(&fault_key) {
            counters.dropped_writes.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        if frame::write_frame(&mut stream, kind, &payload).is_err() {
            // dead peer: stop writing; the reader half notices on its
            // next peek and winds the connection down
            break;
        }
        if kind == FrameKind::Response {
            counters.responses.fetch_add(1, Ordering::Relaxed);
        }
    }
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Config;
    use crate::formats::{Coo, Dense};
    use crate::net::wire::WireRequest;
    use crate::qos::QosConfig;
    use crate::util::rng::Rng;

    fn qos_config() -> Config {
        Config {
            workers: 2,
            qos: Some(QosConfig {
                queue_capacity: 64,
                watermark_s: 0.0,
                default_deadline: None,
            }),
            ..Default::default()
        }
    }

    fn served_server() -> (Server, String) {
        let coord = Arc::new(Coordinator::start(qos_config(), None));
        let coo = Coo::random(64, 96, 0.05, &mut Rng::new(7));
        coord.register("m0", &coo);
        let cfg = ServerConfig { name: "test".into(), ..Default::default() };
        (Server::start(coord, cfg).expect("bind loopback"), "m0".into())
    }

    fn send_request(stream: &mut TcpStream, id: u64, matrix: &str, b: Dense) {
        let req = WireRequest {
            request_id: id,
            priority: Priority::Normal,
            deadline_us: 0,
            matrix: matrix.into(),
            b,
        };
        frame::write_frame(stream, FrameKind::Request, &wire::encode_request(&req)).unwrap();
    }

    fn read_response(stream: &mut TcpStream) -> WireResponse {
        let (kind, payload) = frame::decode(stream).expect("response frame");
        assert_eq!(kind, FrameKind::Response);
        wire::decode_response(&payload).expect("decodable response")
    }

    #[test]
    fn serves_requests_and_pings_over_tcp() {
        let (server, matrix) = served_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // ping → pong with the payload echoed
        frame::write_frame(&mut stream, FrameKind::Ping, b"probe-1").unwrap();
        let (kind, body) = frame::decode(&mut stream).unwrap();
        assert_eq!(kind, FrameKind::Pong);
        assert_eq!(body, b"probe-1");
        // request → computed response
        let b = Dense::random(96, 8, &mut Rng::new(3));
        send_request(&mut stream, 41, &matrix, b);
        let resp = read_response(&mut stream);
        assert_eq!(resp.request_id, 41);
        let ok = resp.body.expect("served ok");
        assert_eq!(ok.c.rows, 64);
        assert_eq!(ok.c.cols, 8);
        server.drain();
    }

    #[test]
    fn hostile_frames_get_typed_errors_and_the_connection_survives() {
        let (server, matrix) = served_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // a bit-flipped frame: typed protocol error back
        let mut bad = frame::encode(FrameKind::Request, b"some payload");
        let n = bad.len();
        bad[n - 1] ^= 0x40;
        stream.write_all(&bad).unwrap();
        let resp = read_response(&mut stream);
        let err = resp.body.expect_err("protocol error");
        assert_eq!(err.kind(), "protocol");
        assert!(err.is_transport());
        // an unknown matrix: typed error with the request id echoed
        send_request(&mut stream, 77, "no-such-matrix", Dense::zeros(96, 2));
        let resp = read_response(&mut stream);
        assert_eq!(resp.request_id, 77);
        assert_eq!(resp.body.expect_err("unknown").kind(), "unknown_matrix");
        // the same connection still serves real work
        send_request(&mut stream, 78, &matrix, Dense::random(96, 4, &mut Rng::new(5)));
        let resp = read_response(&mut stream);
        assert_eq!(resp.request_id, 78);
        assert!(resp.body.is_ok());
        assert!(server.counters().protocol_errors.load(Ordering::Relaxed) >= 1);
        server.drain();
    }

    #[test]
    fn drain_answers_every_accepted_request_before_closing() {
        let (server, matrix) = served_server();
        let addr = server.addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        for id in 0..8u64 {
            send_request(&mut stream, id, &matrix, Dense::random(96, 4, &mut Rng::new(id)));
        }
        // wait until the reader half has admitted all 8 into the
        // coordinator (drain guarantees cover *accepted* work; bytes
        // still in the kernel buffer are legitimately refused)
        let coord = Arc::clone(server.coordinator());
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while coord.metrics().requests.load(Ordering::Relaxed) < 8 {
            assert!(std::time::Instant::now() < deadline, "reader never admitted the batch");
            std::thread::sleep(Duration::from_millis(2));
        }
        server.drain();
        // every accepted request resolved — as a result or a typed
        // shutdown rejection — and was written before the listener closed
        let mut got = Vec::new();
        for _ in 0..8 {
            let resp = read_response(&mut stream);
            match resp.body {
                Ok(_) => got.push(resp.request_id),
                Err(e) => {
                    assert!(matches!(e.kind(), "shed" | "shutdown"), "unexpected {e}");
                    got.push(resp.request_id);
                }
            }
        }
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
        // the port is closed afterwards
        assert!(
            TcpStream::connect(addr).is_err()
                || frame::decode(&mut TcpStream::connect(addr).unwrap())
                    .err()
                    .map(|e| !e.recoverable())
                    .unwrap_or(false)
        );
    }

    #[test]
    fn kill_cuts_connections_abruptly() {
        let (server, _matrix) = served_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        frame::write_frame(&mut stream, FrameKind::Ping, b"pre-kill").unwrap();
        let (kind, _) = frame::decode(&mut stream).unwrap();
        assert_eq!(kind, FrameKind::Pong);
        server.kill();
        // the socket dies: subsequent reads surface a terminal error, not
        // a hang (drain-style pleasantries are exactly what kill skips)
        let err = loop {
            match frame::decode(&mut stream) {
                Ok(_) => continue, // a response already in flight
                Err(e) => break e,
            }
        };
        assert!(!err.recoverable(), "expected terminal error, got {err:?}");
    }
}
