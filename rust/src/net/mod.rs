//! Network serving layer: a length-prefixed binary wire protocol in front
//! of the [`crate::coordinator`].
//!
//! Layers, bottom up:
//!
//! - [`frame`] — versioned, checksummed, length-prefixed frames with a
//!   hard size cap. Hostile bytes are typed [`frame::FrameError`]s, and
//!   every *recoverable* error leaves the stream aligned on the next
//!   frame (the proptests in `frame::tests` pin this).
//! - [`wire`] — request/response payload encoding (matrix name + dense B
//!   operand in; result C or a [`crate::coordinator::ServeError`] with
//!   its stable numeric code out).
//! - [`server`] — a TCP listener per coordinator: per-connection
//!   reader/writer pairs with a bounded in-flight window (backpressure,
//!   never an unbounded queue), read/write deadlines, `net_drop` /
//!   `net_stall` chaos hooks, and both graceful ([`server::Server::drain`])
//!   and abrupt ([`server::Server::kill`]) shutdown.
//! - [`client`] — a multiplexed connection: many in-flight requests share
//!   one stream, correlated by caller-owned request ids; a dead
//!   connection fails every pending request with a typed transport error
//!   and suppresses late duplicate responses.
//!
//! The [`crate::shard`] router composes N of these into a
//! consistent-hashed, replicated service.

pub mod client;
pub mod frame;
pub mod server;
pub mod wire;

pub use client::{CallResult, Connection};
pub use frame::{FrameError, FrameKind};
pub use server::{NetCounters, Server, ServerConfig};
pub use wire::{WireError, WireOk, WireRequest, WireResponse};
