//! Payload encoding for [`frame`](super::frame) frames: requests carry a
//! matrix name + dense B operand, responses carry either a result C or a
//! [`ServeError`] with its stable numeric wire code.
//!
//! Layouts (all integers little-endian):
//!
//! ```text
//! Request payload
//!   0       8   request id (u64) — idempotency key for replica failover
//!   8       1   priority (0 = normal, 1 = high)
//!   9       8   deadline in µs from receipt (u64, 0 = none)
//!   17      2   matrix-name length (u16), then that many UTF-8 bytes
//!   ..      4   B rows (u32)
//!   ..      4   B cols (u32)
//!   ..      4n  B data, row-major f32
//!
//! Response payload
//!   0       8   request id (u64)
//!   8       2   status (u16): 0 = ok, else ServeError::code()
//!   ok body:    engine-name length (u16) + UTF-8, C rows (u32),
//!               C cols (u32), C data row-major f32
//!   err body:   ServeError::to_json() as UTF-8 JSON text
//! ```
//!
//! Decoding is cursor-based and total: every malformed payload is a typed
//! [`WireError`], which the server degrades to `ServeError::Protocol` —
//! hostile bytes never panic the handler.

use crate::coordinator::ServeError;
use crate::formats::Dense;
use crate::qos::Priority;
use crate::util::json;

/// A decoded request payload.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub request_id: u64,
    pub priority: Priority,
    /// Deadline budget in microseconds from receipt; 0 means "use the
    /// server's default".
    pub deadline_us: u64,
    pub matrix: String,
    pub b: Dense,
}

/// A decoded response payload.
#[derive(Clone, Debug)]
pub struct WireResponse {
    pub request_id: u64,
    pub body: Result<WireOk, ServeError>,
}

/// The success body of a response.
#[derive(Clone, Debug, PartialEq)]
pub struct WireOk {
    pub engine: String,
    pub c: Dense,
}

/// Typed decode failures for payload bytes (frame-level integrity is
/// already guaranteed by the checksum; these catch *structural* garbage).
#[derive(Clone, Debug, PartialEq)]
pub enum WireError {
    /// Payload ended before a fixed-width field.
    Short { field: &'static str, needed: usize, remaining: usize },
    /// Priority byte outside {0, 1}.
    BadPriority(u8),
    /// A length-prefixed string was not valid UTF-8.
    BadUtf8 { field: &'static str },
    /// The f32 data section does not match rows × cols.
    DataMismatch { rows: usize, cols: usize, floats: usize },
    /// An error-status response whose JSON body did not parse back into a
    /// known [`ServeError`] code.
    BadErrorBody { status: u16 },
    /// Rows × cols would overflow or exceeds the frame budget.
    AbsurdShape { rows: usize, cols: usize },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Short { field, needed, remaining } => {
                write!(f, "payload too short for {field}: needed {needed}, have {remaining}")
            }
            WireError::BadPriority(p) => write!(f, "invalid priority byte {p}"),
            WireError::BadUtf8 { field } => write!(f, "{field} is not valid utf-8"),
            WireError::DataMismatch { rows, cols, floats } => {
                write!(f, "data section has {floats} floats for a {rows}x{cols} operand")
            }
            WireError::BadErrorBody { status } => {
                write!(f, "undecodable error body for status code {status}")
            }
            WireError::AbsurdShape { rows, cols } => {
                write!(f, "absurd operand shape {rows}x{cols}")
            }
        }
    }
}

/// Reject shapes whose data section could not possibly fit in a frame —
/// stops a hostile header from driving a huge allocation before the
/// length check.
const MAX_ELEMS: usize = super::frame::MAX_FRAME / 4;

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, field: &'static str) -> Result<&'a [u8], WireError> {
        let remaining = self.buf.len() - self.pos;
        if remaining < n {
            return Err(WireError::Short { field, needed: n, remaining });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self, field: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, field)?[0])
    }

    fn u16(&mut self, field: &'static str) -> Result<u16, WireError> {
        let b = self.take(2, field)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, field: &'static str) -> Result<u32, WireError> {
        let b = self.take(4, field)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, field: &'static str) -> Result<u64, WireError> {
        let b = self.take(8, field)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self, field: &'static str) -> Result<String, WireError> {
        let len = self.u16(field)? as usize;
        let bytes = self.take(len, field)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8 { field })
    }

    fn dense(&mut self, field: &'static str) -> Result<Dense, WireError> {
        let rows = self.u32(field)? as usize;
        let cols = self.u32(field)? as usize;
        let elems = rows.checked_mul(cols).filter(|&e| e <= MAX_ELEMS);
        let Some(elems) = elems else {
            return Err(WireError::AbsurdShape { rows, cols });
        };
        let remaining = (self.buf.len() - self.pos) / 4;
        if remaining < elems {
            return Err(WireError::DataMismatch { rows, cols, floats: remaining });
        }
        let raw = self.take(elems * 4, field)?;
        let mut data = Vec::with_capacity(elems);
        for chunk in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(Dense { rows, cols, data })
    }
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    // names are short identifiers; a >64 KiB name is a caller bug
    assert!(s.len() <= u16::MAX as usize, "wire string too long");
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_dense(out: &mut Vec<u8>, d: &Dense) {
    out.extend_from_slice(&(d.rows as u32).to_le_bytes());
    out.extend_from_slice(&(d.cols as u32).to_le_bytes());
    out.reserve(d.data.len() * 4);
    for &v in &d.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + req.matrix.len() + req.b.data.len() * 4);
    out.extend_from_slice(&req.request_id.to_le_bytes());
    out.push(match req.priority {
        Priority::Normal => 0,
        Priority::High => 1,
    });
    out.extend_from_slice(&req.deadline_us.to_le_bytes());
    put_string(&mut out, &req.matrix);
    put_dense(&mut out, &req.b);
    out
}

pub fn decode_request(payload: &[u8]) -> Result<WireRequest, WireError> {
    let mut c = Cursor::new(payload);
    let request_id = c.u64("request_id")?;
    let priority = match c.u8("priority")? {
        0 => Priority::Normal,
        1 => Priority::High,
        p => return Err(WireError::BadPriority(p)),
    };
    let deadline_us = c.u64("deadline_us")?;
    let matrix = c.string("matrix")?;
    let b = c.dense("b")?;
    Ok(WireRequest { request_id, priority, deadline_us, matrix, b })
}

pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&resp.request_id.to_le_bytes());
    match &resp.body {
        Ok(ok) => {
            out.extend_from_slice(&0u16.to_le_bytes());
            put_string(&mut out, &ok.engine);
            put_dense(&mut out, &ok.c);
        }
        Err(e) => {
            out.extend_from_slice(&e.code().to_le_bytes());
            out.extend_from_slice(e.to_json().to_string().as_bytes());
        }
    }
    out
}

pub fn decode_response(payload: &[u8]) -> Result<WireResponse, WireError> {
    let mut c = Cursor::new(payload);
    let request_id = c.u64("request_id")?;
    let status = c.u16("status")?;
    if status == 0 {
        let engine = c.string("engine")?;
        let c_mat = c.dense("c")?;
        return Ok(WireResponse { request_id, body: Ok(WireOk { engine, c: c_mat }) });
    }
    let rest = &c.buf[c.pos..];
    let text = std::str::from_utf8(rest).map_err(|_| WireError::BadUtf8 { field: "error" })?;
    let err = json::parse(text)
        .ok()
        .as_ref()
        .and_then(ServeError::from_json)
        .ok_or(WireError::BadErrorBody { status })?;
    Ok(WireResponse { request_id, body: Err(err) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::RejectReason;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn sample_request(id: u64) -> WireRequest {
        let mut rng = Rng::new(id ^ 0xd00d);
        let b = Dense::from_vec(4, 3, (0..12).map(|_| rng.f32()).collect());
        WireRequest {
            request_id: id,
            priority: if id % 2 == 0 { Priority::Normal } else { Priority::High },
            deadline_us: id * 1000,
            matrix: format!("banded-{id}"),
            b,
        }
    }

    #[test]
    fn requests_round_trip_bit_exactly() {
        for id in 0..8 {
            let req = sample_request(id);
            let back = decode_request(&encode_request(&req)).unwrap();
            assert_eq!(back, req);
        }
    }

    #[test]
    fn ok_responses_round_trip() {
        let c = Dense::from_vec(2, 2, vec![1.0, -2.5, f32::MIN_POSITIVE, 3.0e8]);
        let resp = WireResponse {
            request_id: 42,
            body: Ok(WireOk { engine: "csr-fallback".into(), c: c.clone() }),
        };
        let back = decode_response(&encode_response(&resp)).unwrap();
        assert_eq!(back.request_id, 42);
        let ok = back.body.unwrap();
        assert_eq!(ok.engine, "csr-fallback");
        assert_eq!(ok.c, c);
    }

    #[test]
    fn error_responses_carry_the_typed_serve_error() {
        let errs = [
            ServeError::UnknownMatrix(crate::coordinator::MatrixId(99)),
            ServeError::Quarantined { matrix: "poisoned".into() },
            ServeError::Shed(crate::qos::Rejected {
                reason: RejectReason::Overload,
                est_wait: Duration::from_micros(1500),
                priority: Priority::Normal,
            }),
            ServeError::Protocol { detail: "bad checksum".into() },
        ];
        for e in errs {
            let resp = WireResponse { request_id: 7, body: Err(e.clone()) };
            let back = decode_response(&encode_response(&resp)).unwrap();
            assert_eq!(back.request_id, 7);
            let back_err = back.body.unwrap_err();
            assert_eq!(back_err.code(), e.code());
            assert_eq!(back_err.kind(), e.kind());
        }
    }

    #[test]
    fn malformed_payloads_yield_typed_wire_errors_not_panics() {
        // short everywhere: every prefix of a valid request decodes to a
        // typed error
        let full = encode_request(&sample_request(3));
        for cut in 0..full.len() {
            assert!(decode_request(&full[..cut]).is_err(), "prefix {cut} decoded");
        }
        // bad priority byte
        let mut bad = full.clone();
        bad[8] = 9;
        assert_eq!(decode_request(&bad).unwrap_err(), WireError::BadPriority(9));
        // invalid utf-8 in the matrix name
        let mut bad = full.clone();
        bad[19] = 0xFF; // first name byte (8 id + 1 prio + 8 deadline + 2 len)
        assert!(matches!(
            decode_request(&bad).unwrap_err(),
            WireError::BadUtf8 { field: "matrix" }
        ));
        // absurd shape: rows/cols whose product overflows
        let mut req = sample_request(1);
        req.b = Dense { rows: 0, cols: 0, data: Vec::new() };
        let mut bytes = encode_request(&req);
        let shape_at = bytes.len() - 8;
        bytes[shape_at..shape_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        bytes[shape_at + 4..shape_at + 8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_request(&bytes).unwrap_err(), WireError::AbsurdShape { .. }));
        // error response with garbage JSON body
        let mut resp = Vec::new();
        resp.extend_from_slice(&1u64.to_le_bytes());
        resp.extend_from_slice(&5u16.to_le_bytes());
        resp.extend_from_slice(b"not json at all {{{");
        assert_eq!(
            decode_response(&resp).unwrap_err(),
            WireError::BadErrorBody { status: 5 }
        );
    }
}
