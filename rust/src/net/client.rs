//! Multiplexed client connection: many in-flight requests share one TCP
//! stream, correlated by request id.
//!
//! The failure contract is what the shard router's failover builds on:
//!
//! - every submitted request resolves exactly once — with the server's
//!   response, or with a typed transport error
//!   (`ServeError::Protocol`) the moment the connection is known dead;
//! - a response with no waiting request (a late arrival after the caller
//!   already failed over and re-resolved elsewhere) is *suppressed* and
//!   counted, never delivered twice;
//! - a dead connection fails fast: submissions after the reader marks it
//!   dead return the transport error immediately instead of queueing into
//!   a black hole.

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::frame::{self, FrameKind};
use super::wire::{self, WireOk, WireRequest};
use crate::coordinator::ServeError;

/// What a request resolves to: the served result or a typed error.
pub type CallResult = Result<WireOk, ServeError>;

/// How a pending request is resolved — a boxed callback, so the shard
/// router can fan thousands of in-flight requests into one completion
/// channel instead of one thread-per-receiver.
type Callback = Box<dyn FnOnce(CallResult) + Send>;

struct ConnInner {
    /// Writes are serialized under this lock (frames must not interleave).
    writer: Mutex<TcpStream>,
    /// Dup handle for shutdown on drop.
    socket: TcpStream,
    /// In-flight requests awaiting a response, by request id.
    pending: Mutex<HashMap<u64, Callback>>,
    /// FIFO pong waiters (pings are answered in order per connection).
    pongs: Mutex<Vec<Sender<Vec<u8>>>>,
    alive: AtomicBool,
    /// Late responses with no waiting request — suppressed duplicates.
    orphans: AtomicU64,
    /// Undecodable or unexpected frames from the peer.
    protocol_errors: AtomicU64,
}

impl ConnInner {
    /// Mark the connection dead and fail everything still waiting with a
    /// typed transport error. Idempotent.
    fn mark_dead(&self, why: &str) {
        if self.alive.swap(false, Ordering::SeqCst) {
            let waiters: Vec<_> = {
                let mut p = self.pending.lock().unwrap_or_else(|e| e.into_inner());
                p.drain().collect()
            };
            // callbacks run outside the lock: one may submit elsewhere
            for (_id, done) in waiters {
                done(Err(ServeError::Protocol { detail: format!("connection lost: {why}") }));
            }
            self.pongs.lock().unwrap_or_else(|e| e.into_inner()).clear();
        }
    }
}

impl Drop for ConnInner {
    fn drop(&mut self) {
        let _ = self.socket.shutdown(Shutdown::Both);
    }
}

/// A shareable client connection (clone freely; all clones multiplex the
/// same stream).
#[derive(Clone)]
pub struct Connection {
    inner: Arc<ConnInner>,
}

impl Connection {
    /// Connect and start the demultiplexing reader thread.
    pub fn connect(addr: SocketAddr) -> std::io::Result<Connection> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let reader_half = stream.try_clone()?;
        let socket = stream.try_clone()?;
        let inner = Arc::new(ConnInner {
            writer: Mutex::new(stream),
            socket,
            pending: Mutex::new(HashMap::new()),
            pongs: Mutex::new(Vec::new()),
            alive: AtomicBool::new(true),
            orphans: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
        });
        let weak = Arc::downgrade(&inner);
        std::thread::spawn(move || reader_loop(reader_half, weak));
        Ok(Connection { inner })
    }

    pub fn alive(&self) -> bool {
        self.inner.alive.load(Ordering::SeqCst)
    }

    /// Suppressed late responses (would-be duplicates after a failover).
    pub fn orphans(&self) -> u64 {
        self.inner.orphans.load(Ordering::Relaxed)
    }

    pub fn protocol_errors(&self) -> u64 {
        self.inner.protocol_errors.load(Ordering::Relaxed)
    }

    /// Submit a request, resolving `done` exactly once — with the server's
    /// verdict, or with a typed transport error the moment the connection
    /// is known dead. `req.request_id` is the idempotency key — the
    /// caller owns its uniqueness (the shard router allocates ids
    /// globally).
    pub fn submit_callback(&self, req: &WireRequest, done: impl FnOnce(CallResult) + Send + 'static) {
        if !self.alive() {
            done(Err(ServeError::Protocol { detail: "connection already dead".into() }));
            return;
        }
        self.inner
            .pending
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(req.request_id, Box::new(done));
        let payload = wire::encode_request(req);
        let write = {
            let mut w = self.inner.writer.lock().unwrap_or_else(|e| e.into_inner());
            frame::write_frame(&mut *w, FrameKind::Request, &payload)
        };
        if let Err(e) = write {
            // fail *all* pending (the stream state is unknown after a
            // partial write), which includes this request's waiter
            self.inner.mark_dead(&format!("write failed: {e}"));
        }
    }

    /// [`Connection::submit_callback`] with a channel-shaped result.
    pub fn submit(&self, req: &WireRequest) -> Receiver<CallResult> {
        let (tx, rx) = channel();
        self.submit_callback(req, move |r| {
            let _ = tx.send(r);
        });
        rx
    }

    /// Submit and wait.
    pub fn call(&self, req: &WireRequest) -> CallResult {
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Err(ServeError::Protocol { detail: "client gone".into() }))
    }

    /// Health probe: round-trip a ping within `timeout`. An `Err` is a
    /// transport-shaped verdict the shard breaker records as a fault.
    pub fn ping(&self, payload: &[u8], timeout: Duration) -> Result<Vec<u8>, ServeError> {
        if !self.alive() {
            return Err(ServeError::Protocol { detail: "connection already dead".into() });
        }
        let (tx, rx) = channel();
        self.inner.pongs.lock().unwrap_or_else(|e| e.into_inner()).push(tx);
        let write = {
            let mut w = self.inner.writer.lock().unwrap_or_else(|e| e.into_inner());
            frame::write_frame(&mut *w, FrameKind::Ping, payload)
        };
        if let Err(e) = write {
            self.inner.mark_dead(&format!("write failed: {e}"));
            return Err(ServeError::Protocol { detail: format!("ping write failed: {e}") });
        }
        rx.recv_timeout(timeout)
            .map_err(|_| ServeError::Protocol { detail: "ping timed out".into() })
    }

    /// Tear the connection down (pending requests fail with the typed
    /// transport error).
    pub fn close(&self) {
        self.inner.mark_dead("closed by caller");
        let _ = self.inner.socket.shutdown(Shutdown::Both);
    }
}

fn reader_loop(mut stream: TcpStream, weak: std::sync::Weak<ConnInner>) {
    loop {
        let frame = frame::decode(&mut stream);
        // the connection may have been dropped while we blocked in read
        let Some(inner) = weak.upgrade() else { return };
        match frame {
            Ok((FrameKind::Response, payload)) => match wire::decode_response(&payload) {
                Ok(resp) => {
                    let waiter = inner
                        .pending
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .remove(&resp.request_id);
                    match waiter {
                        Some(done) => done(resp.body),
                        // nobody is waiting: a late response after the
                        // caller failed over — suppress, don't duplicate
                        None => {
                            inner.orphans.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                Err(_) => {
                    inner.protocol_errors.fetch_add(1, Ordering::Relaxed);
                }
            },
            Ok((FrameKind::Pong, body)) => {
                let waiter = {
                    let mut pongs = inner.pongs.lock().unwrap_or_else(|e| e.into_inner());
                    if pongs.is_empty() { None } else { Some(pongs.remove(0)) }
                };
                if let Some(tx) = waiter {
                    let _ = tx.send(body);
                }
            }
            // a server has no business sending Request/Ping to a client
            Ok(_) => {
                inner.protocol_errors.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) if e.recoverable() => {
                inner.protocol_errors.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                inner.mark_dead(&e.to_string());
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, Coordinator};
    use crate::formats::{Coo, Dense};
    use crate::net::server::{Server, ServerConfig};
    use crate::qos::{Priority, QosConfig};
    use crate::util::rng::Rng;

    fn served() -> (Server, Connection) {
        let coord = Arc::new(Coordinator::start(
            Config {
                workers: 2,
                qos: Some(QosConfig {
                    queue_capacity: 256,
                    watermark_s: 0.0,
                    default_deadline: None,
                }),
                ..Default::default()
            },
            None,
        ));
        let coo = Coo::random(64, 96, 0.05, &mut Rng::new(11));
        coord.register("m0", &coo);
        let server =
            Server::start(coord, ServerConfig { name: "client-test".into(), ..Default::default() })
                .expect("bind");
        let conn = Connection::connect(server.addr()).expect("connect");
        (server, conn)
    }

    fn request(id: u64, cols: usize) -> WireRequest {
        WireRequest {
            request_id: id,
            priority: Priority::Normal,
            deadline_us: 0,
            matrix: "m0".into(),
            b: Dense::random(96, cols, &mut Rng::new(id ^ 0xabc)),
        }
    }

    #[test]
    fn multiplexes_concurrent_requests_by_id() {
        let (server, conn) = served();
        // submit a burst before reading anything back: responses
        // demultiplex by id no matter the completion order
        let rxs: Vec<_> = (0..16u64).map(|id| (id, conn.submit(&request(id, 4)))).collect();
        for (id, rx) in rxs {
            let ok = rx
                .recv_timeout(Duration::from_secs(20))
                .expect("resolved")
                .unwrap_or_else(|e| panic!("request {id} failed: {e}"));
            assert_eq!(ok.c.rows, 64);
            assert_eq!(ok.c.cols, 4);
        }
        assert_eq!(conn.orphans(), 0);
        server.drain();
    }

    #[test]
    fn ping_round_trips_and_times_out_on_dead_peer() {
        let (server, conn) = served();
        let body = conn.ping(b"alive?", Duration::from_secs(10)).expect("pong");
        assert_eq!(body, b"alive?");
        server.kill();
        let err = match conn.ping(b"anyone?", Duration::from_millis(500)) {
            Err(e) => e,
            Ok(_) => panic!("pinged a killed server"),
        };
        assert!(err.is_transport());
    }

    #[test]
    fn killed_server_fails_pending_requests_with_transport_errors() {
        let (server, conn) = served();
        // warm call proves the path works
        assert!(conn.call(&request(1, 2)).is_ok());
        let pending: Vec<_> = (10..20u64).map(|id| conn.submit(&request(id, 4))).collect();
        server.kill();
        let mut transport = 0;
        for rx in pending {
            match rx.recv_timeout(Duration::from_secs(20)).expect("resolved") {
                Ok(_) => {} // raced the kill and was served — fine
                Err(e) => {
                    assert!(
                        e.is_transport() || matches!(e.kind(), "shed" | "shutdown"),
                        "unexpected error class: {e}"
                    );
                    transport += 1;
                }
            }
        }
        // every unserved request resolved exactly once, as a typed error
        let _ = transport;
        assert!(!conn.alive() || transport == 0);
        // and new submissions fail fast once the death is observed
        if !conn.alive() {
            assert!(conn.call(&request(99, 2)).is_err());
        }
    }
}
