//! Wave-aware load balancing — the paper's §5 scheme plus the two strawmen
//! it argues against (used by the ablation bench).
//!
//! Row panels have wildly different numbers of blocks. §5's insight: if the
//! grid runs in `num_waves` waves over the SMs, a panel only needs splitting
//! when its work exceeds *a whole device-wave's worth* of average panels —
//! splitting any finer just buys atomic-consolidation cost without reducing
//! the critical path. Hence `partition_ratio = num_loads / num_waves`
//! (Eq. 7) instead of the naive `num_loads` (Eq. 6) alone.

use crate::hrpb::Hrpb;

/// How thread blocks map onto panels — the output of a balancing policy.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// One entry per *virtual* panel: the source row panel and the block
    /// subrange `[start, end)` it covers (within that panel's blocks).
    pub units: Vec<WorkUnit>,
    /// Virtual panels per source panel > 1 require atomic consolidation of
    /// partial C tiles; this counts those extra atomically-merged units.
    pub atomic_units: usize,
}

/// One thread-block's worth of work: a contiguous run of blocks in a panel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkUnit {
    pub panel: u32,
    /// Block range within the panel (indices into the panel's block list).
    pub start: u32,
    pub end: u32,
    /// True when this unit is one of several covering its panel (its writes
    /// must be atomic / merged).
    pub atomic: bool,
}

impl Schedule {
    /// Max blocks any single unit processes — the critical path length in
    /// block units (what balancing minimizes).
    pub fn critical_path(&self) -> usize {
        self.units.iter().map(|u| (u.end - u.start) as usize).max().unwrap_or(0)
    }

    /// Validate that units exactly tile every panel's blocks.
    pub fn validate(&self, hrpb: &Hrpb) -> Result<(), String> {
        let mut covered: Vec<Vec<(u32, u32)>> = vec![Vec::new(); hrpb.num_panels()];
        for u in &self.units {
            if u.start > u.end {
                return Err("unit range inverted".into());
            }
            covered[u.panel as usize].push((u.start, u.end));
        }
        for p in 0..hrpb.num_panels() {
            let blocks =
                (hrpb.blocked_row_ptr[p + 1] - hrpb.blocked_row_ptr[p]) as u32;
            let mut runs = covered[p].clone();
            runs.sort_unstable();
            let mut pos = 0u32;
            for (s, e) in runs {
                if s != pos {
                    return Err(format!("panel {p}: gap/overlap at block {pos}"));
                }
                pos = e;
            }
            if pos != blocks {
                return Err(format!("panel {p}: covered {pos} of {blocks} blocks"));
            }
        }
        Ok(())
    }
}

/// Device geometry needed by the wave computation (§5). For the analytical
/// GPU models this comes from `gpumodel::Machine`; for the native CPU engine
/// it is threads × 1.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub num_sms: usize,
    pub blocks_per_sm: usize,
}

impl Device {
    pub fn concurrent_blocks(&self) -> usize {
        (self.num_sms * self.blocks_per_sm).max(1)
    }

    /// §5: `num_waves = ceil(total_thread_blocks / (SMs × blocks/SM))`.
    pub fn num_waves(&self, total_blocks: usize) -> usize {
        total_blocks.div_ceil(self.concurrent_blocks()).max(1)
    }
}

/// Blocks per panel (the §5 workload measure).
pub fn panel_loads(hrpb: &Hrpb) -> Vec<usize> {
    (0..hrpb.num_panels())
        .map(|p| (hrpb.blocked_row_ptr[p + 1] - hrpb.blocked_row_ptr[p]) as usize)
        .collect()
}

/// Average blocks over *non-empty* panels (`AVG_BLK_ROW_PANEL` in Eq. 6).
pub fn avg_blocks_per_panel(loads: &[usize]) -> f64 {
    let active: Vec<usize> = loads.iter().copied().filter(|&l| l > 0).collect();
    if active.is_empty() {
        return 0.0;
    }
    active.iter().sum::<usize>() as f64 / active.len() as f64
}

/// No balancing: one unit per non-empty panel (the §3 base kernel).
pub fn schedule_none(hrpb: &Hrpb) -> Schedule {
    let units = panel_loads(hrpb)
        .iter()
        .enumerate()
        .filter(|&(_, &l)| l > 0)
        .map(|(p, &l)| WorkUnit { panel: p as u32, start: 0, end: l as u32, atomic: false })
        .collect();
    Schedule { units, atomic_units: 0 }
}

/// Strawman 1 (§5): keep one unit per panel but order heaviest-first.
/// Improves tail scheduling but disrupts consecutive-panel B reuse; it never
/// splits, so the critical path is unchanged.
pub fn schedule_sorted(hrpb: &Hrpb) -> Schedule {
    let mut s = schedule_none(hrpb);
    s.units.sort_by_key(|u| std::cmp::Reverse(u.end - u.start));
    s
}

/// Strawman 2 (§5): split every panel whose load exceeds the average down to
/// average-sized virtual panels, ignoring waves — maximal atomics.
pub fn schedule_avg_split(hrpb: &Hrpb) -> Schedule {
    let loads = panel_loads(hrpb);
    let avg = avg_blocks_per_panel(&loads).max(1.0);
    split_by_ratio(&loads, |load| load as f64 / avg)
}

/// The paper's scheme (Eqs 6-7): split only by `num_loads / num_waves`.
pub fn schedule_wave_aware(hrpb: &Hrpb, dev: Device) -> Schedule {
    let loads = panel_loads(hrpb);
    let avg = avg_blocks_per_panel(&loads).max(1.0);
    let total_blocks: usize = loads.iter().filter(|&&l| l > 0).map(|_| 1).sum();
    let waves = dev.num_waves(total_blocks) as f64;
    split_by_ratio(&loads, |load| (load as f64 / avg) / waves)
}

/// Shared splitter: `ratio(load)` gives the desired number of virtual panels
/// (≤ 1 means no split); block ranges are dealt out as evenly as possible.
fn split_by_ratio(loads: &[usize], ratio: impl Fn(usize) -> f64) -> Schedule {
    let mut units = Vec::new();
    let mut atomic_units = 0usize;
    for (p, &load) in loads.iter().enumerate() {
        if load == 0 {
            continue;
        }
        let parts = ratio(load).floor().max(1.0) as usize;
        let parts = parts.min(load); // at least one block per unit
        if parts <= 1 {
            units.push(WorkUnit { panel: p as u32, start: 0, end: load as u32, atomic: false });
            continue;
        }
        let base = load / parts;
        let extra = load % parts;
        let mut pos = 0u32;
        for i in 0..parts {
            let len = base + usize::from(i < extra);
            units.push(WorkUnit {
                panel: p as u32,
                start: pos,
                end: pos + len as u32,
                atomic: true,
            });
            pos += len as u32;
        }
        atomic_units += parts - 1; // first writer needs no merge
    }
    Schedule { units, atomic_units }
}

/// Simulated makespan of a schedule on `workers` equal workers using LPT
/// greedy dispatch (largest remaining unit to the least-loaded worker) —
/// a proxy for the wave argument in §5's 991-panel example, used by tests
/// and the ablation bench. The least-loaded worker comes off a min-heap:
/// O((units + workers) log workers) instead of the O(units × workers)
/// linear scan.
pub fn simulate_makespan(schedule: &Schedule, workers: usize) -> usize {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut lens: Vec<usize> =
        schedule.units.iter().map(|u| (u.end - u.start) as usize).collect();
    lens.sort_unstable_by_key(|&l| Reverse(l));
    let mut heap: BinaryHeap<Reverse<usize>> =
        (0..workers.max(1)).map(|_| Reverse(0usize)).collect();
    for l in lens {
        let Reverse(load) = heap.pop().expect("heap holds one entry per worker");
        heap.push(Reverse(load + l));
    }
    heap.into_iter().map(|Reverse(load)| load).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::build_from_coo;
    use crate::util::rng::Rng;

    /// A matrix with one heavy panel (many active cols) and many light ones.
    fn skewed(rows: usize) -> Coo {
        let mut t = Vec::new();
        // panel 0: 160 active columns -> 10 blocks
        for c in 0..160 {
            t.push((c % 16, c * 2, 1.0f32));
        }
        // other panels: one block each
        for r in (16..rows).step_by(16) {
            t.push((r, 0, 1.0f32));
        }
        Coo::from_triplets(rows, 512, &t)
    }

    #[test]
    fn none_schedule_tiles_panels() {
        let hrpb = build_from_coo(&skewed(160));
        let s = schedule_none(&hrpb);
        s.validate(&hrpb).unwrap();
        assert_eq!(s.atomic_units, 0);
        assert_eq!(s.critical_path(), 10);
    }

    #[test]
    fn sorted_puts_heaviest_first_without_splitting() {
        let hrpb = build_from_coo(&skewed(160));
        let s = schedule_sorted(&hrpb);
        s.validate(&hrpb).unwrap();
        assert_eq!(s.units[0].end - s.units[0].start, 10);
        assert_eq!(s.critical_path(), 10);
    }

    #[test]
    fn avg_split_reduces_critical_path_with_atomics() {
        let hrpb = build_from_coo(&skewed(160));
        let s = schedule_avg_split(&hrpb);
        s.validate(&hrpb).unwrap();
        assert!(s.critical_path() < 10);
        assert!(s.atomic_units > 0);
    }

    #[test]
    fn wave_aware_skips_split_when_waves_absorb_imbalance() {
        // §5's worked example: 10 panels with loads [10,1,...,1] on 1
        // concurrent block -> many waves, no split needed.
        let hrpb = build_from_coo(&skewed(160));
        let dev = Device { num_sms: 1, blocks_per_sm: 1 };
        let s = schedule_wave_aware(&hrpb, dev);
        s.validate(&hrpb).unwrap();
        assert_eq!(s.atomic_units, 0, "waves absorb the heavy panel");
    }

    #[test]
    fn wave_aware_splits_when_single_wave() {
        // plenty of SMs -> 1 wave -> the heavy panel must split
        let hrpb = build_from_coo(&skewed(160));
        let dev = Device { num_sms: 100, blocks_per_sm: 2 };
        let s = schedule_wave_aware(&hrpb, dev);
        s.validate(&hrpb).unwrap();
        assert!(s.atomic_units > 0);
        assert!(s.critical_path() < 10);
    }

    #[test]
    fn wave_aware_never_more_atomics_than_avg_split() {
        let mut rng = Rng::new(40);
        for trial in 0..5 {
            let coo = Coo::random(320, 640, 0.01 + 0.01 * trial as f64, &mut rng);
            let hrpb = build_from_coo(&coo);
            let dev = Device { num_sms: 4, blocks_per_sm: 2 };
            let wave = schedule_wave_aware(&hrpb, dev);
            let avg = schedule_avg_split(&hrpb);
            wave.validate(&hrpb).unwrap();
            avg.validate(&hrpb).unwrap();
            assert!(wave.atomic_units <= avg.atomic_units);
        }
    }

    #[test]
    fn makespan_improves_with_wave_split_on_one_wave() {
        let hrpb = build_from_coo(&skewed(160));
        let dev = Device { num_sms: 20, blocks_per_sm: 1 };
        let none = simulate_makespan(&schedule_none(&hrpb), 20);
        let wave = simulate_makespan(&schedule_wave_aware(&hrpb, dev), 20);
        assert!(wave <= none);
    }

    #[test]
    fn makespan_heap_matches_linear_scan_reference() {
        // ties go to *a* least-loaded worker in both versions; workers are
        // symmetric, so the load multiset (and the max) must be identical
        let mut rng = Rng::new(41);
        for trial in 0..4 {
            let coo = Coo::random(640, 640, 0.01 + 0.01 * trial as f64, &mut rng);
            let hrpb = build_from_coo(&coo);
            let s = schedule_avg_split(&hrpb);
            for workers in [1usize, 3, 8, 64] {
                let mut lens: Vec<usize> =
                    s.units.iter().map(|u| (u.end - u.start) as usize).collect();
                lens.sort_unstable_by_key(|&l| std::cmp::Reverse(l));
                let mut loads = vec![0usize; workers];
                for l in lens {
                    let i = (0..loads.len()).min_by_key(|&i| loads[i]).unwrap();
                    loads[i] += l;
                }
                let want = loads.into_iter().max().unwrap();
                assert_eq!(simulate_makespan(&s, workers), want, "workers={workers}");
            }
        }
    }

    #[test]
    fn empty_matrix_empty_schedule() {
        let hrpb = build_from_coo(&Coo::new(64, 64));
        let s = schedule_wave_aware(&hrpb, Device { num_sms: 4, blocks_per_sm: 4 });
        assert!(s.units.is_empty());
        s.validate(&hrpb).unwrap();
    }
}
