//! Small shared substrates: deterministic RNG, bit manipulation, descriptive
//! statistics, wall-clock measurement, JSON emission and a property-testing
//! mini-framework.

pub mod bits;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tf32;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
