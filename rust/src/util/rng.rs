//! Deterministic, dependency-free pseudo-random numbers.
//!
//! All corpus generation is keyed by explicit seeds so every experiment is
//! exactly reproducible (`cutespmm gen --seed 42` always emits the same
//! corpus). The generator is xoshiro256**, seeded via SplitMix64 — the
//! standard recommendation for non-cryptographic simulation use.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream (for per-matrix sub-seeds).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
            // extremely rare rejection; loop
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal (Box–Muller; one value per call, simple and adequate).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Nonzero value for synthetic matrices: uniform in [-1, 1] excluding 0.
    pub fn nz_value(&mut self) -> f32 {
        loop {
            let v = self.f32() * 2.0 - 1.0;
            if v != 0.0 {
                return v;
            }
        }
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Geometric-ish power-law integer in `[1, max]` with exponent `gamma`
    /// (inverse-CDF sampling of a discrete Pareto; used for graph degrees).
    pub fn power_law(&mut self, max: usize, gamma: f64) -> usize {
        let u = self.f64();
        let xmax = max as f64;
        let e = 1.0 - gamma;
        // inverse CDF of p(x) ~ x^-gamma on [1, xmax]
        let x = ((xmax.powf(e) - 1.0) * u + 1.0).powf(1.0 / e);
        (x as usize).clamp(1, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn power_law_bounds_and_skew() {
        let mut r = Rng::new(13);
        let xs: Vec<usize> = (0..10_000).map(|_| r.power_law(1000, 2.2)).collect();
        assert!(xs.iter().all(|&x| (1..=1000).contains(&x)));
        let ones = xs.iter().filter(|&&x| x == 1).count();
        assert!(ones > xs.len() / 3, "power law should be bottom-heavy: {ones}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
