//! TF32 emulation — the tensor-core input format (§2 of the paper): FP32's
//! 8-bit exponent with a 10-bit mantissa. Inputs are rounded to TF32,
//! products accumulate in FP32 — the paper's numerics contract for
//! "preserving the output precision of FP32".
//!
//! Used by the error-bound tests to show the HRPB engine's results under
//! TF32 input rounding stay within the paper-implied tolerance of full
//! FP32, and available to callers who want GPU-faithful numerics.

/// Round an f32 to TF32 precision (10 explicit mantissa bits), using
/// round-to-nearest-even on the truncated 13 bits — what the A100's TCU
/// does to FP32 inputs.
#[inline]
pub fn round_tf32(x: f32) -> f32 {
    if !x.is_finite() {
        return x;
    }
    let bits = x.to_bits();
    // keep 1 sign + 8 exponent + 10 mantissa = top 19 bits; RNE on bit 12
    let mask: u32 = 0x0000_1FFF; // low 13 mantissa bits dropped
    let half: u32 = 0x0000_1000;
    let trunc = bits & !mask;
    let rem = bits & mask;
    let rounded = if rem > half || (rem == half && (trunc >> 13) & 1 == 1) {
        trunc.wrapping_add(0x0000_2000)
    } else {
        trunc
    };
    f32::from_bits(rounded)
}

/// Round a slice in place.
pub fn round_slice(xs: &mut [f32]) {
    for x in xs {
        *x = round_tf32(*x);
    }
}

/// TF32-emulated SpMM wrapper: rounds both operands' values to TF32, runs
/// the wrapped engine (FP32 accumulation), mirroring the TCU dataflow.
pub fn spmm_tf32(
    engine: &dyn crate::spmm::SpmmEngine,
    b: &crate::formats::Dense,
) -> crate::formats::Dense {
    let mut b32 = b.clone();
    round_slice(&mut b32.data);
    engine.spmm(&b32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Coo, Dense};
    use crate::spmm::Algo;
    use crate::util::rng::Rng;

    #[test]
    fn exact_values_survive() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 1024.0, -0.125] {
            assert_eq!(round_tf32(v), v, "{v} is exactly representable in TF32");
        }
    }

    #[test]
    fn mantissa_is_10_bits() {
        // 1 + 2^-10 representable; 1 + 2^-11 rounds to 1 or 1 + 2^-10
        let v = 1.0 + 2f32.powi(-10);
        assert_eq!(round_tf32(v), v);
        let w = 1.0 + 2f32.powi(-11);
        let r = round_tf32(w);
        assert!(r == 1.0 || r == v, "RNE lands on a TF32 neighbour, got {r}");
        // rounded values always have zero low mantissa bits
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = (rng.f32() - 0.5) * 1e6;
            assert_eq!(round_tf32(x).to_bits() & 0x1FFF, 0);
        }
    }

    #[test]
    fn relative_error_bounded_by_tf32_eps() {
        // eps(TF32) = 2^-11 for RNE
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 1e8;
            if x == 0.0 {
                continue;
            }
            let rel = ((round_tf32(x) - x) / x).abs();
            assert!(rel <= 2f32.powi(-11), "x={x} rel={rel}");
        }
    }

    #[test]
    fn specials_pass_through() {
        assert!(round_tf32(f32::NAN).is_nan());
        assert_eq!(round_tf32(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_tf32(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn tf32_spmm_error_within_paper_bound() {
        // §2: TF32 inputs + FP32 accumulate preserves "FP32 output
        // precision" — relative error should track eps(TF32) ~ 5e-4, far
        // from eps(FP16) ~ 1e-3 * dynamic-range problems
        let mut rng = Rng::new(3);
        let coo = Coo::random(256, 256, 0.05, &mut rng);
        let b = Dense::random(256, 64, &mut rng);
        let engine = Algo::Hrpb.prepare(&coo);
        let exact = engine.spmm(&b);
        // round A too: rebuild with rounded values
        let mut coo32 = coo.clone();
        round_slice(&mut coo32.values);
        let engine32 = Algo::Hrpb.prepare(&coo32);
        let approx = spmm_tf32(engine32.as_ref(), &b);
        let rel = approx.rel_fro_error(&exact);
        assert!(rel > 0.0, "rounding must actually perturb something");
        assert!(rel < 2e-3, "TF32 error {rel} above the paper-implied bound");
    }
}
