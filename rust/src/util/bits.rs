//! Brick-pattern bit manipulation — the scalar-core half of the paper's
//! Algorithm 1 (lines 33-39): each thread finds its nonzero via a prefix
//! popcount over the brick's pattern word.
//!
//! Every layout-dependent helper takes the [`BrickGeometry`] whose pattern
//! it manipulates; a brick's pattern occupies the low `geo.bits()` bits of
//! one `u64` word (row-major, the paper's Fig. 3(b) encoding generalized
//! over the catalog).

use crate::params::BrickGeometry;

/// Bit index of element `(row, col)` inside a brick pattern (row-major).
#[inline]
pub fn brick_bit(geo: BrickGeometry, row: usize, col: usize) -> u32 {
    debug_assert!(row < geo.brick_m && col < geo.brick_k);
    (row * geo.brick_k + col) as u32
}

/// Number of nonzeros encoded by a pattern.
#[inline]
pub fn pattern_nnz(pattern: u64) -> usize {
    pattern.count_ones() as usize
}

/// Prefix popcount: how many set bits strictly below `bit` — the index of the
/// nonzero assigned to lane `bit` inside the brick's packed value run
/// (Algorithm 1 line 34: `count_1s[pattern[0:lane_id]]`).
#[inline]
pub fn prefix_count(pattern: u64, bit: u32) -> usize {
    debug_assert!(bit < 64);
    (pattern & ((1u64 << bit) - 1)).count_ones() as usize
}

/// Is element `(row, col)` present?
#[inline]
pub fn pattern_has(geo: BrickGeometry, pattern: u64, row: usize, col: usize) -> bool {
    pattern >> brick_bit(geo, row, col) & 1 == 1
}

/// Set element `(row, col)`.
#[inline]
pub fn pattern_set(geo: BrickGeometry, pattern: u64, row: usize, col: usize) -> u64 {
    pattern | 1u64 << brick_bit(geo, row, col)
}

/// Iterate `(row, col, value_index)` of every nonzero in pattern order.
pub fn pattern_iter(
    geo: BrickGeometry,
    pattern: u64,
) -> impl Iterator<Item = (usize, usize, usize)> {
    let mut bits = pattern;
    let mut idx = 0usize;
    std::iter::from_fn(move || {
        if bits == 0 {
            return None;
        }
        let bit = bits.trailing_zeros() as usize;
        bits &= bits - 1;
        let out = (bit / geo.brick_k, bit % geo.brick_k, idx);
        idx += 1;
        Some(out)
    })
}

/// Ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to a multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: BrickGeometry = BrickGeometry::DEFAULT;

    #[test]
    fn bit_layout_is_row_major() {
        assert_eq!(brick_bit(G, 0, 0), 0);
        assert_eq!(brick_bit(G, 0, 3), 3);
        assert_eq!(brick_bit(G, 1, 0), 4);
        assert_eq!(brick_bit(G, 15, 3), 63);
    }

    #[test]
    fn bit_layout_follows_the_geometry() {
        for geo in BrickGeometry::CATALOG {
            assert_eq!(brick_bit(geo, 0, 0), 0);
            assert_eq!(
                brick_bit(geo, geo.brick_m - 1, geo.brick_k - 1) as usize,
                geo.bits() - 1,
                "{geo}: last element lands on the last pattern bit"
            );
            if geo.brick_m > 1 {
                assert_eq!(brick_bit(geo, 1, 0) as usize, geo.brick_k);
            }
        }
    }

    #[test]
    fn prefix_count_matches_scan() {
        let p: u64 = 0b1011_0110_0101;
        for bit in 0..64u32 {
            let naive = (0..bit).filter(|&b| p >> b & 1 == 1).count();
            assert_eq!(prefix_count(p, bit), naive, "bit {bit}");
        }
    }

    #[test]
    fn set_then_has() {
        let mut p = 0u64;
        p = pattern_set(G, p, 3, 2);
        p = pattern_set(G, p, 15, 3);
        assert!(pattern_has(G, p, 3, 2));
        assert!(pattern_has(G, p, 15, 3));
        assert!(!pattern_has(G, p, 0, 0));
        assert_eq!(pattern_nnz(p), 2);
    }

    #[test]
    fn iter_yields_in_pattern_order_with_indices() {
        let mut p = 0u64;
        p = pattern_set(G, p, 0, 1); // bit 1
        p = pattern_set(G, p, 2, 0); // bit 8
        p = pattern_set(G, p, 2, 3); // bit 11
        let got: Vec<_> = pattern_iter(G, p).collect();
        assert_eq!(got, vec![(0, 1, 0), (2, 0, 1), (2, 3, 2)]);
    }

    #[test]
    fn iter_full_pattern() {
        let got: Vec<_> = pattern_iter(G, u64::MAX).collect();
        assert_eq!(got.len(), 64);
        assert_eq!(got[63], (15, 3, 63));
    }

    #[test]
    fn set_iter_roundtrips_across_the_catalog() {
        for geo in BrickGeometry::CATALOG {
            let mut p = 0u64;
            let mut want = Vec::new();
            // a deterministic scatter of elements valid for this geometry
            for i in 0..geo.bits() {
                if i % 3 == 0 {
                    let (r, c) = (i / geo.brick_k, i % geo.brick_k);
                    p = pattern_set(geo, p, r, c);
                    want.push((r, c));
                }
            }
            let got: Vec<_> = pattern_iter(geo, p).map(|(r, c, _)| (r, c)).collect();
            assert_eq!(got, want, "{geo}");
            for &(r, c) in &want {
                assert!(pattern_has(geo, p, r, c), "{geo} ({r},{c})");
            }
        }
    }

    #[test]
    fn ceil_and_round() {
        assert_eq!(ceil_div(10, 4), 3);
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
    }
}
