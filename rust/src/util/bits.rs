//! Brick-pattern bit manipulation — the scalar-core half of the paper's
//! Algorithm 1 (lines 33-39): each thread finds its nonzero via a prefix
//! popcount over the brick's 64-bit pattern.

use crate::params::{BRICK_K, BRICK_M};

/// Bit index of element `(row, col)` inside a brick pattern (row-major, the
/// paper's Fig. 3(b) encoding).
#[inline]
pub fn brick_bit(row: usize, col: usize) -> u32 {
    debug_assert!(row < BRICK_M && col < BRICK_K);
    (row * BRICK_K + col) as u32
}

/// Number of nonzeros encoded by a pattern.
#[inline]
pub fn pattern_nnz(pattern: u64) -> usize {
    pattern.count_ones() as usize
}

/// Prefix popcount: how many set bits strictly below `bit` — the index of the
/// nonzero assigned to lane `bit` inside the brick's packed value run
/// (Algorithm 1 line 34: `count_1s[pattern[0:lane_id]]`).
#[inline]
pub fn prefix_count(pattern: u64, bit: u32) -> usize {
    debug_assert!(bit < 64);
    (pattern & ((1u64 << bit) - 1)).count_ones() as usize
}

/// Is element `(row, col)` present?
#[inline]
pub fn pattern_has(pattern: u64, row: usize, col: usize) -> bool {
    pattern >> brick_bit(row, col) & 1 == 1
}

/// Set element `(row, col)`.
#[inline]
pub fn pattern_set(pattern: u64, row: usize, col: usize) -> u64 {
    pattern | 1u64 << brick_bit(row, col)
}

/// Iterate `(row, col, value_index)` of every nonzero in pattern order.
pub fn pattern_iter(pattern: u64) -> impl Iterator<Item = (usize, usize, usize)> {
    let mut bits = pattern;
    let mut idx = 0usize;
    std::iter::from_fn(move || {
        if bits == 0 {
            return None;
        }
        let bit = bits.trailing_zeros() as usize;
        bits &= bits - 1;
        let out = (bit / BRICK_K, bit % BRICK_K, idx);
        idx += 1;
        Some(out)
    })
}

/// Ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to a multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_layout_is_row_major() {
        assert_eq!(brick_bit(0, 0), 0);
        assert_eq!(brick_bit(0, 3), 3);
        assert_eq!(brick_bit(1, 0), 4);
        assert_eq!(brick_bit(15, 3), 63);
    }

    #[test]
    fn prefix_count_matches_scan() {
        let p: u64 = 0b1011_0110_0101;
        for bit in 0..64u32 {
            let naive = (0..bit).filter(|&b| p >> b & 1 == 1).count();
            assert_eq!(prefix_count(p, bit), naive, "bit {bit}");
        }
    }

    #[test]
    fn set_then_has() {
        let mut p = 0u64;
        p = pattern_set(p, 3, 2);
        p = pattern_set(p, 15, 3);
        assert!(pattern_has(p, 3, 2));
        assert!(pattern_has(p, 15, 3));
        assert!(!pattern_has(p, 0, 0));
        assert_eq!(pattern_nnz(p), 2);
    }

    #[test]
    fn iter_yields_in_pattern_order_with_indices() {
        let mut p = 0u64;
        p = pattern_set(p, 0, 1); // bit 1
        p = pattern_set(p, 2, 0); // bit 8
        p = pattern_set(p, 2, 3); // bit 11
        let got: Vec<_> = pattern_iter(p).collect();
        assert_eq!(got, vec![(0, 1, 0), (2, 0, 1), (2, 3, 2)]);
    }

    #[test]
    fn iter_full_pattern() {
        let got: Vec<_> = pattern_iter(u64::MAX).collect();
        assert_eq!(got.len(), 64);
        assert_eq!(got[63], (15, 3, 63));
    }

    #[test]
    fn ceil_and_round() {
        assert_eq!(ceil_div(10, 4), 3);
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
    }
}
