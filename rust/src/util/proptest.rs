//! A minimal property-testing harness (the real `proptest` crate is not in
//! the offline vendor set — see DESIGN.md §9).
//!
//! `check` runs a property over `cases` randomly-generated inputs; on failure
//! it performs greedy shrinking via the generator's `shrink` hook and panics
//! with the smallest failing case and its seed, so failures are reproducible
//! with `CUTESPMM_PROPTEST_SEED=<seed> cargo test`.

use crate::util::rng::Rng;

/// A generator of random test inputs with optional shrinking.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    /// Produce a random value.
    fn gen(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values (tried in order during shrinking).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` random inputs from `gen`.
///
/// Panics with the (shrunk) counterexample on the first failure.
pub fn check<G: Gen>(name: &str, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let seed = std::env::var("CUTESPMM_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            // deterministic per property name: stable across runs, distinct
            // across properties
            name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x100000001b3)
            })
        });
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.gen(&mut rng);
        if !prop(&v) {
            let shrunk = shrink_loop(gen, v, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed}).\n\
                 counterexample (shrunk): {shrunk:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // greedy descent, bounded to avoid pathological loops
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

/// Generator: usize uniform in [lo, hi] that shrinks toward lo.
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Value = usize;
    fn gen(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: pair of independent generators; shrinks component-wise.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn gen(&self, rng: &mut Rng) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Generator for random sparse matrices in triplet form, shrinking by
/// dropping nonzeros and reducing dimensions.
pub struct SparseGen {
    pub max_m: usize,
    pub max_k: usize,
    pub max_density: f64,
}

/// A generated sparse matrix specification.
#[derive(Clone, Debug)]
pub struct SparseCase {
    pub m: usize,
    pub k: usize,
    pub triplets: Vec<(usize, usize, f32)>,
}

impl Gen for SparseGen {
    type Value = SparseCase;

    fn gen(&self, rng: &mut Rng) -> SparseCase {
        let m = rng.range(1, self.max_m + 1);
        let k = rng.range(1, self.max_k + 1);
        let density = rng.f64() * self.max_density;
        let target = ((m * k) as f64 * density).ceil() as usize;
        let mut seen = std::collections::HashSet::new();
        let mut triplets = Vec::new();
        for _ in 0..target {
            let r = rng.below(m);
            let c = rng.below(k);
            if seen.insert((r, c)) {
                triplets.push((r, c, rng.nz_value()));
            }
        }
        SparseCase { m, k, triplets }
    }

    fn shrink(&self, v: &SparseCase) -> Vec<SparseCase> {
        let mut out = Vec::new();
        if v.triplets.len() > 1 {
            // halve the nonzeros
            let mut half = v.clone();
            half.triplets.truncate(v.triplets.len() / 2);
            out.push(half);
            // drop the last nonzero
            let mut minus = v.clone();
            minus.triplets.pop();
            out.push(minus);
        } else if !v.triplets.is_empty() {
            out.push(SparseCase { m: v.m, k: v.k, triplets: vec![] });
        }
        if v.m > 1 {
            let m2 = v.m / 2 + 1;
            out.push(SparseCase {
                m: m2.min(v.m - 1),
                k: v.k,
                triplets: v
                    .triplets
                    .iter()
                    .filter(|t| t.0 < m2.min(v.m - 1))
                    .cloned()
                    .collect(),
            });
        }
        if v.k > 1 {
            let k2 = v.k / 2 + 1;
            out.push(SparseCase {
                m: v.m,
                k: k2.min(v.k - 1),
                triplets: v
                    .triplets
                    .iter()
                    .filter(|t| t.1 < k2.min(v.k - 1))
                    .cloned()
                    .collect(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("usize in range", 200, &UsizeGen { lo: 2, hi: 50 }, |&v| {
            (2..=50).contains(&v)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_counterexample() {
        check("always fails", 10, &UsizeGen { lo: 0, hi: 100 }, |_| false);
    }

    #[test]
    fn shrinking_reaches_minimum() {
        // property "v < 10" fails from 10 upward; shrinker should land at 10
        let gen = UsizeGen { lo: 0, hi: 1000 };
        let failing = 873;
        let shrunk = shrink_loop(&gen, failing, &|&v: &usize| v < 10);
        assert_eq!(shrunk, 10);
    }

    #[test]
    fn sparse_gen_respects_bounds() {
        let g = SparseGen { max_m: 40, max_k: 60, max_density: 0.3 };
        check("sparse bounds", 50, &g, |c| {
            c.m >= 1
                && c.m <= 40
                && c.k >= 1
                && c.k <= 60
                && c.triplets.iter().all(|&(r, cc, v)| r < c.m && cc < c.k && v != 0.0)
        });
    }

    #[test]
    fn pair_gen_shrinks_componentwise() {
        let g = PairGen(UsizeGen { lo: 0, hi: 10 }, UsizeGen { lo: 0, hi: 10 });
        let shrinks = g.shrink(&(5, 5));
        assert!(shrinks.iter().any(|&(a, b)| a < 5 && b == 5));
        assert!(shrinks.iter().any(|&(a, b)| a == 5 && b < 5));
    }
}
