//! Measurement harness: warmup + repeated timing with robust aggregation.
//! This replaces criterion (unavailable on the offline image) for the
//! `benches/` targets; methodology mirrors criterion's warmup/sample split.

use std::time::{Duration, Instant};

/// Simple scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Result of a repeated measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Minimum seconds per iteration (least-noise estimate).
    pub min_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    pub samples: usize,
}

impl Measurement {
    /// Throughput in "units per second" for a per-iteration work amount.
    pub fn per_sec(&self, work_per_iter: f64) -> f64 {
        work_per_iter / self.median_s
    }

    /// GFLOP/s given FLOPs per iteration.
    pub fn gflops(&self, flops_per_iter: f64) -> f64 {
        flops_per_iter / self.median_s / 1e9
    }
}

/// Run `f` with `warmup` unmeasured iterations, then time `samples`
/// iterations individually and aggregate.
pub fn measure<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Measurement {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_s = times[times.len() / 2];
    let min_s = times[0];
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    Measurement { median_s, min_s, mean_s, samples }
}

/// Time a single invocation (for one-shot costs like preprocessing).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let mut n = 0usize;
        let m = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(m.samples, 5);
        assert!(m.min_s <= m.median_s && m.median_s <= m.mean_s * 5.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, s) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn gflops_math() {
        let m = Measurement { median_s: 0.5, min_s: 0.5, mean_s: 0.5, samples: 1 };
        assert!((m.gflops(1e9) - 2.0).abs() < 1e-12);
        assert!((m.per_sec(10.0) - 20.0).abs() < 1e-12);
    }
}
