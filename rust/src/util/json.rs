//! Minimal JSON: a writer for experiment reports and a small recursive-descent
//! parser for the artifact `manifest.json` (serde is unavailable offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` keys are ordered for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Member access helpers (None when type/key mismatches).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Intended for trusted build artifacts (manifest);
/// errors carry byte offsets for debuggability.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (valid UTF-8 passes through)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("fig9")),
            ("n", Json::num(128.0)),
            ("vals", Json::arr([Json::num(1.0), Json::num(2.5)])),
            ("ok", Json::Bool(true)),
        ]);
        let s = j.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "tm": 16, "tk": 16,
            "artifacts": [
                {"name": "a", "file": "a.hlo.txt", "nb": 256,
                 "args": [{"shape": [256, 16, 16], "dtype": "float32"}]}
            ]
        }"#;
        let j = parse(text).unwrap();
        assert_eq!(j.get("tm").unwrap().as_usize(), Some(16));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("a"));
        let shape = arts[0].get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 3);
    }

    #[test]
    fn escapes() {
        let j = Json::str("a\"b\\c\nd");
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }
}
