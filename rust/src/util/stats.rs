//! Descriptive statistics used by the experiment harness: percentiles,
//! box-plot summaries (the paper's Fig. 9), Pearson/Spearman correlation
//! (Fig. 7), and geometric means for speedup aggregation (Fig. 10).

/// Five-number summary + mean, matching a matplotlib box plot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q25: f64,
    pub median: f64,
    pub q75: f64,
    pub max: f64,
    pub mean: f64,
    pub count: usize,
}

/// Linear-interpolated percentile `p` in `[0, 100]` of unsorted data.
pub fn percentile(data: &[f64], p: f64) -> f64 {
    assert!(!data.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of already-sorted data.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Box-plot summary of unsorted data.
pub fn box_stats(data: &[f64]) -> BoxStats {
    assert!(!data.is_empty(), "box_stats of empty slice");
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BoxStats {
        min: v[0],
        q25: percentile_sorted(&v, 25.0),
        median: percentile_sorted(&v, 50.0),
        q75: percentile_sorted(&v, 75.0),
        max: v[v.len() - 1],
        mean: v.iter().sum::<f64>() / v.len() as f64,
        count: v.len(),
    }
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        let dx = a - mx;
        let dy = b - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Spearman rank correlation (Pearson over fractional ranks).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Fractional ranks with tie averaging.
pub fn ranks(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = avg;
        }
        i = j + 1;
    }
    out
}

/// Geometric mean (requires strictly positive inputs).
pub fn geomean(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    let s: f64 = data
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (s / data.len() as f64).exp()
}

/// Mean and sample standard deviation.
pub fn mean_std(data: &[f64]) -> (f64, f64) {
    assert!(!data.is_empty());
    let n = data.len() as f64;
    let mean = data.iter().sum::<f64>() / n;
    if data.len() < 2 {
        return (mean, 0.0);
    }
    let var = data.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_endpoints_and_median() {
        let d = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&d, 0.0), 1.0);
        assert_eq!(percentile(&d, 100.0), 5.0);
        assert_eq!(percentile(&d, 50.0), 3.0);
        assert_eq!(percentile(&d, 25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let d = [0.0, 10.0];
        assert!((percentile(&d, 30.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn box_stats_basic() {
        let d = [5.0, 1.0, 3.0, 2.0, 4.0];
        let b = box_stats(&d);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 5.0);
        assert_eq!(b.median, 3.0);
        assert_eq!(b.mean, 3.0);
        assert_eq!(b.count, 5);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let x = [1.0, 1.0, 1.0];
        let y = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&x, &y), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v: &f64| v.exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_tie_averaging() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935).abs() < 1e-6);
    }
}
