//! PJRT service thread — multi-threaded access to the (`!Send`) PJRT
//! runtime.
//!
//! PJRT handles are raw pointers, so [`super::PjrtRuntime`] must live on one
//! thread. The service owns that thread and a request channel; callers hold
//! a cheap cloneable [`PjrtHandle`] and get synchronous results. This is the
//! engine the coordinator's workers call into.

use crate::formats::Dense;
use crate::hrpb::Hrpb;
use crate::runtime::executor::PjrtRuntime;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

enum Req {
    Spmm {
        hrpb: Arc<Hrpb>,
        b: Dense,
        reply: Sender<Result<Dense, String>>,
    },
    Platform {
        reply: Sender<String>,
    },
    Shutdown,
}

/// Cloneable handle to the PJRT service thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Req>,
}

// Sender<Req> is Send but not Sync; wrap-per-clone is fine because each
// worker clones its own handle.
impl PjrtHandle {
    /// Run the AOT SpMM on the service thread (blocks for the result).
    pub fn spmm(&self, hrpb: Arc<Hrpb>, b: Dense) -> Result<Dense, String> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Spmm { hrpb, b, reply })
            .map_err(|_| "pjrt service stopped".to_string())?;
        rx.recv().map_err(|_| "pjrt service dropped reply".to_string())?
    }

    pub fn platform(&self) -> Result<String, String> {
        let (reply, rx) = channel();
        self.tx.send(Req::Platform { reply }).map_err(|_| "pjrt service stopped".to_string())?;
        rx.recv().map_err(|_| "pjrt service dropped reply".to_string())
    }
}

/// The running service; dropping it shuts the thread down.
pub struct PjrtService {
    tx: Sender<Req>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl PjrtService {
    /// Spawn the service over an artifacts directory. Fails fast if the
    /// manifest or PJRT client cannot be created.
    pub fn start(artifacts_dir: PathBuf) -> Result<PjrtService, String> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let mut rt = match PjrtRuntime::new(&artifacts_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Spmm { hrpb, b, reply } => {
                            let _ = reply.send(rt.spmm(&hrpb, &b));
                        }
                        Req::Platform { reply } => {
                            let _ = reply.send(rt.platform());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .map_err(|e| format!("spawn pjrt service: {e}"))?;
        ready_rx.recv().map_err(|_| "pjrt service died at startup".to_string())??;
        Ok(PjrtService { tx, join: Some(join) })
    }

    pub fn handle(&self) -> PjrtHandle {
        PjrtHandle { tx: self.tx.clone() }
    }

    /// Explicit graceful shutdown (dropping the service does the same).
    ///
    /// Shutdown ordering: stop the *coordinator first*, then this service —
    /// coordinator workers hold [`PjrtHandle`]s, and while a dead handle
    /// only degrades them to the native fallback, shutting down in order
    /// keeps every in-flight batch on its planned engine.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::build_from_coo;
    use crate::util::rng::Rng;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn service_runs_spmm_from_many_threads() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let svc = PjrtService::start(artifacts_dir()).unwrap();
        let mut rng = Rng::new(300);
        let coo = Coo::random(128, 256, 0.05, &mut rng);
        let hrpb = Arc::new(build_from_coo(&coo));
        let want = {
            let b = Dense::from_vec(256, 32, vec![1.0; 256 * 32]);
            coo.to_dense().matmul(&b)
        };
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = svc.handle();
                let hrpb = hrpb.clone();
                let want = &want;
                s.spawn(move || {
                    let b = Dense::from_vec(256, 32, vec![1.0; 256 * 32]);
                    let got = h.spmm(hrpb, b).unwrap();
                    assert!(got.rel_fro_error(want) < 1e-4);
                });
            }
        });
    }

    #[test]
    fn bad_dir_fails_fast() {
        assert!(PjrtService::start(PathBuf::from("/nonexistent")).is_err());
    }
}
