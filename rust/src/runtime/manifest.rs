//! Artifact manifest loader — reads `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) so the Rust side can validate feeds without
//! parsing HLO.

use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// Declared shape+dtype of one executable argument.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub model: String,
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub out_shape: Vec<usize>,
    /// Model-specific dims (nb/mp/k/n/f/m) kept as raw pairs.
    pub dims: Vec<(String, usize)>,
}

impl Artifact {
    pub fn dim(&self, key: &str) -> Option<usize> {
        self.dims.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tm: usize,
    pub tk: usize,
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let root = parse(&text)?;
        let tm = root.get("tm").and_then(Json::as_usize).ok_or("manifest: tm")?;
        let tk = root.get("tk").and_then(Json::as_usize).ok_or("manifest: tk")?;
        let mut artifacts = Vec::new();
        for a in root.get("artifacts").and_then(Json::as_arr).ok_or("manifest: artifacts")? {
            let name = a.get("name").and_then(Json::as_str).ok_or("artifact name")?.to_string();
            let model = a.get("model").and_then(Json::as_str).ok_or("artifact model")?.to_string();
            let file = dir.join(a.get("file").and_then(Json::as_str).ok_or("artifact file")?);
            let mut args = Vec::new();
            for spec in a.get("args").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = spec
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("arg shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dtype = spec.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string();
                args.push(ArgSpec { shape, dtype });
            }
            let out_shape = a
                .get("out_shape")
                .and_then(Json::as_arr)
                .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            let mut dims = Vec::new();
            for key in ["nb", "mp", "k", "n", "f", "m"] {
                if let Some(v) = a.get(key).and_then(Json::as_usize) {
                    dims.push((key.to_string(), v));
                }
            }
            artifacts.push(Artifact { name, model, file, args, out_shape, dims });
        }
        Ok(Manifest { tm, tk, artifacts, dir: dir.to_path_buf() })
    }

    pub fn find(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts of one model kind.
    pub fn by_model(&self, model: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.model == model).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.tm, 16);
        assert_eq!(m.tk, 16);
        assert!(!m.artifacts.is_empty());
        let spmm = m.by_model("hrpb_spmm");
        assert!(!spmm.is_empty());
        for a in spmm {
            assert_eq!(a.args.len(), 4, "{}: blocks, active_cols, panel_ids, B", a.name);
            assert!(a.file.exists(), "{} missing", a.file.display());
            let nb = a.dim("nb").unwrap();
            assert_eq!(a.args[0].shape, vec![nb, m.tm, m.tk]);
        }
    }

    #[test]
    fn missing_dir_is_an_error() {
        assert!(Manifest::load(Path::new("/nonexistent-dir")).is_err());
    }
}
