//! PJRT executor — loads `artifacts/*.hlo.txt`, compiles once per artifact
//! (warm cache), and runs the AOT-compiled SpMM from the Rust hot path.
//!
//! Interchange is HLO *text* (see `aot.py` header: jax ≥ 0.5 emits protos
//! with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids). Lowering used `return_tuple=True`, so outputs unwrap with
//! `to_tuple1`.

use crate::formats::Dense;
use crate::hrpb::decode::DenseBrickFeed;
use crate::hrpb::Hrpb;
use crate::runtime::bucket::{pick_spmm_bucket, SpmmBucket};
use crate::runtime::manifest::Manifest;
// Offline build: the `xla` crate is not in the vendor set, so the executor
// compiles against the API-compatible stub (see `runtime::xla_stub`). Swap
// this alias for the extern crate on a machine that has `xla` vendored.
use crate::runtime::xla_stub as xla;
use std::collections::HashMap;
use std::path::Path;

/// A compiled-executable cache over one PJRT CPU client.
///
/// NOT `Send`: PJRT handles hold raw pointers. Use [`super::service`] to
/// drive it from multi-threaded code.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU-PJRT runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<PjrtRuntime, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        Ok(PjrtRuntime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch) the executable for a named artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable, String> {
        if !self.cache.contains_key(name) {
            let art = self.manifest.find(name).ok_or_else(|| format!("no artifact '{name}'"))?;
            let proto = xla::HloModuleProto::from_text_file(
                art.file.to_str().ok_or("non-utf8 path")?,
            )
            .map_err(|e| format!("parse {}: {e}", art.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| format!("compile {name}: {e}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Is an artifact available (and will `spmm` succeed bucket-wise)?
    pub fn can_spmm(&self, hrpb: &Hrpb, k: usize, n: usize) -> bool {
        let mp = hrpb.num_panels();
        pick_spmm_bucket(hrpb.num_blocks().max(1), mp, k, n)
            .map(|b| self.manifest.find(&b.artifact_name()).is_some())
            .unwrap_or(false)
    }

    /// Run the AOT `hrpb_spmm` artifact: pad the feed to the bucket, execute,
    /// slice the padded output back to `rows × n`.
    pub fn spmm(&mut self, hrpb: &Hrpb, b: &Dense) -> Result<Dense, String> {
        assert_eq!(b.rows, hrpb.cols, "B rows must equal A cols");
        let mut feed = crate::hrpb::decode::to_feed(hrpb);
        if feed.num_blocks == 0 {
            feed.pad_to(1); // artifact needs >= 1 (inert) block
        }
        let mp = hrpb.num_panels();
        let bucket = pick_spmm_bucket(feed.num_blocks, mp, b.rows, b.cols)
            .ok_or_else(|| format!(
                "no bucket fits nb={} mp={} k={} n={}",
                feed.num_blocks, mp, b.rows, b.cols
            ))?;
        let out = self.spmm_in_bucket(&mut feed, b, bucket)?;
        // slice padded output (bucket.mp * TM rows) down to the real rows
        let mut c = Dense::zeros(hrpb.rows, b.cols);
        c.data.copy_from_slice(&out.data[..hrpb.rows * b.cols]);
        Ok(c)
    }

    fn spmm_in_bucket(
        &mut self,
        feed: &mut DenseBrickFeed,
        b: &Dense,
        bucket: SpmmBucket,
    ) -> Result<Dense, String> {
        feed.pad_to(bucket.nb);
        // pad B with zero rows up to bucket.k
        let mut b_padded = vec![0f32; bucket.k * bucket.n];
        for r in 0..b.rows {
            b_padded[r * bucket.n..r * bucket.n + b.cols].copy_from_slice(b.row(r));
        }

        let lit_blocks = xla::Literal::vec1(feed.blocks.as_slice())
            .reshape(&[bucket.nb as i64, feed.tm as i64, feed.tk as i64])
            .map_err(|e| format!("reshape blocks: {e}"))?;
        let lit_cols = xla::Literal::vec1(feed.active_cols.as_slice())
            .reshape(&[bucket.nb as i64, feed.tk as i64])
            .map_err(|e| format!("reshape active_cols: {e}"))?;
        let lit_pids = xla::Literal::vec1(feed.panel_ids.as_slice());
        let lit_b = xla::Literal::vec1(b_padded.as_slice())
            .reshape(&[bucket.k as i64, bucket.n as i64])
            .map_err(|e| format!("reshape B: {e}"))?;

        let name = bucket.artifact_name();
        let exe = self.executable(&name)?;
        let result = exe
            .execute::<xla::Literal>(&[lit_blocks, lit_cols, lit_pids, lit_b])
            .map_err(|e| format!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch result: {e}"))?;
        let out = result.to_tuple1().map_err(|e| format!("untuple: {e}"))?;
        let data = out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))?;
        let rows = bucket.mp * feed.tm;
        if data.len() != rows * bucket.n {
            return Err(format!("output size {} != {}x{}", data.len(), rows, bucket.n));
        }
        Ok(Dense::from_vec(rows, bucket.n, data))
    }

    /// Run the dense reference artifact (examples / self-check).
    pub fn dense_mm(&mut self, a: &Dense, b: &Dense, name: &str) -> Result<Dense, String> {
        let art = self.manifest.find(name).ok_or_else(|| format!("no artifact '{name}'"))?;
        let (m, k, n) = (
            art.dim("m").ok_or("dense_mm m")?,
            art.dim("k").ok_or("dense_mm k")?,
            art.dim("n").ok_or("dense_mm n")?,
        );
        if a.rows != m || a.cols != k || b.rows != k || b.cols != n {
            return Err(format!(
                "dense_mm {name}: shape mismatch a={}x{} b={}x{}",
                a.rows, a.cols, b.rows, b.cols
            ));
        }
        let la = xla::Literal::vec1(a.data.as_slice())
            .reshape(&[m as i64, k as i64])
            .map_err(|e| e.to_string())?;
        let lb = xla::Literal::vec1(b.data.as_slice())
            .reshape(&[k as i64, n as i64])
            .map_err(|e| e.to_string())?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&[la, lb]).map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?;
        let out = result.to_tuple1().map_err(|e| e.to_string())?;
        let data = out.to_vec::<f32>().map_err(|e| e.to_string())?;
        Ok(Dense::from_vec(m, n, data))
    }

    /// Number of compiled executables held warm.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::hrpb::build_from_coo;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<PjrtRuntime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping PJRT test: run `make artifacts`");
            return None;
        }
        Some(PjrtRuntime::new(&dir).unwrap())
    }

    #[test]
    fn pjrt_spmm_matches_native() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(200);
        let coo = Coo::random(300, 400, 0.02, &mut rng);
        let b = Dense::random(400, 32, &mut rng);
        let hrpb = build_from_coo(&coo);
        let got = rt.spmm(&hrpb, &b).unwrap();
        let want = coo.to_dense().matmul(&b);
        assert!(got.rel_fro_error(&want) < 1e-4, "err {}", got.rel_fro_error(&want));
    }

    #[test]
    fn pjrt_executable_cache_warm() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(201);
        let coo = Coo::random(100, 200, 0.03, &mut rng);
        let hrpb = build_from_coo(&coo);
        let b = Dense::random(200, 32, &mut rng);
        assert_eq!(rt.cached(), 0);
        rt.spmm(&hrpb, &b).unwrap();
        assert_eq!(rt.cached(), 1);
        rt.spmm(&hrpb, &b).unwrap();
        assert_eq!(rt.cached(), 1, "second call reuses the compiled executable");
    }

    #[test]
    fn pjrt_rejects_oversize() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(202);
        let coo = Coo::random(64, 100_000, 0.0001, &mut rng);
        let hrpb = build_from_coo(&coo);
        let b = Dense::zeros(100_000, 128);
        assert!(rt.spmm(&hrpb, &b).is_err());
    }

    #[test]
    fn pjrt_dense_mm() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::new(203);
        let a = Dense::random(256, 256, &mut rng);
        let b = Dense::random(256, 128, &mut rng);
        let got = rt.dense_mm(&a, &b, "dense_mm__m256_k256_n128").unwrap();
        assert!(got.rel_fro_error(&a.matmul(&b)) < 1e-4);
    }
}
