//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The vendor set of this image has no `xla` crate, so the executor compiles
//! against this API-compatible shim instead: every constructor that would
//! touch PJRT fails with a clear error, and the coordinator/CLI fall back to
//! the native engine exactly as they do when artifacts are missing. On a
//! machine with the real crate vendored, add `xla` to `Cargo.toml` and switch
//! the `use ... as xla` line in `executor.rs` back to the extern crate — no
//! other code changes.

use std::fmt;

/// Error carrying the shim's "unavailable" message (the real crate's error
/// type is also `Display`, which is all `executor.rs` relies on).
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla_stub::Error({})", self.0)
    }
}

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: built against runtime::xla_stub (the offline image \
         has no `xla` crate); the native engine serves all traffic"
            .to_string(),
    )
}

/// PJRT client handle. The stub can never be constructed, which keeps every
/// downstream method unreachable at runtime.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Host-side tensor literal. Construction succeeds (executor builds literals
/// before compiling), but every conversion fails like the client does.
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal(()))
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn literals_construct_but_do_not_convert() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        let l = l.reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.to_tuple1().is_err());
    }
}
