//! AOT runtime — loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them via the `xla` crate's PJRT CPU
//! client. Python never runs on the request path: `make artifacts` is the
//! one-time build step, and the Rust binary is self-contained afterwards.
//!
//! Offline builds (no `xla` crate in the vendor set) compile against the
//! API-compatible [`xla_stub`]; every PJRT entry point then fails cleanly
//! and callers fall back to the native engine.

pub mod bucket;
pub mod executor;
pub mod manifest;
pub mod service;
pub mod xla_stub;

pub use bucket::{pick_spmm_bucket, SpmmBucket};
pub use executor::PjrtRuntime;
pub use manifest::{Artifact, Manifest};
pub use service::{PjrtHandle, PjrtService};

use std::path::PathBuf;

/// Default artifacts directory: `$CUTESPMM_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("CUTESPMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Are artifacts present (manifest exists)?
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
