//! Shape buckets — MUST agree with `python/compile/aot.py`.
//!
//! PJRT executables are compiled for fixed shapes; a matrix/feed is padded up
//! to the smallest bucket that fits (inert padding blocks, zero B rows,
//! output rows sliced back). `N` must match exactly: padding the feature
//! dimension would change the artifact's output width contract.

/// One `hrpb_spmm` artifact bucket: (num_blocks, num_panels, K, N).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpmmBucket {
    pub nb: usize,
    pub mp: usize,
    pub k: usize,
    pub n: usize,
}

/// Mirror of `aot.py::SPMM_BUCKETS`.
pub const SPMM_BUCKETS: [SpmmBucket; 6] = [
    SpmmBucket { nb: 256, mp: 32, k: 512, n: 32 },
    SpmmBucket { nb: 256, mp: 32, k: 512, n: 128 },
    SpmmBucket { nb: 1024, mp: 128, k: 2048, n: 32 },
    SpmmBucket { nb: 1024, mp: 128, k: 2048, n: 128 },
    SpmmBucket { nb: 4096, mp: 192, k: 4096, n: 32 },
    SpmmBucket { nb: 4096, mp: 192, k: 4096, n: 128 },
];

impl SpmmBucket {
    pub fn artifact_name(&self) -> String {
        format!("hrpb_spmm__nb{}_mp{}_k{}_n{}", self.nb, self.mp, self.k, self.n)
    }

    /// Does a workload with these requirements fit this bucket?
    pub fn fits(&self, nb: usize, mp: usize, k: usize, n: usize) -> bool {
        nb <= self.nb && mp <= self.mp && k <= self.k && n == self.n
    }

    /// Padded-problem cost proxy (used to pick the cheapest fitting bucket).
    pub fn cost(&self) -> usize {
        self.nb * self.k * self.n
    }
}

/// Smallest bucket fitting `(nb, mp, k, n)`, or `None` (fall back to the
/// native engine).
pub fn pick_spmm_bucket(nb: usize, mp: usize, k: usize, n: usize) -> Option<SpmmBucket> {
    SPMM_BUCKETS
        .iter()
        .filter(|b| b.fits(nb, mp, k, n))
        .min_by_key(|b| b.cost())
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_smallest_fitting() {
        let b = pick_spmm_bucket(100, 20, 400, 32).unwrap();
        assert_eq!(b, SPMM_BUCKETS[0]);
        let b = pick_spmm_bucket(100, 20, 400, 128).unwrap();
        assert_eq!(b, SPMM_BUCKETS[1]);
        let b = pick_spmm_bucket(2000, 150, 3000, 128).unwrap();
        assert_eq!(b.nb, 4096);
    }

    #[test]
    fn n_must_match_exactly() {
        assert!(pick_spmm_bucket(10, 10, 100, 64).is_none());
        assert!(pick_spmm_bucket(10, 10, 100, 32).is_some());
    }

    #[test]
    fn oversize_returns_none() {
        assert!(pick_spmm_bucket(10_000, 10, 100, 128).is_none());
        assert!(pick_spmm_bucket(10, 10, 100_000, 128).is_none());
    }

    #[test]
    fn names_match_python_convention() {
        assert_eq!(
            SPMM_BUCKETS[0].artifact_name(),
            "hrpb_spmm__nb256_mp32_k512_n32"
        );
    }
}
