//! Fixed-capacity span storage: a preallocated drop-oldest ring.
//!
//! One ring never reallocates after construction — the hot path writes a
//! `Copy` span into a preallocated slot under a short uncontended lock
//! (rings are per thread; the only cross-thread access is the drain).
//! Overflow evicts the *oldest* span and counts the eviction, so a drained
//! trace can report exactly how much history it lost.

/// Token value for spans not tied to a request (kernel/worker spans).
pub const NO_TOKEN: u64 = u64::MAX;

/// Bounded key/value payload carried by a span — sized so recording stays
/// allocation-free. Keys are static names; an empty key marks a free slot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanArgs {
    /// Engine lane name for exec spans (`None` elsewhere).
    pub engine: Option<&'static str>,
    kv: [(&'static str, u64); 3],
}

impl SpanArgs {
    pub fn new() -> SpanArgs {
        SpanArgs::default()
    }

    pub fn engine(name: &'static str) -> SpanArgs {
        SpanArgs { engine: Some(name), ..SpanArgs::default() }
    }

    /// Attach a key/value pair; silently dropped once all slots are taken
    /// (the bounded payload is part of the no-allocation contract).
    pub fn with(mut self, key: &'static str, value: u64) -> SpanArgs {
        for slot in self.kv.iter_mut() {
            if slot.0.is_empty() {
                *slot = (key, value);
                break;
            }
        }
        self
    }

    /// The occupied key/value pairs, in insertion order.
    pub fn pairs(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.kv.iter().copied().filter(|(k, _)| !k.is_empty())
    }
}

/// One completed span. Timestamps are µs since the process trace epoch
/// ([`super::install`] pins it), matching Chrome `trace_event`'s `ts`/`dur`.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Per-ring sequence number (assigned by [`SpanRing::push`]); gaps at
    /// the front of a drained ring are the evicted history.
    pub seq: u64,
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    /// Request token, or [`NO_TOKEN`] for kernel-side spans.
    pub token: u64,
    pub args: SpanArgs,
}

/// Fixed-capacity, sequence-numbered, drop-oldest span ring.
pub struct SpanRing {
    buf: Vec<Span>,
    capacity: usize,
    /// Index of the oldest span once the ring is full.
    head: usize,
    next_seq: u64,
    dropped: u64,
    dropped_total: u64,
}

impl SpanRing {
    pub fn new(capacity: usize) -> SpanRing {
        let capacity = capacity.max(1);
        SpanRing {
            buf: Vec::with_capacity(capacity),
            capacity,
            head: 0,
            next_seq: 0,
            dropped: 0,
            dropped_total: 0,
        }
    }

    /// Record a span, stamping its sequence number. Beyond capacity the
    /// oldest span is overwritten in place — never a reallocation.
    pub fn push(&mut self, mut span: Span) {
        span.seq = self.next_seq;
        self.next_seq += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(span);
        } else {
            self.buf[self.head] = span;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
            self.dropped_total += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Configured span capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Heap slots actually allocated — the overflow test pins this to the
    /// construction-time value (drop-oldest must never reallocate).
    pub fn allocated(&self) -> usize {
        self.buf.capacity()
    }

    /// Spans evicted by overflow since construction or the last drain.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Spans ever pushed (monotonic across drains).
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    /// Spans ever evicted by overflow (monotonic across drains — the
    /// session-lifetime loss counter behind `metrics`' trace section).
    pub fn dropped_total(&self) -> u64 {
        self.dropped_total
    }

    /// Remove and return every stored span, oldest first. Keeps the
    /// allocation and the monotonic sequence counter; resets the overflow
    /// counter (each drain reports only its own losses).
    pub fn drain_ordered(&mut self) -> Vec<Span> {
        let n = self.buf.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(self.buf[(self.head + i) % n]);
        }
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
        out
    }

    /// Clear contents and counters and adopt a new capacity (a new trace
    /// session installing).
    pub fn reset(&mut self, capacity: usize) {
        *self = SpanRing::new(capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(tag: u64) -> Span {
        Span { seq: 0, name: "t", start_us: tag, dur_us: 1, token: tag, args: SpanArgs::new() }
    }

    #[test]
    fn ring_drops_oldest_counts_exactly_and_never_reallocates() {
        let mut r = SpanRing::new(8);
        let alloc0 = r.allocated();
        for i in 0..25 {
            r.push(span(i));
        }
        assert_eq!(r.len(), 8);
        assert_eq!(r.dropped(), 17, "25 pushes into 8 slots evict exactly 17");
        assert_eq!(r.recorded(), 25);
        assert_eq!(r.allocated(), alloc0, "overflow must overwrite in place");
        let spans = r.drain_ordered();
        assert_eq!(spans.len(), 8);
        // survivors are exactly the newest 8, oldest first, densely numbered
        assert_eq!(spans.first().unwrap().seq, 17);
        assert_eq!(spans.last().unwrap().seq, 24);
        assert!(spans.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
        assert_eq!(r.dropped(), 0, "drain resets the overflow counter");
        assert_eq!(r.recorded(), 25, "the sequence counter stays monotonic");
        assert_eq!(r.dropped_total(), 17, "lifetime drop counter survives the drain");
        assert_eq!(r.allocated(), alloc0);
        r.push(span(25));
        assert_eq!(r.dropped_total(), 17, "non-evicting pushes leave it unchanged");
        r.reset(8);
        assert_eq!(r.dropped_total(), 0, "a new session starts the counter over");
    }

    #[test]
    fn partial_ring_drains_in_insertion_order() {
        let mut r = SpanRing::new(16);
        for i in 0..5 {
            r.push(span(i));
        }
        let spans = r.drain_ordered();
        assert_eq!(spans.iter().map(|s| s.seq).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn args_hold_three_pairs_then_drop() {
        let a = SpanArgs::engine("cutespmm").with("a", 1).with("b", 2).with("c", 3).with("d", 4);
        let pairs: Vec<_> = a.pairs().collect();
        assert_eq!(pairs, vec![("a", 1), ("b", 2), ("c", 3)]);
        assert_eq!(a.engine, Some("cutespmm"));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = SpanRing::new(0);
        r.push(span(0));
        r.push(span(1));
        assert_eq!(r.len(), 1);
        assert_eq!(r.dropped(), 1);
        assert_eq!(r.drain_ordered()[0].seq, 1);
    }
}
