//! Drained-trace container and Chrome `trace_event` export.
//!
//! The export is the JSON Object Format of the Trace Event spec: complete
//! (`ph:"X"`) duration events plus `thread_name` metadata, loadable in
//! `chrome://tracing` and <https://ui.perfetto.dev>. Written with
//! [`crate::util::json`] (serde is unavailable offline).

use super::ring::{Span, NO_TOKEN};
use super::Kind;
use crate::util::json::Json;
use std::io;
use std::path::Path;

/// A thread that contributed spans (names come from the OS thread name —
/// `cutespmm-exec-{i}` for pool workers, `coord-worker-{i}` for the
/// coordinator pool, etc.).
#[derive(Clone, Debug)]
pub struct TraceThread {
    pub tid: u64,
    pub name: String,
}

/// One span attributed to its recording thread.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpan {
    pub tid: u64,
    pub kind: Kind,
    pub span: Span,
}

/// Everything [`super::drain`] collected: spans across all threads, sorted
/// by start time, plus the exact number of spans lost to ring overflow.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub threads: Vec<TraceThread>,
    pub spans: Vec<TraceSpan>,
    /// Spans evicted by drop-oldest before this drain.
    pub dropped: u64,
}

impl Trace {
    /// Spans with the given stage name.
    pub fn count(&self, name: &str) -> usize {
        self.spans.iter().filter(|s| s.span.name == name).count()
    }

    /// Total duration across spans with the given stage name (µs).
    pub fn sum_dur_us(&self, name: &str) -> u64 {
        self.spans.iter().filter(|s| s.span.name == name).map(|s| s.span.dur_us).sum()
    }

    /// The Chrome `trace_event` JSON document.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::with_capacity(self.threads.len() + self.spans.len());
        for t in &self.threads {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(t.tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(t.name.clone()))])),
            ]));
        }
        for s in &self.spans {
            let mut args = vec![("seq", Json::num(s.span.seq as f64))];
            if s.span.token != NO_TOKEN {
                args.push(("token", Json::num(s.span.token as f64)));
            }
            if let Some(engine) = s.span.args.engine {
                args.push(("engine", Json::str(engine)));
            }
            for (k, v) in s.span.args.pairs() {
                args.push((k, Json::num(v as f64)));
            }
            events.push(Json::obj(vec![
                ("name", Json::str(s.span.name)),
                ("cat", Json::str(s.kind.name())),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.tid as f64)),
                ("ts", Json::num(s.span.start_us as f64)),
                ("dur", Json::num(s.span.dur_us as f64)),
                ("args", Json::obj(args)),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("otherData", Json::obj(vec![("dropped_spans", Json::num(self.dropped as f64))])),
        ])
    }

    /// Write the Chrome export, creating parent directories.
    pub fn write_chrome(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_chrome_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::super::ring::SpanArgs;
    use super::*;
    use crate::util::json;

    fn sample() -> Trace {
        Trace {
            threads: vec![TraceThread { tid: 1, name: "router".into() }],
            spans: vec![
                TraceSpan {
                    tid: 1,
                    kind: Kind::Request,
                    span: Span {
                        seq: 0,
                        name: "exec",
                        start_us: 10,
                        dur_us: 40,
                        token: 7,
                        args: SpanArgs::engine("cutespmm").with("reqs", 3),
                    },
                },
                TraceSpan {
                    tid: 1,
                    kind: Kind::Kernel,
                    span: Span {
                        seq: 1,
                        name: "unit",
                        start_us: 12,
                        dur_us: 9,
                        token: NO_TOKEN,
                        args: SpanArgs::new().with("panel", 4).with("bricks", 128),
                    },
                },
            ],
            dropped: 5,
        }
    }

    #[test]
    fn chrome_export_parses_and_carries_metadata() {
        let t = sample();
        let doc = json::parse(&t.to_chrome_json().to_string()).expect("valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3, "thread_name metadata + 2 spans");
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        let exec = &events[1];
        assert_eq!(exec.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(exec.get("name").unwrap().as_str(), Some("exec"));
        assert_eq!(exec.get("dur").unwrap().as_usize(), Some(40));
        assert_eq!(exec.get("args").unwrap().get("engine").unwrap().as_str(), Some("cutespmm"));
        let unit = &events[2];
        assert_eq!(unit.get("cat").unwrap().as_str(), Some("kernel"));
        assert_eq!(unit.get("args").unwrap().get("token"), None, "NO_TOKEN is omitted");
        assert_eq!(unit.get("args").unwrap().get("bricks").unwrap().as_usize(), Some(128));
        assert_eq!(doc.get("otherData").unwrap().get("dropped_spans").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn count_and_sum_helpers() {
        let t = sample();
        assert_eq!(t.count("exec"), 1);
        assert_eq!(t.count("unit"), 1);
        assert_eq!(t.count("missing"), 0);
        assert_eq!(t.sum_dur_us("exec"), 40);
    }
}
