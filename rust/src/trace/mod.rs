//! Runtime-gated request tracing and kernel profiling.
//!
//! The serving stack's aggregate metrics say *that* the planner's modeled
//! runtime drifted; they cannot say *which stage* ate the time — admission
//! wait, the batcher, worker-pool scheduling, HRPB brick decode, or the
//! scatter epilogue. This layer records a span tree per request
//! (`admit → queue_wait → batch → exec → scatter`) plus kernel-side spans
//! (per pool worker, per HRPB work unit) into lock-light per-thread ring
//! buffers, drained into a Chrome `trace_event` export.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled ≈ free.** Every instrumentation point starts with one
//!    relaxed atomic load ([`enabled`]/[`kernel_enabled`]); the acceptance
//!    budget is ≤ 2% serving-throughput overhead with tracing off
//!    (`experiment trace` measures it).
//! 2. **Recording never allocates or contends.** Spans are `Copy` with a
//!    bounded arg payload, written into a preallocated per-thread
//!    [`SpanRing`] under that thread's own mutex (contended only by a
//!    drain). Overflow drops the *oldest* span and counts it.
//! 3. **Kernel spans cannot evict request spans.** Each thread owns two
//!    rings — request-lifecycle and kernel — because a coordinator worker
//!    also participates in pool jobs: thousands of `unit` spans would
//!    otherwise wash out the handful of `exec` spans that the overhead
//!    experiment reconciles against the engine-lane `observed_us` counters.
//!
//! The state is process-global (threads outlive any one coordinator), so a
//! trace *session* — [`install`] → run → [`drain`] — must be serialized by
//! holding [`session_guard`] across it, as the serve CLI, the trace
//! experiment, and the tests all do.

pub mod export;
pub mod ring;

pub use export::{Trace, TraceSpan, TraceThread};
pub use ring::{Span, SpanArgs, SpanRing, NO_TOKEN};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Runtime tracing configuration ([`crate::coordinator::Config::trace`]).
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Master gate. Off (the default) leaves one relaxed atomic load per
    /// instrumentation point.
    pub enabled: bool,
    /// Fraction of requests recording the per-request span tree
    /// (admit/queue_wait/batch/exec/scatter); the decision is a
    /// deterministic hash of the request token. 1.0 traces everything.
    pub sample_rate: f64,
    /// Record kernel profiling spans (per pool worker part, per HRPB work
    /// unit) in each thread's separate kernel ring.
    pub kernel: bool,
    /// Per-thread, per-ring span capacity; drop-oldest beyond it.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, sample_rate: 1.0, kernel: true, ring_capacity: 8192 }
    }
}

/// Which of a thread's two rings a span lands in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Request-lifecycle stages: admit, queue_wait, batch, exec, scatter.
    Request,
    /// Kernel profiling: pool worker parts, HRPB work units.
    Kernel,
}

impl Kind {
    pub fn name(&self) -> &'static str {
        match self {
            Kind::Request => "request",
            Kind::Kernel => "kernel",
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static KERNEL: AtomicBool = AtomicBool::new(false);
/// `f64::to_bits` of the sample rate (0x3FF0... = 1.0).
static SAMPLE_BITS: AtomicU64 = AtomicU64::new(0x3FF0_0000_0000_0000);
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(8192);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Both rings of one recording thread. Registered globally so [`drain`]
/// can reach rings of threads that are still running (pool workers never
/// exit); the per-ring mutexes are uncontended except during a drain.
struct ThreadRing {
    tid: u64,
    name: String,
    request: Mutex<SpanRing>,
    kernel: Mutex<SpanRing>,
}

static REGISTRY: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: RefCell<Option<Arc<ThreadRing>>> = const { RefCell::new(None) };
}

/// The timestamp origin all spans are measured from (µs offsets keep the
/// Chrome export's `ts` fields small). Pinned at first use.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Is request tracing on? One relaxed load — the entire disabled-path cost
/// at most instrumentation points.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Are kernel profiling spans (worker/unit) on?
#[inline]
pub fn kernel_enabled() -> bool {
    KERNEL.load(Ordering::Relaxed)
}

/// Per-request sampling decision: a deterministic splitmix64 hash of the
/// token against the configured rate, so the same token always samples the
/// same way and no RNG state is shared.
pub fn sample(token: u64) -> bool {
    if !enabled() {
        return false;
    }
    let rate = f64::from_bits(SAMPLE_BITS.load(Ordering::Relaxed));
    if rate >= 1.0 {
        return true;
    }
    if rate <= 0.0 {
        return false;
    }
    let mut z = token.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 11) as f64 / (1u64 << 53) as f64) < rate
}

fn local() -> Arc<ThreadRing> {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(r) = slot.as_ref() {
            return r.clone();
        }
        let cap = RING_CAPACITY.load(Ordering::Relaxed);
        let ring = Arc::new(ThreadRing {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            name: std::thread::current().name().unwrap_or("thread").to_string(),
            request: Mutex::new(SpanRing::new(cap)),
            kernel: Mutex::new(SpanRing::new(cap)),
        });
        REGISTRY.lock().unwrap().push(ring.clone());
        *slot = Some(ring.clone());
        ring
    })
}

/// Record a completed span that started at `start` and ends now. Call
/// sites capture `start` only when the relevant gate is on, so the
/// disabled path never touches the clock.
pub fn record(kind: Kind, name: &'static str, start: Instant, token: u64, args: SpanArgs) {
    if !enabled() {
        return;
    }
    let start_us = start.saturating_duration_since(epoch()).as_micros() as u64;
    let dur_us = start.elapsed().as_micros() as u64;
    let ring = local();
    let target = match kind {
        Kind::Request => &ring.request,
        Kind::Kernel => &ring.kernel,
    };
    target.lock().unwrap().push(Span { seq: 0, name, start_us, dur_us, token, args });
}

/// Install a trace session: set the gates and sampling rate, reset every
/// registered ring to the configured capacity. `enabled: false` configs
/// just turn tracing off.
pub fn install(config: &TraceConfig) {
    let _ = epoch(); // pin the timestamp origin before any span records
    ENABLED.store(false, Ordering::Relaxed);
    KERNEL.store(false, Ordering::Relaxed);
    SAMPLE_BITS.store(config.sample_rate.to_bits(), Ordering::Relaxed);
    let cap = config.ring_capacity.max(1);
    RING_CAPACITY.store(cap, Ordering::Relaxed);
    for ring in REGISTRY.lock().unwrap().iter() {
        ring.request.lock().unwrap().reset(cap);
        ring.kernel.lock().unwrap().reset(cap);
    }
    KERNEL.store(config.enabled && config.kernel, Ordering::Relaxed);
    ENABLED.store(config.enabled, Ordering::Relaxed);
}

/// Turn tracing off. Already-recorded spans stay drainable.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    KERNEL.store(false, Ordering::Relaxed);
}

/// Collect (and remove) every recorded span across all threads, sorted by
/// start time. Threads that recorded nothing are omitted.
pub fn drain() -> Trace {
    let mut trace = Trace::default();
    for ring in REGISTRY.lock().unwrap().iter() {
        let (req, kern, dropped) = {
            let mut req = ring.request.lock().unwrap();
            let mut kern = ring.kernel.lock().unwrap();
            let dropped = req.dropped() + kern.dropped();
            (req.drain_ordered(), kern.drain_ordered(), dropped)
        };
        trace.dropped += dropped;
        if req.is_empty() && kern.is_empty() {
            continue;
        }
        trace.threads.push(TraceThread { tid: ring.tid, name: ring.name.clone() });
        trace.spans.extend(
            req.into_iter().map(|s| TraceSpan { tid: ring.tid, kind: Kind::Request, span: s }),
        );
        trace.spans.extend(
            kern.into_iter().map(|s| TraceSpan { tid: ring.tid, kind: Kind::Kernel, span: s }),
        );
    }
    trace.spans.sort_by_key(|s| (s.span.start_us, s.tid, s.span.seq));
    trace
}

/// Session-lifetime ring totals, summed over every registered thread's
/// request and kernel rings.
#[derive(Clone, Copy, Debug, Default)]
pub struct RingTotals {
    /// Spans ever pushed (monotonic; drains do not reset it).
    pub recorded: u64,
    /// Spans ever evicted by ring overflow (monotonic; drains do not reset
    /// it — unlike [`Trace::dropped`], which reports per-drain losses).
    pub dropped: u64,
}

/// Sum the monotonic recorded/dropped counters across all thread rings.
/// Cheap enough to call after every batch: one registry lock plus two
/// uncontended ring locks per thread.
pub fn ring_totals() -> RingTotals {
    let mut totals = RingTotals::default();
    for ring in REGISTRY.lock().unwrap().iter() {
        let req = ring.request.lock().unwrap();
        totals.recorded += req.recorded();
        totals.dropped += req.dropped_total();
        drop(req);
        let kern = ring.kernel.lock().unwrap();
        totals.recorded += kern.recorded();
        totals.dropped += kern.dropped_total();
    }
    totals
}

/// Serialize whole-process trace sessions. The gates and rings are global
/// (pool threads outlive any coordinator), so concurrent sessions would
/// interleave and steal each other's spans — hold this guard across
/// [`install`] → run → [`drain`], as the serve CLI, the trace experiment,
/// and every tracing test do.
pub fn session_guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_drain_roundtrip() {
        let _session = session_guard();
        install(&TraceConfig { enabled: true, sample_rate: 1.0, kernel: true, ring_capacity: 64 });
        let token = 0xDEAD_BEEF_0B5Eu64; // distinctive, not a live coordinator token
        let t0 = Instant::now();
        record(Kind::Request, "admit", t0, token, SpanArgs::new().with("lane", 1));
        record(Kind::Kernel, "unit", t0, NO_TOKEN, SpanArgs::new().with("panel", 3));
        let trace = drain();
        disable();
        // other tests may flow through instrumented paths while the gate is
        // on, so assert on our own token / at-least bounds only
        let mine: Vec<_> = trace.spans.iter().filter(|s| s.span.token == token).collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].span.name, "admit");
        assert_eq!(mine[0].kind, Kind::Request);
        assert!(trace.count("unit") >= 1);
        assert!(!trace.threads.is_empty());
        // a second drain finds our spans gone
        let again = drain();
        assert_eq!(again.spans.iter().filter(|s| s.span.token == token).count(), 0);
    }

    #[test]
    fn sampling_is_deterministic_and_proportional() {
        let _session = session_guard();
        install(&TraceConfig { enabled: true, sample_rate: 0.5, ..Default::default() });
        let hits = (0..10_000u64).filter(|&t| sample(t)).count();
        assert!((4000..=6000).contains(&hits), "rate 0.5 sampled {hits}/10000");
        assert_eq!(sample(42), sample(42), "decision is deterministic per token");
        install(&TraceConfig { enabled: true, sample_rate: 1.0, ..Default::default() });
        assert!((0..100u64).all(sample));
        install(&TraceConfig { enabled: true, sample_rate: 0.0, ..Default::default() });
        assert!(!(0..100u64).any(sample));
        disable();
        assert!(!sample(1), "disabled tracing never samples");
        let _ = drain();
    }

    #[test]
    fn disabled_recording_is_a_noop() {
        let _session = session_guard();
        install(&TraceConfig::default());
        let token = 0xFEED_FACE_u64;
        record(Kind::Request, "admit", Instant::now(), token, SpanArgs::new());
        let trace = drain();
        assert_eq!(trace.spans.iter().filter(|s| s.span.token == token).count(), 0);
    }
}
